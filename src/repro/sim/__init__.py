from repro.core.registry import available_systems, register_system  # noqa: F401
from repro.sim.engine import Sim  # noqa: F401
from repro.sim.systems import (  # noqa: F401
    EmulationContext, SystemResult, WorkloadResult, run_system,
)
from repro.sim.traces import (  # noqa: F401
    montage_like, nasa_ipsc_like, sdsc_blue_like, standard_workloads,
)
