"""Statistically-calibrated synthetic workload generators (paper §4.2).

The SWF archive traces the paper uses (NASA-iPSC, SDSC-BLUE) are not
redistributable offline, so we generate seeded synthetic traces calibrated
to every statistic the paper reports:

  nasa_ipsc_like : 128 nodes, two weeks, 46.6% utilization, 2,603 jobs,
                   smooth arrivals that "varied each day", power-of-two
                   node demands (iPSC/860 partitioning).
  sdsc_blue_like : 144 nodes, two weeks, 76.2% utilization, 2,649 jobs,
                   infrequent arrivals in week 1 / frequent + bursty in
                   week 2, node demands in multiples of 8 (8-CPU nodes
                   scaled to 1-CPU nodes per §4.4).
  montage_like   : 1,000-task Montage workflow DAG (mProjectPP/mDiffFit/
                   mConcatFit/mBgModel/mBackground/mImgtbl/mAdd/mShrink/
                   mJPEG), mean task runtime 11.38 s, accumulated parallel
                   demand ~166 nodes in most of the running time.

Runtimes are rescaled so the utilization target is hit *exactly*; all other
statistics are matched distributionally. Generators are deterministic per
seed and EXPERIMENTS.md reports our numbers beside the paper's.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass

import numpy as np

from repro.core.types import Job, Workload

TWO_WEEKS_S = 14 * 86400.0


# --------------------------------------------------------------------------
# HTC traces
# --------------------------------------------------------------------------
def _diurnal_arrivals(rng, n_jobs: int, period: float, day_weights,
                      burst: float = 0.0, day_night: float = 3.0) -> np.ndarray:
    """Arrival times from a piecewise-constant daily/hourly rate profile.

    day_weights: relative job volume per day; within a day, a day/night
    shape (office hours ~``day_night``x the night rate). burst>0 adds
    Poisson-cluster bunching (a fraction of jobs arrive in short bursts).
    """
    days = len(day_weights)
    day_weights = np.asarray(day_weights, float)
    day_weights = day_weights / day_weights.sum()
    hour_shape = np.where((np.arange(24) >= 8) & (np.arange(24) < 20),
                          day_night, 1.0)
    hour_shape = hour_shape / hour_shape.sum()
    counts = rng.multinomial(n_jobs, day_weights)
    times = []
    for d, c in enumerate(counts):
        hours = rng.choice(24, size=c, p=hour_shape)
        t = d * 86400.0 + hours * 3600.0 + rng.uniform(0, 3600.0, c)
        times.append(t)
    t = np.concatenate(times) if times else np.array([])
    if burst > 0:
        # move a fraction of jobs into bursts around randomly chosen anchors
        n_burst = int(burst * len(t))
        idx = rng.choice(len(t), n_burst, replace=False)
        anchors = rng.choice(t, max(n_burst // 8, 1))
        t[idx] = rng.choice(anchors, n_burst) + rng.exponential(120.0, n_burst)
    t = np.clip(t, 0, period - 1.0)
    t.sort()
    return t


def _self_throttle(jobs: list[Job], cap: int) -> None:
    """Shift arrivals so eager concurrency never exceeds the original
    machine's capacity. Recorded traces carry this feedback loop implicitly
    (users submit into a finite machine); without it, synthetic bursts
    exceed anything the source system could have produced and every
    elastic system looks worse than the paper's measurements."""
    import heapq
    running: list[tuple[float, int]] = []   # (finish, nodes)
    used = 0
    t_cursor = 0.0   # FIFO: the source machine admits jobs in order, so a
    # shifted job delays everything submitted after it
    for j in sorted(jobs, key=lambda j: j.arrival):
        t = max(j.arrival, t_cursor)
        while True:
            while running and running[0][0] <= t:
                used -= heapq.heappop(running)[1]
            if used + j.nodes <= cap or not running:
                break
            t = running[0][0]
        j.arrival = t
        t_cursor = t
        used += j.nodes
        heapq.heappush(running, (t + j.runtime, j.nodes))


def _calibrated_runtimes(rng, sizes: np.ndarray, *, target_work: float,
                         median_s: float, sigma: float,
                         max_runtime: float, size_corr: float = 0.0
                         ) -> np.ndarray:
    rt = rng.lognormal(np.log(median_s), sigma, len(sizes))
    if size_corr:
        # wider partitions tend to run longer (size_corr = elasticity)
        rt = rt * (sizes / float(np.mean(sizes))) ** size_corr
    rt = np.clip(rt, 30.0, max_runtime)
    scale = target_work / float(np.sum(sizes * rt))
    rt = np.clip(rt * scale, 15.0, max_runtime)
    # one final exact correction (clip may have shifted the total)
    rt *= target_work / float(np.sum(sizes * rt))
    return rt


def nasa_ipsc_like(seed: int = 0, *, nodes: int = 128, n_jobs: int = 2603,
                   util: float = 0.466, period: float = TWO_WEEKS_S) -> Workload:
    rng = np.random.default_rng(seed)
    # smooth: day volumes vary mildly around the mean ("varied each day")
    day_weights = rng.uniform(0.85, 1.15, 14)
    arrivals = _diurnal_arrivals(rng, n_jobs, period, day_weights,
                                 day_night=2.0)
    # iPSC/860: power-of-two partitions, mid-sized partitions dominant,
    # whole-machine jobs rare (but present: they set the DCS configuration)
    pow2 = np.array([1, 2, 4, 8, 16, 32, 64, 128])
    probs = np.array([0.09, 0.10, 0.11, 0.16, 0.26, 0.22, 0.04, 0.02])
    sizes = rng.choice(pow2, n_jobs, p=probs / probs.sum())
    # iPSC jobs are short (minutes): this is what makes per-job hour-rounded
    # DRP leases waste ~2.7x (paper: 54,118 billed vs ~20,066 worked)
    target_work = util * nodes * period
    rts = _calibrated_runtimes(rng, sizes, target_work=target_work,
                               median_s=120.0, sigma=1.0, max_runtime=4 * 3600)
    jobs = [Job(jid=i, arrival=float(a), runtime=float(r), nodes=int(s),
                name=f"nasa-{i}")
            for i, (a, r, s) in enumerate(zip(arrivals, rts, sizes))]
    _self_throttle(jobs, nodes)
    return Workload("nasa", "htc", jobs, trace_nodes=nodes, period=period)


def sdsc_blue_like(seed: int = 1, *, nodes: int = 144, n_jobs: int = 2649,
                   util: float = 0.51, period: float = TWO_WEEKS_S) -> Workload:
    """The paper quotes 76.2% utilization for the *full* BLUE trace; its
    two-week slice works out much lower: the paper's own DRP billing for
    the slice (35,838 node-h, hour-rounded, so an upper bound on worked
    node-hours) caps the slice's utilization at 35,838 / 48,384 = 74% and
    the long-running hour-scale jobs that dominate BLUE leave real gaps
    below that bound — we target 51.0% (the default ``util=0.51``, asserted
    in tests), which lands every derived table value in the paper's regime
    (DRP < DCS on this trace, DawningCloud between them)."""
    rng = np.random.default_rng(seed)
    # week 1 infrequent, week 2 frequent; bursty throughout week 2
    day_weights = np.concatenate([rng.uniform(0.4, 0.65, 7),
                                  rng.uniform(1.2, 1.75, 7)])
    arrivals = _diurnal_arrivals(rng, n_jobs, period, day_weights, burst=0.2)
    # BLUE's 8-CPU hosts are scaled to 1-CPU nodes (§4.4): job CPU counts
    # divide by 8, so most jobs need only a handful of nodes
    opts = np.array([1, 2, 4, 8, 16, 32, 64, 144])
    probs = np.array([0.28, 0.25, 0.20, 0.13, 0.08, 0.04, 0.015, 0.005])
    sizes = rng.choice(opts, n_jobs, p=probs / probs.sum())
    # BLUE jobs run for hours: hour-rounded leases waste little, which is
    # why DRP beats the fixed-size systems on this trace (paper Table 3)
    target_work = util * nodes * period
    rts = _calibrated_runtimes(rng, sizes, target_work=target_work,
                               median_s=1800.0, sigma=0.85,
                               max_runtime=24 * 3600)
    jobs = [Job(jid=i, arrival=float(a), runtime=float(r), nodes=int(s),
                name=f"blue-{i}")
            for i, (a, r, s) in enumerate(zip(arrivals, rts, sizes))]
    _self_throttle(jobs, nodes)
    return Workload("blue", "htc", jobs, trace_nodes=nodes, period=period)


# --------------------------------------------------------------------------
# MTC workflow (Montage-like DAG)
# --------------------------------------------------------------------------
def _check_montage_graph(n_jobs: int, n_project: int) -> None:
    """Guarded raise, not assert: the stage widths below are wired to
    ``n_project`` in four places; a drifted edit would silently ship a
    miscounted mosaic under ``python -O`` and every trace-scale stream
    built from it would replay the wrong workflow."""
    if n_jobs != 6 * n_project + 4:
        raise RuntimeError(
            f"montage graph inconsistent: {n_jobs} jobs != "
            f"6*{n_project}+4 for the 9-stage mosaic")


def montage_like(seed: int = 2, *, n_project: int = 166,
                 mean_runtime: float = 11.38) -> Workload:
    """Montage mosaic workflow: 1,000 tasks in 9 stages.

    Stage widths: mProjectPP=166, mDiffFit=494, mConcatFit=1, mBgModel=1,
    mBackground=166, mImgtbl=1, mAdd=166, mShrink=4, mJPEG=1 (total 1,000).
    Parallel tasks run seconds; the serial fit/model/table stages are the
    long poles, reproducing the paper's makespan regime (~2.5 tasks/s at a
    166-node configuration).
    """
    rng = np.random.default_rng(seed)
    jobs: list[Job] = []
    jid = 0

    def add(name, runtime, deps):
        nonlocal jid
        jobs.append(Job(jid=jid, arrival=0.0, runtime=float(max(runtime, 0.5)),
                        nodes=1, deps=tuple(deps), wid=0, name=name))
        jid += 1
        return jid - 1

    n_diff = 4 * n_project - 2   # ~4 overlap pairs per projection (662 at the paper's 166: the DRP peak in Table 4)
    project = [add(f"mProjectPP-{i}", rng.lognormal(np.log(11.0), 0.12), [])
               for i in range(n_project)]
    diff = []
    for i in range(n_diff):
        a = project[i % n_project]
        b = project[(i + 1 + i // n_project) % n_project]
        diff.append(add(f"mDiffFit-{i}", rng.lognormal(np.log(11.0), 0.12),
                        [a] if a == b else [a, b]))
    concat = add("mConcatFit", 110.0, diff)
    bgmodel = add("mBgModel", 125.0, [concat])
    background = [add(f"mBackground-{i}", rng.lognormal(np.log(11.0), 0.12),
                      [bgmodel, project[i]]) for i in range(n_project)]
    imgtbl = add("mImgtbl", 35.0, background)
    madd = add("mAdd", 45.0, [imgtbl])
    shrink = add("mShrink", 20.0, [madd])
    add("mJPEG", 15.0, [shrink])
    # calibrate mean task runtime to the paper's 11.38 s
    mean_now = float(np.mean([j.runtime for j in jobs]))
    for j in jobs:
        j.runtime *= mean_runtime / mean_now
    _check_montage_graph(len(jobs), n_project)
    # the configured width scales with the mosaic (166 at the paper's size)
    wl = Workload("montage", "mtc", jobs, trace_nodes=n_project, period=3600.0)
    return wl


def standard_workloads(seed: int = 0) -> list[Workload]:
    """The paper's three consolidated service-provider workloads."""
    return [nasa_ipsc_like(seed), sdsc_blue_like(seed + 1),
            montage_like(seed + 2)]


# --------------------------------------------------------------------------
# fleet-scale workload families
# --------------------------------------------------------------------------
_NASA_JOBS, _NASA_UTIL = 2603, 0.466
_BLUE_JOBS, _BLUE_UTIL = 2649, 0.51
_MONTAGE_PROJECT = 166


def workload_family(n_htc: int, n_mtc: int, seed: int = 0, *,
                    jobs_scale: float = 1.0) -> list[Workload]:
    """``n_htc + n_mtc`` heterogeneous service providers scaled out from
    the calibrated generators — the scale axis of the paper's headline
    question (its companion, arXiv:1004.1276, frames the same systems at
    scientific-community scale).

    The first providers are the paper's canonical trio bit-for-bit: with
    ``jobs_scale=1``, a (2 HTC + 1 MTC) family IS ``standard_workloads
    (seed)`` — HTC provider ``i`` draws seed ``seed+i`` and MTC provider
    ``j`` draws ``seed+n_htc+j``, so ``nasa``/``blue``/``montage`` keep
    their standard seeds and parity with the Table 2-4 runs is exact.
    Providers beyond the trio are *heterogeneous variants*: NASA/BLUE
    flavors alternate, and each draws its own job volume (0.7-1.3x),
    utilization target (0.95-1.05x — small, so real work jitter does not
    drown the economies-of-scale signal) and, for MTC, mosaic size from
    a family-level RNG, under a per-provider generator seed.

    jobs_scale: global volume multiplier (smoke runs use < 1 to keep CI
    wall-clock down; it scales job counts, not per-job statistics).
    """
    fam_rng = np.random.default_rng((seed << 8) ^ 0x5CA1E)
    out: list[Workload] = []
    flavors = ((nasa_ipsc_like, _NASA_JOBS, _NASA_UTIL),
               (sdsc_blue_like, _BLUE_JOBS, _BLUE_UTIL))
    for i in range(n_htc):
        fn, base_jobs, base_util = flavors[i % 2]
        if i < 2:
            vol, util = 1.0, base_util          # canonical nasa / blue
        else:
            # volume jitter is free heterogeneity (calibrated runtimes keep
            # total work at the util target); util jitter moves real work,
            # so it stays small enough that the economies-of-scale signal
            # is not drowned by per-variant load noise
            vol = fam_rng.uniform(0.7, 1.3)
            util = base_util * fam_rng.uniform(0.95, 1.05)
        n_jobs = max(int(round(base_jobs * vol * jobs_scale)), 16)
        wl = fn(seed + i, n_jobs=n_jobs, util=util)
        if i >= 2:
            wl.name = f"{wl.name}{i}"
        out.append(wl)
    for j in range(n_mtc):
        if j == 0:
            n_project = max(int(round(_MONTAGE_PROJECT * jobs_scale)), 8)
        else:
            n_project = max(int(round(_MONTAGE_PROJECT * jobs_scale
                                      * fam_rng.uniform(0.7, 1.3))), 8)
        wl = montage_like(seed + n_htc + j, n_project=n_project)
        if j > 0:
            wl.name = f"{wl.name}{j}"
        out.append(wl)
    return out


# --------------------------------------------------------------------------
# request-DAG emission (MTC serving): workflows as inference request streams
# --------------------------------------------------------------------------
def mark_tokens(wl: Workload, *, seconds_per_token: float = 1.0,
                prompt_lens: tuple[int, ...] = (4, 6, 8),
                seed: int = 0) -> Workload:
    """Stamp token-length marks onto a workflow's tasks: each MTC task is
    one inference request whose decode budget reproduces its trace runtime
    at the engine's decode rate (``decode_len = runtime / seconds_per_token``,
    floored at 1 so every task costs at least one decode step). Prompt
    lengths are drawn from a small discrete set so a batched admit can
    group same-shape prefills into one call. Deterministic per seed;
    returns a fresh workload, the input is untouched."""
    rng = np.random.default_rng((seed << 4) ^ zlib.crc32(wl.name.encode()))
    out = wl.fresh()
    for j in out.jobs:
        j.prompt_len = int(rng.choice(prompt_lens))
        j.decode_len = max(int(round(j.runtime / seconds_per_token)), 1)
    return out


def request_stream(workloads: list[Workload], *, period: float | None = None,
                   seed: int = 0, seconds_per_token: float = 1.0,
                   prompt_lens: tuple[int, ...] = (4, 6, 8),
                   width: int = 1,
                   ) -> list[tuple[float, list[Job]]]:
    """Merge MTC workloads into one trace-rate workflow arrival stream.

    Each workload's DAG (a whole Montage-shaped workflow) becomes one
    stream entry ``(arrival_t, jobs)``: jids are re-keyed to be globally
    unique (deps remapped, ``wid`` = stream index) so thousands of
    workflows can share a single ``MTCRuntimeEnv`` trigger monitor, and
    every task carries token-length marks (:func:`mark_tokens`). Workflow
    arrivals are a seeded Poisson process over ``[0, period)`` (default:
    the widest workload window) — the trace timestamps a serving driver
    replays on its tick clock. Sorted by arrival; workflow 0 arrives at
    t=0 so a stream is never empty-headed.

    width: node units one task of this tenant occupies (its model-size
    class in a heterogeneous fleet): every emitted task carries
    ``nodes = width`` so unit-denominated provisioning (``ServeDriver.
    slot_width`` / ``ServeFleet(widths=...)``) bills a big-model slot at
    its true pool cost. The default (1) keeps the homogeneous marks."""
    if width < 1:
        raise ValueError(f"width must be >= 1, got {width}")
    mtc = [wl for wl in workloads if wl.kind == "mtc"]
    if not mtc:
        return []
    if period is None:
        period = max(wl.period for wl in mtc)
    rng = np.random.default_rng((seed << 8) ^ 0x5E12E)
    gaps = rng.exponential(period / max(len(mtc), 1), len(mtc))
    arrivals = np.concatenate([[0.0], np.cumsum(gaps)[:-1]])
    arrivals = np.minimum(arrivals, period - 1.0)
    stream: list[tuple[float, list[Job]]] = []
    base = 0
    for k, wl in enumerate(mtc):
        marked = mark_tokens(wl, seconds_per_token=seconds_per_token,
                             prompt_lens=prompt_lens, seed=seed + k)
        jobs = []
        for j in marked.jobs:
            jobs.append(Job(
                jid=base + j.jid, arrival=float(arrivals[k]),
                runtime=j.runtime, nodes=width,
                deps=tuple(base + d for d in j.deps), wid=k,
                name=f"{wl.name}/{j.name}", prompt_len=j.prompt_len,
                decode_len=j.decode_len))
        base += len(marked.jobs)
        stream.append((float(arrivals[k]), jobs))
    stream.sort(key=lambda e: e[0])
    return stream


# --------------------------------------------------------------------------
# heterogeneous serve profiles (mixed model-size classes in one fleet)
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class ServeProfile:
    """One tenant's serving profile in a heterogeneous fleet: the slot
    width (node units one batching slot of its model class costs) plus
    the prompt/decode-length scales its requests are marked with. Bigger
    model classes decode more tokens per trace-second of work
    (``seconds_per_token < 1`` stretches ``decode_len``) and carry longer
    prompts — the workload heterogeneity the paper's consolidation
    argument needs, not just N copies of one tenant."""

    width: int = 1
    seconds_per_token: float = 1.0
    prompt_lens: tuple[int, ...] = (4, 6, 8)

    def stream(self, workloads: list[Workload], *,
               period: float | None = None,
               seed: int = 0) -> list[tuple[float, list[Job]]]:
        """:func:`request_stream` with this profile's marks and width."""
        return request_stream(
            workloads, period=period, seed=seed,
            seconds_per_token=self.seconds_per_token,
            prompt_lens=self.prompt_lens, width=self.width)


#: canonical model-size classes, keyed by slot width: small (the PR 4
#: homogeneous profile, bit-for-bit), medium, large. Wider classes decode
#: longer outputs from the same trace runtime and prompt with more tokens.
SERVE_PROFILES: dict[int, ServeProfile] = {
    1: ServeProfile(width=1, seconds_per_token=1.0, prompt_lens=(4, 6, 8)),
    2: ServeProfile(width=2, seconds_per_token=0.5, prompt_lens=(6, 8, 12)),
    4: ServeProfile(width=4, seconds_per_token=0.25,
                    prompt_lens=(8, 12, 16)),
}


# --------------------------------------------------------------------------
# HTC training streams (gang-scheduled jobs for repro.serve.tenant)
# --------------------------------------------------------------------------
@dataclass
class TrainJob:
    """One gang-scheduled training run in an HTC stream.

    The gang starts only when ``world_min`` nodes are free (``nodes``
    always queues at the floor — the DR2 ``min_useful`` contract) and
    may elastically grow to ``world_max``. Work is denominated in
    emulated optimizer steps: the job is done after ``steps`` steps,
    where one step costs ``world_min * step_ticks`` node-ticks (elastic
    growth is linear speedup), and a checkpoint exists at every
    ``ckpt_every`` boundary — what a preemption can resume from.
    Deliberately shaped like :class:`repro.core.types.Job` (jid /
    arrival / nodes / deps / timestamps) so ``RuntimeEnv`` scheduling,
    tracking, and triggers treat it as any other task; it carries no
    ``runtime`` estimate, so the backfill scheduler takes no release
    reservation for it (training end-times are elastic)."""

    jid: int
    arrival: float
    world_min: int
    world_max: int
    steps: int
    ckpt_every: int = 8
    step_ticks: int = 1
    arch: str = ""
    name: str = ""
    deps: tuple[int, ...] = ()
    wid: int = -1
    nodes: int = 0
    submit_time: float = -1.0
    start: float = -1.0
    finish: float = -1.0

    def __post_init__(self):
        if self.world_min < 1 or self.world_max < self.world_min:
            raise ValueError(
                f"bad world band [{self.world_min}, {self.world_max}] "
                f"for train job {self.name!r}")
        if self.steps < 1 or self.ckpt_every < 1 or self.step_ticks < 1:
            raise ValueError(
                f"steps/ckpt_every/step_ticks must be >= 1 for train "
                f"job {self.name!r}")
        if self.nodes == 0:
            self.nodes = self.world_min


@dataclass(frozen=True)
class TrainProfile:
    """One model class's training-job shape, keyed by a ``repro.configs``
    registry arch — the HTC counterpart of :class:`ServeProfile`. An HTC
    training community is *many small heterogeneous runs* (the NAS-search
    pattern: the same family swept over sizes/steps), so a stream draws
    jobs from several profiles rather than one long run."""

    arch: str
    world_min: int
    world_max: int
    steps: int
    ckpt_every: int = 8
    step_ticks: int = 1

    def job(self, jid: int, arrival: float, *, name: str = "",
            wid: int = -1) -> TrainJob:
        return TrainJob(
            jid=jid, arrival=arrival, world_min=self.world_min,
            world_max=self.world_max, steps=self.steps,
            ckpt_every=self.ckpt_every, step_ticks=self.step_ticks,
            arch=self.arch, name=name or f"{self.arch}/{jid}", wid=wid)


#: canonical training-job classes at emulation scale, keyed by registry
#: arch: a small fast-iterating run, a mid-size gang, a wide gang with
#: real elastic range. World sizes are pool node units (same denomination
#: as serve slot widths), steps are emulated optimizer steps.
TRAIN_PROFILES: dict[str, TrainProfile] = {
    "mamba2-1.3b": TrainProfile(arch="mamba2-1.3b", world_min=1,
                                world_max=2, steps=48, ckpt_every=8),
    "qwen2-7b": TrainProfile(arch="qwen2-7b", world_min=2,
                             world_max=4, steps=64, ckpt_every=8),
    "musicgen-large": TrainProfile(arch="musicgen-large", world_min=4,
                                   world_max=8, steps=96, ckpt_every=16),
}


def train_stream(n_jobs: int, *, seed: int = 0,
                 period: float = 86_400.0,
                 profiles: "Sequence[TrainProfile] | None" = None,
                 jid_base: int = 0) -> list[TrainJob]:
    """A seeded HTC training stream: ``n_jobs`` gang-scheduled runs
    cycling over ``profiles`` (default: the :data:`TRAIN_PROFILES`
    classes in key order), arriving as a Poisson process over
    ``[0, period)`` — the same arrival model as :func:`request_stream`,
    with its own namespaced RNG. Job 0 arrives at t=0. ``jid_base``
    keeps jids disjoint from any serve stream sharing the run."""
    if n_jobs <= 0:
        return []
    if profiles is None:
        profiles = [TRAIN_PROFILES[k] for k in sorted(TRAIN_PROFILES)]
    rng = np.random.default_rng((seed << 8) ^ 0x7A41)
    gaps = rng.exponential(period / max(n_jobs, 1), n_jobs)
    arrivals = np.concatenate([[0.0], np.cumsum(gaps)[:-1]])
    arrivals = np.minimum(arrivals, period - 1.0)
    jobs = []
    for k in range(n_jobs):
        prof = profiles[k % len(profiles)]
        jobs.append(prof.job(jid_base + k, float(arrivals[k]),
                             name=f"{prof.arch}/run{k}", wid=k))
    return jobs


# --------------------------------------------------------------------------
# columnar stream materialization (10^5-10^6 workflows in NumPy arrays)
# --------------------------------------------------------------------------
@dataclass
class ColumnarStream:
    """A workflow arrival stream as preallocated NumPy columns — the
    native input of ``repro.serve.columnar.ColumnarServeDriver``, which a
    million-workflow run cannot afford to hold as per-task ``Job``
    objects (~1 KB each).

    Task axis: *emission position* — entry-major, tasks of entry ``e``
    occupy positions ``entry_ptr[e]:entry_ptr[e+1]`` in their scalar
    submit order. Dependencies are position-indexed CSR
    (``dep_idx[dep_ptr[i]:dep_ptr[i+1]]``), so jids stay free to be any
    globally-unique ints (the parity traces' are non-contiguous).
    ``to_jobs()`` materializes the exact scalar stream, which is how the
    bit-parity suite feeds both paths one identical workload."""

    entry_arrival: np.ndarray       # float64[n_entries], ascending
    entry_wid: np.ndarray           # int64[n_entries]
    entry_ptr: np.ndarray           # int64[n_entries + 1] CSR into tasks
    jid: np.ndarray                 # int64[n_tasks], globally unique
    runtime: np.ndarray             # float64[n_tasks]
    nodes: np.ndarray               # int64[n_tasks]
    prompt_len: np.ndarray          # int64[n_tasks]
    decode_len: np.ndarray          # int64[n_tasks]
    dep_ptr: np.ndarray             # int64[n_tasks + 1]
    dep_idx: np.ndarray             # int64[nnz], task positions
    names: list | None = None       # per-task, synthesized when absent

    @property
    def n_entries(self) -> int:
        return len(self.entry_arrival)

    @property
    def n_tasks(self) -> int:
        return len(self.jid)

    def name_of(self, i: int) -> str:
        if self.names is not None:
            return self.names[i]
        e = int(np.searchsorted(self.entry_ptr, i, side="right")) - 1
        return f"wf{int(self.entry_wid[e])}/t{i - int(self.entry_ptr[e])}"

    @staticmethod
    def from_jobs(stream) -> "ColumnarStream":
        """Columnarize a scalar ``[(arrival_t, jobs), ...]`` stream (jids
        may be arbitrary unique ints; deps are remapped to positions)."""
        entries = sorted(stream, key=lambda e: e[0])
        entries = [(t, jobs) for t, jobs in entries if jobs]
        pos = {}
        for _, jobs in entries:
            for j in jobs:
                if j.jid in pos:
                    raise ValueError(f"duplicate jid {j.jid} in stream")
                pos[j.jid] = len(pos)
        n = len(pos)
        arr = np.array([t for t, _ in entries], float)
        wid = np.zeros(len(entries), np.int64)
        eptr = np.zeros(len(entries) + 1, np.int64)
        jid = np.zeros(n, np.int64)
        runtime = np.zeros(n, float)
        nodes = np.zeros(n, np.int64)
        plen = np.zeros(n, np.int64)
        dlen = np.zeros(n, np.int64)
        dep_ptr = np.zeros(n + 1, np.int64)
        dep_idx: list[int] = []
        names: list[str] = []
        i = 0
        for e, (_, jobs) in enumerate(entries):
            wid[e] = jobs[0].wid
            for j in jobs:
                jid[i] = j.jid
                runtime[i] = j.runtime
                nodes[i] = j.nodes
                plen[i] = j.prompt_len
                dlen[i] = j.decode_len
                dep_idx.extend(pos[d] for d in j.deps)
                dep_ptr[i + 1] = len(dep_idx)
                names.append(j.name)
                i += 1
            eptr[e + 1] = i
        return ColumnarStream(
            entry_arrival=arr, entry_wid=wid, entry_ptr=eptr, jid=jid,
            runtime=runtime, nodes=nodes, prompt_len=plen, decode_len=dlen,
            dep_ptr=dep_ptr, dep_idx=np.array(dep_idx, np.int64),
            names=names)

    def to_jobs(self):
        """Materialize the exact scalar stream: ``[(arrival_t, [Job])]``
        with deps as jids — what ``ServeDriver`` replays, so scalar-vs-
        columnar runs consume one identical workload by construction."""
        out = []
        for e in range(self.n_entries):
            lo, hi = int(self.entry_ptr[e]), int(self.entry_ptr[e + 1])
            jobs = [Job(
                jid=int(self.jid[i]), arrival=float(self.entry_arrival[e]),
                runtime=float(self.runtime[i]), nodes=int(self.nodes[i]),
                deps=tuple(int(self.jid[d]) for d in
                           self.dep_idx[self.dep_ptr[i]:self.dep_ptr[i + 1]]),
                wid=int(self.entry_wid[e]), name=self.name_of(i),
                prompt_len=int(self.prompt_len[i]),
                decode_len=int(self.decode_len[i]))
                for i in range(lo, hi)]
            out.append((float(self.entry_arrival[e]), jobs))
        return out


def _montage_template(n_project: int):
    """The 9-stage mosaic DAG shape at width ``n_project``: per-task stage
    names, fixed runtimes for the serial stages (NaN = lognormal draw for
    the parallel ones), and position-indexed deps. One template, tiled
    across every workflow of a columnar stream."""
    names: list[str] = []
    fixed: list[float] = []
    deps: list[tuple[int, ...]] = []

    def add(name, runtime, dd):
        names.append(name)
        fixed.append(runtime)
        deps.append(tuple(dd))
        return len(names) - 1

    n_diff = 4 * n_project - 2
    project = [add(f"mProjectPP-{i}", np.nan, []) for i in range(n_project)]
    diff = []
    for i in range(n_diff):
        a = project[i % n_project]
        b = project[(i + 1 + i // n_project) % n_project]
        diff.append(add(f"mDiffFit-{i}", np.nan, [a] if a == b else [a, b]))
    concat = add("mConcatFit", 110.0, diff)
    bgmodel = add("mBgModel", 125.0, [concat])
    background = [add(f"mBackground-{i}", np.nan, [bgmodel, project[i]])
                  for i in range(n_project)]
    imgtbl = add("mImgtbl", 35.0, background)
    madd = add("mAdd", 45.0, [imgtbl])
    shrink = add("mShrink", 20.0, [madd])
    add("mJPEG", 15.0, [shrink])
    _check_montage_graph(len(names), n_project)
    return names, np.array(fixed, float), deps


#: workflows per generation chunk — bounds every 2-D intermediate (the
#: per-chunk runtime/prompt draws and dep tiles) to a few MB regardless
#: of the stream's total size, which is what lets generation push past
#: 10^6 workflows without the transient arrays dwarfing the outputs.
COLUMNAR_CHUNK = 1 << 16


def montage_stream_columnar(n_workflows: int, *, n_project: int = 8,
                            seed: int = 0, period: float = 3600.0,
                            width: int = 1,
                            seconds_per_token: float = 1.0,
                            prompt_lens: tuple[int, ...] = (4, 6, 8),
                            mean_runtime: float = 11.38,
                            chunk: int | None = None) -> ColumnarStream:
    """``n_workflows`` Montage-shaped workflows as one columnar stream,
    generated in bounded whole-array RNG chunks — the 10^5-10^6+
    workflow scale where looping :func:`montage_like` +
    :func:`request_stream` per workflow costs more than the run itself,
    and where monolithic ``(workflows x tasks)`` intermediates stop
    fitting next to the outputs.

    Workflows share the ``n_project`` mosaic DAG shape but draw their own
    parallel-task runtimes and prompt lengths; each workflow's mean task
    runtime is calibrated to ``mean_runtime`` exactly like
    :func:`montage_like` (row-local, so chunking can't move it).
    Arrivals are the same seeded Poisson process as
    :func:`request_stream` (workflow 0 at t=0). jids are dense
    ``0..n_tasks-1``, ``wid`` = workflow index.

    chunk: workflows generated per RNG pass (default
        :data:`COLUMNAR_CHUNK`). **Any** chunk size yields the same
        stream bit-for-bit: each draw purpose (runtimes / token marks /
        arrival gaps) has its own seeded generator, and numpy
        ``Generator`` array fills consume the underlying bit stream
        element-sequentially in C order, so splitting one ``(N, k)``
        fill into row-block fills leaves every element's draw in place
        (pinned in ``tests/test_serve_columnar.py`` at 10^5 workflows).
    """
    if n_workflows < 1:
        raise ValueError(f"need n_workflows >= 1, got {n_workflows}")
    if width < 1:
        raise ValueError(f"width must be >= 1, got {width}")
    if chunk is None:
        chunk = COLUMNAR_CHUNK
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")
    # one generator per draw purpose: chunking must not let one
    # purpose's draw count shift another purpose's position in the
    # shared bit stream
    rng_rt = np.random.default_rng((seed << 8) ^ 0x5E12E)
    rng_tok = np.random.default_rng((seed << 8) ^ 0x70CE2)
    rng_arr = np.random.default_rng((seed << 8) ^ 0xA1271)
    names_t, fixed, deps_t = _montage_template(n_project)
    m = len(names_t)                      # tasks per workflow
    par = np.isnan(fixed)                 # parallel stages draw lognormal
    npar = int(par.sum())
    prompt_set = np.asarray(prompt_lens, np.int64)
    dcount = np.array([len(d) for d in deps_t], np.int64)
    dflat = np.array([p for d in deps_t for p in d], np.int64)
    dper = len(dflat)                     # dep-edges per workflow
    # preallocated flat outputs; chunks write disjoint slices
    n = n_workflows * m
    runtime = np.empty(n, float)
    plen_out = np.empty(n, np.int64)
    dlen_out = np.empty(n, np.int64)
    arrivals = np.empty(n_workflows, float)
    dep_ptr = np.empty(n + 1, np.int64)
    dep_ptr[0] = 0
    dep_idx = np.empty(n_workflows * dper, np.int64)
    elapsed = 0.0                         # arrival prefix-sum carry
    for lo in range(0, n_workflows, chunk):
        hi = min(lo + chunk, n_workflows)
        c = hi - lo
        # runtimes: a (chunk x parallel-tasks) lognormal pass, serial
        # stages fixed, then per-workflow mean calibration
        rt = np.broadcast_to(fixed, (c, m)).copy()
        rt[:, par] = rng_rt.lognormal(np.log(11.0), 0.12, (c, npar))
        rt = np.maximum(rt, 0.5)
        rt *= (mean_runtime / rt.mean(axis=1))[:, None]
        runtime[lo * m:hi * m] = rt.reshape(-1)
        # token marks: prompt lens from the profile's discrete set,
        # decode budget reproducing the trace runtime at the decode rate
        plen_out[lo * m:hi * m] = rng_tok.choice(prompt_set,
                                                 (c, m)).reshape(-1)
        dlen_out[lo * m:hi * m] = np.maximum(
            np.round(rt / seconds_per_token), 1).astype(np.int64).reshape(-1)
        # Poisson workflow arrivals over [0, period), workflow 0 at t=0:
        # each workflow arrives at the sum of every EARLIER gap, so the
        # chunk's last gap rolls into the carry for the next chunk
        # seeding the cumsum with the carry keeps every addition in the
        # same sequential left-fold a monolithic cumsum performs, so the
        # prefix sums are bit-identical for any chunk size (a scalar
        # ``carry + cumsum(chunk)`` would regroup the float additions)
        gaps = rng_arr.exponential(period / n_workflows, c)
        seq = np.cumsum(np.concatenate([[elapsed], gaps]))
        arrivals[lo:hi] = seq[:-1]
        elapsed = seq[-1]
        # deps: the template CSR tiled with per-workflow position offsets
        dep_ptr[lo * m + 1:hi * m + 1] = (dep_ptr[lo * m]
                                          + np.cumsum(np.tile(dcount, c)))
        dep_idx[lo * dper:hi * dper] = (
            np.tile(dflat, c)
            + np.repeat(np.arange(lo, hi, dtype=np.int64) * m, dper))
    np.minimum(arrivals, period - 1.0, out=arrivals)
    return ColumnarStream(
        entry_arrival=arrivals,
        entry_wid=np.arange(n_workflows, dtype=np.int64),
        entry_ptr=np.arange(n_workflows + 1, dtype=np.int64) * m,
        jid=np.arange(n, dtype=np.int64),
        runtime=runtime,
        nodes=np.full(n, width, np.int64),
        prompt_len=plen_out,
        decode_len=dlen_out,
        dep_ptr=dep_ptr,
        dep_idx=dep_idx,
        names=None)
