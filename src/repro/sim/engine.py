"""Discrete-event simulation kernel.

The paper emulates its systems with a 100x wall-clock speedup (§4.1); a
discrete-event kernel is strictly faster and exact: the clock jumps between
events (job arrivals/finishes, policy scan ticks, hourly release checks).
Events at equal times fire in scheduling order (stable heap).
"""
from __future__ import annotations

import heapq
import math
from typing import Callable


class Sim:
    def __init__(self):
        self.t = 0.0
        self.last_event_t = 0.0   # time of the last event actually fired
        self._heap: list = []
        self._seq = 0

    @property
    def drained(self) -> bool:
        """True when every scheduled event has fired (the run ended on its
        own rather than being cut off at a ``run(until=...)`` bound)."""
        return not self._heap

    def at(self, t: float, fn: Callable, *args) -> None:
        # guarded raise, not assert: an event scheduled in the past would
        # silently fire out of order under ``python -O`` and desequence
        # the whole run (billing/idle integrals depend on event order)
        if t < self.t - 1e-9:
            raise RuntimeError(
                f"event scheduled in the past: t={t} < now={self.t}")
        heapq.heappush(self._heap, (t, self._seq, fn, args))
        self._seq += 1

    def after(self, dt: float, fn: Callable, *args) -> None:
        self.at(self.t + dt, fn, *args)

    def every(self, interval: float, fn: Callable[[], bool]) -> None:
        """Repeat ``fn`` every ``interval`` while it returns True."""
        def tick():
            if fn():
                self.after(interval, tick)
        self.after(interval, tick)

    def run(self, until: float = math.inf) -> float:
        while self._heap and self._heap[0][0] <= until:
            t, _, fn, args = heapq.heappop(self._heap)
            self.t = t
            self.last_event_t = t
            fn(*args)
        if math.isfinite(until):
            self.t = max(self.t, until)
        return self.t
