"""Emulated systems for the usage models (paper §4.1, Figs 6-8).

Since the ``repro.core.tre`` redesign, this module contains no control-plane
logic of its own: the complete DSP cycle (queue + trigger monitor,
scheduler dispatch, ``PolicyEngine`` negotiation, time-integral idle
accounting, lifecycle transitions) lives in ``repro.core.tre.RuntimeEnv``,
shared verbatim with the live JAX controller. What remains here is the
*discrete-event driver* side of the split:

  - ``REServer`` is a thin shell over ``HTCRuntimeEnv``/``MTCRuntimeEnv``:
    it owns simulated time — job arrivals, finish events ``runtime`` later,
    periodic scan/release ticks — and forwards each to the env. Fixed mode
    (DCS & SSP: the env owns a static configuration for the whole workload
    period) and dsp mode (DawningCloud: the env renegotiates via the same
    ``PolicyEngine`` that drives live training) are env modes, not forks.
  - ``DRPRunner`` models Deelman-style direct resource provision: each HTC
    job is an end user leasing its own nodes for ceil-hour of its runtime;
    an MTC workflow is one end-user application whose leased pool grows to
    its eager (no-queue) execution width and is held until the workflow
    finishes. No TRE exists, so it bypasses the runtime env by design.

Usage models are plugins: each is a ``repro.core.registry.System``
registered under its name (``dcs`` / ``ssp`` / ``drp`` / ``dawningcloud``,
plus the beyond-paper ``dawningcloud-backfill`` / ``dawningcloud-easy``
(conservative vs EASY backfill), and the multi-tenant
``dawningcloud-coordinated`` / ``dawningcloud-quota`` scenarios that route
through ``repro.core.provider.ResourceProvider`` — shared finite capacity,
admission queueing, PhoenixCloud-style coordination), and ``run_system`` is
registry dispatch — a new scenario is a new registered class, not an
``elif``. The serving-path counterpart, ``dawningcloud-serve-fleet``
(N serve TREs partitioning one engine pool on a ``TickClock``), registers
from ``repro.serve.fleet`` and runs through its ``serve`` entry point
rather than ``run_system``. All billing goes through ``repro.core.provision`` (1-hour lease
units); TRE creation/destruction goes through ``repro.core.lifecycle``
(§3.1.3 state machine).
"""
from __future__ import annotations

import math
import zlib
from dataclasses import dataclass, field

import numpy as np

from repro.core.lifecycle import LifecycleService
from repro.core.policy import MgmtPolicy
from repro.core.provider import ResourceProvider
from repro.core.provision import BILL_UNIT_S, ProvisionService
from repro.core.registry import System, get_system, register_system
from repro.core.tre import HTCRuntimeEnv, MTCRuntimeEnv
from repro.core.types import Job, Workload
from repro.sim.engine import Sim


class SimClock:
    """Adapts the discrete-event kernel to the ``repro.core.tre.Clock``
    protocol: env time *is* simulated wall time."""

    def __init__(self, sim: Sim):
        self._sim = sim

    def now(self) -> float:
        return self._sim.t


# --------------------------------------------------------------------------
# runtime-environment driver (DCS / SSP / DawningCloud)
# --------------------------------------------------------------------------
class REServer:
    """Discrete-event driver for one TRE: wires sim time into a RuntimeEnv.

    The driver schedules arrivals, turns ``launch`` into a finish event
    ``job.runtime`` later, and (dsp mode) fires the env's scan/release
    cycles at the policy's intervals. Everything else — scheduling,
    negotiation, idle accounting, lifecycle — happens inside the env.
    """

    def __init__(self, sim: Sim, workload: Workload,
                 provision: ProvisionService, *, mode: str,
                 fixed_nodes: int | None = None,
                 policy: MgmtPolicy | None = None, count_adjust: bool = True,
                 hold_until: float = 0.0,
                 lifecycle: LifecycleService | None = None, scheduler=None,
                 phase: float = 0.0):
        # guarded raise, not assert: a typo'd mode would silently run a
        # fixed env with dsp billing under ``python -O``
        if mode not in ("fixed", "dsp"):
            raise ValueError(
                f"unknown TRE mode {mode!r} (expected 'fixed' or 'dsp')")
        self.sim = sim
        self.wl = workload
        self.name = workload.name
        self.hold_until = hold_until   # fixed REs persist at least this long
        self.fixed_nodes = fixed_nodes  # configuration size (None in dsp)
        env_cls = HTCRuntimeEnv if workload.kind == "htc" else MTCRuntimeEnv
        self.env = env_cls(
            workload.name, provision=provision, clock=SimClock(sim),
            launch=self._launch, scheduler=scheduler, lifecycle=lifecycle,
            count_adjust=count_adjust,
            policy=policy if mode == "dsp" else None,
            fixed_nodes=fixed_nodes if mode == "fixed" else None)
        self.env.track(workload.jobs)
        if mode == "dsp":
            # phase in [0, 1) staggers this TRE's control cycles within
            # their intervals. The paper's single-tenant runs keep phase 0
            # (every cycle on the global grid — bit-for-bit with PR 1);
            # multi-tenant scenarios spread tenants out so scans/releases
            # do not collide at identical instants — and a parked
            # admission-queue request then waits O(interval/N) for the
            # next tenant's release instead of a whole synchronized window
            sim.after((1.0 + phase) * policy.scan_interval, self._scan)
            sim.after((1.0 + phase) * policy.release_interval,
                      self._release_check)
        # arrivals: only dependency-free jobs arrive by time; the trigger
        # monitor submits dependent tasks when their last dependency finishes
        for j in workload.jobs:
            if not j.deps:
                sim.at(j.arrival, self.env.submit, j)

    # ------------------------------------------------------ driver hooks
    def _launch(self, job: Job) -> None:
        self.sim.after(job.runtime, self._finish, job)

    def _finish(self, job: Job) -> None:
        if self.env.finish(job):
            # fixed REs (DCS/SSP) hold their configuration for the whole
            # workload period; DSP REs are destroyed once the work is done
            self.sim.at(max(self.sim.t, self.hold_until), self.env.destroy)

    def _scan(self) -> None:
        if self.env.destroyed:
            return
        self.env.scan()
        self.sim.after(self.env.engine.policy.scan_interval, self._scan)

    def _release_check(self) -> None:
        if self.env.destroyed:
            return
        self.env.release_check()
        self.sim.after(self.env.engine.policy.release_interval,
                       self._release_check)

    # ------------------------------------------------- env state mirror
    @property
    def completed(self) -> list[Job]:
        return self.env.completed

    @property
    def owned(self) -> int:
        return self.env.owned

    @property
    def destroyed(self) -> bool:
        return self.env.destroyed


# --------------------------------------------------------------------------
# DRP (direct resource provision, Deelman et al.)
# --------------------------------------------------------------------------
class DRPRunner:
    def __init__(self, sim: Sim, workload: Workload, provision: ProvisionService):
        self.sim = sim
        self.wl = workload
        self.provision = provision
        self.completed: list[Job] = []
        self._ndeps = {j.jid: len(j.deps) for j in workload.jobs}
        self._children: dict[int, list[Job]] = {}
        for j in workload.jobs:
            for d in j.deps:
                self._children.setdefault(d, []).append(j)
        if workload.kind == "htc":
            for j in workload.jobs:
                sim.at(j.arrival, self._run_htc_job, j)
        else:
            # one end-user pool for the whole workflow
            self.pool_name = f"{workload.name}-user"
            self.pool = 0          # leased high-watermark
            self.in_use = 0
            for j in workload.jobs:
                if not j.deps:
                    sim.at(j.arrival, self._run_mtc_task, j)

    # HTC: every job is its own end user/lease
    def _run_htc_job(self, job: Job):
        job.submit_time = job.start = self.sim.t
        user = f"{self.wl.name}-u{job.jid}"
        self.provision.request(user, job.nodes, self.sim.t)
        self.sim.after(job.runtime, self._finish_htc_job, job, user)

    def _finish_htc_job(self, job: Job, user: str):
        job.finish = self.sim.t
        self.provision.release(user, job.nodes, self.sim.t)
        self.completed.append(job)

    # MTC: eager execution; pool grows to peak width, held to the end
    def _run_mtc_task(self, job: Job):
        job.submit_time = job.start = self.sim.t
        need = self.in_use + job.nodes - self.pool
        if need > 0:
            self.provision.request(self.pool_name, need, self.sim.t)
            self.pool += need
        self.in_use += job.nodes
        self.sim.after(job.runtime, self._finish_mtc_task, job)

    def _finish_mtc_task(self, job: Job):
        job.finish = self.sim.t
        self.in_use -= job.nodes
        self.completed.append(job)
        for child in self._children.get(job.jid, ()):
            self._ndeps[child.jid] -= 1
            if self._ndeps[child.jid] == 0:
                self._run_mtc_task(child)
        if len(self.completed) == len(self.wl.jobs):
            self.provision.destroy(self.pool_name, self.sim.t)
            self.pool = 0


# --------------------------------------------------------------------------
# results
# --------------------------------------------------------------------------
@dataclass
class WorkloadResult:
    workload: str
    kind: str
    system: str
    completed_in_window: int
    completed_total: int
    node_hours: float
    makespan: float
    tasks_per_second: float
    mean_wait_s: float

    def as_dict(self):
        return dict(self.__dict__)


@dataclass
class SystemResult:
    system: str
    per_workload: dict[str, WorkloadResult]
    total_node_hours: float
    peak_nodes_per_hour: int
    adjust_count: int
    setup_overhead_s: float
    window_s: float
    capacity: int | None = None        # shared platform size (None = unbounded)

    @property
    def overhead_s_per_hour(self) -> float:
        return self.setup_overhead_s / max(self.window_s / 3600.0, 1e-9)


def _collect(system: str, wl: Workload, jobs_done: list[Job],
             node_hours: float, window: float) -> WorkloadResult:
    done_total = len(jobs_done)
    done_window = sum(1 for j in jobs_done if j.finish <= window + 1e-6)
    finish = max((j.finish for j in jobs_done), default=0.0)
    start = min((j.submit_time for j in jobs_done), default=0.0)
    makespan = finish - start
    tps = done_total / makespan if makespan > 0 else 0.0
    waits = [j.wait for j in jobs_done if j.wait >= 0]
    return WorkloadResult(
        workload=wl.name, kind=wl.kind, system=system,
        completed_in_window=done_window, completed_total=done_total,
        node_hours=node_hours, makespan=makespan, tasks_per_second=tps,
        mean_wait_s=sum(waits) / len(waits) if waits else 0.0)


# --------------------------------------------------------------------------
# registered usage models
# --------------------------------------------------------------------------
@dataclass
class EmulationContext:
    """Everything a registered ``System`` needs to build its runners. The
    billing horizon is NOT context state: ``finalize``/``node_hours``
    receive the authoritative ``end`` (the run's last fired event — or the
    horizon cutoff when the run was cut off — floored at the workload
    window) as a parameter."""
    sim: Sim
    provision: ProvisionService
    lifecycle: LifecycleService
    policies: dict[str, MgmtPolicy] = field(default_factory=dict)
    schedulers: dict[str, object] = field(default_factory=dict)
    mtc_fixed_nodes: int | None = None


class _EmulatedSystem(System):
    """Shared finalize: any TRE still running at the end of the window is
    destroyed through the lifecycle service (closing its leases at ``end``)."""

    def finalize(self, ctx: EmulationContext, runner, end: float) -> None:
        if isinstance(runner, REServer) and not runner.destroyed:
            runner.env.destroy(at=end)


@register_system("dcs")
class DCSSystem(_EmulatedSystem):
    """Dedicated cluster system: each provider owns a fixed configuration."""
    count_adjust = False     # owning a cluster is not a node adjustment

    def build(self, ctx: EmulationContext, wl: Workload) -> REServer:
        nodes = (wl.trace_nodes if wl.kind == "htc"
                 else (ctx.mtc_fixed_nodes or wl.trace_nodes))
        return REServer(ctx.sim, wl, ctx.provision, mode="fixed",
                        fixed_nodes=nodes, count_adjust=self.count_adjust,
                        hold_until=wl.period, lifecycle=ctx.lifecycle,
                        scheduler=ctx.schedulers.get(wl.name))

    def node_hours(self, ctx, runner, end) -> float:
        # paper §4.3: consumption = configuration size x workload period
        # (the immutable configuration, not post-destroy allocation state)
        return runner.fixed_nodes * math.ceil(runner.wl.period / BILL_UNIT_S)


@register_system("ssp")
class SSPSystem(DCSSystem):
    """Static service provision: same fixed configuration, but leased from
    the cloud — identical performance to DCS (§4.5.2), different TCO and
    adjustment accounting."""
    count_adjust = True


@register_system("drp")
class DRPSystem(System):
    """Direct resource provision: end users lease for themselves; no TRE."""

    def build(self, ctx: EmulationContext, wl: Workload) -> DRPRunner:
        return DRPRunner(ctx.sim, wl, ctx.provision)

    def node_hours(self, ctx, runner, end) -> float:
        # sum this workload's end-user leases
        wl = runner.wl
        return sum(l.billed_node_hours(end)
                   for l in ctx.provision.closed_leases
                   if l.tre.startswith(wl.name + "-u"))


@register_system("dawningcloud")
class DawningCloudSystem(_EmulatedSystem):
    """The paper's DSP model: elastic TREs negotiating with the provision
    service under per-provider (B, R) management policies."""

    def default_policy(self, wl: Workload) -> MgmtPolicy:
        return (MgmtPolicy.htc(40, 1.2) if wl.kind == "htc"
                else MgmtPolicy.mtc(10, 8.0))

    def default_scheduler(self, wl: Workload):
        return None                      # paper default for the workload kind

    def default_phase(self, wl: Workload) -> float:
        return 0.0                       # paper: every cycle on the grid

    def build(self, ctx: EmulationContext, wl: Workload) -> REServer:
        pol = ctx.policies.get(wl.name) or self.default_policy(wl)
        sched = ctx.schedulers.get(wl.name) or self.default_scheduler(wl)
        return REServer(ctx.sim, wl, ctx.provision, mode="dsp", policy=pol,
                        lifecycle=ctx.lifecycle, scheduler=sched,
                        phase=self.default_phase(wl))

    def node_hours(self, ctx, runner, end) -> float:
        return ctx.provision.node_hours(runner.wl.name, now=end)


@register_system("dawningcloud-backfill")
class DawningCloudBackfillSystem(DawningCloudSystem):
    """Beyond-paper consolidated scenario: the same DSP negotiation, but
    every HTC TRE schedules with conservative backfill while MTC TREs keep
    FCFS — a per-TRE scheduler mix the string-dispatch run_system could not
    express. Explicit ``schedulers={...}`` overrides still win."""

    def default_scheduler(self, wl: Workload):
        return "backfill" if wl.kind == "htc" else None


@register_system("dawningcloud-easy")
class DawningCloudEasySystem(DawningCloudBackfillSystem):
    """EASY-backfill variant: HTC TREs reserve only the blocked head
    (aggressive fills, the head's reserved start still inviolable) —
    higher utilization than conservative backfill at the cost of
    reservation guarantees for non-head queue positions."""

    def default_scheduler(self, wl: Workload):
        return "easy" if wl.kind == "htc" else None


# --------------------------------------------------------------------------
# multi-tenant scenarios (the economies-of-scale axis)
# --------------------------------------------------------------------------
def _aggregate_demand_events(workloads: list[Workload]):
    """(sorted times, demand levels) of the summed eager-execution demand
    across all tenants (HTC jobs at their trace arrivals/durations; a
    workflow TRE counts as its configured width over its period)."""
    ts, deltas = [], []
    for wl in workloads:
        if wl.kind == "htc":
            arr = np.array([j.arrival for j in wl.jobs])
            rt = np.array([j.runtime for j in wl.jobs])
            nd = np.array([j.nodes for j in wl.jobs])
            ts.append(arr)
            deltas.append(nd)
            ts.append(arr + rt)
            deltas.append(-nd)
        else:
            ts.append(np.array([0.0, wl.period]))
            deltas.append(np.array([wl.trace_nodes, -wl.trace_nodes]))
    t = np.concatenate(ts)
    d = np.concatenate(deltas)
    order = np.argsort(t, kind="stable")
    return t[order], np.cumsum(d[order])


def aggregate_demand_peak(workloads: list[Workload]) -> int:
    """Instantaneous peak of the summed eager-execution demand — the sum
    of per-tenant peaks grows linearly with the tenant count, but
    independent bursts do not align, so the peak of the sum grows
    sublinearly (statistical multiplexing)."""
    _, levels = _aggregate_demand_events(workloads)
    return int(levels.max())


def aggregate_hourly_peak(workloads: list[Workload]) -> int:
    """Peak *hourly-averaged* aggregate demand — the Fig 13 "nodes per
    hour" notion applied to the whole tenant fleet. This is the capacity a
    consolidated platform must host to serve every hour's average load:
    sub-hour bursts are buffered by the admission queue instead of being
    provisioned for, so the per-provider platform size falls as tenants
    consolidate (the economies-of-scale curve), while the sustained
    (week-scale, diurnal) plateaus every tenant shares stay fully covered
    — which is what keeps queueing delay bounded and tenants' workloads
    completing on schedule."""
    t, levels = _aggregate_demand_events(workloads)
    horizon = max(float(t.max()), max(wl.period for wl in workloads))
    # cumulative integral of the demand step function at event times, then
    # per-hour means via interpolation onto the hour grid
    t = np.concatenate([[0.0], t])
    levels = np.concatenate([[0], levels])
    integral = np.concatenate(
        [[0.0], np.cumsum(levels[:-1] * np.diff(t))])
    edges = np.arange(0.0, horizon + BILL_UNIT_S, BILL_UNIT_S)
    idx = np.searchsorted(t, edges, side="right") - 1
    at_edges = integral[idx] + levels[idx] * (edges - t[idx])
    hourly_mean = np.diff(at_edges) / BILL_UNIT_S
    return int(math.ceil(float(hourly_mean.max())))


@register_system("dawningcloud-coordinated")
class DawningCloudCoordinatedSystem(DawningCloudSystem):
    """PhoenixCloud-style consolidated scenario (arXiv:1006.1401): N DSP
    TREs share one *finite* platform sized at the aggregate demand peak
    (statistical multiplexing), simultaneous DR1/DR2 requests are
    arbitrated together by the coordinated policy, and deferred requests
    park in the provider's admission queue until another tenant's release
    frees capacity. At small N the shared capacity is an outlier far above
    typical demand and every request is served whole (DawningCloud
    semantics); as N grows the aggregate demand concentrates, the platform
    runs closer to its capacity, and burst requests get trimmed to fair
    shares — which is exactly where the per-provider consumption saving
    (the economies of scale) comes from."""

    coordination = "coordinated"

    def default_phase(self, wl: Workload) -> float:
        # deterministic per-tenant stagger (crc32: stable across processes,
        # unlike str hash) so N tenants' scans/releases interleave instead
        # of colliding at identical instants
        return (zlib.crc32(wl.name.encode()) % 997) / 997.0

    def default_capacity(self, workloads, policies) -> int:
        hourly = aggregate_hourly_peak(workloads)
        # liveness floor: when every tenant is back at its initial B, the
        # widest single job must still fit (else a DR2 can starve forever);
        # and creation must never be rejected (all Bs fit with margin)
        sum_b = sum((policies.get(wl.name) or self.default_policy(wl)).initial
                    for wl in workloads)
        widest = max(j.nodes for wl in workloads for j in wl.jobs)
        return max(hourly, sum_b + widest, math.ceil(1.25 * sum_b))


@register_system("dawningcloud-quota")
class DawningCloudQuotaSystem(DawningCloudSystem):
    """Per-tenant quota scenario: first-come provisioning (the paper's
    arrival-order semantics) on a shared platform, but no TRE may lease
    beyond its original dedicated-cluster size — the provider-side guard
    that one tenant's burst cannot crowd the platform (§3.2.2.3's provision
    policy parameterized per tenant)."""

    coordination = "first-come"

    def default_quotas(self, workloads, policies) -> dict[str, int]:
        return {wl.name: max(
            wl.trace_nodes,
            (policies.get(wl.name) or self.default_policy(wl)).initial)
            for wl in workloads}


# --------------------------------------------------------------------------
# registry-dispatched experiment runner
# --------------------------------------------------------------------------
def run_system(system: str, workloads: list[Workload], *,
               policies: dict[str, MgmtPolicy] | None = None,
               capacity: int | None = None,
               mtc_fixed_nodes: int | None = None,
               schedulers: dict[str, object] | None = None,
               coordination=None,
               quotas: dict[str, int] | None = None,
               reservations: dict[str, int] | None = None,
               horizon: float | None = None) -> SystemResult:
    """Run one registered system over consolidated workloads.

    system: any ``repro.core.registry`` name ("dcs" | "ssp" | "drp" |
        "dawningcloud" | "dawningcloud-backfill" | "dawningcloud-coordinated"
        | "dawningcloud-quota" | plugins)
    policies: workload name -> MgmtPolicy (DSP systems only)
    mtc_fixed_nodes: DCS/SSP configuration for MTC workloads (paper: 166)
    schedulers: workload name -> scheduler callable or SCHEDULERS key
    coordination: multi-tenant coordination policy name/instance; defaults
        to the system's ``coordination`` attribute. Any of coordination /
        quotas / reservations (explicit or system defaults) routes the run
        through a ``ResourceProvider`` with an admission queue; otherwise
        the paper's plain grant-or-reject ``ProvisionService`` is used.
    quotas / reservations: per-TRE hard caps / guaranteed minimums
    horizon: hard simulation cutoff (default 16x the workload window). A
        capacity-starved multi-tenant run can cycle hourly forever
        (release-check frees idle blocks, the admission queue re-grants
        them); the bound guarantees termination and surfaces the stall as
        incomplete job counts instead of a hung emulator.
    """
    impl = get_system(system)
    workloads = [wl.fresh() for wl in workloads]
    policies = dict(policies or {})
    coordination = coordination if coordination is not None \
        else impl.coordination
    if quotas is None:
        quotas = impl.default_quotas(workloads, policies)
    if reservations is None:
        reservations = impl.default_reservations(workloads)
    if coordination is not None or quotas or reservations:
        if capacity is None:
            capacity = impl.default_capacity(workloads, policies)
        provision: ProvisionService = ResourceProvider(
            capacity, coordination=coordination, quotas=quotas,
            reservations=reservations)
    else:
        provision = ProvisionService(capacity)
    sim = Sim()
    lifecycle = LifecycleService(provision)
    window = max(wl.period for wl in workloads)
    ctx = EmulationContext(sim=sim, provision=provision, lifecycle=lifecycle,
                          policies=policies,
                          schedulers=dict(schedulers or {}),
                          mtc_fixed_nodes=mtc_fixed_nodes)
    runners = [impl.build(ctx, wl) for wl in workloads]
    sim.run(until=horizon if horizon is not None else 16.0 * window)
    # fixed REs persist for the whole workload period even after the last
    # job; a completed run's end is its last fired event (sim.t is bumped
    # to the cutoff even when the event heap drained long before it)
    end = max(sim.last_event_t if sim.drained else sim.t, window)
    # withdraw every parked request BEFORE the destroy loop: one tenant's
    # destroy releases capacity, and a horizon-cutoff run may still have
    # requests queued — a grant landing between two destroys would open a
    # zero-duration lease billed a whole hour. drain=False: each cancel
    # must not serve the *other* still-parked requests either
    for r in runners:
        env = getattr(r, "env", None)
        if env is not None and not env.destroyed:
            env.cancel_pending(end, drain=False)
    for r in runners:
        impl.finalize(ctx, r, end)
    per = {
        r.wl.name: _collect(system, r.wl, r.completed,
                            impl.node_hours(ctx, r, end), window)
        for r in runners
    }
    total = sum(res.node_hours for res in per.values())
    return SystemResult(
        system=system, per_workload=per, total_node_hours=total,
        peak_nodes_per_hour=provision.peak_nodes_per_hour(end),
        adjust_count=provision.adjust_count(),
        setup_overhead_s=provision.setup_overhead_s(),
        window_s=window, capacity=provision.capacity)
