"""Emulated systems for the four usage models (paper §4.1, Figs 6-8).

One ``REServer`` implements the runtime-environment server + scheduler +
trigger monitor; it runs in two modes:

  - ``fixed``  (DCS & SSP): the RE owns/leases a fixed-size cluster for the
    whole workload period. DCS and SSP produce identical performance
    (paper §4.5.2) and differ only in TCO (benchmarks/tco.py).
  - ``dsp``    (DawningCloud): the RE starts with the policy's initial
    resources ``B`` and renegotiates with the provision service via the
    *same* ``PolicyEngine`` that drives the live elastic JAX controller.

``DRPRunner`` models Deelman-style direct resource provision: each HTC job
is an end user leasing its own nodes for ceil-hour of its runtime; an MTC
workflow is one end-user application whose leased pool grows to its eager
(no-queue) execution width and is held until the workflow finishes.

All billing goes through ``repro.core.provision`` (1-hour lease units).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

from repro.core.policy import MgmtPolicy, PolicyEngine
from repro.core.provision import BILL_UNIT_S, ProvisionService
from repro.core.scheduling import scheduler_for
from repro.core.types import Job, Workload
from repro.sim.engine import Sim


# --------------------------------------------------------------------------
# runtime-environment server (DCS / SSP / DawningCloud)
# --------------------------------------------------------------------------
class REServer:
    def __init__(self, sim: Sim, workload: Workload, provision: ProvisionService,
                 *, mode: str, fixed_nodes: int | None = None,
                 policy: MgmtPolicy | None = None, count_adjust: bool = True,
                 hold_until: float = 0.0):
        assert mode in ("fixed", "dsp")
        self.sim = sim
        self.wl = workload
        self.name = workload.name
        self.provision = provision
        self.mode = mode
        self.hold_until = hold_until   # fixed REs persist at least this long
        self.scheduler = scheduler_for(workload.kind)
        self.count_adjust = count_adjust
        self.queue: list[Job] = []
        self.busy = 0
        self.completed: list[Job] = []
        self.destroyed = False
        # trigger monitor state (MTC): dependency bookkeeping
        self._ndeps = {j.jid: len(j.deps) for j in workload.jobs}
        self._children: dict[int, list[Job]] = {}
        for j in workload.jobs:
            for d in j.deps:
                self._children.setdefault(d, []).append(j)
        # resources
        if mode == "fixed":
            assert fixed_nodes is not None
            self.owned = fixed_nodes
            ok = provision.request(self.name, fixed_nodes, sim.t,
                                   count_adjust=count_adjust)
            assert ok, "fixed RE could not lease its configuration"
            self.engine = None
        else:
            assert policy is not None
            self.engine = PolicyEngine(policy)
            self.owned = policy.initial
            ok = provision.request(self.name, policy.initial, sim.t,
                                   count_adjust=count_adjust)
            assert ok, "initial resources rejected"
            sim.after(policy.scan_interval, self._scan)
            sim.after(policy.release_interval, self._release_check)
        # arrivals: only dependency-free jobs arrive by time; the trigger
        # monitor submits dependent tasks when their last dependency finishes
        for j in workload.jobs:
            if not j.deps:
                sim.at(j.arrival, self.submit, j)

    # ------------------------------------------------------------ server
    @property
    def free(self) -> int:
        return self.owned - self.busy

    def _account_idle(self):
        """Accumulate the time-integral of idle nodes. The hourly release
        check frees blocks covered by the *time-averaged* idle of the past
        hour: instantaneous idle thrashes (release->regrant bills a fresh
        lease hour), whole-hour-idle ratchets the pool up; average idle
        tracks the load curve with one hour of lag."""
        t = self.sim.t
        self._idle_acc = getattr(self, "_idle_acc", 0.0) + \
            self.free * (t - getattr(self, "_idle_t", t))
        self._idle_t = t

    def submit(self, job: Job):
        job.submit_time = self.sim.t
        self.queue.append(job)
        # DSP servers schedule at scan ticks (the scan both resizes and
        # loads jobs, §3.2.2); fixed REs schedule on submission
        if self.mode == "fixed":
            self._try_start()

    def _try_start(self):
        for job in self.scheduler(self.queue, self.free):
            self.queue.remove(job)
            job.start = self.sim.t
            self._account_idle()
            self.busy += job.nodes
            self.sim.after(job.runtime, self._finish, job)

    def _finish(self, job: Job):
        job.finish = self.sim.t
        self._account_idle()
        self.busy -= job.nodes
        self.completed.append(job)
        # trigger monitor: release newly-ready dependents into the queue
        for child in self._children.get(job.jid, ()):
            self._ndeps[child.jid] -= 1
            if self._ndeps[child.jid] == 0:
                self.submit(child)
        if len(self.completed) == len(self.wl.jobs):
            # fixed REs (DCS/SSP) hold their configuration for the whole
            # workload period; DSP REs are destroyed once the work is done
            self.sim.at(max(self.sim.t, self.hold_until), self._destroy)
        else:
            self._try_start()

    # --------------------------------------------------------- dsp loops
    def _scan(self):
        if self.destroyed:
            return
        req = self.engine.scan([j.nodes for j in self.queue], self.owned)
        if req > 0 and self.provision.request(self.name, req, self.sim.t,
                                              count_adjust=self.count_adjust):
            self._account_idle()
            self.engine.granted(req)
            self.owned += req
        self._try_start()
        self.sim.after(self.engine.policy.scan_interval, self._scan)

    def _release_check(self):
        if self.destroyed:
            return
        self._account_idle()
        interval = self.engine.policy.release_interval
        idle_avg = getattr(self, "_idle_acc", 0.0) / interval
        rel = self.engine.release_check(int(min(idle_avg, self.free)))
        if rel > 0:
            self.provision.release(self.name, rel, self.sim.t,
                                   count_adjust=self.count_adjust)
            self.owned -= rel
        self._idle_acc = 0.0
        self.sim.after(self.engine.policy.release_interval, self._release_check)

    def _destroy(self):
        """All jobs done: service provider destroys the RE (releases leases)."""
        if self.destroyed:
            return
        self.destroyed = True
        self.provision.destroy(self.name, self.sim.t)


# --------------------------------------------------------------------------
# DRP (direct resource provision, Deelman et al.)
# --------------------------------------------------------------------------
class DRPRunner:
    def __init__(self, sim: Sim, workload: Workload, provision: ProvisionService):
        self.sim = sim
        self.wl = workload
        self.provision = provision
        self.completed: list[Job] = []
        self._ndeps = {j.jid: len(j.deps) for j in workload.jobs}
        self._children: dict[int, list[Job]] = {}
        for j in workload.jobs:
            for d in j.deps:
                self._children.setdefault(d, []).append(j)
        if workload.kind == "htc":
            for j in workload.jobs:
                sim.at(j.arrival, self._run_htc_job, j)
        else:
            # one end-user pool for the whole workflow
            self.pool_name = f"{workload.name}-user"
            self.pool = 0          # leased high-watermark
            self.in_use = 0
            for j in workload.jobs:
                if not j.deps:
                    sim.at(j.arrival, self._run_mtc_task, j)

    # HTC: every job is its own end user/lease
    def _run_htc_job(self, job: Job):
        job.submit_time = job.start = self.sim.t
        user = f"{self.wl.name}-u{job.jid}"
        self.provision.request(user, job.nodes, self.sim.t)
        self.sim.after(job.runtime, self._finish_htc_job, job, user)

    def _finish_htc_job(self, job: Job, user: str):
        job.finish = self.sim.t
        self.provision.release(user, job.nodes, self.sim.t)
        self.completed.append(job)

    # MTC: eager execution; pool grows to peak width, held to the end
    def _run_mtc_task(self, job: Job):
        job.submit_time = job.start = self.sim.t
        need = self.in_use + job.nodes - self.pool
        if need > 0:
            self.provision.request(self.pool_name, need, self.sim.t)
            self.pool += need
        self.in_use += job.nodes
        self.sim.after(job.runtime, self._finish_mtc_task, job)

    def _finish_mtc_task(self, job: Job):
        job.finish = self.sim.t
        self.in_use -= job.nodes
        self.completed.append(job)
        for child in self._children.get(job.jid, ()):
            self._ndeps[child.jid] -= 1
            if self._ndeps[child.jid] == 0:
                self._run_mtc_task(child)
        if len(self.completed) == len(self.wl.jobs):
            self.provision.destroy(self.pool_name, self.sim.t)
            self.pool = 0


# --------------------------------------------------------------------------
# results
# --------------------------------------------------------------------------
@dataclass
class WorkloadResult:
    workload: str
    kind: str
    system: str
    completed_in_window: int
    completed_total: int
    node_hours: float
    makespan: float
    tasks_per_second: float
    mean_wait_s: float

    def as_dict(self):
        return dict(self.__dict__)


@dataclass
class SystemResult:
    system: str
    per_workload: dict[str, WorkloadResult]
    total_node_hours: float
    peak_nodes_per_hour: int
    adjust_count: int
    setup_overhead_s: float
    window_s: float

    @property
    def overhead_s_per_hour(self) -> float:
        return self.setup_overhead_s / max(self.window_s / 3600.0, 1e-9)


def _collect(system: str, wl: Workload, jobs_done: list[Job],
             node_hours: float, window: float) -> WorkloadResult:
    done_total = len(jobs_done)
    done_window = sum(1 for j in jobs_done if j.finish <= window + 1e-6)
    finish = max((j.finish for j in jobs_done), default=0.0)
    start = min((j.submit_time for j in jobs_done), default=0.0)
    makespan = finish - start
    tps = done_total / makespan if makespan > 0 else 0.0
    waits = [j.wait for j in jobs_done if j.wait >= 0]
    return WorkloadResult(
        workload=wl.name, kind=wl.kind, system=system,
        completed_in_window=done_window, completed_total=done_total,
        node_hours=node_hours, makespan=makespan, tasks_per_second=tps,
        mean_wait_s=sum(waits) / len(waits) if waits else 0.0)


def run_system(system: str, workloads: list[Workload], *,
               policies: dict[str, MgmtPolicy] | None = None,
               capacity: int | None = None,
               mtc_fixed_nodes: int | None = None) -> SystemResult:
    """Run one emulated system over consolidated workloads.

    system: "dcs" | "ssp" | "drp" | "dawningcloud"
    policies: workload name -> MgmtPolicy (dawningcloud only)
    mtc_fixed_nodes: DCS/SSP configuration for MTC workloads (paper: 166)
    """
    workloads = [wl.fresh() for wl in workloads]
    sim = Sim()
    provision = ProvisionService(capacity)
    window = max(wl.period for wl in workloads)
    runners = []
    for wl in workloads:
        if system in ("dcs", "ssp"):
            nodes = (wl.trace_nodes if wl.kind == "htc"
                     else (mtc_fixed_nodes or wl.trace_nodes))
            runners.append(REServer(sim, wl, provision, mode="fixed",
                                    fixed_nodes=nodes,
                                    count_adjust=(system == "ssp"),
                                    hold_until=wl.period))
        elif system == "dawningcloud":
            pol = (policies or {}).get(wl.name) or (
                MgmtPolicy.htc(40, 1.2) if wl.kind == "htc"
                else MgmtPolicy.mtc(10, 8.0))
            runners.append(REServer(sim, wl, provision, mode="dsp", policy=pol))
        elif system == "drp":
            runners.append(DRPRunner(sim, wl, provision))
        else:
            raise ValueError(system)
    sim.run()
    # fixed REs persist for the whole workload period even after the last job
    end = max(sim.t, window)
    for r in runners:
        if isinstance(r, REServer) and not r.destroyed:
            r.provision.destroy(r.name, end)
            r.destroyed = True
    per = {}
    for r in runners:
        wl = r.wl
        if system in ("dcs", "ssp"):
            # paper §4.3: consumption = configuration size x workload period
            nh = r.owned * math.ceil(wl.period / BILL_UNIT_S)
        elif isinstance(r, REServer):
            nh = provision.node_hours(wl.name, now=end)
        else:  # DRP: sum this workload's end-user leases
            nh = sum(l.billed_node_hours(end) for l in provision.closed_leases
                     if l.tre.startswith(wl.name + "-u"))
        per[wl.name] = _collect(system, wl, r.completed, nh, window)
    total = sum(res.node_hours for res in per.values())
    return SystemResult(
        system=system, per_workload=per, total_node_hours=total,
        peak_nodes_per_hour=provision.peak_nodes_per_hour(end),
        adjust_count=provision.adjust_count(),
        setup_overhead_s=provision.setup_overhead_s(),
        window_s=window)
