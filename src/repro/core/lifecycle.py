"""TRE lifecycle management (paper §3.1.3, Fig 4).

The CSF's lifecycle management service owns the state machine
``inexistent -> planning -> created -> running -> inexistent`` and performs
the side effects of each transition: validating the request, deploying the
TRE package (modeled as a per-node setup cost), registering it with the
resource provision service, starting its components, and destroying it
(prompt-backup -> stop daemons -> offload -> withdraw resources).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from enum import Enum

from repro.core.policy import MgmtPolicy
from repro.core.provision import ProvisionService


class TREState(Enum):
    INEXISTENT = "inexistent"
    PLANNING = "planning"
    CREATED = "created"
    RUNNING = "running"


_VALID = {
    (TREState.INEXISTENT, TREState.PLANNING),
    (TREState.PLANNING, TREState.CREATED),
    (TREState.CREATED, TREState.RUNNING),
    (TREState.RUNNING, TREState.INEXISTENT),
    # rejected requests fall back
    (TREState.PLANNING, TREState.INEXISTENT),
}


@dataclass
class TRERecord:
    name: str
    kind: str                    # "htc" | "mtc"
    policy: MgmtPolicy
    state: TREState = TREState.INEXISTENT
    created_t: float = -1.0
    destroyed_t: float = -1.0
    history: list = field(default_factory=list)

    def transition(self, to: TREState, t: float):
        if (self.state, to) not in _VALID:
            raise ValueError(f"invalid TRE transition {self.state} -> {to}")
        self.history.append((t, self.state.value, to.value))
        self.state = to


class LifecycleService:
    """Creates/destroys TREs on behalf of service providers."""

    def __init__(self, provision: ProvisionService):
        self.provision = provision
        self.tres: dict[str, TRERecord] = {}

    def apply(self, name: str, kind: str, policy: MgmtPolicy, t: float,
              *, count_adjust: bool = True) -> TRERecord | None:
        """Service provider applies for a new TRE (steps 1-5 of §3.1.3).

        Returns the record in RUNNING state, or None if the platform cannot
        provision the initial resources (request rejected). ``count_adjust``
        mirrors ``ProvisionService.request``: DCS REs own their configuration
        outright, so deploying one is not a node *adjustment* (§4.5.4).
        """
        if kind not in ("htc", "mtc"):
            raise ValueError(f"unknown workload kind {kind!r}")
        if name in self.tres and self.tres[name].state != TREState.INEXISTENT:
            raise ValueError(f"TRE {name!r} already exists")
        rec = TRERecord(name, kind, policy)
        self.tres[name] = rec
        rec.transition(TREState.PLANNING, t)          # validated
        if not self.provision.request(name, policy.initial, t,
                                      count_adjust=count_adjust):
            rec.transition(TREState.INEXISTENT, t)    # rejected
            return None
        rec.transition(TREState.CREATED, t)           # deployed
        rec.transition(TREState.RUNNING, t)           # components started
        rec.created_t = t
        return rec

    def destroy(self, name: str, t: float, *, count_adjust: bool = True) -> None:
        """Destroy a TRE (step 8): withdraw all resources. As with
        :meth:`apply`, withdrawing an owned (DCS) configuration is not a
        node adjustment (§4.5.4) — pass ``count_adjust=False`` there."""
        rec = self.tres[name]
        if rec.state != TREState.RUNNING:
            raise ValueError(f"cannot destroy TRE in state {rec.state}")
        self.provision.destroy(name, t, count_adjust=count_adjust)
        rec.transition(TREState.INEXISTENT, t)
        rec.destroyed_t = t
