"""Shared workload types for the DSP model (paper §2).

A *Job* is the unit both the emulator and the live controllers schedule:
HTC jobs are independent (``deps=()``); MTC workflow tasks carry control-flow
dependencies (``deps`` = jids within the same workflow) and are released to
the queue by the trigger monitor only when every dependency has finished.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace


@dataclass
class Job:
    jid: int
    arrival: float          # seconds from trace start (MTC tasks: 0)
    runtime: float          # seconds
    nodes: int
    deps: tuple = ()        # jids this job waits on (same workload)
    wid: int = -1           # workflow id (-1 = independent HTC job)
    name: str = ""
    # ---- token-length marks (MTC serving: one task = one inference
    # request; repro.sim.traces.mark_tokens stamps these from runtime) ----
    prompt_len: int = 0     # prompt tokens (0 = not an inference task)
    decode_len: int = 0     # decode tokens = service ticks at 1 tok/tick
    # ---- filled in by a run ----
    submit_time: float = -1.0   # entered the queue (deps satisfied)
    start: float = -1.0
    finish: float = -1.0

    @property
    def wait(self) -> float:
        return self.start - self.submit_time if self.start >= 0 else -1.0

    def fresh(self) -> "Job":
        return replace(self, submit_time=-1.0, start=-1.0, finish=-1.0)


@dataclass
class Workload:
    """One service provider's workload (= one TRE's job stream)."""
    name: str
    kind: str               # "htc" | "mtc"
    jobs: list[Job] = field(default_factory=list)
    trace_nodes: int = 0    # original platform size (DCS/SSP config size)
    period: float = 0.0     # trace window in seconds

    def fresh(self) -> "Workload":
        return Workload(self.name, self.kind, [j.fresh() for j in self.jobs],
                        self.trace_nodes, self.period)

    @property
    def total_work(self) -> float:
        """node*seconds of actual compute demand."""
        return sum(j.nodes * j.runtime for j in self.jobs)

    @property
    def max_job_nodes(self) -> int:
        return max(j.nodes for j in self.jobs)

    def utilization(self, nodes: int | None = None) -> float:
        n = nodes or self.trace_nodes
        return self.total_work / (n * self.period) if self.period else 0.0
