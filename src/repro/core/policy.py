"""DSP resource-management & provision policies (paper §3.2.2).

``PolicyEngine`` is *pure decision logic*: given queue state it returns how
many nodes to request; given idle state it returns how many to release. The
same engine instance drives (a) the discrete-event emulator
(``repro.sim.systems``) and (b) the live elastic JAX controller
(``repro.core.controller``) — one implementation, two drivers, which is what
makes the reproduction a framework rather than a simulator.

Paper semantics implemented here:

HTC (§3.2.2.1): initial resources ``B`` are never released; the server scans
the queue every 60 s; with *ratio of obtaining resources* =
(accumulated demand of queued jobs) / (currently owned):
  - ratio > R           -> request DR1 = demand - owned
  - biggest job > owned -> request DR2 = biggest - owned   (when ratio <= R)
Each granted block registers an hourly idle-check; a block is released when
idle resources cover its size.

MTC (§3.2.2.2): identical, but the scan period is 3 s (tasks run in seconds)
and every queued workflow-constituent job counts toward the demand.

Provision policy (§3.2.2.3): grant if available else reject; releases are
passively reclaimed. Implemented by ``repro.core.provision``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

HTC_SCAN_S = 60.0
MTC_SCAN_S = 3.0
RELEASE_CHECK_S = 3600.0


@dataclass(frozen=True)
class MgmtPolicy:
    """A service provider's resource-management policy (B, R)."""
    initial: int                 # B: initial resources (never reclaimed)
    ratio: float                 # R: threshold ratio of obtaining resources
    scan_interval: float         # 60 s (HTC) / 3 s (MTC)
    release_interval: float = RELEASE_CHECK_S

    @staticmethod
    def htc(B: int, R: float) -> "MgmtPolicy":
        return MgmtPolicy(B, R, HTC_SCAN_S)

    @staticmethod
    def mtc(B: int, R: float) -> "MgmtPolicy":
        return MgmtPolicy(B, R, MTC_SCAN_S)


class PolicyEngine:
    """Stateful wrapper tracking outstanding dynamic blocks (DR1/DR2)."""

    def __init__(self, policy: MgmtPolicy):
        self.policy = policy
        self.dynamic_blocks: list[int] = []

    # ------------------------------------------------------------- scan
    def scan_request_stats(self, total: int, biggest: int, smallest: int,
                           owned: int) -> tuple[int, int]:
        """(nodes to request, minimum useful grant) from queue *summary
        statistics* — total / biggest / smallest queued node demand. The
        decision only ever reads these three aggregates, so a columnar
        driver holding 10^5-10^6 queued tasks as arrays can negotiate
        without materializing a per-job demand list (``repro.serve.
        columnar`` keeps them as ``queue_len * width``).

        A grant is *useful* only if it can put at least one queued job on
        nodes; anything smaller sits idle until the hourly release check
        reclaims it (thrash that bills a fresh lease-hour per cycle). For
        a DR1 backlog the floor is what the narrowest queued job would
        need even if everything owned were free (1 when it already fits
        inside owned — the grant then relieves genuine contention); DR2
        exists to fit one job wider than everything owned, so it is
        all-or-nothing.
        """
        if total <= 0:
            return 0, 0
        ratio = total / max(owned, 1)
        if ratio > self.policy.ratio and total > owned:
            floor = max(1, smallest - owned)
            return total - owned, floor      # DR1: divisible down to floor
        if biggest > owned:
            return biggest - owned, biggest - owned   # DR2: indivisible
        return 0, 0

    def scan_request(self, queued_demands: Sequence[int],
                     owned: int) -> tuple[int, int]:
        """Per-job-list form of :meth:`scan_request_stats` (the historical
        signature; both must stay decision-identical — pinned in tests)."""
        if not queued_demands:
            return 0, 0
        return self.scan_request_stats(sum(queued_demands),
                                       max(queued_demands),
                                       min(queued_demands), owned)

    def scan(self, queued_demands: Sequence[int], owned: int) -> int:
        """Nodes to request right now (0 = no action).

        queued_demands: per-job node demands of everything in the queue.
        """
        return self.scan_request(queued_demands, owned)[0]

    def urgency_stats(self, total: int, owned: int) -> float:
        """The §3.2.2.1 *ratio of obtaining resources* (queued demand over
        owned) as a cross-TRE arbitration priority: a coordinated provider
        (``repro.core.provider.CoordinatedPolicy``) serves the most
        oversubscribed tenant first when simultaneous requests contend."""
        if total <= 0:
            return 0.0
        return total / max(owned, 1)

    def urgency(self, queued_demands: Sequence[int], owned: int) -> float:
        """Per-job-list form of :meth:`urgency_stats`."""
        return self.urgency_stats(sum(queued_demands), owned)

    def granted(self, n: int) -> None:
        if n > 0:
            self.dynamic_blocks.append(n)

    @property
    def dynamic_total(self) -> int:
        return sum(self.dynamic_blocks)

    # ---------------------------------------------------------- release
    def release_check(self, idle: int) -> int:
        """Hourly idle check: release every dynamic block covered by idle
        resources (biggest blocks first). Returns total nodes to release."""
        released = 0
        keep: list[int] = []
        for blk in sorted(self.dynamic_blocks, reverse=True):
            if idle - released >= blk:
                released += blk
            else:
                keep.append(blk)
        self.dynamic_blocks = keep
        return released

    def release_all(self) -> int:
        n = self.dynamic_total
        self.dynamic_blocks = []
        return n
