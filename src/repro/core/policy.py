"""DSP resource-management & provision policies (paper §3.2.2).

``PolicyEngine`` is *pure decision logic*: given queue state it returns how
many nodes to request; given idle state it returns how many to release. The
same engine instance drives (a) the discrete-event emulator
(``repro.sim.systems``) and (b) the live elastic JAX controller
(``repro.core.controller``) — one implementation, two drivers, which is what
makes the reproduction a framework rather than a simulator.

Paper semantics implemented here:

HTC (§3.2.2.1): initial resources ``B`` are never released; the server scans
the queue every 60 s; with *ratio of obtaining resources* =
(accumulated demand of queued jobs) / (currently owned):
  - ratio > R           -> request DR1 = demand - owned
  - biggest job > owned -> request DR2 = biggest - owned   (when ratio <= R)
Each granted block registers an hourly idle-check; a block is released when
idle resources cover its size.

MTC (§3.2.2.2): identical, but the scan period is 3 s (tasks run in seconds)
and every queued workflow-constituent job counts toward the demand.

Provision policy (§3.2.2.3): grant if available else reject; releases are
passively reclaimed. Implemented by ``repro.core.provision``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

HTC_SCAN_S = 60.0
MTC_SCAN_S = 3.0
RELEASE_CHECK_S = 3600.0


@dataclass(frozen=True)
class MgmtPolicy:
    """A service provider's resource-management policy (B, R)."""
    initial: int                 # B: initial resources (never reclaimed)
    ratio: float                 # R: threshold ratio of obtaining resources
    scan_interval: float         # 60 s (HTC) / 3 s (MTC)
    release_interval: float = RELEASE_CHECK_S

    @staticmethod
    def htc(B: int, R: float) -> "MgmtPolicy":
        return MgmtPolicy(B, R, HTC_SCAN_S)

    @staticmethod
    def mtc(B: int, R: float) -> "MgmtPolicy":
        return MgmtPolicy(B, R, MTC_SCAN_S)


class PolicyEngine:
    """Stateful wrapper tracking outstanding dynamic blocks (DR1/DR2)."""

    def __init__(self, policy: MgmtPolicy):
        self.policy = policy
        self.dynamic_blocks: list[int] = []

    # ------------------------------------------------------------- scan
    def scan(self, queued_demands: Sequence[int], owned: int) -> int:
        """Nodes to request right now (0 = no action).

        queued_demands: per-job node demands of everything in the queue.
        """
        if not queued_demands:
            return 0
        demand = sum(queued_demands)
        biggest = max(queued_demands)
        owned = max(owned, 1)
        ratio = demand / owned
        if ratio > self.policy.ratio and demand > owned:
            return demand - owned            # DR1
        if biggest > owned:
            return biggest - owned           # DR2
        return 0

    def granted(self, n: int) -> None:
        if n > 0:
            self.dynamic_blocks.append(n)

    @property
    def dynamic_total(self) -> int:
        return sum(self.dynamic_blocks)

    # ---------------------------------------------------------- release
    def release_check(self, idle: int) -> int:
        """Hourly idle check: release every dynamic block covered by idle
        resources (biggest blocks first). Returns total nodes to release."""
        released = 0
        keep: list[int] = []
        for blk in sorted(self.dynamic_blocks, reverse=True):
            if idle - released >= blk:
                released += blk
            else:
                keep.append(blk)
        self.dynamic_blocks = keep
        return released

    def release_all(self) -> int:
        n = self.dynamic_total
        self.dynamic_blocks = []
        return n
