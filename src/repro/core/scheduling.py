"""Job scheduling policies (paper §4.4(1)).

``first_fit``  — HTC: scan all queued jobs in arrival order and start every
                 job whose node demand fits the currently free nodes.
``fcfs``       — MTC: strict first-come-first-served over *ready* tasks
                 (dependencies satisfied); head-of-line blocks the queue.

Both return the list of jobs to start now; the caller removes them from the
queue and commits the nodes.
"""
from __future__ import annotations

from typing import Sequence

from repro.core.types import Job


def first_fit(queue: Sequence[Job], free: int) -> list[Job]:
    started: list[Job] = []
    for job in queue:
        if job.nodes <= free:
            started.append(job)
            free -= job.nodes
    return started


def fcfs(queue: Sequence[Job], free: int) -> list[Job]:
    started: list[Job] = []
    for job in queue:
        if job.nodes > free:
            break
        started.append(job)
        free -= job.nodes
    return started


SCHEDULERS = {"first_fit": first_fit, "fcfs": fcfs}


def scheduler_for(kind: str):
    """HTC -> first-fit; MTC -> FCFS (paper §4.4)."""
    return first_fit if kind == "htc" else fcfs
