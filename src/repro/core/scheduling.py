"""Job scheduling policies (paper §4.4(1) + beyond-paper backfill).

``first_fit``  — HTC: scan all queued jobs in arrival order and start every
                 job whose node demand fits the currently free nodes.
``fcfs``       — MTC: strict first-come-first-served over *ready* tasks
                 (dependencies satisfied); head-of-line blocks the queue.
``backfill``   — HTC, beyond-paper: FCFS with conservative backfill. Every
                 queued job gets a reservation against the projected
                 free-node profile; a job may jump a blocked head only when
                 starting it now cannot delay any earlier job's reserved
                 start. Needs the release times of running jobs — when the
                 caller cannot supply a complete profile it degrades to
                 plain ``fcfs`` (never optimistic).
``easy``       — HTC, beyond-paper: EASY (aggressive) backfill. Only the
                 *blocked head* gets a reservation; any later job may jump
                 it if starting now cannot delay that reservation — jobs
                 behind the head hold no reservation, so a fill may delay
                 *them* (the EASY trade-off: better utilization, weaker
                 fairness, head start-time guarantee kept). Same
                 degrade-to-FCFS rule on an incomplete release profile.

All schedulers share one signature: ``sched(queue, free, **context)`` and
return the list of jobs to start now; the caller removes them from the
queue and commits the nodes. The optional context keywords (``now``,
``running`` = sequence of ``(end_time, nodes)`` reservations, ``busy``) are
supplied by ``repro.core.tre.RuntimeEnv`` and ignored by the paper's two
schedulers. New policies plug in via the ``SCHEDULERS`` registry.
"""
from __future__ import annotations

from typing import Sequence

from repro.core.types import Job


def first_fit(queue: Sequence[Job], free: int, **_ctx) -> list[Job]:
    started: list[Job] = []
    for job in queue:
        if job.nodes <= free:
            started.append(job)
            free -= job.nodes
    return started


def fcfs(queue: Sequence[Job], free: int, **_ctx) -> list[Job]:
    started: list[Job] = []
    for job in queue:
        if job.nodes > free:
            break
        started.append(job)
        free -= job.nodes
    return started


# ------------------------------------------------------- conservative backfill
def _earliest_start(profile: list[list[float]], nodes: int,
                    runtime: float) -> float | None:
    """Earliest profile breakpoint where ``nodes`` stay available for
    ``runtime``. ``profile`` is a sorted list of ``[t, avail]`` steps; the
    last step extends to infinity. None = never fits (job wider than pool)."""
    for i, (t0, a0) in enumerate(profile):
        if a0 < nodes:
            continue
        end = t0 + runtime
        if all(a >= nodes for t, a in profile[i + 1:] if t < end):
            return t0
    return None


def _reserve(profile: list[list[float]], t0: float, runtime: float,
             nodes: int) -> None:
    """Subtract ``nodes`` from the profile over ``[t0, t0 + runtime)``."""
    end = t0 + runtime
    for t_cut in (t0, end):
        for i, (t, a) in enumerate(profile):
            if t == t_cut:
                break
            if t > t_cut:
                profile.insert(i, [t_cut, profile[i - 1][1]])
                break
        else:
            profile.append([t_cut, profile[-1][1]])
    for step in profile:
        if t0 <= step[0] < end:
            step[1] -= nodes


def _release_profile(free: int, now: float,
                     running: Sequence[tuple[float, int]], busy: int,
                     ) -> list[list[float]] | None:
    """Projected free-node profile ``[[t, avail], ...]`` from the running
    set's release times. Drops overdue reservations (a task running past
    its estimate has NOT freed its nodes); returns None when any release
    is unknown or stale — a missing release makes a head's reservation
    infinitely late and every fill "harmless", so backfill variants must
    refuse to guess and degrade to strict FCFS."""
    running = [(t, n) for t, n in running if n > 0 and t > now]
    if sum(n for _, n in running) < busy:
        return None
    profile: list[list[float]] = [[now, free]]
    for t_end, n in sorted(running):
        profile.append([t_end, profile[-1][1] + n])
    return profile


def backfill(queue: Sequence[Job], free: int, *, now: float = 0.0,
             running: Sequence[tuple[float, int]] = (), busy: int = 0,
             **_ctx) -> list[Job]:
    """FCFS with conservative backfill over the projected release profile."""
    if not queue:
        return []
    profile = _release_profile(free, now, running, busy)
    if profile is None:
        return fcfs(queue, free)
    started: list[Job] = []
    for job in queue:
        t_start = _earliest_start(profile, job.nodes, job.runtime)
        if t_start is None:
            # wider than the pool ever gets: in a DSP env the next scan's
            # DR2 will grow the pool for it, so give it FCFS-blocking
            # semantics — nothing behind it may start, else the fill would
            # delay it past the grant
            break
        if t_start <= now:
            started.append(job)
        _reserve(profile, t_start, job.runtime, job.nodes)
    return started


def easy_backfill(queue: Sequence[Job], free: int, *, now: float = 0.0,
                  running: Sequence[tuple[float, int]] = (), busy: int = 0,
                  **_ctx) -> list[Job]:
    """EASY backfill: FCFS until a job blocks; the blocked head reserves
    its earliest start against the release profile, and later jobs may
    start *now* only if they fit the profile including that reservation —
    the head's reserved start can never be delayed. Unlike conservative
    ``backfill``, jobs behind the head get no reservation of their own
    (a fill may push them back)."""
    if not queue:
        return []
    profile = _release_profile(free, now, running, busy)
    if profile is None:
        return fcfs(queue, free)          # incomplete profile: never guess
    started: list[Job] = []
    head_blocked = False
    for job in queue:
        t_start = _earliest_start(profile, job.nodes, job.runtime)
        if not head_blocked:
            if t_start is None:
                # wider than the pool ever gets: FCFS-blocking so a DSP
                # env's next DR2 grant is not delayed by fills (matches
                # conservative backfill)
                break
            if t_start <= now:
                started.append(job)
                _reserve(profile, now, job.runtime, job.nodes)
            else:
                head_blocked = True
                # the head's reservation — the only one EASY honors
                _reserve(profile, t_start, job.runtime, job.nodes)
        elif t_start is not None and t_start <= now:
            started.append(job)
            _reserve(profile, now, job.runtime, job.nodes)
    return started


SCHEDULERS = {"first_fit": first_fit, "fcfs": fcfs, "backfill": backfill,
              "easy": easy_backfill}


def scheduler_for(kind: str):
    """HTC -> first-fit; MTC -> FCFS (paper §4.4)."""
    return first_fit if kind == "htc" else fcfs


def resolve_scheduler(spec, kind: str):
    """Accept a scheduler callable, a ``SCHEDULERS`` registry key, or None
    (= the paper's default for the workload kind)."""
    if spec is None:
        return scheduler_for(kind)
    if callable(spec):
        return spec
    try:
        return SCHEDULERS[spec]
    except KeyError:
        raise ValueError(
            f"unknown scheduler {spec!r}; registered: {sorted(SCHEDULERS)}"
        ) from None
