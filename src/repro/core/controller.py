"""Live elastic controller: DSP policies driving real JAX training jobs.

This is the *live driver* half of the ``repro.core.tre`` split: an
``ElasticController`` owns execution — building meshes, running optimizer
steps, checkpoint/restore — while every control decision (queue loading,
DR1/DR2 grants, idle-averaged releases, lifecycle transitions) comes from
the very same ``HTCRuntimeEnv`` that the discrete-event emulator drives.
Where the emulator advances a simulated-seconds clock, the controller
advances a ``TickClock``: one control tick = ``steps_per_tick`` optimizer
steps of every running job (the emulator owns wall-clock semantics; the
live controller owns real work).

Per tick, mirroring the emulator's event order (finish events land
strictly before the boundary they precede; scans come last):

  1. tasks that completed last tick are reported via ``env.finish`` —
     freeing their nodes and (through the env's scheduler) chaining queued
     work onto them,
  2. every ``ticks_per_release`` ticks, the env's release check frees
     dynamic blocks covered by the window's time-averaged idle,
  3. the env scans the queue and negotiates node grants with the
     ``ProvisionService`` (1 node = 1 accelerator here; on the production
     pod, 1 node = 8 chips), then first-fit schedules into free devices,
  4. beyond-paper elasticity: a *running* job can be resized into spare
     devices via the env's ``grow``/``shrink`` hooks — the controller
     checkpoints, rebuilds the mesh with a new ``data``-axis extent,
     re-places the state (checkpoints are sharding-agnostic) and resumes;
     injected preemptions are absorbed by restart-from-latest-checkpoint.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.configs.base import RunConfig
from repro.core.lifecycle import LifecycleService
from repro.core.policy import MgmtPolicy
from repro.core.provision import ProvisionService
from repro.core.tre import HTCRuntimeEnv, TickClock
from repro.data.synthetic import synthetic_batches
from repro.models.lm import LM
from repro.train import checkpoint as ckpt
from repro.train.train_step import build_train_step


@dataclass
class TrainTask:
    """One HTC job: train ``rcfg`` for ``num_steps`` on ``nodes`` devices."""
    name: str
    rcfg: RunConfig
    nodes: int
    num_steps: int
    ckpt_dir: str
    # estimated duration in control ticks (set by the controller at submit;
    # the env records it as a release reservation so backfill scheduling
    # has a profile to work against — restarts make it stale, which the
    # backfill scheduler treats conservatively)
    runtime: float | None = None
    # ---- runtime state ----
    steps_done: int = 0
    alloc: int = 0                    # devices currently assigned
    losses: list = field(default_factory=list)
    resizes: int = 0
    restarts: int = 0

    @property
    def done(self) -> bool:
        return self.steps_done >= self.num_steps


class ElasticController:
    def __init__(self, *, policy: MgmtPolicy, provision: ProvisionService,
                 tre_name: str = "train-tre", devices=None,
                 steps_per_tick: int = 10, ticks_per_release: int = 5,
                 elastic_grow: bool = True,
                 lifecycle: LifecycleService | None = None, scheduler=None):
        self.devices = list(devices if devices is not None else jax.devices())
        self.clock = TickClock()
        self.env = HTCRuntimeEnv(
            tre_name, provision=provision, clock=self.clock,
            launch=self._launch, policy=policy, lifecycle=lifecycle,
            scheduler=scheduler, max_nodes=len(self.devices))
        self.steps_per_tick = steps_per_tick
        self.ticks_per_release = ticks_per_release
        self.elastic_grow = elastic_grow
        self.running: list[TrainTask] = []
        self.finished: list[TrainTask] = []
        self._done_last_tick: list[TrainTask] = []

    # ----------------------------------------------------------- plumbing
    @property
    def name(self) -> str:
        return self.env.name

    @property
    def queue(self) -> list[TrainTask]:
        return self.env.queue

    @property
    def owned(self) -> int:
        return self.env.owned

    @property
    def busy(self) -> int:
        return self.env.busy

    @property
    def free(self) -> int:
        return self.env.free

    @property
    def _tick(self) -> int:
        return int(self.clock.now())

    def submit(self, task: TrainTask) -> None:
        if task.runtime is None:
            task.runtime = math.ceil(
                (task.num_steps - task.steps_done) / self.steps_per_tick)
        self.env.submit(task)

    def _launch(self, task: TrainTask) -> None:
        task.alloc = task.nodes
        self.running.append(task)

    def _mesh_for(self, n: int):
        if n <= 1:
            return None
        # guarded raise, not assert: a mesh wider than the device pool
        # must fail loudly (under ``python -O`` jax would raise a shape
        # error much later, far from the sizing bug)
        if n > len(self.devices):
            raise RuntimeError(
                f"mesh wider than device pool: {n} > {len(self.devices)}")
        from jax.sharding import Mesh
        from repro.parallel.sharding import AXIS_DATA
        return Mesh(np.array(self.devices[:n]), (AXIS_DATA,))

    # ------------------------------------------------------------- a tick
    def _run_segment(self, task: TrainTask, fail: bool = False) -> None:
        """Run ``steps_per_tick`` steps of a task under its current mesh."""
        mesh = self._mesh_for(task.alloc)
        lm = LM(task.rcfg.model)
        step_fn, rt, opt = build_train_step(lm, task.rcfg, mesh)
        jit_step = jax.jit(step_fn, donate_argnums=(0,))
        start = ckpt.latest_step(task.ckpt_dir)
        if start is None:
            params = jax.jit(lambda k: lm.init(k)[0])(
                jax.random.key(task.rcfg.seed))
            state = opt.init(params)
            start = 0
        else:
            abs_state = opt.init_abstract(lm.init(None, abstract=True)[0])
            state, start = ckpt.restore(task.ckpt_dir, abs_state)
        batch_fn = synthetic_batches(task.rcfg, mesh)
        end = min(start + self.steps_per_tick, task.num_steps)
        for step in range(start, end):
            if fail and step == start + 1:
                task.restarts += 1
                return  # simulated preemption: resume from last checkpoint
            state, metrics = jit_step(state, batch_fn(step))
            task.losses.append(float(metrics["loss"]))
        ckpt.save(task.ckpt_dir, end, state)
        task.steps_done = end

    def tick(self, *, fail_task: str | None = None) -> None:
        """One control cycle: finishes -> release -> scan/schedule -> train."""
        k = int(self.clock.advance())
        # 1) report last tick's completions: frees nodes, chains queued work
        self._flush_done(reschedule=True)
        # 2) window-end release check on time-averaged idle (env integrates
        #    free-node time exactly; the tick is the time unit here)
        if self.ticks_per_release and k % self.ticks_per_release == 0:
            self.env.release_check()
        # 3) DSP scan: negotiate growth, then schedule queued tasks
        self.env.scan()
        # 4) beyond-paper: grow a running job into spare devices (2x max)
        if self.elastic_grow:
            for task in self.running:
                grow = task.alloc
                if self.env.free >= grow and task.alloc < 2 * task.nodes:
                    self.env.grow(task, grow)
                    task.alloc += grow
                    task.resizes += 1
        # 5) run one segment of every running job
        for task in list(self.running):
            self._run_segment(task, fail=(task.name == fail_task))
            if task.done:
                self.running.remove(task)
                self._done_last_tick.append(task)
        # 6) shrink grown jobs back when the queue needs their devices
        if self.env.queue:
            for task in self.running:
                if task.alloc > task.nodes:
                    self.env.shrink(task, task.alloc - task.nodes)
                    task.alloc = task.nodes
                    task.resizes += 1

    def _flush_done(self, *, reschedule: bool) -> None:
        for task in self._done_last_tick:
            task.alloc = 0
            self.finished.append(task)
            self.env.finish(task, reschedule=reschedule)
        self._done_last_tick.clear()

    def run(self, *, max_ticks: int = 1000, fail_at: dict | None = None) -> None:
        fail_at = dict(fail_at or {})
        while (self.env.queue or self.running or self._done_last_tick) \
                and self._tick < max_ticks:
            self.tick(fail_task=fail_at.pop(self._tick + 1, None))
        # hitting max_ticks must not strand final-tick completions in the
        # deferred list (unreported to the env = phantom busy nodes);
        # reschedule=False so the env doesn't launch queued work into a
        # driver that has stopped ticking
        self._flush_done(reschedule=False)

    def destroy(self) -> None:
        self.env.destroy()
