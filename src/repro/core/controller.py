"""Live elastic controller: DSP policies driving real JAX training jobs.

This is the bridge between the paper's resource-management layer and the
training substrate. An ``ElasticController`` is the *server* of an HTC TRE
whose jobs are JAX training runs:

  - queued tasks are scheduled first-fit onto the TRE's device allocation,
  - the same ``PolicyEngine`` used by the emulator scans the queue and
    negotiates node grants/releases with the ``ProvisionService``
    (1 node = 1 accelerator here; on the production pod, 1 node = 8 chips),
  - a *running* job can be elastically resized: the controller checkpoints,
    rebuilds the mesh with a new ``data``-axis extent, re-places the state
    (checkpoints are sharding-agnostic) and resumes,
  - injected preemptions are absorbed by restart-from-latest-checkpoint.

Control runs in *steps* rather than wall seconds: one control tick =
``steps_per_tick`` optimizer steps of every running job (the emulator owns
wall-clock semantics; the live controller owns real work).
"""
from __future__ import annotations

import math
import os
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.configs.base import RunConfig
from repro.core.policy import MgmtPolicy, PolicyEngine
from repro.core.provision import ProvisionService
from repro.core.scheduling import first_fit
from repro.data.synthetic import synthetic_batches
from repro.models.lm import LM
from repro.train import checkpoint as ckpt
from repro.train.train_step import build_train_step


@dataclass
class TrainTask:
    """One HTC job: train ``rcfg`` for ``num_steps`` on ``nodes`` devices."""
    name: str
    rcfg: RunConfig
    nodes: int
    num_steps: int
    ckpt_dir: str
    # ---- runtime state ----
    steps_done: int = 0
    alloc: int = 0                    # devices currently assigned
    losses: list = field(default_factory=list)
    resizes: int = 0
    restarts: int = 0

    @property
    def done(self) -> bool:
        return self.steps_done >= self.num_steps


class ElasticController:
    def __init__(self, *, policy: MgmtPolicy, provision: ProvisionService,
                 tre_name: str = "train-tre", devices=None,
                 steps_per_tick: int = 10, ticks_per_release: int = 5,
                 elastic_grow: bool = True):
        self.policy_engine = PolicyEngine(policy)
        self.provision = provision
        self.name = tre_name
        self.devices = list(devices if devices is not None else jax.devices())
        self.steps_per_tick = steps_per_tick
        self.ticks_per_release = ticks_per_release
        self.elastic_grow = elastic_grow
        self.queue: list[TrainTask] = []
        self.running: list[TrainTask] = []
        self.finished: list[TrainTask] = []
        self.owned = policy.initial
        ok = provision.request(tre_name, policy.initial, 0.0)
        assert ok, "initial resources rejected"
        self._tick = 0
        self._idle_acc = 0.0

    # ----------------------------------------------------------- plumbing
    @property
    def busy(self) -> int:
        return sum(t.alloc for t in self.running)

    @property
    def free(self) -> int:
        return self.owned - self.busy

    def submit(self, task: TrainTask) -> None:
        self.queue.append(task)

    def _mesh_for(self, n: int):
        if n <= 1:
            return None
        assert n <= len(self.devices), (n, len(self.devices))
        from jax.sharding import Mesh
        from repro.parallel.sharding import AXIS_DATA
        return Mesh(np.array(self.devices[:n]), (AXIS_DATA,))

    # ------------------------------------------------------------- a tick
    def _run_segment(self, task: TrainTask, fail: bool = False) -> None:
        """Run ``steps_per_tick`` steps of a task under its current mesh."""
        mesh = self._mesh_for(task.alloc)
        lm = LM(task.rcfg.model)
        step_fn, rt, opt = build_train_step(lm, task.rcfg, mesh)
        jit_step = jax.jit(step_fn, donate_argnums=(0,))
        start = ckpt.latest_step(task.ckpt_dir)
        if start is None:
            params = jax.jit(lambda k: lm.init(k)[0])(
                jax.random.key(task.rcfg.seed))
            state = opt.init(params)
            start = 0
        else:
            abs_state = opt.init_abstract(lm.init(None, abstract=True)[0])
            state, start = ckpt.restore(task.ckpt_dir, abs_state)
        batch_fn = synthetic_batches(task.rcfg, mesh)
        end = min(start + self.steps_per_tick, task.num_steps)
        for step in range(start, end):
            if fail and step == start + 1:
                task.restarts += 1
                return  # simulated preemption: resume from last checkpoint
            state, metrics = jit_step(state, batch_fn(step))
            task.losses.append(float(metrics["loss"]))
        ckpt.save(task.ckpt_dir, end, state)
        task.steps_done = end

    def tick(self, *, fail_task: str | None = None) -> None:
        """One control cycle: schedule -> train -> negotiate resources."""
        self._tick += 1
        # 1) DSP scan: the queue's demand may call for more resources
        req = self.policy_engine.scan([t.nodes for t in self.queue], self.owned)
        if req > 0:
            cap = len(self.devices) - self.owned
            req = min(req, cap)
            if req > 0 and self.provision.request(self.name, req, self._tick):
                self.policy_engine.granted(req)
                self.owned += req
        # 2) first-fit schedule queued tasks onto free devices
        for task in first_fit(self.queue, self.free):
            self.queue.remove(task)
            task.alloc = task.nodes
            self.running.append(task)
        # 3) beyond-paper: grow a running job into spare devices (2x max)
        if self.elastic_grow:
            for task in self.running:
                grow = task.alloc
                if self.free >= grow and task.alloc < 2 * task.nodes:
                    task.alloc += grow
                    task.resizes += 1
        # 4) run one segment of every running job
        for task in list(self.running):
            self._run_segment(task, fail=(task.name == fail_task))
            if task.done:
                self.running.remove(task)
                self.finished.append(task)
                task.alloc = 0
        # 5) shrink grown jobs back when the queue needs their devices
        if self.queue:
            for task in self.running:
                if task.alloc > task.nodes:
                    task.alloc = task.nodes
                    task.resizes += 1
        # 6) hourly-analogue release check on averaged idle
        self._idle_acc += self.free
        if self._tick % self.ticks_per_release == 0:
            idle_avg = self._idle_acc / self.ticks_per_release
            rel = self.policy_engine.release_check(
                int(min(idle_avg, self.free)))
            if rel > 0:
                self.provision.release(self.name, rel, self._tick)
                self.owned -= rel
            self._idle_acc = 0.0

    def run(self, *, max_ticks: int = 1000, fail_at: dict | None = None) -> None:
        fail_at = dict(fail_at or {})
        while (self.queue or self.running) and self._tick < max_ticks:
            self.tick(fail_task=fail_at.pop(self._tick + 1, None))

    def destroy(self) -> None:
        self.provision.destroy(self.name, self._tick)
        self.owned = 0
