"""Multi-tenant resource provider: shared capacity, admission queueing,
coordinated provisioning.

Paper mapping
-------------
§3.1.2 gives the cloud provider a *resource provision service* that the
paper models as grant-or-reject against a single consolidated platform;
§3.2.2.3 fixes its provision policy to "grant if available, else reject,
releases passively reclaimed" (that policy is ``ProvisionService``, kept
bit-for-bit). This module generalizes that service to the multi-tenant
form the paper's headline question needs — *do providers benefit from the
economies of scale?* is only answerable when one platform hosts N service
providers:

  - **finite capacity shared by N TREs** with per-TRE *quotas* (hard caps)
    and *reservations* (guaranteed minimums) — the §3.2.2.3 provision
    policy parameterized per tenant instead of globally,
  - an **admission queue**: a DR1/DR2 request that cannot be granted now
    parks instead of being dropped, and is re-granted when capacity frees
    (a release triggers a drain; the grant lands through the request's
    ``on_grant`` callback, so a ``RuntimeEnv``'s queued grow applies the
    moment another tenant shrinks — §3.2.2.3's "the resource provision
    service only passively receives requests" upgraded to an actively
    completing broker),
  - a pluggable **coordination policy** deciding which parked requests are
    served when capacity is contended. ``first-come`` reproduces the
    paper's arrival-order semantics (FIFO, head-of-line blocking on global
    capacity); ``coordinated`` is the PhoenixCloud-style policy
    (arXiv:1006.1401): requests pending at an arbitration point are
    decided *together* — most urgent first (the §3.2.2.1 ratio of
    obtaining resources is carried on each request as ``priority``), and a
    backlog wider than the remaining capacity is water-filled across
    tenants rather than served whole-block.

Requests complete through ``on_grant(offer, t) -> accepted``: the
requester re-validates its deficit at grant time (its queue may have
drained while parked), commits its own bookkeeping for the accepted
amount, and the provider opens the lease for exactly that. A stale request
(accepted == 0) is dropped, not granted — the admission queue can never
push nodes onto a tenant that no longer wants them.
"""
from __future__ import annotations

from typing import Callable, Mapping, Sequence

from repro.core.provision import ProvisionService, ResourceRequest

_UNBOUNDED = 1 << 31


class CoordinationPolicy:
    """Arbitration strategy over the admission queue. ``arbitrate`` returns
    ``(request, offer)`` grants that are *jointly* feasible: offers must
    respect per-TRE headroom and global free capacity as if applied in
    order (the provider applies the batch without re-planning, clamping
    only against what requesters decline)."""

    name: str = ""

    def arbitrate(self, pending: Sequence[ResourceRequest],
                  provider: "ResourceProvider", t: float,
                  ) -> list[tuple[ResourceRequest, int]]:
        raise NotImplementedError

    def direct_claim(self, pending: Sequence[ResourceRequest],
                     provider: "ResourceProvider", tre: str,
                     t: float) -> int:
        """Free capacity a *direct* grant-or-reject request by ``tre``
        (lifecycle creation, DRP end users, scripted contention) must
        leave untouched for parked elder requests. The direct path cannot
        queue, so without this a newcomer's burst silently overtakes
        every request this policy would have served first — the elder's
        claim must be charged against the pool before the newcomer is
        judged against it. 0 = no parked request has a prior claim."""
        return 0


class FirstComePolicy(CoordinationPolicy):
    """Arrival-order service (the paper's §3.2.2.3 semantics): walk the
    queue FIFO, grant whole requests while they fit. A head blocked on
    *shared* capacity — including capacity set aside by other tenants'
    undrawn reservations — blocks everything behind it (FIFO-fair: later
    requests cannot overtake it into the pool it is waiting for), but a
    *divisible* blocked head (DR1 backlog, ``min_useful`` below the
    available pool) is served whatever the pool has rather than idling it
    — work-conserving FIFO; a DR1 deficit can exceed what the platform
    could ever grant (the tenant's own allocation counts against
    capacity), and whole-or-nothing service would park it, and the fleet
    behind it, forever. A head blocked only by its own quota is skipped,
    so one capped tenant cannot starve the fleet."""

    name = "first-come"

    def arbitrate(self, pending, provider, t):
        grants: list[tuple[ResourceRequest, int]] = []
        overlay = dict(provider.allocated)
        for req in pending:
            h = provider.headroom(req.tre, overlay=overlay)
            if req.nodes <= h:
                grants.append((req, req.nodes))
                overlay[req.tre] = overlay.get(req.tre, 0) + req.nodes
            else:
                # a divisible blocked request still takes what its headroom
                # allows (work-conserving — for a quota-capped tenant that
                # is everything up to its quota)
                if h >= max(req.min_useful, 1):
                    grants.append((req, h))
                    overlay[req.tre] = overlay.get(req.tre, 0) + h
                q = provider.quotas.get(req.tre)
                quota_room = (_UNBOUNDED if q is None
                              else q - overlay.get(req.tre, 0))
                if req.nodes - h > quota_room:
                    continue                 # own-quota-capped: skip
                break                        # shared-pool-blocked: FIFO-fair
        return grants

    def direct_claim(self, pending, provider, tre, t):
        """FIFO-fair against the direct path too: every parked request is
        an elder of a direct request arriving now, so its whole remaining
        shared-pool entitlement is spoken for. A head blocked only by its
        own quota claims just the room its quota leaves (the fleet is not
        starved by it — mirroring :meth:`arbitrate`'s skip); the
        requesting tenant's own parked request never blocks its own
        direct path (same tenant, nothing is overtaken)."""
        claim = 0
        for req in pending:
            if req.tre == tre:
                continue
            need = req.nodes
            q = provider.quotas.get(req.tre)
            if q is not None:
                need = min(need,
                           max(q - provider.allocated.get(req.tre, 0), 0))
            claim += need
        return claim


class CoordinatedPolicy(CoordinationPolicy):
    """PhoenixCloud-style coordinated provisioning (arXiv:1006.1401):
    simultaneous requests are arbitrated as one decision. Pass 1 serves
    whole requests in urgency order (highest §3.2.2.1 obtaining ratio
    first, FIFO tiebreak). Pass 2 water-fills the remaining capacity
    across every tenant still waiting — ascending remaining need, each
    gets at most an equal share of what is left — so a contended platform
    trims burst requests to fair partial grants instead of parking whole
    blocks behind a wide head. Partially served requests stay queued for
    the next drain."""

    name = "coordinated"

    #: a request parked longer than this is *starving*: the arbiter then
    #: sets aside (reserves) its useful floor out of the free capacity so
    #: younger requests cannot consume what is accumulating for it.
    #: Without it a contended platform regrants every released node to
    #: small requests instantly, so a wide DR2 (a job as wide as a whole
    #: original machine) can wait unboundedly — and the starved tenant's
    #: stretched lifetime bills its whole configuration for the duration.
    #: The reservation is conservative-backfill at the provider level:
    #: the elder's claim hardens, everyone else keeps flowing through the
    #: remaining capacity.
    starvation_s = 3600.0

    #: phantom overlay tenant charging blocked elders' reservations against
    #: free capacity during arbitration (never a real allocation)
    _RESERVE = "\x00starving-reserve"

    def __init__(self, starvation_s: float | None = None):
        if starvation_s is not None:
            self.starvation_s = starvation_s

    def arbitrate(self, pending, provider, t):
        grants: list[tuple[ResourceRequest, int]] = []
        overlay = dict(provider.allocated)
        served: set[int] = set()
        # pass 0: starving elders, oldest first — serve what fits the
        # useful floor; a still-blocked elder reserves its floor
        elders = sorted((r for r in pending if t - r.t >= self.starvation_s),
                        key=lambda r: (r.t, r.seq))
        for req in elders:
            offer = min(req.nodes, provider.headroom(req.tre, overlay=overlay))
            floor = max(req.min_useful, 1)
            if offer >= floor:
                grants.append((req, offer))
                overlay[req.tre] = overlay.get(req.tre, 0) + offer
            else:
                q = provider.quotas.get(req.tre)
                if q is not None and floor > q - overlay.get(req.tre, 0):
                    # own-quota-capped: accumulating shared capacity can
                    # never satisfy it — don't reserve the pool for it
                    continue
                overlay[self._RESERVE] = (overlay.get(self._RESERVE, 0)
                                          + floor)
            served.add(req.seq)
        rest = [r for r in pending if r.seq not in served]
        # pass 1: whole grants, most urgent first (§3.2.2.1 ratio), FIFO
        # tiebreak
        rest.sort(key=lambda r: (-r.priority, r.t, r.seq))
        waiting: list[ResourceRequest] = []
        for req in rest:
            if req.nodes <= provider.headroom(req.tre, overlay=overlay):
                grants.append((req, req.nodes))
                overlay[req.tre] = overlay.get(req.tre, 0) + req.nodes
            else:
                waiting.append(req)
        # pass 2: water-fill the leftovers — smallest remaining need
        # first, equal shares of the remaining free capacity, but never
        # below a request's useful floor (a partial DR2 would idle-thrash)
        waiting.sort(key=lambda r: (r.nodes, r.t, r.seq))
        for i, req in enumerate(waiting):
            share = provider.free_capacity(overlay=overlay) // (len(waiting) - i)
            offer = min(req.nodes, provider.headroom(req.tre, overlay=overlay),
                        share)
            if offer >= max(req.min_useful, 1):
                grants.append((req, offer))
                overlay[req.tre] = overlay.get(req.tre, 0) + offer
        return grants

    def direct_claim(self, pending, provider, tre, t):
        """Coordinated arbitration re-plans every drain, so younger
        parked requests hold no hard claim against a direct newcomer —
        but a *starving* elder's useful floor is already being reserved
        out of free capacity at every arbitration (pass 0), and a direct
        grant must honor the same reservation or it drains exactly the
        capacity accumulating for the elder."""
        claim = 0
        for req in pending:
            if req.tre == tre or t - req.t < self.starvation_s:
                continue
            floor = max(req.min_useful, 1)
            q = provider.quotas.get(req.tre)
            if q is not None and \
                    floor > max(q - provider.allocated.get(req.tre, 0), 0):
                continue                     # own-quota-capped: no claim
            claim += floor
        return claim


COORDINATION_POLICIES: dict[str, Callable[[], CoordinationPolicy]] = {
    "first-come": FirstComePolicy,
    "coordinated": CoordinatedPolicy,
}


def resolve_coordination(spec) -> CoordinationPolicy:
    """Accept a policy instance, a registry key, or None (= first-come)."""
    if spec is None:
        return FirstComePolicy()
    if isinstance(spec, CoordinationPolicy):
        return spec
    try:
        return COORDINATION_POLICIES[spec]()
    except KeyError:
        raise ValueError(
            f"unknown coordination policy {spec!r}; registered: "
            f"{sorted(COORDINATION_POLICIES)}") from None


class ResourceProvider(ProvisionService):
    """Multi-tenant provision service: finite capacity shared by N TREs,
    per-TRE quota/reservation policies, an admission queue for deferred
    DR1/DR2 requests, and pluggable cross-tenant coordination."""

    def __init__(self, capacity: int | None = None, *,
                 coordination=None,
                 quotas: Mapping[str, int] | None = None,
                 reservations: Mapping[str, int] | None = None):
        super().__init__(capacity)
        self.policy = resolve_coordination(coordination)
        self.quotas = dict(quotas or {})
        self.reservations = dict(reservations or {})
        if capacity is not None and sum(self.reservations.values()) > capacity:
            raise ValueError("reservations exceed capacity")
        self.admission_queue: list[ResourceRequest] = []
        self._seq = 0
        self._draining = False

    # ----------------------------------------------------------- headroom
    def free_capacity(self, *, overlay: Mapping[str, int] | None = None) -> int:
        alloc = self.allocated if overlay is None else overlay
        if self.capacity is None:
            return _UNBOUNDED
        return max(self.capacity - sum(alloc.values()), 0)

    def headroom(self, tre: str, *,
                 overlay: Mapping[str, int] | None = None) -> int:
        """Nodes grantable to ``tre`` right now: global free capacity minus
        other tenants' undrawn reservations (a tenant can always draw its
        own), capped by the tenant's quota."""
        alloc = self.allocated if overlay is None else overlay
        mine = alloc.get(tre, 0)
        if self.capacity is None:
            room = _UNBOUNDED
        else:
            free = self.capacity - sum(alloc.values())
            debt = sum(max(0, r - alloc.get(name, 0))
                       for name, r in self.reservations.items() if name != tre)
            room = free - debt
            own = self.reservations.get(tre, 0)
            room = max(room, min(own - mine, free))
        q = self.quotas.get(tre)
        if q is not None:
            room = min(room, q - mine)
        return max(int(room), 0)

    # ------------------------------------------------------------ actions
    def request(self, tre: str, n: int, t: float, *, count_adjust=True) -> bool:
        """Direct grant-or-reject (lifecycle creation, DRP end users) under
        the per-tenant quota/reservation policy, ARBITRATION-AWARE: a
        direct request cannot queue, so it is judged against the headroom
        left after parked elder requests' prior claims
        (:meth:`CoordinationPolicy.direct_claim`) — granting against live
        headroom alone would let a creation or DRP burst overtake a FIFO
        head (or a starving coordinated elder) that queued first. The
        tenant's own undrawn reservation stays senior to any parked
        claim: a guaranteed minimum is exactly the capacity no elder can
        speak for."""
        if n > 0:
            room = self.headroom(tre)
            if n > room:
                return False
            if self.admission_queue:
                claim = self.policy.direct_claim(
                    tuple(self.admission_queue), self, tre, t)
                if claim > 0:
                    free = self.free_capacity()
                    own = min(max(self.reservations.get(tre, 0)
                                  - self.allocated.get(tre, 0), 0), free)
                    if n > max(room - claim, own):
                        return False
        return super().request(tre, n, t, count_adjust=count_adjust)

    def submit_request(self, tre: str, n: int, t: float, *,
                       on_grant, count_adjust: bool = True,
                       priority: float = 0.0,
                       min_useful: int = 1) -> ResourceRequest:
        """Park the request in the admission queue and drain. An
        uncontended fitting request is granted within this call (status
        ``granted``); a deferred one stays ``queued`` and completes through
        ``on_grant`` when a release or amend frees its way."""
        req = ResourceRequest(tre, n, t, on_grant, count_adjust, priority,
                              min_useful)
        req.seq = self._seq
        self._seq += 1
        if n <= 0:
            req.status = "granted"
            return req
        req.status = "queued"
        self.admission_queue.append(req)
        self._drain(t)
        return req

    def amend(self, req: ResourceRequest, n: int, t: float,
              min_useful: int = 1,
              priority: float | None = None) -> ResourceRequest:
        """Refresh a queued request with the requester's live deficit and
        urgency (the env re-scans its queue every scan tick; a parked
        request must track the current need and priority, not the state
        at submission — coordinated arbitration orders by it). ``n <= 0``
        cancels.

        A *priority-only* change re-drains too: under ``coordinated``
        arbitration the urgency ordering IS the grant decision, so an
        urgency bump must be able to unblock a parked request right away —
        not sit until an unrelated release happens to trigger a drain
        (e.g. a request declined in an earlier drain whose tenant's
        backlog has since refilled at the same width)."""
        if req.status != "queued":
            return req
        if n <= 0:
            self.cancel(req, t)
            return req
        changed = (n != req.nodes or min_useful != req.min_useful
                   or (priority is not None and priority != req.priority))
        req.nodes = n
        req.min_useful = min_useful
        if priority is not None:
            req.priority = priority
        if changed:
            self._drain(t)
        return req

    def cancel(self, req: ResourceRequest, t: float | None = None, *,
               drain: bool = True) -> None:
        """Withdraw a parked request. A cancelled head unblocks everything
        FIFO-fair behind it, so the queue re-drains immediately — at ``t``
        (callers should pass the current time; the request's submission
        time is a last resort). ``drain=False`` detaches without serving
        anyone — for teardown, where a grant would open a lease that is
        destroyed moments later."""
        was_queued = req in self.admission_queue
        if was_queued:
            self.admission_queue.remove(req)
        super().cancel(req)
        if was_queued and drain:
            if t is None:
                # never backdate a drain: a grant stamped before already-
                # recorded allocation events would overbill the follower
                # and break the alloc curve's time order. With no
                # allocation event recorded yet the request's own
                # submission time is the only defensible floor
                last = self._alloc_curve[-1][0] if self._alloc_curve \
                    else req.t
                t = max(req.t, last)
            self._drain(t)

    def release(self, tre: str, n: int, t: float, *, count_adjust=True) -> None:
        super().release(tre, n, t, count_adjust=count_adjust)
        self._drain(t)        # freed capacity completes parked requests

    # -------------------------------------------------------------- drain
    def _drain(self, t: float) -> None:
        """Serve the admission queue until the coordination policy has no
        feasible grant left. Re-entrancy guarded: an ``on_grant`` callback
        may schedule work whose side effects land back here."""
        if self._draining:
            return
        self._draining = True
        declined: set[int] = set()
        try:
            while self.admission_queue:
                grants = self.policy.arbitrate(
                    tuple(self.admission_queue), self, t)
                if not grants:
                    break
                progress = False
                for req, offer in grants:
                    if req.seq in declined or req.status != "queued":
                        continue
                    offer = min(offer, req.nodes, self.headroom(req.tre))
                    if offer < max(req.min_useful, 1):
                        continue
                    take = req.on_grant(offer, t)
                    if take > 0:
                        ok = ProvisionService.request(
                            self, req.tre, take, t,
                            count_adjust=req.count_adjust)
                        if not ok:
                            # the offer was clamped against live headroom
                            # just above — a failure here means the ledger
                            # and the arbitration overlay disagree, and
                            # granting anyway would oversubscribe capacity
                            raise RuntimeError(
                                f"drain grant exceeds capacity: "
                                f"{take} nodes to {req.tre!r} at t={t}")
                        req.granted += take
                        progress = True
                    if take == 0:
                        # declined: the requester's live floor may have
                        # risen past the offer (or its need vanished).
                        # Keep it parked — FIFO position and starvation
                        # age survive; the tenant's next scan amends it
                        # to the live deficit or cancels it outright
                        declined.add(req.seq)
                    elif take < offer or offer == req.nodes:
                        # satisfied (possibly for less than asked: done)
                        self.admission_queue.remove(req)
                        req.status = "granted"
                    else:
                        req.nodes -= take           # partial: stay queued
                if not progress:
                    break
        finally:
            self._draining = False
