"""The paper's contribution: the Dynamic Service Provision (DSP) model.

- ``types``      Job / Workload — the unit of MTC/HTC work
- ``policy``     resource-management policies (B, R, DR1/DR2 semantics)
- ``provision``  grant-or-reject provision service + lease billing
- ``lifecycle``  TRE state machine (CSF lifecycle management service)
- ``scheduling`` first-fit (HTC), FCFS (MTC) and conservative-backfill
                 job schedulers, pluggable via ``SCHEDULERS``
- ``tre``        the unified RuntimeEnv control plane: queue + trigger
                 monitor + policy negotiation + idle accounting, shared by
                 the emulator and the live controller through Clock/driver
                 protocols
- ``registry``   pluggable System registry: usage models register by name
- ``controller`` the live driver: DSP decisions on real elastic JAX jobs
"""
from repro.core.lifecycle import LifecycleService, TREState  # noqa: F401
from repro.core.policy import MgmtPolicy, PolicyEngine  # noqa: F401
from repro.core.provision import ProvisionService  # noqa: F401
from repro.core.registry import (  # noqa: F401
    System, available_systems, get_system, register_system,
)
from repro.core.tre import (  # noqa: F401
    Clock, HTCRuntimeEnv, MTCRuntimeEnv, RuntimeEnv, TickClock,
)
from repro.core.types import Job, Workload  # noqa: F401
