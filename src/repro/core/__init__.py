"""The paper's contribution: the Dynamic Service Provision (DSP) model.

- ``types``      Job / Workload — the unit of MTC/HTC work
- ``policy``     resource-management policies (B, R, DR1/DR2 semantics)
- ``provision``  grant-or-reject provision service + lease billing
- ``lifecycle``  TRE state machine (CSF lifecycle management service)
- ``scheduling`` first-fit (HTC) and FCFS (MTC) job schedulers
- ``controller`` bridges DSP decisions to live elastic JAX training jobs
"""
from repro.core.lifecycle import LifecycleService, TREState  # noqa: F401
from repro.core.policy import MgmtPolicy, PolicyEngine  # noqa: F401
from repro.core.provision import ProvisionService  # noqa: F401
from repro.core.types import Job, Workload  # noqa: F401
