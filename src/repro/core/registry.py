"""Pluggable system registry: usage models as plugins, not ``elif``s.

The paper compares four usage models (DCS / SSP / DRP / DawningCloud); the
PhoenixCloud and scientific-communities follow-ups extend exactly this axis
with new coordinated policies and workload mixes. A ``System`` encapsulates
everything one usage model needs to run over consolidated workloads —
which runner to build per workload, and how its resource consumption is
billed — so a new scenario is a ``@register_system("name")`` class, not an
edit to ``run_system``.

This module is driver-agnostic: it defines only the registry mechanism and
the abstract ``System``. The emulated systems live in
``repro.sim.systems``; a live-serving scenario could register here just as
well.
"""
from __future__ import annotations

from typing import Any


class System:
    """One usage model. Subclass and register::

        @register_system("myscenario")
        class MyScenario(System):
            def build(self, ctx, workload): ...
            def node_hours(self, ctx, runner, end): ...

    ``ctx`` is whatever context object the experiment runner passes (the
    emulator uses ``repro.sim.systems.EmulationContext``: sim clock,
    provision + lifecycle services, per-workload policies and scheduler
    overrides).
    """

    name: str = ""
    #: route runs through a multi-tenant ``ResourceProvider`` with this
    #: coordination policy ("first-come" / "coordinated" / a
    #: ``CoordinationPolicy`` instance); None = the paper's plain
    #: grant-or-reject ``ProvisionService``
    coordination: Any = None

    def build(self, ctx: Any, workload: Any) -> Any:
        """Create and wire this system's runner for one workload."""
        raise NotImplementedError

    # ---- multi-tenant platform defaults (used when the caller does not
    # ---- pass capacity/quotas/reservations explicitly) ----
    def default_capacity(self, workloads: Any, policies: Any) -> int | None:
        """Shared platform size for these tenants (None = unbounded)."""
        return None

    def default_quotas(self, workloads: Any, policies: Any) -> dict | None:
        """Per-TRE hard allocation caps (None = uncapped)."""
        return None

    def default_reservations(self, workloads: Any) -> dict | None:
        """Per-TRE guaranteed minimum capacity (None = none)."""
        return None

    def finalize(self, ctx: Any, runner: Any, end: float) -> None:
        """Hook after the run completes (e.g. destroy surviving TREs)."""

    def node_hours(self, ctx: Any, runner: Any, end: float) -> float:
        """Billed node*hours for this runner's workload (paper §4.3)."""
        raise NotImplementedError


_REGISTRY: dict[str, System] = {}


def register_system(name: str, *, replace: bool = False):
    """Class decorator: instantiate and register a ``System`` under ``name``."""

    def deco(cls: type[System]) -> type[System]:
        if name in _REGISTRY and not replace:
            raise ValueError(f"system {name!r} already registered "
                             f"(pass replace=True to override)")
        inst = cls()
        inst.name = name
        _REGISTRY[name] = inst
        return cls

    return deco


def _ensure_builtin_systems() -> None:
    """The built-in usage models register as an import side effect of
    ``repro.sim.systems`` (emulated) and ``repro.serve.fleet`` (the
    tick-driven serving fleet); make the accessors self-sufficient so
    ``from repro.core import available_systems`` works standalone."""
    import repro.serve.fleet  # noqa: F401
    import repro.sim.systems  # noqa: F401


def get_system(name: str) -> System:
    _ensure_builtin_systems()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown system {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def available_systems() -> tuple[str, ...]:
    _ensure_builtin_systems()
    return tuple(sorted(_REGISTRY))
