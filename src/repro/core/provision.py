"""Resource provision service (paper §3.1.2, §3.2.2.3) with lease accounting.

Grant-or-reject provisioning plus the metrics the paper evaluates:
  - per-TRE resource consumption in node*hours, billed per *started* hour
    (the paper's one-hour leasing time unit, §4.4(2)),
  - the provider's total + peak allocation ("nodes per hour", Fig 13),
  - accumulated node-adjustment counts and the setup overhead they imply
    (15.743 s per adjusted node, §4.5.4).

Leases are block-structured: every grant opens a block, releases close the
newest blocks first (matching ``PolicyEngine``'s LIFO block release), and a
partial release splits a block so billing stays exact.

Two request paths exist since the multi-tenant refactor:

  - :meth:`ProvisionService.request` — the raw grant-or-reject ledger entry
    (lifecycle creation, DRP end-user leases, internal lease opening);
  - :meth:`ProvisionService.submit_request` — the negotiation path used by
    ``RuntimeEnv`` DR1/DR2 scans. It carries a :class:`ResourceRequest`
    whose ``on_grant`` callback lets the provider complete a grant *later*
    (``repro.core.provider.ResourceProvider`` parks rejected requests in an
    admission queue and re-grants on release). The base class keeps the
    paper's plain provision policy: grant now if available, else reject —
    nothing is ever queued, so the behavior is bit-for-bit the pre-refactor
    grant-or-reject bool.

The accounting hot paths (:meth:`node_hours`, :meth:`peak_nodes_per_hour`)
are NumPy-vectorized over columnar lease/event arrays — at fleet scale
(``benchmarks/scale_curve.py`` sweeps N providers x seeds) they dominate
the post-simulation cost. The per-lease Python reference implementations
are kept as ``*_loop`` for the benchmark comparison and equivalence tests.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

SETUP_COST_PER_NODE_S = 15.743   # measured in the paper's real test
BILL_UNIT_S = 3600.0             # one-hour leasing time unit


@dataclass
class Lease:
    tre: str
    nodes: int
    t0: float
    t1: float = -1.0             # -1 = still open

    def billed_hours(self, now: float) -> float:
        end = self.t1 if self.t1 >= 0 else now
        return math.ceil(max(end - self.t0, 1e-9) / BILL_UNIT_S)

    def billed_node_hours(self, now: float) -> float:
        return self.nodes * self.billed_hours(now)


@dataclass
class AdjustEvent:
    t: float
    tre: str
    delta: int                    # +granted / -released


# grant callback: (offered nodes, time) -> nodes accepted. The callee must
# commit its own bookkeeping for the returned amount; the provider opens the
# lease for exactly what was accepted.
GrantCallback = Callable[[int, float], int]


@dataclass
class ResourceRequest:
    """One DR1/DR2 negotiation in flight against the provision service.

    ``status`` lifecycle: ``granted`` (completed, possibly for less than
    asked if the requester's need shrank), ``queued`` (parked in a
    multi-tenant admission queue awaiting capacity), ``rejected`` (plain
    grant-or-reject provision with no queue), ``cancelled`` (withdrawn by
    the requester or stale at grant time).
    """
    tre: str
    nodes: int
    t: float                       # submission time (FIFO age — amends keep it)
    on_grant: GrantCallback
    count_adjust: bool = True
    priority: float = 0.0          # requester urgency (ratio of obtaining
    # resources, §3.2.2.1) — coordinated arbitration orders by it
    min_useful: int = 1            # smallest grant that lets the requester
    # progress: 1 for a divisible DR1 backlog, the whole deficit for an
    # indivisible DR2 (a single job wider than everything owned)
    status: str = "pending"
    granted: int = 0               # total nodes granted so far
    seq: int = field(default=0, compare=False)   # FIFO tiebreak


class ProvisionService:
    """The CSF resource provision service. ``capacity=None`` = unbounded
    (DRP peak measurement); DawningCloud runs use the platform size."""

    def __init__(self, capacity: int | None = None):
        self.capacity = capacity
        self.allocated: dict[str, int] = {}
        self.open_leases: dict[str, list[Lease]] = {}
        self.closed_leases: list[Lease] = []
        self.adjust_events: list[AdjustEvent] = []
        # preemption ledger: nodes reclaimed from (negative delta) and
        # resumed to (positive delta) preemptible tenants — the lease
        # checkpoint/resume bookkeeping the train+serve consolidation
        # bench audits (how much churn did trough-soaking cost?)
        self.preempt_events: list[AdjustEvent] = []
        self.resume_events: list[AdjustEvent] = []
        self._alloc_curve: list[tuple[float, int]] = [(0.0, 0)]
        # columnar mirror of closed_leases (appended in lockstep by
        # _close) so the vectorized accounting never walks Lease objects
        self._tre_ids: dict[str, int] = {}
        self._c_tre: list[int] = []
        self._c_t0: list[float] = []
        self._c_t1: list[float] = []
        self._c_nodes: list[int] = []
        self._c_arrays: tuple | None = None   # ndarray cache of the above

    # ------------------------------------------------------------ state
    @property
    def total_allocated(self) -> int:
        return sum(self.allocated.values())

    def available(self) -> int | None:
        if self.capacity is None:
            return None
        return self.capacity - self.total_allocated

    def _record(self, t: float):
        self._alloc_curve.append((t, self.total_allocated))

    def _tre_id(self, tre: str) -> int:
        return self._tre_ids.setdefault(tre, len(self._tre_ids))

    def _close(self, lease: Lease) -> None:
        self.closed_leases.append(lease)
        self._c_tre.append(self._tre_id(lease.tre))
        self._c_t0.append(lease.t0)
        self._c_t1.append(lease.t1)
        self._c_nodes.append(lease.nodes)
        self._c_arrays = None

    def _closed_arrays(self):
        """ndarray view of the closed-lease columns, cached between closes
        — metric queries (one per tenant + one total per experiment) must
        not re-convert the whole ledger every call."""
        if self._c_arrays is None:
            self._c_arrays = (np.asarray(self._c_tre),
                              np.asarray(self._c_t0),
                              np.asarray(self._c_t1),
                              np.asarray(self._c_nodes, dtype=float))
        return self._c_arrays

    # ---------------------------------------------------------- actions
    def request(self, tre: str, n: int, t: float, *, count_adjust=True) -> bool:
        """Grant ``n`` nodes to ``tre`` or reject (provision policy)."""
        if n <= 0:
            return True
        if self.capacity is not None and self.total_allocated + n > self.capacity:
            return False
        self.allocated[tre] = self.allocated.get(tre, 0) + n
        self.open_leases.setdefault(tre, []).append(Lease(tre, n, t))
        if count_adjust:
            self.adjust_events.append(AdjustEvent(t, tre, n))
        self._record(t)
        return True

    def submit_request(self, tre: str, n: int, t: float, *,
                       on_grant: GrantCallback, count_adjust: bool = True,
                       priority: float = 0.0,
                       min_useful: int = 1) -> ResourceRequest:
        """Negotiation path for DR1/DR2 scans: the paper's plain provision
        policy — grant immediately if available, else reject. Nothing
        queues here; ``repro.core.provider.ResourceProvider`` overrides
        this with admission queueing and coordinated arbitration."""
        req = ResourceRequest(tre, n, t, on_grant, count_adjust, priority,
                              min_useful)
        if n <= 0:
            req.status = "granted"
            return req
        avail = self.available()
        if avail is not None and avail < n:
            req.status = "rejected"
            return req
        take = on_grant(n, t)
        if take > 0:
            ok = self.request(tre, take, t, count_adjust=count_adjust)
            if not ok:
                # availability was checked above and nothing ran between:
                # a failure means the requester accepted more than offered
                raise RuntimeError(
                    f"grant exceeds capacity: {take} nodes to {tre!r} "
                    f"(offered {n}) at t={t}")
            req.granted = take
            req.status = "granted"
        else:
            req.status = "cancelled"     # requester declined (stale need)
        return req

    def amend(self, req: ResourceRequest, n: int, t: float,
              min_useful: int = 1,
              priority: float | None = None) -> ResourceRequest:
        """Refresh a queued request with the requester's live deficit. The
        base service never queues, so this only adjusts the record."""
        if req.status == "queued":       # pragma: no cover - base never queues
            req.nodes = n
            req.min_useful = min_useful
            if priority is not None:
                req.priority = priority
        return req

    def cancel(self, req: ResourceRequest, t: float | None = None, *,
               drain: bool = True) -> None:
        if req.status in ("pending", "queued"):
            req.status = "cancelled"

    def release(self, tre: str, n: int, t: float, *, count_adjust=True) -> None:
        """Passively reclaim ``n`` nodes (closes newest lease blocks first)."""
        if n <= 0:
            return
        held = self.allocated.get(tre, 0)
        if held < n:
            # guarded raise, not assert: releasing more than held would
            # silently corrupt lease accounting under ``python -O``
            raise RuntimeError(
                f"release exceeds holding: {n} nodes from {tre!r} "
                f"(holds {held}) at t={t}")
        self.allocated[tre] -= n
        remaining = n
        blocks = self.open_leases[tre]
        while remaining > 0:
            blk = blocks[-1]
            if blk.nodes <= remaining:
                blocks.pop()
                blk.t1 = t
                self._close(blk)
                remaining -= blk.nodes
            else:
                blk.nodes -= remaining
                self._close(Lease(tre, remaining, blk.t0, t))
                remaining = 0
        if count_adjust:
            self.adjust_events.append(AdjustEvent(t, tre, -n))
        self._record(t)

    def destroy(self, tre: str, t: float, *, count_adjust: bool = True) -> None:
        n = self.allocated.get(tre, 0)
        if n:
            self.release(tre, n, t, count_adjust=count_adjust)

    # ------------------------------------------------- preemption ledger
    def preempt(self, tre: str, n: int, t: float, *,
                count_adjust: bool = True) -> None:
        """Release ``n`` nodes a preemptible tenant vacated for foreign
        demand. Lease mechanics are a plain :meth:`release` (newest
        blocks close first — the dynamic blocks a training gang grew
        into); the separate ledger entry is what distinguishes *forced*
        churn from a tenant's own idle-release cadence."""
        if n <= 0:
            return
        self.preempt_events.append(AdjustEvent(t, tre, -n))
        self.release(tre, n, t, count_adjust=count_adjust)

    def record_resume(self, tre: str, n: int, t: float) -> None:
        """Record a preempted tenant relaunching ``n`` nodes' worth of
        work from its checkpoint (the grant itself came through the
        normal request path — this is ledger-only)."""
        if n <= 0:
            return
        self.resume_events.append(AdjustEvent(t, tre, n))

    def preempt_count(self, tre: str | None = None) -> int:
        return sum(1 for e in self.preempt_events
                   if tre is None or e.tre == tre)

    def preempted_nodes(self, tre: str | None = None) -> int:
        return sum(-e.delta for e in self.preempt_events
                   if tre is None or e.tre == tre)

    def resume_count(self, tre: str | None = None) -> int:
        return sum(1 for e in self.resume_events
                   if tre is None or e.tre == tre)

    # ---------------------------------------------------------- metrics
    def _iter_leases(self, tre: str | None):
        leases = [l for l in self.closed_leases
                  if tre is None or l.tre == tre]
        for name, blocks in self.open_leases.items():
            if tre is None or name == tre:
                leases.extend(blocks)
        return leases

    def node_hours(self, tre: str | None = None, now: float = 0.0) -> float:
        """Billed node*hours (per started hour) for one TRE or all.

        Vectorized: closed leases live in columnar arrays, so the ceil and
        the weighted sum are single NumPy expressions instead of a method
        call per lease (the fleet-scale hot path)."""
        tres, t0, end, nodes = self._closed_arrays()
        if tre is not None:
            tid = self._tre_ids.get(tre)
            if tid is None:
                mask = np.zeros(len(t0), dtype=bool)
            else:
                mask = tres == tid
            t0, end, nodes = t0[mask], end[mask], nodes[mask]
        total = float(np.sum(
            nodes * np.ceil(np.maximum(end - t0, 1e-9) / BILL_UNIT_S)))
        # open leases: a handful of blocks per TRE, loop is fine
        for name, blocks in self.open_leases.items():
            if tre is None or name == tre:
                total += sum(l.billed_node_hours(now) for l in blocks)
        return total

    def node_hours_loop(self, tre: str | None = None, now: float = 0.0) -> float:
        """Per-lease Python reference for :meth:`node_hours` (kept for the
        scale-curve benchmark and the vectorization equivalence tests)."""
        return sum(l.billed_node_hours(now) for l in self._iter_leases(tre))

    def peak_nodes(self) -> int:
        return max(v for _, v in self._alloc_curve)

    def peak_nodes_per_hour(self, horizon: float) -> int:
        """Max allocation within any wall-clock hour bucket (Fig 13).

        Vectorized over the allocation event curve: each level ``v_k``
        covers the hour buckets from its own event to the next event
        (inclusive on both clipped ends, matching the loop reference), and
        since event times are non-decreasing the covering set of any bucket
        is a contiguous index range found with two searchsorted calls."""
        n_buckets = int(math.ceil(horizon / BILL_UNIT_S)) + 1
        ts = np.array([t for t, _ in self._alloc_curve])
        vs = np.array([v for _, v in self._alloc_curve])
        last = n_buckets - 1
        # level v_k spans buckets [s_k, e_k] (the final level spans only
        # its own bucket — the loop's trailing point update)
        s = np.minimum((ts // BILL_UNIT_S).astype(np.int64), last)
        e = np.empty_like(s)
        e[:-1] = np.minimum((ts[1:] // BILL_UNIT_S).astype(np.int64), last)
        e[-1] = s[-1]
        buckets = np.arange(n_buckets)
        los = np.searchsorted(e, buckets, side="left")
        his = np.searchsorted(s, buckets, side="right")
        peak = 0
        for lo, hi in zip(los, his):
            if lo < hi:
                peak = max(peak, int(vs[lo:hi].max()))
        return peak

    def peak_nodes_per_hour_loop(self, horizon: float) -> int:
        """Per-event Python reference for :meth:`peak_nodes_per_hour`."""
        n_buckets = int(math.ceil(horizon / BILL_UNIT_S)) + 1
        peak = [0] * n_buckets
        level = 0
        prev_t = 0.0
        for t, v in self._alloc_curve:
            b0 = int(prev_t // BILL_UNIT_S)
            b1 = min(int(t // BILL_UNIT_S), n_buckets - 1)
            for b in range(b0, b1 + 1):
                peak[b] = max(peak[b], level)
            level = v
            prev_t = t
            peak[min(int(t // BILL_UNIT_S), n_buckets - 1)] = max(
                peak[min(int(t // BILL_UNIT_S), n_buckets - 1)], level)
        return max(peak)

    def adjust_count(self, tre: str | None = None) -> int:
        """Accumulated size of adjusted nodes (Fig 14)."""
        return sum(abs(e.delta) for e in self.adjust_events
                   if tre is None or e.tre == tre)

    def setup_overhead_s(self, tre: str | None = None) -> float:
        return self.adjust_count(tre) * SETUP_COST_PER_NODE_S
