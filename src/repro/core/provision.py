"""Resource provision service (paper §3.1.2, §3.2.2.3) with lease accounting.

Grant-or-reject provisioning plus the metrics the paper evaluates:
  - per-TRE resource consumption in node*hours, billed per *started* hour
    (the paper's one-hour leasing time unit, §4.4(2)),
  - the provider's total + peak allocation ("nodes per hour", Fig 13),
  - accumulated node-adjustment counts and the setup overhead they imply
    (15.743 s per adjusted node, §4.5.4).

Leases are block-structured: every grant opens a block, releases close the
newest blocks first (matching ``PolicyEngine``'s LIFO block release), and a
partial release splits a block so billing stays exact.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

SETUP_COST_PER_NODE_S = 15.743   # measured in the paper's real test
BILL_UNIT_S = 3600.0             # one-hour leasing time unit


@dataclass
class Lease:
    tre: str
    nodes: int
    t0: float
    t1: float = -1.0             # -1 = still open

    def billed_hours(self, now: float) -> float:
        end = self.t1 if self.t1 >= 0 else now
        return math.ceil(max(end - self.t0, 1e-9) / BILL_UNIT_S)

    def billed_node_hours(self, now: float) -> float:
        return self.nodes * self.billed_hours(now)


@dataclass
class AdjustEvent:
    t: float
    tre: str
    delta: int                    # +granted / -released


class ProvisionService:
    """The CSF resource provision service. ``capacity=None`` = unbounded
    (DRP peak measurement); DawningCloud runs use the platform size."""

    def __init__(self, capacity: int | None = None):
        self.capacity = capacity
        self.allocated: dict[str, int] = {}
        self.open_leases: dict[str, list[Lease]] = {}
        self.closed_leases: list[Lease] = []
        self.adjust_events: list[AdjustEvent] = []
        self._alloc_curve: list[tuple[float, int]] = [(0.0, 0)]

    # ------------------------------------------------------------ state
    @property
    def total_allocated(self) -> int:
        return sum(self.allocated.values())

    def available(self) -> int | None:
        if self.capacity is None:
            return None
        return self.capacity - self.total_allocated

    def _record(self, t: float):
        self._alloc_curve.append((t, self.total_allocated))

    # ---------------------------------------------------------- actions
    def request(self, tre: str, n: int, t: float, *, count_adjust=True) -> bool:
        """Grant ``n`` nodes to ``tre`` or reject (provision policy)."""
        if n <= 0:
            return True
        if self.capacity is not None and self.total_allocated + n > self.capacity:
            return False
        self.allocated[tre] = self.allocated.get(tre, 0) + n
        self.open_leases.setdefault(tre, []).append(Lease(tre, n, t))
        if count_adjust:
            self.adjust_events.append(AdjustEvent(t, tre, n))
        self._record(t)
        return True

    def release(self, tre: str, n: int, t: float, *, count_adjust=True) -> None:
        """Passively reclaim ``n`` nodes (closes newest lease blocks first)."""
        if n <= 0:
            return
        assert self.allocated.get(tre, 0) >= n, (tre, n, self.allocated)
        self.allocated[tre] -= n
        remaining = n
        blocks = self.open_leases[tre]
        while remaining > 0:
            blk = blocks[-1]
            if blk.nodes <= remaining:
                blocks.pop()
                blk.t1 = t
                self.closed_leases.append(blk)
                remaining -= blk.nodes
            else:
                blk.nodes -= remaining
                self.closed_leases.append(Lease(tre, remaining, blk.t0, t))
                remaining = 0
        if count_adjust:
            self.adjust_events.append(AdjustEvent(t, tre, -n))
        self._record(t)

    def destroy(self, tre: str, t: float, *, count_adjust: bool = True) -> None:
        n = self.allocated.get(tre, 0)
        if n:
            self.release(tre, n, t, count_adjust=count_adjust)

    # ---------------------------------------------------------- metrics
    def node_hours(self, tre: str | None = None, now: float = 0.0) -> float:
        """Billed node*hours (per started hour) for one TRE or all."""
        leases = [l for l in self.closed_leases
                  if tre is None or l.tre == tre]
        for name, blocks in self.open_leases.items():
            if tre is None or name == tre:
                leases.extend(blocks)
        return sum(l.billed_node_hours(now) for l in leases)

    def peak_nodes(self) -> int:
        return max(v for _, v in self._alloc_curve)

    def peak_nodes_per_hour(self, horizon: float) -> int:
        """Max allocation within any wall-clock hour bucket (Fig 13)."""
        n_buckets = int(math.ceil(horizon / BILL_UNIT_S)) + 1
        peak = [0] * n_buckets
        level = 0
        prev_t = 0.0
        for t, v in self._alloc_curve:
            b0 = int(prev_t // BILL_UNIT_S)
            b1 = min(int(t // BILL_UNIT_S), n_buckets - 1)
            for b in range(b0, b1 + 1):
                peak[b] = max(peak[b], level)
            level = v
            prev_t = t
            peak[min(int(t // BILL_UNIT_S), n_buckets - 1)] = max(
                peak[min(int(t // BILL_UNIT_S), n_buckets - 1)], level)
        return max(peak)

    def adjust_count(self, tre: str | None = None) -> int:
        """Accumulated size of adjusted nodes (Fig 14)."""
        return sum(abs(e.delta) for e in self.adjust_events
                   if tre is None or e.tre == tre)

    def setup_overhead_s(self, tre: str | None = None) -> float:
        return self.adjust_count(tre) * SETUP_COST_PER_NODE_S
