"""The unified DSP control plane: one RuntimeEnv, many drivers.

A *TRE* (thin runtime environment, paper §3.1) is the unit DawningCloud
leases resources to. Before this module existed the TRE server logic was
implemented twice — once inside the discrete-event emulator
(``repro.sim.systems.REServer``) and once inside the live JAX controller
(``repro.core.controller.ElasticController``) — sharing only the pure
``PolicyEngine``. ``RuntimeEnv`` owns the complete control cycle exactly
once:

  - **queue + trigger monitor** (§3.2.1): dependency bookkeeping; a task
    enters the queue only when every dependency has finished,
  - **scheduler dispatch** (§4.4): first-fit (HTC) / FCFS (MTC) / any
    ``repro.core.scheduling.SCHEDULERS`` entry, per-TRE overridable,
  - **policy negotiation** (§3.2.2): ``PolicyEngine`` scan -> a DR1/DR2
    ``ResourceRequest`` submitted to the provision service. A plain
    ``ProvisionService`` answers grant-or-reject inline (the paper's
    §3.2.2.3 policy); a multi-tenant ``repro.core.provider.
    ResourceProvider`` may instead *park* the request in its admission
    queue — the env then amends it with the live deficit at every scan
    and the deferred grant lands through the ``on_grant`` callback when
    another tenant's release frees capacity. Hourly release checks run
    over *time-averaged* idle,
  - **idle accounting**: explicit time-integral of free nodes (no lazy
    ``getattr`` state),
  - **elastic hooks** (beyond paper): ``grow``/``shrink`` let a live driver
    resize a running task's allocation while the env keeps busy/free exact,
  - **lifecycle** (§3.1.3): creation and destruction go through
    ``LifecycleService``, so every run exercises the
    inexistent -> planning -> created -> running state machine.

Drivers own *time and execution*, nothing else. A driver supplies

  - a ``Clock`` (``now() -> float``): the emulator's is the simulation
    clock in seconds; the live controller's is a ``TickClock`` counting
    control ticks,
  - a ``launch(task)`` callable: the emulator schedules a finish event
    ``task.runtime`` later; the live controller actually trains/serves,
    and calls :meth:`RuntimeEnv.finish` when the task completes,
  - the cadence: the emulator wires scan/release events onto its event
    heap; the live controller calls :meth:`scan` / :meth:`release_check`
    from its tick loop.

``HTCRuntimeEnv`` and ``MTCRuntimeEnv`` fix the paper's per-kind defaults.
Both the emulator and the live controller are thin shells over these — one
implementation, two drivers, which is what makes the reproduction a
framework rather than a simulator.
"""
from __future__ import annotations

from typing import Any, Callable, Iterable, Protocol, runtime_checkable

from repro.core.lifecycle import LifecycleService
from repro.core.policy import MgmtPolicy, PolicyEngine
from repro.core.provision import ProvisionService
from repro.core.scheduling import resolve_scheduler


@runtime_checkable
class Clock(Protocol):
    """The only notion of time a RuntimeEnv has. Drivers define its unit:
    seconds (emulator) or control ticks (live controller)."""

    def now(self) -> float: ...


class TickClock:
    """Integer-stepped clock for tick-driven (live) drivers."""

    def __init__(self, start: float = 0.0):
        self._now = start

    def now(self) -> float:
        return self._now

    def advance(self, dt: float = 1.0) -> float:
        self._now += dt
        return self._now


class RuntimeEnv:
    """Driver-agnostic TRE server: the DSP control cycle, implemented once.

    Modes (mutually exclusive constructor arguments):
      - ``fixed_nodes=N``: DCS/SSP semantics — the env owns/leases a fixed
        configuration and never renegotiates; jobs schedule on submission.
      - ``policy=MgmtPolicy(...)``: DawningCloud semantics — starts at the
        policy's initial resources ``B`` and renegotiates at every
        :meth:`scan` / :meth:`release_check` the driver issues.
    """

    kind = "htc"

    def __init__(self, name: str, *, provision: ProvisionService,
                 clock: Clock, launch: Callable[[Any], None],
                 policy: MgmtPolicy | None = None,
                 fixed_nodes: int | None = None,
                 scheduler=None, lifecycle: LifecycleService | None = None,
                 count_adjust: bool = True, max_nodes: int | None = None):
        if (policy is None) == (fixed_nodes is None):
            raise ValueError("exactly one of policy / fixed_nodes required")
        self.name = name
        self.provision = provision
        self.clock = clock
        self._launch = launch
        self.count_adjust = count_adjust
        self.max_nodes = max_nodes
        self.mode = "fixed" if fixed_nodes is not None else "dsp"
        self.scheduler = resolve_scheduler(scheduler, self.kind)
        self.engine = PolicyEngine(policy) if policy is not None else None
        # ---- server state ----
        self.queue: list[Any] = []
        self.completed: list[Any] = []
        self._completed_n = 0     # == len(completed) for the scalar path;
        # a columnar driver tracks completions as array batches and bumps
        # only this counter, so ``all_done`` must never read len(completed)
        self.busy = 0
        self.destroyed = False
        # idle accounting: explicit time-integral state (not lazy getattr —
        # a silent 0.0 default here once hid whole-hour accounting gaps)
        t0 = clock.now()
        self._idle_acc = 0.0            # node*time integral of free nodes
        self._idle_t = t0               # last integration point
        self._release_t = t0            # start of the current release window
        # trigger monitor (populated by track())
        self._expected: int | None = None
        self._ndeps: dict[int, int] = {}
        self._children: dict[int, list[Any]] = {}
        # per-task allocation + projected release profile (for backfill)
        self._alloc: dict[int, int] = {}
        self._reserved: dict[int, tuple[float, int]] = {}
        # DR1/DR2 negotiation in flight: a multi-tenant provider may park
        # the request in its admission queue instead of rejecting it; while
        # one is parked the env amends it at each scan rather than
        # re-submitting (double-queueing would double-grant)
        self._pending_req = None
        # live-driver hook: called with (nodes, t, deferred) after every
        # committed grant. ``deferred`` is True when the grant landed
        # through the provider's admission-queue drain (another tenant's
        # release) rather than inside this env's own scan — a trace-rate
        # serving driver uses it to observe asynchronous slot growth
        # between its control ticks
        self.grant_listener: Callable[[int, float, bool], None] | None = None
        self._in_scan = False
        # ---- lifecycle: §3.1.3 creation path ----
        eff_policy = policy if policy is not None else \
            MgmtPolicy(fixed_nodes, 0.0, float("inf"))
        self.lifecycle = lifecycle or LifecycleService(provision)
        self.record = self.lifecycle.apply(name, self.kind, eff_policy, t0,
                                           count_adjust=count_adjust)
        if self.record is None:
            raise RuntimeError(
                f"TRE {name!r}: initial resources rejected by provision")
        self.owned = eff_policy.initial

    # ------------------------------------------------------------ state
    @property
    def free(self) -> int:
        return self.owned - self.busy

    @property
    def all_done(self) -> bool:
        return (self._expected is not None
                and self._completed_n == self._expected)

    def _account_idle(self) -> None:
        """Accumulate the time-integral of idle nodes. The release check
        frees blocks covered by the *time-averaged* idle of the past window:
        instantaneous idle thrashes (release->regrant bills a fresh lease
        hour), whole-window idle ratchets the pool up; average idle tracks
        the load curve with one window of lag. Call before every change to
        ``owned`` or ``busy``."""
        t = self.clock.now()
        self._idle_acc += self.free * (t - self._idle_t)
        self._idle_t = t

    # --------------------------------------------------- trigger monitor
    def track(self, jobs: Iterable[Any], *, extend: bool = False) -> None:
        """Register a workload's dependency graph with the trigger monitor.
        Dependency-free jobs must still be submitted by the driver (at their
        arrival times); dependent jobs are auto-submitted by :meth:`finish`
        when their last dependency completes.

        ``extend=True`` adds the jobs to the already-tracked graph instead
        of replacing it — a streaming driver registers each workflow as it
        arrives (jids must be globally unique across the stream)."""
        jobs = list(jobs)
        if not extend:
            self._expected = len(jobs)
            self._ndeps = {j.jid: len(j.deps) for j in jobs}
            self._children = {}
        else:
            self._expected = (self._expected or 0) + len(jobs)
            for j in jobs:
                # guarded raise, not assert: a duplicate jid silently
                # corrupts the dependency counts under ``python -O`` and
                # the workflow never completes (or completes twice)
                if j.jid in self._ndeps:
                    raise RuntimeError(
                        f"duplicate jid {j.jid} in extended track")
                self._ndeps[j.jid] = len(j.deps)
        for j in jobs:
            for d in j.deps:
                self._children.setdefault(d, []).append(j)

    def submit(self, task: Any) -> None:
        task.submit_time = self.clock.now()
        self.queue.append(task)
        # DSP envs load jobs at scan ticks (the scan both resizes and loads,
        # §3.2.2); fixed envs schedule on submission
        if self.mode == "fixed":
            self.schedule()

    # --------------------------------------------------------- scheduling
    def schedule(self) -> list[Any]:
        """Load the queue onto free nodes; returns (and launches) starts."""
        started = self.scheduler(
            self.queue, self.free, now=self.clock.now(),
            running=tuple(self._reserved.values()), busy=self.busy)
        if started:
            # one linear rebuild, not a remove() per start: a trace-scale
            # MTC queue holds thousands of ready tasks and a wide grant
            # starts hundreds of them in one schedule call
            started_ids = {id(t) for t in started}
            self.queue = [t for t in self.queue if id(t) not in started_ids]
        for task in started:
            task.start = self.clock.now()
            self._account_idle()
            self.busy += task.nodes
            self._alloc[id(task)] = task.nodes
            runtime = getattr(task, "runtime", None)
            if runtime is not None:
                self._reserved[id(task)] = (self.clock.now() + runtime,
                                            task.nodes)
            self._launch(task)
        return started

    def finish(self, task: Any, *, reschedule: bool = True) -> bool:
        """Driver reports a task completion. Frees its allocation, releases
        newly-ready dependents into the queue, reschedules. Returns True
        when the tracked workload is fully complete (driver may destroy).
        Pass ``reschedule=False`` when the driver is winding down and must
        not be handed freshly-launched work (e.g. a tick-budget cutoff)."""
        task.finish = self.clock.now()
        self._account_idle()
        self.busy -= self._alloc.pop(id(task), task.nodes)
        self._reserved.pop(id(task), None)
        self.completed.append(task)
        self._completed_n += 1
        jid = getattr(task, "jid", None)
        if jid is not None:
            for child in self._children.get(jid, ()):
                self._ndeps[child.jid] -= 1
                if self._ndeps[child.jid] == 0:
                    self.submit(child)
        if self.all_done:
            return True
        if reschedule:
            self.schedule()
        return False

    # ------------------------------------------------------ DSP control
    def _queue_demand_stats(self) -> tuple[int, int, int]:
        """(total, biggest, smallest) node demand of the queue — the only
        aggregates the policy engine's scan decision reads. The batch hook
        a columnar driver overrides: its queue is an index array of
        uniform-width tasks, so the stats are ``(len * width, width,
        width)`` with no per-job list ever materialized."""
        if not self.queue:
            return 0, 0, 0
        demands = [t.nodes for t in self.queue]
        return sum(demands), max(demands), min(demands)

    def _deficit(self, stats: tuple[int, int, int] | None = None,
                 ) -> tuple[int, int]:
        """(current DR1/DR2 need, minimum useful grant) per the policy
        engine, capped by the driver's node ceiling. When the ceiling cuts
        the need below its useful floor (e.g. a DR2 for a job wider than
        the driver will ever own), the request is suppressed entirely —
        nodes granted below the floor could never run the job and would
        idle-thrash through the hourly release checks."""
        if stats is None:
            stats = self._queue_demand_stats()
        total, biggest, smallest = stats
        need, min_useful = self.engine.scan_request_stats(
            total, biggest, smallest, self.owned)
        if need > 0 and self.max_nodes is not None:
            need = min(need, self.max_nodes - self.owned)
        if need < min_useful:
            return 0, 0
        return need, min_useful

    def _apply_grant(self, offer: int, t: float) -> int:
        """Grant callback for the provision service: validate the offer
        against the *live* deficit (a parked request's need may have
        drained while it queued), commit the accepted nodes, and load the
        queue onto them. Returns the nodes accepted — the provider opens
        the lease for exactly that amount, so a stale deferred grant can
        never push nodes onto a TRE that no longer wants them."""
        if self.destroyed or self.engine is None:
            return 0
        need, min_useful = self._deficit()
        take = min(offer, need)
        if take <= 0 or take < min_useful:
            # below the useful floor (e.g. a partial DR2 would idle until
            # the release check thrashes it): decline. The provider keeps
            # a declined request parked, so the pending handle stays — the
            # next scan amends it to the live deficit (or cancels it)
            return 0
        self._account_idle()
        self.engine.granted(take)
        self.owned += take
        if self.grant_listener is not None:
            self.grant_listener(take, t, not self._in_scan)
        self.schedule()
        return take

    def scan(self) -> int:
        """One DSP scan: negotiate growth with the provision service, then
        load the queue. Returns the nodes granted during this call (a
        deferred request granted later lands through :meth:`_apply_grant`
        when the provider's admission queue drains)."""
        if self.destroyed:
            return 0
        owned_before = self.owned
        self._in_scan = True
        try:
            if self.engine is not None:
                stats = self._queue_demand_stats()
                need, min_useful = self._deficit(stats)
                t = self.clock.now()
                pending = self._pending_req
                urgency = self.engine.urgency_stats(stats[0], self.owned)
                if pending is not None and pending.status == "queued":
                    # refresh the parked request with the live deficit and
                    # urgency; the amend may complete it immediately (a
                    # smaller need now fits)
                    self.provision.amend(pending, need, t, min_useful,
                                         priority=urgency)
                    if pending.status != "queued":
                        self._pending_req = None
                elif need > 0:
                    req = self.provision.submit_request(
                        self.name, need, t, on_grant=self._apply_grant,
                        count_adjust=self.count_adjust, priority=urgency,
                        min_useful=min_useful)
                    self._pending_req = req if req.status == "queued" else None
            self.schedule()
        finally:
            self._in_scan = False
        return self.owned - owned_before

    def release_check(self) -> int:
        """Window-end idle check: release every dynamic block covered by the
        window's time-averaged idle. Returns the nodes released."""
        if self.destroyed or self.engine is None:
            return 0
        self._account_idle()
        t = self.clock.now()
        elapsed = t - self._release_t
        idle_avg = self._idle_acc / elapsed if elapsed > 0 else 0.0
        rel = self.engine.release_check(int(min(idle_avg, self.free)))
        if rel > 0:
            # shrink owned BEFORE telling the provider: a multi-tenant
            # release drains the admission queue inline, which may re-grant
            # the freed nodes to this very env's parked request — its
            # deficit must be computed against the post-release pool, or
            # busy can end up exceeding owned
            self.owned -= rel
            self.provision.release(self.name, rel, t,
                                   count_adjust=self.count_adjust)
        self._idle_acc = 0.0
        self._release_t = t
        return rel

    def acquire(self, n: int) -> None:
        """Commit ``n`` directly-granted nodes (a ``provision.request``
        outside the scan path has already succeeded — e.g. a training
        tenant growing a gang into a trough): registers the dynamic
        block with the policy engine and keeps the idle integral exact,
        the same bookkeeping order as :meth:`_apply_grant`."""
        if n <= 0 or self.destroyed:
            return
        self._account_idle()
        if self.engine is not None:
            self.engine.granted(n)
        self.owned += n

    def yield_nodes(self, limit: int | None = None) -> int:
        """Preemption support: immediately release free dynamic blocks.
        Unlike :meth:`release_check` this reads the *instantaneous* free
        count, not the window-averaged idle — the caller has just
        vacated the nodes on purpose (checkpointed gangs shrunk away for
        foreign demand) and they must reach the provider's admission
        queue now, not at the next release window. Goes through
        ``provision.preempt`` so the lease ledger records forced churn
        separately from idle releases. Returns the nodes released."""
        if self.destroyed or self.engine is None:
            return 0
        self._account_idle()
        avail = self.free if limit is None else min(self.free, limit)
        rel = self.engine.release_check(int(avail))
        t = self.clock.now()
        if rel > 0:
            # owned shrinks BEFORE the provider call for the same drain
            # re-entrancy reason as release_check above
            self.owned -= rel
            self.provision.preempt(self.name, rel, t,
                                   count_adjust=self.count_adjust)
        # the vacated nodes are gone — they must not ALSO count toward
        # the next scheduled idle-release window
        self._idle_acc = 0.0
        self._release_t = t
        return rel

    # ---------------------------------------------------- elastic hooks
    def grow(self, task: Any, extra: int) -> None:
        """Beyond-paper: a live driver widens a *running* task into spare
        nodes (e.g. data-parallel mesh growth). Keeps busy/idle exact."""
        # guarded raise, not assert: growing past the free pool would
        # silently oversubscribe busy vs owned under ``python -O``
        if extra > self.free:
            raise RuntimeError(
                f"grow exceeds free nodes: {extra} > {self.free} "
                f"on {self.name!r}")
        self._account_idle()
        self.busy += extra
        self._alloc[id(task)] = self._alloc.get(id(task), task.nodes) + extra
        self._adjust_reservation(task, extra)

    def shrink(self, task: Any, n: int) -> None:
        """Inverse of :meth:`grow`: return ``n`` of the task's nodes."""
        held = self._alloc.get(id(task), task.nodes)
        # guarded raise, not assert: shrinking below the allocation would
        # drive busy negative and break idle accounting under ``python -O``
        if n > held:
            raise RuntimeError(
                f"shrink exceeds task allocation: {n} > {held} "
                f"on {self.name!r}")
        self._account_idle()
        self.busy -= n
        self._alloc[id(task)] -= n
        self._adjust_reservation(task, -n)

    def _adjust_reservation(self, task: Any, delta: int) -> None:
        """Keep the release profile in step with elastic resizes — a grown
        task frees its whole allocation at its estimated end, and a stale
        profile would silently degrade backfill scheduling to FCFS."""
        res = self._reserved.get(id(task))
        if res is not None:
            self._reserved[id(task)] = (res[0], res[1] + delta)

    def cancel_pending(self, at: float | None = None, *,
                       drain: bool = True) -> None:
        """Withdraw any parked DR1/DR2 request. ``drain=False`` detaches
        without letting the provider serve other tenants from the drain —
        required when tearing down a whole experiment (a grant landing
        between two finalize destroys would open a zero-duration lease
        billed a whole hour)."""
        if self._pending_req is not None:
            self.provision.cancel(self._pending_req,
                                  self.clock.now() if at is None else at,
                                  drain=drain)
            self._pending_req = None

    # --------------------------------------------------------- lifecycle
    def destroy(self, at: float | None = None) -> None:
        """All work done (or window over): the service provider destroys the
        TRE — §3.1.3 step 8, withdrawing every lease via the lifecycle
        service. Billing that depends on a configuration size must read it
        from the TRE record's policy, not from post-destroy state."""
        if self.destroyed:
            return
        self.destroyed = True
        self.cancel_pending(at)
        self.lifecycle.destroy(self.name,
                               self.clock.now() if at is None else at,
                               count_adjust=self.count_adjust)
        self.owned = 0


class HTCRuntimeEnv(RuntimeEnv):
    """HTC TRE: batch jobs, first-fit scheduling, 60 s scans (§3.2.2.1)."""
    kind = "htc"


class MTCRuntimeEnv(RuntimeEnv):
    """MTC TRE: workflow tasks under FCFS, 3 s scans (§3.2.2.2); the
    trigger monitor feeds the queue as dependencies complete."""
    kind = "mtc"
