"""AdamW with distributed state sharding (built from scratch — no optax).

Moments default to bf16 so a 1T-param model's optimizer state is 3x params
(bf16 p + m + v) instead of 12x — combined with FSDP/ZeRO sharding over the
``data`` axis this is what lets kimi-k2 train on 512 v5e chips. Update math
runs in fp32 regardless of storage dtype.

ZeRO-1: even when params use plain TP placement, optimizer-state *storage*
specs are resolved under the ``fsdp_tp`` rule table (extra ``data``-axis
sharding). GSPMD then turns the grad all-reduce into reduce-scatter + update
+ all-gather — the canonical ZeRO-1 dataflow — without manual collectives.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclass
class TrainState:
    step: jax.Array
    params: Any
    m: Any
    v: Any


@dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    min_lr_frac: float = 0.1
    moment_dtype: str = "bfloat16"

    def init(self, params) -> TrainState:
        mdt = jnp.dtype(self.moment_dtype)
        zeros = lambda p: jnp.zeros(p.shape, mdt)
        return TrainState(
            step=jnp.zeros((), jnp.int32),
            params=params,
            m=jax.tree.map(zeros, params),
            v=jax.tree.map(zeros, params),
        )

    def init_abstract(self, params) -> TrainState:
        mdt = jnp.dtype(self.moment_dtype)
        zeros = lambda p: jax.ShapeDtypeStruct(p.shape, mdt)
        return TrainState(
            step=jax.ShapeDtypeStruct((), jnp.int32),
            params=params,
            m=jax.tree.map(zeros, params),
            v=jax.tree.map(zeros, params),
        )

    def schedule(self, step):
        """Linear warmup then cosine decay to min_lr_frac."""
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / max(self.warmup_steps, 1), 1.0)
        t = jnp.clip((step - self.warmup_steps)
                     / max(self.total_steps - self.warmup_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        frac = self.min_lr_frac + (1 - self.min_lr_frac) * cos
        return self.lr * warm * frac

    def apply(self, state: TrainState, grads) -> tuple[TrainState, dict]:
        # global-norm clip in fp32
        sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                 for g in jax.tree.leaves(grads))
        gnorm = jnp.sqrt(sq)
        scale = jnp.minimum(1.0, self.grad_clip / jnp.maximum(gnorm, 1e-9))
        step = state.step + 1
        lr = self.schedule(step)
        b1, b2 = self.b1, self.b2
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            mf = b1 * m.astype(jnp.float32) + (1 - b1) * g
            vf = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
            u = (mf / bc1) / (jnp.sqrt(vf / bc2) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            newp = p.astype(jnp.float32) - lr * u
            return newp.astype(p.dtype), mf.astype(m.dtype), vf.astype(v.dtype)

        flat_p, treedef = jax.tree.flatten(state.params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.m)
        flat_v = treedef.flatten_up_to(state.v)
        out = [upd(p, g, m, v) for p, g, m, v in
               zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
        new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
        new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
        return (TrainState(step, new_p, new_m, new_v),
                {"grad_norm": gnorm, "lr": lr})
