from repro.train.optimizer import AdamW, TrainState  # noqa: F401
from repro.train.train_step import build_train_step  # noqa: F401
