"""Fault-tolerant elastic training loop.

This is the HTC-TRE payload: a job that (a) checkpoints on an interval,
(b) survives injected failures/preemptions by auto-resuming from the newest
checkpoint, and (c) honors *elastic resize* requests from the DSP
controller — on resize the loop checkpoints, rebuilds its mesh with the new
``data``-axis extent, re-places the state and continues (checkpoints are
sharding-agnostic).

The same loop runs single-device smoke tests (mesh=None) and the production
pod (mesh from repro.launch.mesh).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable

import jax
import numpy as np

from repro.configs.base import RunConfig
from repro.data.synthetic import synthetic_batches
from repro.models.lm import LM
from repro.train import checkpoint as ckpt
from repro.train.train_step import build_train_step, make_optimizer


class Preemption(Exception):
    """Injected node failure / preemption (tests + emulated cluster)."""


@dataclass
class LoopReport:
    steps_run: int = 0
    restarts: int = 0
    resizes: int = 0
    losses: list = field(default_factory=list)
    final_loss: float = float("nan")


def train_loop(
    rcfg: RunConfig,
    *,
    ckpt_dir: str,
    num_steps: int,
    ckpt_every: int = 50,
    mesh=None,
    batch_fn: Callable | None = None,
    fail_at: dict | None = None,
    resize_at: dict | None = None,
    max_restarts: int = 10,
) -> LoopReport:
    """Run (and re-run, on failure) the training job to ``num_steps``.

    fail_at: {step: True} — raise Preemption *before* checkpointing step.
    resize_at: {step: new_mesh_or_None} — elastic re-mesh at that step.
    """
    lm = LM(rcfg.model)
    report = LoopReport()
    fail_at = dict(fail_at or {})
    resize_at = dict(resize_at or {})

    attempt = 0
    while True:
        attempt += 1
        try:
            _run_attempt(lm, rcfg, ckpt_dir, num_steps, ckpt_every, mesh,
                         batch_fn, fail_at, resize_at, report)
            return report
        except Preemption:
            report.restarts += 1
            if report.restarts > max_restarts:
                raise


def _run_attempt(lm, rcfg, ckpt_dir, num_steps, ckpt_every, mesh, batch_fn,
                 fail_at, resize_at, report):
    step_fn, rt, opt = build_train_step(lm, rcfg, mesh)
    jit_step = jax.jit(step_fn, donate_argnums=(0,))
    if batch_fn is None:
        batch_fn = synthetic_batches(rcfg, mesh)

    start = ckpt.latest_step(ckpt_dir)
    if start is None:
        params = jax.jit(lambda k: lm.init(k)[0])(jax.random.key(rcfg.seed))
        state = opt.init(params)
        start = 0
    else:
        params_abs, _ = lm.init(None, abstract=True)
        state_abs = opt.init_abstract(params_abs)
        state, start = ckpt.restore(ckpt_dir, state_abs)

    for step in range(start, num_steps):
        if fail_at.pop(step, None):
            raise Preemption(f"injected failure at step {step}")
        if step in resize_at:
            new_mesh = resize_at.pop(step)
            ckpt.save(ckpt_dir, step, state)
            report.resizes += 1
            # re-enter with the new mesh; restore re-places the state
            return _run_attempt(lm, rcfg, ckpt_dir, num_steps, ckpt_every,
                                new_mesh, batch_fn, fail_at, resize_at, report)
        batch = batch_fn(step)
        state, metrics = jit_step(state, batch)
        report.steps_run += 1
        loss = float(metrics["loss"] if "loss" in metrics else metrics["ce"])
        report.losses.append(loss)
        if ckpt_every and (step + 1) % ckpt_every == 0:
            ckpt.save(ckpt_dir, step + 1, state)
    ckpt.save(ckpt_dir, num_steps, state)
    report.final_loss = report.losses[-1] if report.losses else float("nan")
