"""Sharded, atomic, elastic checkpoints (no orbax).

Layout: ``<dir>/step_<n>/`` containing one ``.npy`` per leaf (bf16 stored as
a uint16 view + dtype tag) and a msgpack ``manifest`` with the tree
structure, dtypes and the step. Writes go to ``step_<n>.tmp`` and are
``os.replace``d into place — a crash mid-write never corrupts the latest
checkpoint, which is what the DSP elastic controller relies on when it
kills and re-shards a training TRE.

Checkpoints are *sharding-agnostic*: leaves are saved as full host arrays
and re-placed under whatever mesh/sharding the restoring job uses — this is
the mechanism behind elastic data-parallel resizing (grow/shrink the
``data`` axis between restarts).
"""
from __future__ import annotations

import os
import re
import shutil

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

_BF16 = "bfloat16"


def _leaf_path(d: str, i: int) -> str:
    return os.path.join(d, f"leaf_{i:05d}.npy")


def save(path: str, step: int, tree, keep: int = 3) -> str:
    """Save pytree ``tree`` at ``path/step_<step>``. Returns the final dir."""
    leaves, treedef = jax.tree.flatten(tree)
    final = os.path.join(path, f"step_{step}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    dtypes = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        dtypes.append(str(arr.dtype))
        if arr.dtype == _BF16:
            arr = arr.view(np.uint16)
        np.save(_leaf_path(tmp, i), arr)
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "dtypes": dtypes,
        "treedef": str(treedef),
    }
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    _gc(path, keep)
    return final


def _steps(path: str) -> list[int]:
    if not os.path.isdir(path):
        return []
    out = []
    for name in os.listdir(path):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(path, name, "manifest.msgpack")):
            out.append(int(m.group(1)))
    return sorted(out)


def _gc(path: str, keep: int):
    steps = _steps(path)
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(path, f"step_{s}"), ignore_errors=True)


def latest_step(path: str) -> int | None:
    steps = _steps(path)
    return steps[-1] if steps else None


def restore(path: str, like, step: int | None = None, shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings``: optional matching tree of shardings
    for placement under a (possibly different) mesh."""
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {path}")
    d = os.path.join(path, f"step_{step}")
    with open(os.path.join(d, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    leaves_like, treedef = jax.tree.flatten(like)
    assert manifest["n_leaves"] == len(leaves_like), (
        f"checkpoint has {manifest['n_leaves']} leaves, expected "
        f"{len(leaves_like)}")
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(leaves_like))
    out = []
    for i, (lk, sh) in enumerate(zip(leaves_like, shard_leaves)):
        arr = np.load(_leaf_path(d, i))
        dt = manifest["dtypes"][i]
        if dt == _BF16:
            arr = arr.view(jnp.bfloat16)
        assert tuple(arr.shape) == tuple(lk.shape), (i, arr.shape, lk.shape)
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree.unflatten(treedef, out), step
