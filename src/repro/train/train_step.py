"""Train-step builder: value_and_grad + microbatch accumulation + sharding.

``build_train_step`` returns (step_fn, state_shardings, batch_sharding) so
the launcher / dry-run can jit with explicit in/out shardings. Gradient
accumulation scans over microbatches with bf16 accumulators kept in the
optimizer-state (ZeRO) sharding, deferring the cross-``data`` reduction to
the weight update — the accumulation itself adds no collectives.

Cross-pod gradient compression (int8 + error feedback) is available for the
multi-pod mesh via ``ParallelConfig.grad_compress_pod``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import RunConfig
from repro.models.lm import LM, Runtime
from repro.models.module import is_axes_leaf
from repro.parallel.sharding import batch_axes, resolve_spec
from repro.train.optimizer import AdamW, TrainState


def make_optimizer(rcfg: RunConfig) -> AdamW:
    return AdamW(
        lr=rcfg.learning_rate, b1=rcfg.adam_b1, b2=rcfg.adam_b2,
        eps=rcfg.adam_eps, weight_decay=rcfg.weight_decay,
        grad_clip=rcfg.grad_clip, warmup_steps=rcfg.warmup_steps,
        total_steps=rcfg.total_steps, moment_dtype=rcfg.moment_dtype)


def state_specs(lm: LM, axes, mesh, parallel):
    """PartitionSpecs for TrainState: params per strategy; moments ZeRO'd."""
    param_strategy = parallel.strategy
    opt_strategy = "fsdp_tp" if (parallel.zero1 or
                                 parallel.strategy == "fsdp_tp") else "tp"

    def resolve(tree_axes, shapes, strategy):
        leaves_a = jax.tree.leaves(tree_axes, is_leaf=is_axes_leaf)
        leaves_s, treedef = jax.tree.flatten(shapes)
        specs = [resolve_spec(a, s.shape, mesh, strategy)
                 for a, s in zip(leaves_a, leaves_s)]
        return jax.tree.unflatten(treedef, specs)

    abstract_params, _ = lm.init(None, abstract=True)
    p_specs = resolve(axes, abstract_params, param_strategy)
    o_specs = resolve(axes, abstract_params, opt_strategy)
    return TrainState(step=P(), params=p_specs, m=o_specs, v=o_specs)


def batch_spec(mesh):
    return P(batch_axes(mesh) or None)


def batch_shardings(mesh, batch_tree):
    bs = batch_spec(mesh)
    def spec_for(x):
        return NamedSharding(mesh, P(*bs, *([None] * (len(x.shape) - 1))))
    return jax.tree.map(spec_for, batch_tree)


def build_train_step(lm: LM, rcfg: RunConfig, mesh=None):
    """Returns (train_step, rt, opt). train_step(state, batch)->(state, metrics)."""
    rt = lm.runtime(rcfg.parallel, mesh)
    opt = make_optimizer(rcfg)
    n_micro = rcfg.parallel.microbatches

    def loss_fn(params, batch):
        return lm.loss(params, rt, batch)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    if rcfg.parallel.grad_compress_pod and mesh is not None:
        from repro.parallel.compression import build_pod_compressed_grad_fn
        grad_fn = build_pod_compressed_grad_fn(grad_fn, mesh)

    def train_step(state: TrainState, batch):
        if n_micro <= 1:
            (loss, metrics), grads = grad_fn(state.params, batch)
        else:
            def split(x):
                return x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:])
            micro = jax.tree.map(split, batch)

            def acc_step(carry, mb):
                g_acc, l_acc = carry
                (loss, metrics), g = grad_fn(state.params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(a.dtype), g_acc, g)
                return (g_acc, l_acc + loss), metrics

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.bfloat16), state.params)
            (grads, loss), metrics_all = jax.lax.scan(
                acc_step, (g0, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: (g / n_micro).astype(jnp.float32),
                                 grads)
            loss = loss / n_micro
            metrics = jax.tree.map(lambda m: m[-1], metrics_all)
        state, opt_metrics = opt.apply(state, grads)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return state, metrics

    return train_step, rt, opt
