"""InternVL2 76B: InternViT (stub frontend) + LLaMA3-70B-class backbone.

[arXiv:2404.16821; unverified] 80L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256. The vision tower is a STUB: input_specs() provides
precomputed patch embeddings at d_model.
"""
from repro.configs.base import ModelConfig, smoke_reduce

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    vision_stub=True,
    n_patches=1024,
    rope_theta=1e6,
)

SMOKE_CONFIG = smoke_reduce(CONFIG)
