"""Qwen3 14B: dense GQA decoder with QK-norm.

[hf:Qwen/Qwen3-8B; hf] 40L d_model=5120 40H (GQA kv=8) d_ff=17408
vocab=151936.
"""
from repro.configs.base import ModelConfig, smoke_reduce

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=17408,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1e6,
)

SMOKE_CONFIG = smoke_reduce(CONFIG)
