from repro.configs.base import (  # noqa: F401
    ModelConfig, ParallelConfig, RunConfig, ShapeConfig, SHAPES, smoke_reduce,
)
from repro.configs.registry import ARCHS, get_config, get_smoke_config  # noqa: F401
