"""Nemotron-4 15B: dense GQA decoder with squared-ReLU MLP.

[arXiv:2402.16819; unverified] 32L d_model=6144 48H (GQA kv=8) d_ff=24576
vocab=256000. Plain (ungated) MLP with squared-ReLU activation.
"""
from repro.configs.base import ModelConfig, smoke_reduce

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=256000,
    mlp_act="sq_relu",
    rope_theta=1e6,
)

SMOKE_CONFIG = smoke_reduce(CONFIG)
