"""Config system: model architecture, input shapes, parallelism, run config.

Plain frozen dataclasses — no external config library. Every assigned
architecture file in this package exports ``CONFIG`` (full size, dry-run only)
and ``SMOKE_CONFIG`` (reduced, runnable on CPU).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # --- MoE ---
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    moe_layer_period: int = 1   # MoE on layers where (i % period) == period-1
    dense_residual: bool = False  # arctic-style dense MLP in parallel with MoE
    n_shared_experts: int = 0     # kimi-style always-on shared expert(s)
    capacity_factor: float = 1.25

    # --- attention details ---
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1e6

    # --- MLP ---
    mlp_act: str = "swiglu"  # swiglu | sq_relu

    # --- SSM / hybrid ---
    ssm: bool = False              # True: layers default to Mamba2 blocks
    attn_layer_period: int = 0     # hybrid: attention where (i % p) == offset
    attn_layer_offset: int = 3
    d_state: int = 128
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_groups: int = 1    # B/C groups (MQA-like; mamba2 default 1)
    conv_dim: int = 4

    # --- modality ---
    n_codebooks: int = 1   # musicgen: EnCodec codebooks (summed in, multi-head out)
    vision_stub: bool = False
    n_patches: int = 256   # patch embeddings prepended when vision_stub

    # --- numerics ---
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    logits_softcap: float = 0.0

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ---- derived sizes ----
    @property
    def vocab_padded(self) -> int:
        """Vocab rounded to a multiple of 256 so it TP-shards cleanly."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def block_kind(self, i: int) -> str:
        """Block kind for layer i: 'attn' or 'ssm'."""
        if not self.ssm:
            return "attn"
        if self.attn_layer_period and i % self.attn_layer_period == self.attn_layer_offset:
            return "attn"
        return "ssm"

    def is_moe_layer(self, i: int) -> bool:
        return self.moe and (i % self.moe_layer_period == self.moe_layer_period - 1)

    @property
    def pattern_period(self) -> int:
        """Length of the repeating layer pattern (scan unit)."""
        p = 1
        if self.ssm and self.attn_layer_period:
            p = self.attn_layer_period
        if self.moe:
            import math
            p = math.lcm(p, self.moe_layer_period)
        assert self.n_layers % p == 0, (self.name, self.n_layers, p)
        return p

    # ---- parameter counts (for roofline 6ND) ----
    def param_count(self, active: bool = False) -> int:
        d, hd = self.d_model, self.head_dim
        total = self.vocab_size * d * (2 if self.n_codebooks <= 1 else 1 + self.n_codebooks)
        if self.n_codebooks > 1:
            total += (self.n_codebooks - 1) * self.vocab_size * d  # extra in-embeds
        for i in range(self.n_layers):
            if self.block_kind(i) == "attn":
                total += d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
                if self.qkv_bias:
                    total += self.q_dim + 2 * self.kv_dim
            else:  # mamba2 block
                di, ds, nh = self.d_inner, self.d_state, self.n_ssm_heads
                ng = self.ssm_groups
                total += d * (2 * di + 2 * ng * ds + nh) + di * d
                total += self.conv_dim * (di + 2 * ng * ds) + 2 * nh + nh + di
            if self.is_moe_layer(i):
                n_mlp = 3 if self.mlp_act == "swiglu" else 2
                e = self.top_k if active else self.n_experts
                total += e * n_mlp * d * self.d_ff_expert
                total += self.n_shared_experts * n_mlp * d * self.d_ff_expert
                total += d * self.n_experts  # router
                if self.dense_residual:
                    total += n_mlp * d * self.d_ff
            elif self.d_ff > 0:
                n_mlp = 3 if self.mlp_act == "swiglu" else 2
                total += n_mlp * d * self.d_ff
            total += 2 * d  # norms
        return total


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell. kind: train | prefill | decode."""
    name: str
    kind: str
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


# The four assigned LM shape cells.
SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


@dataclass(frozen=True)
class ParallelConfig:
    """How a step is sharded on the mesh. Axes: (pod?, data, model)."""
    strategy: str = "tp"          # tp | fsdp_tp  (param placement)
    zero1: bool = True            # shard optimizer state over data axis
    remat: str = "block"          # none | block | full
    microbatches: int = 1
    moe_dispatch: str = "local"   # local (token-replicated) | a2a
    decode_kv_shard: str = "auto"  # auto | heads | seq
    attn_q_chunk: int = 512
    attn_kv_chunk: int = 1024
    attn_impl: str = "masked"     # masked (full pairs) | triangular (skip upper)
    attn_seq_parallel: bool = False  # ring attention over the model axis
    grad_compress_pod: bool = False  # int8 cross-pod gradient all-reduce
    pp_over_pod: bool = False        # pipeline the pod axis instead of DP


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    parallel: ParallelConfig = field(default_factory=ParallelConfig)
    seed: int = 0
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    adam_b1: float = 0.9
    adam_b2: float = 0.95
    adam_eps: float = 1e-8
    moment_dtype: str = "bfloat16"   # bf16 moments: fits 1T-param opt state
    master_dtype: str = "float32"    # master params fp32 unless fsdp'd big model


def smoke_reduce(cfg: ModelConfig, **over) -> ModelConfig:
    """Shrink a full config to a CPU-runnable config of the same family."""
    repl = dict(
        n_layers=cfg.pattern_period * 2 if (cfg.ssm or cfg.moe) else 2,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        n_experts=8 if cfg.moe else 0,
        top_k=min(cfg.top_k, 2) if cfg.moe else 0,
        d_ff_expert=64 if cfg.moe else 0,
        d_state=16,
        ssm_head_dim=16,
        ssm_chunk=16,
        n_patches=8 if cfg.vision_stub else cfg.n_patches,
        name=cfg.name + "-smoke",
    )
    repl.update(over)
    return dataclasses.replace(cfg, **repl)
