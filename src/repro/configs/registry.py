"""Registry of the assigned architectures: ``--arch <id>``."""
from __future__ import annotations

import importlib

# arch id -> module name
ARCHS = {
    "arctic-480b": "arctic_480b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "granite-3-8b": "granite_3_8b",
    "qwen2-7b": "qwen2_7b",
    "nemotron-4-15b": "nemotron_4_15b",
    "qwen3-14b": "qwen3_14b",
    "mamba2-1.3b": "mamba2_1_3b",
    "internvl2-76b": "internvl2_76b",
    "musicgen-large": "musicgen_large",
}


def _module(arch: str):
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return importlib.import_module(f"repro.configs.{ARCHS[arch]}")


def get_config(arch: str):
    return _module(arch).CONFIG


def get_smoke_config(arch: str):
    return _module(arch).SMOKE_CONFIG
