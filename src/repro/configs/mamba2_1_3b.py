"""Mamba2 1.3B: attention-free SSD (state-space duality) stack.

[arXiv:2405.21060; unverified] 48L d_model=2048 (attn-free) d_ff=0
vocab=50280, ssm_state=128; d_inner=2*d_model, head_dim=64.
"""
from repro.configs.base import ModelConfig, smoke_reduce

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm=True,
    attn_layer_period=0,   # no attention layers at all
    d_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    ssm_groups=1,
)

SMOKE_CONFIG = smoke_reduce(CONFIG)
