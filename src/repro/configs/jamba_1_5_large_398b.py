"""Jamba 1.5 Large: hybrid Mamba+attention (1:7 interleave) with 16e MoE.

[arXiv:2403.19887; hf] 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16e top-2; attention on 1 of every 8 layers, MoE on
alternate layers.
"""
from repro.configs.base import ModelConfig, smoke_reduce

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    moe=True,
    n_experts=16,
    top_k=2,
    d_ff_expert=24576,
    moe_layer_period=2,
    ssm=True,
    attn_layer_period=8,
    attn_layer_offset=3,   # 1 attn per 8 layers (jamba placement)
    d_state=16,            # jamba uses mamba-1-style small state
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    ssm_groups=1,
    rope_theta=1e6,
)

SMOKE_CONFIG = smoke_reduce(CONFIG)
