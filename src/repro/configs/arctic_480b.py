"""Snowflake Arctic 480B: 128-expert top-2 MoE with parallel dense residual.

[hf:Snowflake/snowflake-arctic-base; hf] 35L d_model=7168 56H (GQA kv=8)
d_ff=4864 vocab=32000, MoE 128e top-2 + dense residual MLP on every layer.
"""
from repro.configs.base import ModelConfig, smoke_reduce

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,            # dense residual MLP width
    vocab_size=32000,
    moe=True,
    n_experts=128,
    top_k=2,
    d_ff_expert=4864,
    moe_layer_period=1,
    dense_residual=True,
    rope_theta=1e6,
)

SMOKE_CONFIG = smoke_reduce(CONFIG)
