"""MusicGen Large: decoder-only over EnCodec tokens (4 codebooks).

[arXiv:2306.05284; hf] 48L d_model=2048 32H (kv=32, i.e. MHA) d_ff=8192
vocab=2048 per codebook. EnCodec itself is a stub; the backbone consumes
4 parallel token streams (summed embeddings) and emits 4 heads; the delay
pattern is applied by the data/serving layer.
"""
from repro.configs.base import ModelConfig, smoke_reduce

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    n_codebooks=4,
    rope_theta=1e4,
)

SMOKE_CONFIG = smoke_reduce(CONFIG)
