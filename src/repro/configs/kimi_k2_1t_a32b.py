"""Kimi K2: trillion-parameter MoE, 32B active.

[arXiv:2501.kimi2; unverified] 61L d_model=7168 64H (GQA kv=8) d_ff=2048
(expert width) vocab=163840, MoE 384e top-8.
"""
from repro.configs.base import ModelConfig, smoke_reduce

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=0,               # no dense MLP path; experts only
    vocab_size=163840,
    moe=True,
    n_experts=384,
    top_k=8,
    d_ff_expert=2048,
    moe_layer_period=1,
    n_shared_experts=1,   # always-on shared expert (K2-style)
    rope_theta=1e6,
)

SMOKE_CONFIG = smoke_reduce(CONFIG)
