"""The tenant contract: what ``ServeFleet`` drives, extracted from the
serve stack — plus the first non-serve tenant species.

Before this module the fleet's lanes were hardwired ``ServeDriver``s:
the tick body named serve-specific phase methods and the roll-up read
serve-specific stats. The paper's economies-of-scale claim is about
consolidating *heterogeneous* workloads on one platform (MTC **and**
HTC, §2/§5; arXiv:1004.1276 asks the same question for batch-shaped
scientific communities), so the tenant itself must be an abstraction:

  - :class:`Tenant` — the phase-hook protocol ``ServeFleet._tick``
    drives, one hook per phase of THE serve tick body, in tick order.
    ``ServeDriver`` implements it by aliasing its existing phase
    methods, which is what keeps the all-MTC fleet bit-identical to the
    pre-refactor path (pinned field-for-field in ``tests/test_tenant``).
  - :class:`TrainTenant` — a gang-scheduled HTC *training* tenant
    sharing the provider with the serve lanes: all-or-nothing grants
    through the existing ``ResourceRequest.min_useful`` DR1/DR2 path (a
    single queued gang's deficit IS its useful floor), elastic between
    each job's min and max world size via the ``RuntimeEnv``
    grow/shrink hooks, and *preemptible*: when foreign requests park in
    the provider's admission queue the tenant checkpoints, vacates and
    releases nodes (``RuntimeEnv.yield_nodes`` ->
    ``ProvisionService.preempt``), and the requeued job later resumes
    from its last checkpoint step — the emulated twin of
    ``train.loop.Preemption`` + ``train.checkpoint.latest_step``
    (loss-bit-identical resume is pinned dynamically in
    ``tests/test_train.py``). Jobs come from ``sim.traces.TrainProfile``
    streams: many small heterogeneous runs over the ``repro.configs``
    model registry, an HTC community in the NAS-trainer spirit.

Work model (emulated, deterministic): a job needs
``steps * world_min * step_ticks`` node-ticks of useful work; each tick
it accrues its current world size, so elastic growth is linear speedup.
``steps_done = work // (world_min * step_ticks)``; a checkpoint exists
at every ``ckpt_every`` step boundary, and a preemption rolls work back
to the last checkpoint — exactly what restarting a real ``train_loop``
from ``latest_step`` loses.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Sequence

from repro.core.policy import HTC_SCAN_S, MgmtPolicy
from repro.core.tre import HTCRuntimeEnv, TickClock
from repro.sim.traces import TrainJob


class TenantInvariantError(RuntimeError):
    """A tenant-side invariant was violated (training allocation ledger
    divergence, a gang outside its world-size band). Raised — never
    ``assert``ed — so the checks survive ``python -O``."""


def due_tick_floor(t: float, tick_s: float) -> int:
    """A tick index guaranteed *not later* than the tick at which a
    timestamp ``t`` comes due under the serve loop's ``t <= now + 1e-9``
    check. ``floor`` (vs the exact ``ceil``) concedes at most one tick
    when ``t`` sits on the grid, in exchange for a one-sided guarantee
    that holds even as the accumulated ``TickClock`` drifts from
    ``k * tick_s`` by float error: event-skipping may land *early* (the
    tick is then a no-op and the loop resumes normal stepping) but can
    never jump *past* the event."""
    return int(math.floor((t - 1e-9) / tick_s))


def next_boundary(k: int, every: int, phase: int) -> int:
    """Smallest tick index > ``k`` on the ``k % every == phase % every``
    control-cycle grid (scan/release boundaries)."""
    r = phase % every
    k2 = (k // every) * every + r
    while k2 <= k:
        k2 += every
    return k2


class Tenant:
    """One tenant of the shared pool: the phase-hook contract
    ``ServeFleet._tick`` drives, in tick order. Implementations supply:

    ``name``
        the TRE name (provider leases and stats are keyed by it),
    ``env``
        the tenant's ``RuntimeEnv`` — the fleet reads ``env.owned`` for
        grant bookkeeping and ``env.destroyed`` at teardown,
    ``stats``
        a per-run stats object with ``as_dict()`` plus the roll-up
        fields :meth:`rollup` reads,
    ``tick_s`` / ``max_ticks``
        the tick grain and this tenant's own tick-budget bound.

    Phase hooks, in the order one fleet tick calls them (the fleet's
    pool decode step runs between :meth:`pre_step` and
    :meth:`post_step`):

    1. :meth:`begin_tick` — work intake (arrivals due at ``now``),
    2. :meth:`pre_step` — release cadence and any voluntary yielding
       (a training tenant's preemption check lives here so vacated
       nodes drain to parked serve requests within the same tick),
    3. :meth:`post_step` — consume the step's results (finished decode
       slots / emulated training progress),
    4. :meth:`control` — scan cadence: DR1/DR2 negotiation, elastic
       growth,
    5. :meth:`flush` — batched admissions,
    6. :meth:`check_invariants` — guarded-raise consistency sweeps,
    7. :meth:`accumulate` — per-tick stats integrals.

    Retirement: the fleet polls :meth:`retired` after each tick and
    calls :meth:`finalize` once (destroying the env settles billing);
    at a tick-budget cutoff it first calls :meth:`teardown` on every
    surviving tenant so no parked request can be granted between two
    finalize destroys.

    Event-skipping: :meth:`next_event_tick` names the earliest tick at
    which this tenant could act; :meth:`skip_quiet_stats` applies the
    closed form of ``dq`` quiet ticks to the tenant's own state (stats
    integrals, emulated progress). The fleet skips a span only when it
    is quiet for EVERY tenant.
    """

    name: str = ""
    tick_s: float = 1.0
    max_ticks: int = 0
    env: Any = None
    stats: Any = None

    # ------------------------------------------------------ phase hooks
    def begin_tick(self, now: float) -> None:
        """Phase 1: intake work due at ``now``."""

    def pre_step(self, k: int) -> None:
        """Phase 2: release cadence / voluntary yielding, before the
        pool's decode step."""

    def post_step(self, k: int) -> None:
        """Phase 3: consume the pool step's results."""

    def control(self, k: int) -> None:
        """Phase 4: scan cadence — negotiation and elastic growth."""

    def flush(self) -> None:
        """Phase 5: batched admissions."""

    def check_invariants(self) -> None:
        """Phase 6: guarded-raise consistency sweeps."""

    def accumulate(self) -> None:
        """Phase 7: per-tick stats integrals."""

    # ------------------------------------------------------- retirement
    @property
    def retired(self) -> bool:
        """All work complete: the fleet finalizes and drops the lane."""
        raise NotImplementedError

    def teardown(self, now: float) -> None:
        """Cutoff guard: withdraw any parked request WITHOUT letting the
        provider drain it to other tenants (a grant landing between two
        finalize destroys opens a zero-duration lease billed an hour)."""
        if self.env is not None and not self.env.destroyed:
            self.env.cancel_pending(now, drain=False)

    def finalize(self, ticks: int):
        """Close out: derived rates, destroy the env, settle billing.
        Returns the tenant's stats object."""
        raise NotImplementedError

    # --------------------------------------------------- event-skipping
    def next_event_tick(self, k: int) -> int:
        """Earliest tick after ``k`` at which this tenant could act.
        The conservative default — every tick is an event — disables
        skipping for tenants that don't model their horizons."""
        return k + 1

    def skip_quiet_stats(self, dq: int) -> None:
        """Closed form of ``dq`` quiet ticks of this tenant's own state
        (the busy/owned integrals; subclasses add emulated progress).
        The fleet advances the shared clock and pool itself."""
        self.stats.busy_node_ticks += self.env.busy * self.tick_s * dq
        self.stats.owned_node_ticks += self.env.owned * self.tick_s * dq

    # ----------------------------------------------------------- rollup
    def rollup(self, fleet_stats) -> None:
        """Fold this tenant's stats into a ``FleetStats``. The base form
        covers the fields every tenant species shares; ``ServeDriver``
        extends it with the serve-only counters."""
        ls = self.stats
        fleet_stats.busy_node_ticks += ls.busy_node_ticks
        fleet_stats.owned_node_ticks += ls.owned_node_ticks
        fleet_stats.node_hours += ls.node_hours
        fleet_stats.deferred_grants += ls.deferred_grants
        fleet_stats.deferred_nodes += ls.deferred_nodes
        fleet_stats.tenants.append(ls.as_dict())


# --------------------------------------------------------------------------
# the HTC training tenant
# --------------------------------------------------------------------------
@dataclass
class TrainStats:
    """One training tenant's run: gang/elastic/preemption accounting."""
    name: str
    ticks: int = 0
    tick_s: float = 1.0
    jobs_expected: int = 0
    jobs_completed: int = 0
    steps_expected: int = 0             # optimizer steps across all jobs
    steps_done: int = 0
    makespan_s: float = 0.0
    busy_node_ticks: float = 0.0        # integral of gang-held nodes
    owned_node_ticks: float = 0.0       # integral of granted nodes
    slot_utilization: float = 0.0       # busy / owned integrals
    node_hours: float = 0.0             # billed (per started lease hour)
    peak_owned: int = 0
    queue_peak: int = 0
    deferred_grants: int = 0            # gang grants landed via the queue
    deferred_nodes: int = 0
    preemptions: int = 0                # jobs vacated for foreign demand
    resumes: int = 0                    # preempted jobs relaunched
    rollback_steps: int = 0             # un-checkpointed steps lost
    grow_nodes: int = 0                 # elastic growth committed
    shrink_nodes: int = 0               # elastic shrink (incl. preempt)
    invariant_breaches: int = 0         # non-strict counted breaches

    def as_dict(self) -> dict:
        return dict(self.__dict__)


class TrainTenant(Tenant):
    """A gang-scheduled, elastic, preemptible HTC training tenant.

    jobs: the tenant's HTC stream — ``sim.traces.TrainJob``s (from
        ``TrainProfile.stream`` / ``train_stream``), submitted at their
        arrival times. Each job is a *gang*: it starts only when
        ``world_min`` nodes are free in the env (first-fit over the
        queue), which the DR1/DR2 ``min_useful`` floor guarantees grants
        are sized for — a partial grant below the smallest queued gang
        is declined (``RuntimeEnv._apply_grant``), the all-or-nothing
        contract.
    provider: the shared provision service (a ``ResourceProvider`` for
        consolidation; a plain ``ProvisionService`` for a dedicated
        baseline).
    policy / fixed_nodes: DSP elasticity vs a dedicated fixed pool —
        exactly one, as everywhere.
    preempt_check_s: cadence of the yield check (default: the scan
        interval). At each boundary, if foreign requests are parked in
        the provider's admission queue, the tenant shrinks its gangs to
        ``world_min``, then fully preempts gangs (youngest first:
        checkpoint, vacate, requeue) until the foreign demand is
        covered, releasing the vacated dynamic blocks through
        ``RuntimeEnv.yield_nodes`` so the provider's drain re-grants
        them within the same tick.
    """

    def __init__(self, jobs: Sequence[TrainJob], *, provider,
                 clock: TickClock | None = None,
                 policy: MgmtPolicy | None = None,
                 fixed_nodes: int | None = None,
                 name: str = "htc-train", lifecycle=None,
                 tick_s: float = 1.0, strict: bool = True,
                 phase: int = 0, max_nodes: int | None = None,
                 preempt_check_s: float | None = None,
                 max_ticks: int | None = None):
        self.provider = provider
        self.tick_s = tick_s
        self.strict = strict
        self.clock = clock if clock is not None else TickClock()
        self.jobs = sorted(jobs, key=lambda j: (j.arrival, j.jid))
        for j in self.jobs:
            if j.nodes != j.world_min:
                raise TenantInvariantError(
                    f"train job {j.name!r} queues at nodes={j.nodes} but "
                    f"its gang floor is world_min={j.world_min}")
        self.stats = TrainStats(
            name=name, tick_s=tick_s, jobs_expected=len(self.jobs),
            steps_expected=sum(j.steps for j in self.jobs))
        self._stream_i = 0
        self._phase = phase
        scan_s = policy.scan_interval if policy is not None else HTC_SCAN_S
        self._scan_every = max(int(round(scan_s / tick_s)), 1)
        self._release_every = (max(int(round(policy.release_interval
                                             / tick_s)), 1)
                               if policy is not None else 0)
        pre_s = preempt_check_s if preempt_check_s is not None else scan_s
        self._preempt_every = max(int(round(pre_s / tick_s)), 1)
        # per-jid run state: the task handle, its live allocation, its
        # accrued work (node-ticks) and checkpointed step floor
        self._task: dict[int, TrainJob] = {}
        self._held: dict[int, int] = {}
        self._work: dict[int, int] = {}
        self._ckpt: dict[int, int] = {}     # last checkpointed step
        self._running: list[int] = []       # jids in launch order
        self._was_preempted: set[int] = set()
        self.env = HTCRuntimeEnv(
            name, provision=provider, clock=self.clock,
            launch=self._launch, policy=policy, fixed_nodes=fixed_nodes,
            lifecycle=lifecycle, max_nodes=max_nodes)
        self.env.grant_listener = self._on_grant
        self.env.track(())
        if max_ticks is None:
            span = self.jobs[-1].arrival if self.jobs else 0.0
            work = sum(j.steps * j.step_ticks for j in self.jobs)
            max_ticks = int(span / tick_s + 8 * work + 36_000)
        self.max_ticks = max_ticks

    @property
    def name(self) -> str:
        return self.env.name

    # ------------------------------------------------------- env hooks
    def _target_work(self, job: TrainJob) -> int:
        return job.steps * job.world_min * job.step_ticks

    def _steps_of(self, job: TrainJob, work: int) -> int:
        return min(work // (job.world_min * job.step_ticks), job.steps)

    def _launch(self, task: TrainJob) -> None:
        """The env's scheduler started this gang on ``world_min`` free
        nodes. A relaunch of a preempted job is a *resume*: it continues
        from its checkpointed step (the work floor set at preemption),
        recorded in the provider's lease ledger."""
        jid = task.jid
        self._task[jid] = task
        self._held[jid] = task.nodes
        self._running.append(jid)
        if jid in self._was_preempted:
            self._was_preempted.discard(jid)
            self.stats.resumes += 1
            record = getattr(self.provider, "record_resume", None)
            if record is not None:
                record(self.env.name, task.nodes, self.clock.now())

    def _on_grant(self, nodes: int, t: float, deferred: bool) -> None:
        if deferred:
            self.stats.deferred_grants += 1
            self.stats.deferred_nodes += nodes

    # ------------------------------------------------------ phase hooks
    def begin_tick(self, now: float) -> None:
        while (self._stream_i < len(self.jobs)
               and self.jobs[self._stream_i].arrival <= now + 1e-9):
            job = self.jobs[self._stream_i]
            self._stream_i += 1
            self._work.setdefault(job.jid, 0)
            self._ckpt.setdefault(job.jid, 0)
            self.env.track([job], extend=True)
            self.env.submit(job)

    def pre_step(self, k: int) -> None:
        if (self._release_every and k > 0
                and k % self._release_every == self._phase
                % self._release_every):
            self.env.release_check()
        if (k > 0 and k % self._preempt_every == self._phase
                % self._preempt_every):
            self._maybe_preempt()

    def _foreign_parked(self) -> int:
        """Node demand parked in the provider's admission queue by OTHER
        tenants — the signal that the pool is contended and training
        should get out of the way."""
        queue = getattr(self.provider, "admission_queue", None)
        if not queue:
            return 0
        return sum(r.nodes for r in queue
                   if r.tre != self.env.name and r.status == "queued")

    def _maybe_preempt(self) -> None:
        """Yield to parked foreign demand: elastic shrink first (gangs
        fall back to ``world_min``), then full preemption youngest-first
        — checkpoint (roll accrued work to the last ``ckpt_every``
        boundary), vacate the gang, requeue the job. Vacated nodes are
        released through ``yield_nodes`` -> ``provider.preempt``, whose
        drain re-grants them to the parked requests inline."""
        demand = self._foreign_parked()
        if demand <= 0 or not self._running:
            return
        # only dynamic blocks ever release (the B floor is the tenant's
        # reserved share, and a fixed pool never releases at all) —
        # vacating a gang that runs inside the floor frees nodes the
        # foreign tenant can never receive, so cap the yield at what
        # ``release_check`` could actually hand over.
        demand = min(demand, self.env.engine.dynamic_total
                     if self.env.engine is not None else 0)
        if demand <= 0:
            return
        freed = 0
        for jid in reversed(self._running):
            job = self._task[jid]
            surplus = self._held[jid] - job.world_min
            take = min(surplus, demand - freed)
            if take > 0:
                self.env.shrink(job, take)
                self._held[jid] -= take
                self.stats.shrink_nodes += take
                freed += take
            if freed >= demand:
                break
        while freed < demand and self._running:
            jid = self._running[-1]
            freed += self._preempt_job(jid)
        if freed > 0:
            self.env.yield_nodes()

    def _preempt_job(self, jid: int) -> int:
        """Checkpoint-and-vacate one running gang; returns the nodes
        freed. The job requeues at ``world_min`` and its accrued work
        rolls back to the last checkpoint boundary — the steps a real
        ``train_loop`` would redo after restoring ``latest_step``."""
        job = self._task[jid]
        held = self._held.pop(jid)
        self._running.remove(jid)
        self._task.pop(jid)
        steps = self._steps_of(job, self._work[jid])
        ckpt = (steps // job.ckpt_every) * job.ckpt_every
        self.stats.rollback_steps += steps - ckpt
        self._ckpt[jid] = ckpt
        self._work[jid] = ckpt * job.world_min * job.step_ticks
        self.env.shrink(job, held)
        self.stats.shrink_nodes += held
        self.stats.preemptions += 1
        self._was_preempted.add(jid)
        self.env.submit(job)
        return held

    def post_step(self, k: int) -> None:
        """Advance every running gang by its held nodes' worth of work;
        complete jobs whose step target is reached (freeing the gang and
        rescheduling the queue onto it)."""
        done: list[int] = []
        for jid in self._running:
            job = self._task[jid]
            self._work[jid] += self._held[jid]
            if self._work[jid] >= self._target_work(job):
                done.append(jid)
        for jid in done:
            job = self._task.pop(jid)
            held = self._held.pop(jid)
            self._running.remove(jid)
            # return elastic growth before finish: the env frees the
            # task's base allocation itself, and its ledger carries the
            # grown amount
            if held > job.world_min:
                self.env.shrink(job, held - job.world_min)
            self._work[jid] = self._target_work(job)
            self.stats.jobs_completed += 1
            self.env.finish(job)
        self.stats.steps_done = sum(
            self._steps_of(j, self._work.get(j.jid, 0))
            for j in self.jobs[:self._stream_i])

    def control(self, k: int) -> None:
        if not (self._scan_every and k > 0
                and k % self._scan_every == self._phase % self._scan_every):
            return
        self.env.scan()
        self._maybe_grow()

    def _maybe_grow(self) -> None:
        """Elastic growth, oldest gang first: soak spare owned nodes,
        then ask the provider directly for the rest of the band (a
        direct request is arbitration-aware — it never overtakes parked
        elders — so growth can only soak genuine troughs)."""
        for jid in list(self._running):
            job = self._task[jid]
            want = job.world_max - self._held[jid]
            if want <= 0:
                continue
            g = min(want, self.env.free)
            if g > 0:
                self.env.grow(job, g)
                self._held[jid] += g
                self.stats.grow_nodes += g
                want -= g
            if want > 0 and self.env.engine is not None:
                room = (self.env.max_nodes - self.env.owned
                        if self.env.max_nodes is not None else want)
                ask = min(want, room)
                if ask > 0 and self.provider.request(
                        self.env.name, ask, self.clock.now(),
                        count_adjust=self.env.count_adjust):
                    self.env.acquire(ask)
                    self.env.grow(job, ask)
                    self._held[jid] += ask
                    self.stats.grow_nodes += ask

    def check_invariants(self) -> None:
        held_total = sum(self._held.values())
        bad = None
        if held_total != self.env.busy:
            bad = ("gang ledger divergence: %d held nodes != %d busy"
                   % (held_total, self.env.busy))
        elif self.env.busy > self.env.owned:
            bad = ("gangs exceed grant: %d busy > %d owned"
                   % (self.env.busy, self.env.owned))
        else:
            for jid in self._running:
                job = self._task[jid]
                if not job.world_min <= self._held[jid] <= job.world_max:
                    bad = ("gang %r outside its world band: %d not in "
                           "[%d, %d]" % (job.name, self._held[jid],
                                         job.world_min, job.world_max))
                    break
        if bad is not None:
            self.stats.invariant_breaches += 1
            if self.strict:
                raise TenantInvariantError(bad)

    def accumulate(self) -> None:
        self.stats.busy_node_ticks += self.env.busy * self.tick_s
        self.stats.owned_node_ticks += self.env.owned * self.tick_s
        self.stats.peak_owned = max(self.stats.peak_owned, self.env.owned)
        self.stats.queue_peak = max(self.stats.queue_peak,
                                    len(self.env.queue))

    # ------------------------------------------------------- retirement
    @property
    def retired(self) -> bool:
        return (self._stream_i == len(self.jobs) and self.env.all_done
                and not self._running)

    def finalize(self, ticks: int) -> TrainStats:
        self.stats.ticks = ticks
        self.stats.makespan_s = self.clock.now()
        if self.stats.owned_node_ticks > 0:
            self.stats.slot_utilization = (self.stats.busy_node_ticks
                                           / self.stats.owned_node_ticks)
        if not self.env.destroyed:
            self.env.destroy()
        self.stats.node_hours = self.provider.node_hours(
            self.env.name, now=self.clock.now())
        return self.stats

    # --------------------------------------------------- event-skipping
    def next_event_tick(self, k: int) -> int:
        """Earliest tick after ``k`` at which this tenant could act: an
        arrival coming due, a release boundary, a scan boundary with
        anything to negotiate or grow, a preempt boundary while foreign
        demand is parked, or a running gang reaching its step target.
        Quiet ticks in between only accrue work and integrals, which
        :meth:`skip_quiet_stats` applies in closed form."""
        cands = []
        if self._stream_i < len(self.jobs):
            cands.append(due_tick_floor(self.jobs[self._stream_i].arrival,
                                        self.tick_s))
        if self._release_every:
            cands.append(next_boundary(k, self._release_every, self._phase))
        growth_wanted = any(
            self._held[j] < self._task[j].world_max for j in self._running)
        if self._scan_every and (self.env.queue or growth_wanted
                                 or self.env._pending_req is not None):
            cands.append(next_boundary(k, self._scan_every, self._phase))
        if self._foreign_parked() > 0 and self._running:
            cands.append(next_boundary(k, self._preempt_every, self._phase))
        for jid in self._running:
            job = self._task[jid]
            left = self._target_work(job) - self._work[jid]
            cands.append(k + max(-(-left // max(self._held[jid], 1)), 1))
        if not cands:
            return self.max_ticks
        return max(min(cands), k + 1)

    def skip_quiet_stats(self, dq: int) -> None:
        """``dq`` quiet ticks in closed form: gang work accrual plus the
        busy/owned integrals (no gang can complete inside the span —
        :meth:`next_event_tick` bounded it by the earliest target)."""
        for jid in self._running:
            self._work[jid] += self._held[jid] * dq
        super().skip_quiet_stats(dq)


@dataclass(frozen=True)
class TrainTenantSpec:
    """What ``ServeFleet`` needs to wire one training tenant into the
    shared pool: the job stream, the management policy (B = the gang
    floor it never releases), and the yield cadence."""
    jobs: tuple[TrainJob, ...]
    policy: MgmtPolicy
    name: str = ""
    preempt_check_s: float | None = None


def drive_tenant(tenant: Tenant, *, max_ticks: int | None = None,
                 event_skip: bool = True):
    """Run one tenant standalone through the protocol hooks — the
    dedicated-baseline counterpart of ``ServeFleet.run()`` for tenants
    that need no engine pool (e.g. a ``TrainTenant`` on its own fixed
    nodes). Same phase order as the fleet tick, minus the pool step."""
    clock = tenant.clock
    bound = max_ticks if max_ticks is not None else tenant.max_ticks

    def tick(k: int) -> None:
        now = clock.now()
        tenant.begin_tick(now)
        tenant.pre_step(k)
        tenant.post_step(k)
        tenant.control(k)
        tenant.flush()
        tenant.check_invariants()
        tenant.accumulate()

    k = 0
    tick(k)
    while not tenant.retired and k < bound:
        if event_skip:
            kn = min(tenant.next_event_tick(k), bound)
            dq = kn - k - 1
            if dq > 0:
                tenant.skip_quiet_stats(dq)
                clock.advance(tenant.tick_s * dq)
                k += dq
        k += 1
        clock.advance(tenant.tick_s)
        tick(k)
    tenant.teardown(clock.now())
    return tenant.finalize(k)
