"""Paged KV-cache accounting: fixed-size pages, owner page lists, quotas.

This module is the *physical* half of the fleet's isolation story. PR 5's
``PartitionedEngine`` enforces ``sum_i(active_i * width_i) <= capacity`` as
slot arithmetic; ``PagedKVAllocator`` grounds the same invariant in a real
resource — fixed-size KV-cache pages handed out from one shared free list.
A tenant's width is literally its page quota: a width-``w`` batching slot
maps to ``w * pages_per_unit`` pages of KV cache, so an oversold pool is
not an accounting bug but an allocation failure.

The allocator is deliberately jax-free (plain ints and lists) so the
emulated fleet, the bench drivers, and the physical ``Engine`` all share
one ledger implementation. Owners are opaque hashable keys: the engine
keys by batch-slot index, the fleet keys by job id.

Conservation invariants are guarded raises (``ServeInvariantError``), not
asserts — they survive ``python -O`` (see DC101).
"""
from __future__ import annotations

from typing import Callable, Dict, Hashable, List, Optional

from repro.serve.driver import ServeInvariantError

__all__ = ["PagedKVAllocator", "pages_for"]


def pages_for(tokens: int, page_size: int) -> int:
    """Pages needed to hold ``tokens`` KV entries (at least one page)."""
    if page_size <= 0:
        raise ValueError("page_size must be positive")
    return max(-(-max(int(tokens), 1) // page_size), 1)


class PagedKVAllocator:
    """Free-list allocator for fixed-size KV pages with per-tenant quotas.

    Parameters
    ----------
    n_pages:
        Total pages in the pool, *including* any reserved null page.
    page_size:
        Tokens per page (recorded for callers; the allocator itself only
        counts pages).
    pages_per_unit:
        Pages that one provider node unit entitles a tenant to. Quota
        checks compare ``tenant_pages(t) <= quota_supplier(t)`` where the
        supplier is typically ``granted_units * pages_per_unit``.
    reserve_null:
        When True, page 0 is reserved as a scratch/null page that is never
        handed out. The physical engine points every inactive batch row's
        page table at it so stray decode writes can never land in a page
        owned by an active slot.
    """

    def __init__(self, n_pages: int, *, page_size: int = 1,
                 pages_per_unit: int = 1, reserve_null: bool = False):
        if n_pages < (2 if reserve_null else 1):
            raise ValueError("paged pool needs at least one allocatable page")
        if page_size <= 0 or pages_per_unit <= 0:
            raise ValueError("page_size and pages_per_unit must be positive")
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        self.pages_per_unit = int(pages_per_unit)
        self.null_page: Optional[int] = 0 if reserve_null else None
        first = 1 if reserve_null else 0
        # LIFO free list: freshly freed pages are reused first (cache-warm).
        self._free: List[int] = list(range(self.n_pages - 1, first - 1, -1))
        self._owned: Dict[Hashable, List[int]] = {}
        self._tenant_of: Dict[Hashable, Optional[str]] = {}
        self._quota: Dict[str, Callable[[], int]] = {}
        self.peak_used = 0

    # ------------------------------------------------------------- queries
    @property
    def capacity_pages(self) -> int:
        return self.n_pages - (1 if self.null_page is not None else 0)

    @property
    def used_pages(self) -> int:
        return self.capacity_pages - len(self._free)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def pages_of(self, owner: Hashable) -> List[int]:
        return list(self._owned.get(owner, ()))

    def owners(self) -> List[Hashable]:
        return list(self._owned)

    def tenant_pages(self, tenant: str) -> int:
        return sum(len(pages) for owner, pages in self._owned.items()
                   if self._tenant_of.get(owner) == tenant)

    def set_quota(self, tenant: str, supplier: Callable[[], int]) -> None:
        """Register a live page-quota supplier (e.g. granted units * rate)."""
        self._quota[tenant] = supplier

    # ----------------------------------------------------------- lifecycle
    def alloc(self, owner: Hashable, n: int, *,
              tenant: Optional[str] = None) -> List[int]:
        """Allocate ``n`` pages for ``owner``; raises on any ledger breach.

        Allocation failure is an invariant error by design: every caller
        sizes its request from the same ``decode_budget``/``pages_for``
        formulas that sized the pool, so a failed alloc means the slot
        arithmetic and the physical pool disagree.
        """
        if n <= 0:
            raise ServeInvariantError(f"alloc of {n} pages for {owner!r}")
        if owner in self._owned:
            raise ServeInvariantError(f"owner {owner!r} already holds pages")
        if n > len(self._free):
            raise ServeInvariantError(
                f"paged pool exhausted: need {n}, free {len(self._free)} "
                f"of {self.capacity_pages}")
        if tenant is not None and tenant in self._quota:
            quota = self._quota[tenant]()
            if self.tenant_pages(tenant) + n > quota:
                raise ServeInvariantError(
                    f"tenant {tenant!r} page quota exceeded: "
                    f"{self.tenant_pages(tenant)} + {n} > {quota}")
        pages = [self._free.pop() for _ in range(n)]
        self._owned[owner] = pages
        self._tenant_of[owner] = tenant
        self.peak_used = max(self.peak_used, self.used_pages)
        return list(pages)

    def free(self, owner: Hashable) -> List[int]:
        """Return ``owner``'s pages to the free list."""
        if owner not in self._owned:
            raise ServeInvariantError(f"free of unknown owner {owner!r}")
        pages = self._owned.pop(owner)
        self._tenant_of.pop(owner, None)
        self._free.extend(reversed(pages))
        return list(pages)

    # A preemption is physically identical to a finish: the pages come
    # back whole; only the caller's bookkeeping (requeue vs retire)
    # differs. Kept as a named alias so call sites read correctly.
    preempt = free

    # ----------------------------------------------------------- invariant
    def check_conservation(self) -> None:
        """Guarded conservation sweep: raises ``ServeInvariantError``.

        - used + free == capacity (no page leaked or minted),
        - no page double-mapped across owners,
        - the null page is never owned,
        - every tenant with a registered quota is within it.
        """
        seen: Dict[int, Hashable] = {}
        for owner, pages in self._owned.items():
            for p in pages:
                if p in seen:
                    raise ServeInvariantError(
                        f"page {p} double-mapped: {seen[p]!r} and {owner!r}")
                if self.null_page is not None and p == self.null_page:
                    raise ServeInvariantError(
                        f"null page owned by {owner!r}")
                if not 0 <= p < self.n_pages:
                    raise ServeInvariantError(f"page {p} out of range")
                seen[p] = owner
        in_free = set(self._free)
        if len(in_free) != len(self._free):
            raise ServeInvariantError("duplicate pages on the free list")
        if in_free & set(seen):
            raise ServeInvariantError("page both free and owned")
        if len(seen) + len(self._free) != self.capacity_pages:
            raise ServeInvariantError(
                f"page conservation broken: {len(seen)} owned + "
                f"{len(self._free)} free != {self.capacity_pages}")
        for tenant, supplier in self._quota.items():
            used = self.tenant_pages(tenant)
            quota = supplier()
            if used > quota:
                raise ServeInvariantError(
                    f"tenant {tenant!r} over page quota: {used} > {quota}")
