"""Multi-tenant serving fleet: N serve TREs partitioning one engine pool.

The paper's economies-of-scale claim is about *consolidating heterogeneous
workloads on one platform*; ``repro.serve.driver.ServeDriver`` (PR 3)
serves one MTC tenant. This module is the consolidation step for the
*serving* path, following PhoenixCloud's coordinated runtime-environment
provisioning (arXiv:1006.1401) and continuous-batching slot scheduling à
la Orca/vLLM:

  - **N tenant drivers, one engine pool**: each tenant is a full
    ``ServeDriver`` lane — its own ``MTCRuntimeEnv`` (trigger monitor,
    FCFS dispatch, DR1/DR2 negotiation), its own management policy and
    workflow arrival stream — all replayed on ONE shared ``TickClock``
    against ONE ``ResourceProvider`` whose capacity **is** the engine
    pool: 1 batching slot = 1 node, partitioned across tenants by the
    provider's ``CoordinationPolicy`` (``first-come`` arrival-order vs
    ``coordinated`` urgency arbitration + water-filling). Deferred grants
    land between control ticks through each env's ``grant_listener``.
  - **slot isolation is enforced, not assumed**: ``PartitionedEngine``
    fronts one backing engine (``EmulatedEngine`` or ``JaxEngineAdapter``)
    with per-tenant admit accounting — a tenant's admit is checked against
    *its own* granted slot count at admission AND every tick, so tenant A
    can never decode in tenant B's granted slots. Violations raise
    ``ServeInvariantError`` (never ``assert``: the checks survive
    ``python -O``).
  - **one decode step per tick, fleet-wide**: all tenants' active slots
    decode together in a single backing-engine step (continuous batching
    across the fleet); finished jids are routed back to their owning
    tenant's env. A tenant whose stream completes is destroyed mid-run,
    returning its slots to the pool for the others — which is where the
    consolidated fleet's billed node-hours fall below N dedicated engines.

The fleet's tick replays the SAME phases as ``ServeDriver._tick`` in the
same order (arrivals -> contention -> release checks -> engine step ->
scans -> admission flush -> invariants), phase-major across tenants, so a
``ServeFleet`` of one tenant is bit-identical to a standalone
``ServeDriver`` on the same stream and grant sequence — the parity
contract in ``tests/README.md``.

The ``dawningcloud-serve-fleet`` scenario registers in
``repro.core.registry`` next to the emulated usage models: it carries the
fleet's policy/capacity defaults (pool sized at the peak hourly-averaged
offered decode load — the serving analogue of
``sim.systems.aggregate_hourly_peak``) and serves as the benchmark entry
point. It is tick-driven, not ``Sim``-driven, so it runs through
:meth:`ServeFleetSystem.serve` (or ``ServeFleet`` directly), not
``run_system``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Sequence

from repro.core.policy import MgmtPolicy
from repro.core.provider import ResourceProvider
from repro.core.provision import BILL_UNIT_S
from repro.core.registry import System, register_system
from repro.core.tre import TickClock
from repro.core.types import Job
from repro.serve.driver import (
    EmulatedEngine, ServeDriver, ServeInvariantError, ServeStats,
    decode_budget, default_max_ticks, due_tick_floor, engine_service_ticks,
    replay_contention,
)
from repro.serve.paged import PagedKVAllocator, pages_for
from repro.serve.tenant import Tenant, TrainTenant, TrainTenantSpec


# --------------------------------------------------------------------------
# slot-partitioned engine front
# --------------------------------------------------------------------------
class TenantSlice:
    """One tenant's view of the shared pool: the 3-method engine adapter
    contract (``capacity`` / ``active_count`` / ``admit_many`` / ``step``)
    a ``ServeDriver`` expects, scoped to the tenant's own slots. Admits
    are accounted against the tenant's granted node units by the owning
    ``PartitionedEngine``; ``step()`` drains the finished jids the pool's
    fleet-wide decode step routed to this tenant. ``capacity_units`` is
    the whole pool in node units — the slot width is carried by the
    tenant's driver (``ServeDriver.slot_width``), which weights every
    slots-vs-units comparison."""

    def __init__(self, pool: "PartitionedEngine", tenant: str):
        self._pool = pool
        self.tenant = tenant
        self.capacity = pool.capacity
        self.capacity_units = pool.capacity

    @property
    def width(self) -> int:
        return self._pool.width_of(self.tenant)

    @property
    def active_count(self) -> int:
        return self._pool.active_of(self.tenant)

    def service_ticks(self, job: Job) -> int:
        return engine_service_ticks(self._pool.backing, job)

    def admit_many(self, jobs: Sequence[Job]) -> Sequence[Job]:
        return self._pool.admit_many(self.tenant, jobs)

    def step(self) -> list[int]:
        return self._pool.take_finished(self.tenant)

    def next_finish_in(self):
        """Pool-wide finish horizon (not per-tenant): another tenant's
        finish frees shared slots, so a lane's quiet span must end there
        too — conservative is correct for event-skipping."""
        fn = getattr(self._pool.backing, "next_finish_in", None)
        return fn() if fn is not None else None


class PartitionedEngine:
    """One backing engine, N tenant partitions with per-tenant slot
    widths. Owns the jid -> tenant routing and the *weighted* per-tenant
    accounting that makes isolation a checked invariant: a slot of a
    width-``w`` tenant costs ``w`` node units of the shared pool, so an
    admit beyond the tenant's granted units — or beyond the pool's unit
    capacity — raises ``ServeInvariantError`` (counted instead when
    ``strict=False``), and :meth:`check_isolation` re-asserts every
    tenant's ``active_slots * width <= granted`` plus
    ``sum_i(active_i * width_i) <= capacity`` at every fleet tick. An
    all-width-1 pool is bit-identical to the unweighted partitioning.

    With a ``pager`` (``PagedKVAllocator``) the isolation invariant is
    enforced *physically* in KV-cache pages, not just slot arithmetic:
    every admitted job allocates its cache-budget worth of pages under
    its tenant's tag, a tenant's page quota is its live granted units
    times ``pages_per_unit``, and :meth:`check_isolation` adds page
    conservation + per-tenant quota sweeps. A job's page need is capped
    at its slot's width worth of pages, so page accounting can never bind
    tighter than the slot arithmetic — the paged fleet's admit/stat
    behavior is field-for-field identical to the unpaged one, with the
    ledger checked on top. When the backing engine carries its own
    allocator (a paged ``repro.serve.engine.Engine`` under
    ``JaxEngineAdapter``), the two ledgers' totals are cross-checked
    every tick."""

    def __init__(self, backing, *, strict: bool = True,
                 pager: PagedKVAllocator | None = None):
        self.backing = backing
        self.capacity = backing.capacity
        self.strict = strict
        self.pager = pager
        self.isolation_violations = 0
        self._granted = {}                  # tenant -> () -> granted units
        self._active: dict[str, int] = {}   # tenant -> active slots
        self._width: dict[str, int] = {}    # tenant -> units per slot
        self._owner: dict[int, str] = {}    # active jid -> tenant
        self._finished: dict[str, list[int]] = {}
        self._deferred: set[int] = set()    # jids truncated (counted once)
        # non-engine pool consumers (training tenants' gang-held nodes):
        # name -> () -> live node units, counted in the capacity sweep
        self._external: dict = {}

    # ------------------------------------------------------------ wiring
    def view(self, tenant: str, width: int = 1) -> TenantSlice:
        if tenant in self._active:
            raise ValueError(f"tenant {tenant!r} already has a slice")
        if width < 1:
            raise ValueError(f"slot width must be >= 1, got {width}")
        if width > self.capacity:
            raise ValueError(
                f"tenant {tenant!r} slot width {width} exceeds the pool "
                f"({self.capacity} units): one slot could never be granted")
        self._active[tenant] = 0
        self._width[tenant] = int(width)
        self._finished[tenant] = []
        return TenantSlice(self, tenant)

    def bind(self, tenant: str, granted) -> None:
        """Attach the tenant's granted-slot supplier (its env's live
        ``owned`` count) — the ceiling its admits are checked against.
        With a pager the same supplier prices the tenant's page quota:
        granted units times ``pages_per_unit``."""
        self._granted[tenant] = granted
        if self.pager is not None:
            self.pager.set_quota(
                tenant,
                lambda: self.granted_of(tenant) * self.pager.pages_per_unit)

    def attach_external(self, name: str, units) -> None:
        """Register a pool consumer that holds nodes WITHOUT decoding in
        engine slots (a training tenant's gang-held nodes): its live unit
        count joins the capacity sweep in :meth:`check_isolation`, so
        serve decode + training gangs together can never exceed the pool
        — the mixed-species form of the weighted isolation invariant."""
        if name in self._external or name in self._active:
            raise ValueError(f"pool consumer {name!r} already registered")
        self._external[name] = units

    @property
    def external_units(self) -> int:
        """Node units held by non-engine consumers (training gangs)."""
        return sum(fn() for fn in self._external.values())

    # ---------------------------------------------------------- accounts
    def active_of(self, tenant: str) -> int:
        return self._active[tenant]

    def width_of(self, tenant: str) -> int:
        return self._width[tenant]

    def units_of(self, tenant: str) -> int:
        """Node units the tenant's active slots occupy."""
        return self._active[tenant] * self._width[tenant]

    @property
    def active_total(self) -> int:
        return sum(self._active.values())

    @property
    def active_units(self) -> int:
        """Weighted occupancy of the whole pool, in node units."""
        return sum(a * self._width[t] for t, a in self._active.items())

    def granted_of(self, tenant: str) -> int:
        fn = self._granted.get(tenant)
        return fn() if fn is not None else self.capacity

    def _violate(self, msg: str) -> None:
        self.isolation_violations += 1
        if self.strict:
            raise ServeInvariantError(msg)

    # ------------------------------------------------------------- admit
    def admit_many(self, tenant: str, jobs: Sequence[Job]) -> list[Job]:
        """Admit the tenant's batch; returns the jobs actually admitted.
        In strict mode that is all of them or a raise; a non-strict pool
        may truncate to what fits, and the CALLER must requeue the
        remainder (``ServeDriver._flush_admissions`` keeps it in the
        launch buffer) — dropping it silently loses workflows."""
        if not jobs:
            return []
        w = self._width[tenant]
        granted = self.granted_of(tenant)
        if (self._active[tenant] + len(jobs)) * w > granted:
            self._violate(
                "tenant %r admitting into another tenant's slots: "
                "(%d active + %d admitted) slots x width %d > "
                "%d granted units"
                % (tenant, self._active[tenant], len(jobs), w, granted))
        free = self.capacity - self.active_units
        if len(jobs) * w > free:
            # non-strict (counting) mode must not crash in the backing
            # engine: count the pool-level violation here and admit only
            # what fits — the remainder is returned to the caller's
            # launch buffer, never dropped. The caller retries that
            # remainder every tick, so a violation is counted only when
            # the batch contains jobs not already deferred — the counter
            # measures over-commit events, not backlog duration
            fit = max(free // w, 0)
            dropped = list(jobs)[fit:]
            if self.strict or any(j.jid not in self._deferred
                                  for j in dropped):
                self._violate(
                    "admitting beyond the pool: %d jobs x width %d > "
                    "%d free units"
                    % (len(jobs), w, free))
            self._deferred.update(j.jid for j in dropped)
            jobs = list(jobs)[:fit]
            if not jobs:
                return []
        for job in jobs:
            if job.jid in self._owner:
                raise ValueError(
                    f"jid {job.jid} already active (owned by "
                    f"{self._owner[job.jid]!r}); fleet streams need "
                    f"globally unique jids")
        self.backing.admit_many(jobs)       # raises beyond pool free slots
        self._active[tenant] += len(jobs)
        for job in jobs:
            self._owner[job.jid] = tenant
            self._deferred.discard(job.jid)
            if self.pager is not None:
                # the slot check above passed, and a job never needs more
                # pages than its slot's width worth — so this alloc can
                # only fail if the ledgers disagree (an invariant error)
                self.pager.alloc(job.jid, self._job_pages(tenant, job),
                                 tenant=tenant)
        return list(jobs)

    def _job_pages(self, tenant: str, job: Job) -> int:
        """Pages a job's cache budget needs, capped at its slot's width
        worth. Sized with THE shared ``decode_budget`` formula against
        the backing engine's cache depth, so a physically-paged backing
        engine (``Engine(page_size=...)``) reserves the same totals and
        the two ledgers stay cross-checkable."""
        g = self.pager
        quota_pages = self._width[tenant] * g.pages_per_unit
        depth = getattr(self.backing, "max_len", None)
        if depth is None:
            depth = quota_pages * g.page_size
        plen = min(max(job.prompt_len, 1), depth - 1)
        budget = decode_budget(job.decode_len, plen, depth)
        return min(pages_for(plen + budget, g.page_size), quota_pages)

    # -------------------------------------------------------------- step
    def step_all(self) -> None:
        """ONE decode tick for the whole pool; route finished jids to
        their owning tenant's buffer (drained by the slices' ``step``)."""
        for jid in self.backing.step():
            tenant = self._owner.pop(jid)
            self._active[tenant] -= 1
            self._finished[tenant].append(jid)
            if self.pager is not None:
                self.pager.free(jid)

    def take_finished(self, tenant: str) -> list[int]:
        out = self._finished[tenant]
        self._finished[tenant] = []
        return out

    # -------------------------------------------------------- invariants
    def check_isolation(self) -> None:
        """Every tick: no tenant decodes beyond its granted node units,
        and the weighted partitions together never exceed the pool —
        ``sum_i(active_i * width_i) <= capacity``, the heterogeneous
        isolation invariant."""
        for tenant, active in self._active.items():
            granted = self.granted_of(tenant)
            units = active * self._width[tenant]
            if units > granted:
                self._violate(
                    "tenant %r decoding in foreign slots: %d active x "
                    "width %d > %d granted units"
                    % (tenant, active, self._width[tenant], granted))
        ext = self.external_units
        if self.active_units + ext > self.capacity:
            if ext:
                self._violate(
                    "partitions exceed the pool: %d active + %d external "
                    "(training) units > %d"
                    % (self.active_units, ext, self.capacity))
            else:
                self._violate(
                    "partitions exceed the pool: %d active units > %d"
                    % (self.active_units, self.capacity))
        if self.pager is not None:
            # the physical form of the same invariant: pages conserved,
            # no tenant mapping pages beyond its granted quota
            self.pager.check_conservation()
            backing_pager = getattr(self.backing, "pager", None)
            if (backing_pager is not None
                    and backing_pager.used_pages != self.pager.used_pages):
                self._violate(
                    "page ledger divergence: engine maps %d pages, pool "
                    "accounts %d"
                    % (backing_pager.used_pages, self.pager.used_pages))


def rekey_disjoint(tenant_streams):
    """Clone per-tenant streams onto disjoint jid ranges (deps remapped in
    step) so independently-generated ``request_stream``s — which each
    re-key from 0 — can share one ``PartitionedEngine``. Job objects are
    replaced, not mutated; pass the clones wherever task timings are read
    back."""
    out, base = [], 0
    for stream in tenant_streams:
        jids = [j.jid for _, jobs in stream for j in jobs]
        lo = min(jids, default=0)
        off = base - lo
        out.append([(t, [replace(j, jid=j.jid + off,
                                 deps=tuple(d + off for d in j.deps))
                         for j in jobs]) for t, jobs in stream])
        base += (max(jids, default=lo) - lo + 1) if jids else 0
    return out


# --------------------------------------------------------------------------
# the fleet
# --------------------------------------------------------------------------
@dataclass
class FleetStats:
    """One fleet run: aggregates + the per-tenant ``ServeStats``."""
    name: str
    n_tenants: int
    capacity: int
    coordination: str
    ticks: int = 0
    tick_s: float = 1.0
    workflows_expected: int = 0
    workflows_completed: int = 0
    tasks_completed: int = 0
    makespan_s: float = 0.0
    busy_node_ticks: float = 0.0
    owned_node_ticks: float = 0.0
    slot_utilization: float = 0.0       # busy / owned integrals (leased)
    pool_utilization: float = 0.0       # busy integral / (capacity x span)
    node_hours: float = 0.0             # billed, summed over tenants
    peak_pool_active: int = 0           # peak fleet-wide busy slots
    peak_pool_units: int = 0            # peak width-weighted busy units
    widths: list[int] = field(default_factory=list)  # per-tenant slot width
    deferred_grants: int = 0
    deferred_nodes: int = 0
    over_admissions: int = 0            # summed over tenants (== 0)
    isolation_violations: int = 0       # PartitionedEngine checks (== 0)
    tenants: list[dict] = field(default_factory=list)

    def as_dict(self) -> dict:
        return dict(self.__dict__)


class ServeFleet:
    """N ``ServeDriver`` tenants partitioning one engine pool.

    tenant_streams: one ``request_stream``-style arrival stream per
        tenant. Jids must be globally unique ACROSS tenants (the shared
        engine routes finishes by jid): offset each tenant's stream, or
        build them with disjoint bases as ``benchmarks/serve_fleet.py``
        does.
    engine: the backing engine for the whole pool (``EmulatedEngine`` /
        ``JaxEngineAdapter``); its capacity IS the platform capacity.
    provider: optional pre-built ``ResourceProvider``; must have
        ``capacity == engine.capacity`` (1 slot = 1 node). Default: one is
        built with ``coordination`` / ``quotas`` / ``reservations``.
    policies: one ``MgmtPolicy`` for every tenant, or a per-tenant list.
    stagger: spread tenants' scan/release cycles across their intervals
        (phase ``i * interval / N``) so N tenants' control ticks
        interleave instead of colliding; a single tenant keeps phase 0,
        which is what makes ``ServeFleet`` of one tenant bit-identical to
        ``ServeDriver``.
    contention: fleet-level co-tenant load replayed against the provider,
        same format as ``ServeDriver``'s.
    widths: per-tenant slot widths in node units (the heterogeneous-fleet
        axis: a big-model tenant's batching slot costs ``w > 1`` units of
        the shared pool). Every task of tenant ``i`` must carry
        ``nodes == widths[i]`` — provider grants and env accounting are
        unit-denominated. Default: all 1 (bit-identical to the
        homogeneous fleet).
    page_size: tokens per KV page. When set, the pool's weighted
        isolation is enforced physically through a ``PagedKVAllocator``
        sized at ``capacity * ceil(max_len / page_size)`` pages — every
        admit allocates real pages under its tenant, quotas follow live
        grants, and conservation is swept each tick. Requires the backing
        engine to expose ``max_len`` (its cache depth prices a job's page
        need). Stats are unchanged field-for-field; the ledger rides
        underneath.
    train: ``TrainTenantSpec``s for gang-scheduled HTC training tenants
        sharing the provider pool (``repro.serve.tenant.TrainTenant``).
        Their gangs hold provider nodes without decoding in engine
        slots, so they join the isolation sweep as *external* pool
        consumers: serve decode units + training gang units <= capacity,
        every tick. They preempt themselves for parked serve demand and
        appear in ``FleetStats.tenants`` next to the serve lanes. The
        default (none) leaves the all-MTC fleet bit-identical to PR 8.
    """

    def __init__(self, tenant_streams: Sequence[Sequence[tuple[float, list[Job]]]],
                 *, engine, provider: ResourceProvider | None = None,
                 coordination="first-come",
                 quotas=None, reservations=None,
                 policies: MgmtPolicy | Sequence[MgmtPolicy] = None,
                 names: Sequence[str] | None = None,
                 tick_s: float = 1.0, stagger: bool = True,
                 contention: Sequence[tuple[float, str, int]] = (),
                 scheduler=None, max_ticks: int | None = None,
                 strict: bool = True, name: str = "serve-fleet",
                 widths: Sequence[int] | None = None,
                 event_skip: bool = False,
                 page_size: int | None = None,
                 train: Sequence[TrainTenantSpec] = ()):
        if not tenant_streams:
            raise ValueError("a fleet needs at least one tenant stream")
        n = len(tenant_streams)
        widths = [1] * n if widths is None else [int(w) for w in widths]
        if len(widths) != n:
            raise ValueError("need one slot width per tenant")
        seen: dict[int, int] = {}
        for i, stream in enumerate(tenant_streams):
            for _, jobs in stream:
                for j in jobs:
                    if j.jid in seen:
                        raise ValueError(
                            f"jid {j.jid} appears in tenant {seen[j.jid]} "
                            f"and tenant {i}: fleet streams must use "
                            f"globally unique jids (offset each tenant)")
                    seen[j.jid] = i
                    if j.nodes != widths[i]:
                        raise ValueError(
                            f"tenant {i} task {j.name!r} carries "
                            f"nodes={j.nodes} but the tenant's slot width "
                            f"is {widths[i]}: streams must be emitted at "
                            f"the tenant's width (request_stream(width=))")
        if provider is None:
            provider = ResourceProvider(
                engine.capacity, coordination=coordination,
                quotas=quotas, reservations=reservations)
        if provider.capacity != engine.capacity:
            raise ValueError(
                f"provider capacity ({provider.capacity}) must equal the "
                f"engine pool ({engine.capacity}): 1 batching slot = 1 node")
        if policies is None:
            policies = MgmtPolicy.mtc(4, 2.0)
        if isinstance(policies, MgmtPolicy):
            policies = [policies] * n
        if len(policies) != n:
            raise ValueError("need one policy per tenant")
        names = list(names) if names is not None else [
            f"{name}-t{i}" for i in range(n)]
        self.name = name
        self.provider = provider
        if page_size is not None:
            depth = getattr(engine, "max_len", None)
            if depth is None:
                raise ValueError(
                    "page_size needs an engine with a max_len cache depth "
                    "to price page quotas (EmulatedEngine(max_len=...) or "
                    "a paged jax engine)")
            ppu = -(-int(depth) // int(page_size))
            pager = PagedKVAllocator(engine.capacity * ppu,
                                     page_size=int(page_size),
                                     pages_per_unit=ppu)
        else:
            pager = None
        self.pool = PartitionedEngine(engine, strict=strict, pager=pager)
        self.clock = TickClock()
        self.tick_s = tick_s
        self.strict = strict
        self._contention = sorted(contention, key=lambda e: e[0])
        self._cont_i = 0
        self.lanes: list[Tenant] = []
        for i, (stream, pol, tname, w) in enumerate(
                zip(tenant_streams, policies, names, widths)):
            every = max(int(round(pol.scan_interval / tick_s)), 1)
            phase = int(round(i * every / n)) % every if stagger else 0
            lane = ServeDriver(
                stream, provider=provider,
                engine=self.pool.view(tname, width=w),
                policy=pol, name=tname, scheduler=scheduler,
                tick_s=tick_s, strict=strict, clock=self.clock, phase=phase,
                slot_width=w)
            self.pool.bind(tname, lambda env=lane.env: env.owned)
            self.lanes.append(lane)
        # training tenants: gang-held nodes come from the SAME provider
        # pool, counted against capacity through the pool's external sweep
        # (they hold nodes without decoding in engine slots)
        for i, spec in enumerate(train):
            tname = spec.name or f"{name}-train{i}"
            every = max(int(round(spec.policy.scan_interval / tick_s)), 1)
            phase = (int(round((n + i) * every / (n + len(train)))) % every
                     if stagger else 0)
            tt = TrainTenant(
                spec.jobs, provider=provider, clock=self.clock,
                policy=spec.policy, name=tname, tick_s=tick_s,
                strict=strict, phase=phase, max_nodes=engine.capacity,
                preempt_check_s=spec.preempt_check_s)
            self.pool.attach_external(tname, lambda env=tt.env: env.busy)
            self.lanes.append(tt)
        self._live = list(self.lanes)
        if max_ticks is None:
            merged = [ev for s in tenant_streams for ev in s]
            max_ticks = default_max_ticks(merged, engine, tick_s)
            for lane in self.lanes:
                if isinstance(lane, TrainTenant):
                    max_ticks = max(max_ticks, lane.max_ticks)
        self.max_ticks = max_ticks
        # fleet-level event-skipping: a tick is quiet only if it is quiet
        # for EVERY lane (and the shared pool can jump its countdowns)
        self.event_skip = bool(event_skip) and callable(
            getattr(engine, "next_finish_in", None)) and callable(
            getattr(engine, "advance_quiet", None))
        self.stats = FleetStats(
            name=name, n_tenants=n, capacity=engine.capacity,
            coordination=getattr(provider.policy, "name", "?"),
            tick_s=tick_s, widths=list(widths),
            workflows_expected=sum(len(s) for s in tenant_streams))

    # -------------------------------------------------------------- tick
    def _replay_contention(self, now: float) -> None:
        self._cont_i = replay_contention(self.provider, self._contention,
                                         self._cont_i, now, self.strict)

    def _tick(self, k: int) -> None:
        """``ServeDriver._tick``'s phases, phase-major across tenants,
        with ONE fleet-wide decode step between the release and scan
        phases — driven through the ``Tenant`` protocol hooks, which for
        a serve lane alias exactly the old phase methods (so the all-MTC
        fleet is bit-identical to the pre-protocol tick; pinned by
        ``tests/test_tenant.py``). A training lane's ``pre_step`` is its
        preemption check — deliberately in the release phase, so vacated
        nodes drain to parked serve requests before this tick's scans.
        Keep the order mirrored with the single-tenant tick body or
        fleet(N=1) parity breaks."""
        now = self.clock.now()
        for lane in self._live:
            lane.begin_tick(now)
        self._replay_contention(now)
        for lane in self._live:
            lane.pre_step(k)
        self.pool.step_all()
        for lane in self._live:
            lane.post_step(k)
        for lane in self._live:
            lane.control(k)
        for lane in self._live:
            lane.flush()
        for lane in self._live:
            lane.check_invariants()
        self.pool.check_isolation()
        for lane in self._live:
            lane.accumulate()
        self.stats.peak_pool_active = max(self.stats.peak_pool_active,
                                          self.pool.active_total)
        self.stats.peak_pool_units = max(self.stats.peak_pool_units,
                                         self.pool.active_units)
        # retire completed tenants: the destroy closes their leases and
        # hands the slots back to the pool for everyone still running —
        # the consolidation saving a dedicated engine can never realize
        for lane in [ln for ln in self._live if ln.retired]:
            lane.finalize(k)
            self._live.remove(lane)

    # ---------------------------------------------------- event-skipping
    def next_event_tick(self, k: int) -> int:
        """Earliest tick after ``k`` at which ANY lane could act — the
        fleet-wide quiet span is the min over the lanes' horizons (each
        lane's already folds in the shared pool's next finish through
        ``TenantSlice.next_finish_in``, so one tenant's finish ends every
        lane's quiet span: the freed slots are shared). The fleet-level
        contention stream is a separate candidate — it replays against
        the shared provider outside any lane."""
        cands = [lane.next_event_tick(k) for lane in self._live]
        if self._cont_i < len(self._contention):
            cands.append(due_tick_floor(self._contention[self._cont_i][0],
                                        self.tick_s))
        if not cands:
            return self.max_ticks
        return max(min(cands), k + 1)

    def _skip_quiet(self, dq: int) -> None:
        """Advance ``dq`` fleet-quiet ticks in closed form: ONE pool-wide
        countdown jump plus each live lane's stats integrals — the exact
        batch of what ``dq`` dense fleet ticks would have done (the pool
        refuses to jump past a finish)."""
        if self.pool.backing.active_count:
            self.pool.backing.advance_quiet(dq)
        for lane in self._live:
            lane.skip_quiet_stats(dq)
        self.clock.advance(self.tick_s * dq)

    # --------------------------------------------------------------- run
    def run(self) -> FleetStats:
        k = 0
        self._tick(k)
        while self._live and k < self.max_ticks:
            if self.event_skip:
                kn = min(self.next_event_tick(k), self.max_ticks)
                dq = kn - k - 1
                if dq > 0:
                    self._skip_quiet(dq)
                    k += dq
            k += 1
            self.clock.advance(self.tick_s)
            self._tick(k)
        # tick-budget cutoff stragglers: withdraw every parked request
        # BEFORE the finalize loop — one lane's destroy releases its
        # nodes, and a grant landing in another straggler's queue between
        # two destroys would open a zero-duration lease billed a whole
        # hour (same guard as the emulator teardown in sim.systems)
        now = self.clock.now()
        for lane in self._live:
            lane.teardown(now)
        for lane in self._live:
            lane.finalize(k)
        self._live = []
        s = self.stats
        s.ticks = k
        s.makespan_s = self.clock.now()
        for lane in self.lanes:
            lane.rollup(s)
        if s.owned_node_ticks > 0:
            s.slot_utilization = s.busy_node_ticks / s.owned_node_ticks
        span = max(s.makespan_s, self.tick_s)
        s.pool_utilization = s.busy_node_ticks / (s.capacity * span)
        s.isolation_violations = self.pool.isolation_violations
        return s


# --------------------------------------------------------------------------
# registered scenario
# --------------------------------------------------------------------------
def aggregate_decode_peak(tenant_streams, *, tick_s: float = 1.0) -> int:
    """Peak hourly-averaged offered decode load across the whole fleet, in
    node units — the serving analogue of ``sim.systems.
    aggregate_hourly_peak``: the unit count that serves every hour's
    *arriving* decode work within that hour. Width-weighted: a task of a
    width-``w`` tenant (``j.nodes == w``) occupies ``w`` units for its
    service ticks, so heterogeneous capacity planning charges big-model
    work at its true pool cost. Sub-hour bursts queue in the envs instead
    of being provisioned for, so the pool grows sublinearly in the tenant
    count while each tenant's dedicated engine must cover its own peak
    hour."""
    buckets: dict[int, float] = {}
    for stream in tenant_streams:
        for t, jobs in stream:
            # same service model as EmulatedEngine.service_ticks: token
            # marks when present, else runtime in ticks — capacity
            # planning must count the work the engine will actually
            # serve, weighted by each task's node units
            work = sum((j.decode_len if j.decode_len > 0
                        else max(int(math.ceil(j.runtime / tick_s)), 1))
                       * max(j.nodes, 1)
                       for j in jobs) * tick_s
            buckets[int(t // BILL_UNIT_S)] = (
                buckets.get(int(t // BILL_UNIT_S), 0.0) + work)
    if not buckets:
        return 1
    return max(int(math.ceil(max(buckets.values()) / BILL_UNIT_S)), 1)


@register_system("dawningcloud-serve-fleet")
class ServeFleetSystem(System):
    """Multi-tenant trace-rate serving (the serve-path counterpart of
    ``dawningcloud-coordinated``): N serve TREs on one engine pool sized
    at the peak hourly-averaged offered decode load, slots partitioned by
    the coordination policy. Tick-driven rather than ``Sim``-driven, so
    it runs through :meth:`serve`, not ``run_system``."""

    coordination = "coordinated"

    def default_policy(self) -> MgmtPolicy:
        # MTC serving: small never-released floor, eager growth, 5-minute
        # release windows (the 3 s scans are the MTC §3.2.2.2 cadence)
        return MgmtPolicy(initial=4, ratio=2.0, scan_interval=3.0,
                          release_interval=300.0)

    def default_capacity(self, tenant_streams, policies,
                         tick_s: float = 1.0,
                         widths: Sequence[int] | None = None) -> int:
        hourly = aggregate_decode_peak(tenant_streams, tick_s=tick_s)
        # liveness floor: every tenant's never-released B must coexist
        # with at least one more slot of the widest tenant to drain
        # (1 MTC task = width node units)
        sum_b = sum(p.initial for p in policies)
        return max(hourly, sum_b + max(widths or (1,)))

    def build(self, ctx, workload):
        raise NotImplementedError(
            f"{self.name} is tick-driven (TickClock), not "
            "Sim-driven: use ServeFleetSystem.serve(tenant_streams, ...) "
            "or repro.serve.fleet.ServeFleet directly")

    def serve(self, tenant_streams, *, capacity: int | None = None,
              coordination=None, policies=None, engine=None,
              widths: Sequence[int] | None = None,
              **fleet_kw) -> FleetStats:
        """Build and run a fleet over ``tenant_streams`` with this
        scenario's defaults (an ``EmulatedEngine`` pool sized by
        :meth:`default_capacity` unless given)."""
        n = len(tenant_streams)
        if policies is None:
            policies = [self.default_policy()] * n
        elif isinstance(policies, MgmtPolicy):
            policies = [policies] * n
        if engine is None:
            if capacity is None:
                capacity = self.default_capacity(
                    tenant_streams, policies,
                    tick_s=fleet_kw.get("tick_s", 1.0), widths=widths)
            engine = EmulatedEngine(capacity,
                                    tick_s=fleet_kw.get("tick_s", 1.0))
        fleet = ServeFleet(
            tenant_streams, engine=engine,
            coordination=coordination if coordination is not None
            else self.coordination,
            policies=list(policies), widths=widths, **fleet_kw)
        return fleet.run()


@register_system("dawningcloud-serve-hetero")
class ServeHeteroFleetSystem(ServeFleetSystem):
    """Heterogeneous serving fleet: tenants of MIXED slot widths / model
    sizes consolidated on one weighted pool — the configuration the
    paper's economies-of-scale argument (§2, §5; arXiv:1004.1276 across
    communities of different sizes) is actually about. Tenant ``i``
    defaults to ``width_mix[i % len(width_mix)]`` node units per slot
    (small / medium / large model classes); grants, isolation and
    capacity planning are width-weighted throughout, and the billed
    node-hours come out in the same units as a dedicated width-sized
    engine's, so the consolidation ratio stays apples-to-apples."""

    width_mix: tuple[int, ...] = (1, 2, 4)

    def tenant_widths(self, n: int) -> list[int]:
        """Default width assignment: cycle the mix across the tenants."""
        return [self.width_mix[i % len(self.width_mix)] for i in range(n)]

    def default_policy(self, width: int = 1) -> MgmtPolicy:
        # the homogeneous scenario's 4-slot floor, priced at this
        # tenant's width (B and every grant are node units)
        base = super().default_policy()
        return replace(base, initial=base.initial * width)

    def serve(self, tenant_streams, *, widths=None, policies=None,
              **kw) -> FleetStats:
        n = len(tenant_streams)
        if widths is None:
            widths = self.tenant_widths(n)
        if policies is None:
            policies = [self.default_policy(w) for w in widths]
        return super().serve(tenant_streams, widths=widths,
                             policies=policies, **kw)


@register_system("dawningcloud-train-serve")
class TrainServeFleetSystem(ServeHeteroFleetSystem):
    """Train+serve consolidation: MTC serve tenants AND gang-scheduled
    HTC training tenants on ONE provider pool — the paper's
    heterogeneous-workload claim in its modern form (preemptible training
    soaking the serve troughs; the companion study arXiv:1004.1276 asks
    the same economies-of-scale question for batch-shaped scientific
    communities). Serve lanes keep the hetero scenario's defaults;
    training jobs ride in as ``TrainTenantSpec``s whose never-released
    floor (``MgmtPolicy.initial``) is added to the capacity plan so a
    parked gang floor can never strand the serve path."""

    def default_train_policy(self, world_min: int) -> MgmtPolicy:
        # HTC cadence (§3.2.2.2): 60 s scans, hourly release windows; the
        # floor is one smallest gang so a preempted tenant can always
        # restart its narrowest job without renegotiating
        return MgmtPolicy(initial=world_min, ratio=2.0,
                          scan_interval=60.0, release_interval=3600.0)

    def serve(self, tenant_streams, *, train_jobs=(), train_policy=None,
              train_specs: Sequence[TrainTenantSpec] = (),
              capacity: int | None = None, engine=None,
              widths=None, policies=None, **kw) -> FleetStats:
        """Run the mixed fleet: ``train_jobs`` become one training tenant
        (or pass prebuilt ``train_specs`` for several). Capacity defaults
        to the serve plan plus the training tenants' gang floors."""
        n = len(tenant_streams)
        if widths is None:
            widths = self.tenant_widths(n)
        if policies is None:
            policies = [self.default_policy(w) for w in widths]
        specs = list(train_specs)
        if train_jobs:
            floor = max(j.world_min for j in train_jobs)
            pol = (train_policy if train_policy is not None
                   else self.default_train_policy(floor))
            specs.append(TrainTenantSpec(jobs=tuple(train_jobs),
                                         policy=pol))
        if engine is None and capacity is None:
            capacity = self.default_capacity(
                tenant_streams, policies,
                tick_s=kw.get("tick_s", 1.0), widths=widths)
            capacity += sum(s.policy.initial for s in specs)
        return super().serve(tenant_streams, capacity=capacity,
                             engine=engine, widths=widths,
                             policies=policies, train=tuple(specs), **kw)
