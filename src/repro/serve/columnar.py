"""Columnar serve tick: the ``ServeDriver`` control loop over NumPy task
arrays, for 10^5-10^6-workflow streams.

``ServeDriver`` (PR 3) holds per-task ``Job`` objects, per-jid dicts and a
Python queue; at a million tasks the interpreter work per finish dwarfs
the simulated work. This module keeps the EXACT control plane — the same
``MTCRuntimeEnv`` negotiation (DR1/DR2 scans, time-averaged release
checks, deferred provider grants), the same tick phases, the same billing
— but turns every per-task loop into a whole-array batch:

  - **tasks are positions**: a ``repro.sim.traces.ColumnarStream`` indexes
    tasks by emission position; dep counts, service ticks, timings and the
    FCFS queue are preallocated vectors over them.
  - **batch finish sequencing**: a tick's finishes decrement their
    children's dep counts with one scatter-add; newly-ready children
    enter the queue ordered by the position of their *last* finished
    dependency within the batch — provably the order the scalar tick's
    one-at-a-time finish loop produces (pinned bit-identical in tests).
  - **batch FCFS dispatch**: uniform-width FCFS starts exactly
    ``min(queue_len, free // width)`` head-of-queue tasks, so scheduling
    is pointer arithmetic, and the policy engine's scan decision reads
    queue *summary stats* (``RuntimeEnv._queue_demand_stats``) instead of
    a per-job demand list.
  - **event-skipping** (``ServeDriver.next_event_tick``) is inherited —
    with arrays underneath, the quiet-tick jump plus the batched event
    ticks are what let one process sustain the ROADMAP's trace scale.

The scalar tick stays the reference implementation: ``ColumnarStream.
to_jobs()`` materializes the identical workload for ``ServeDriver``, and
the parity suite pins ``ServeStats``, per-task start/finish times and
completion order bit-identical between the two paths.
"""
from __future__ import annotations

import numpy as np

from repro.core.lifecycle import LifecycleService
from repro.core.policy import MgmtPolicy
from repro.core.provision import ProvisionService
from repro.core.scheduling import fcfs
from repro.core.tre import MTCRuntimeEnv, TickClock
from repro.serve.driver import (
    ServeDriver, ServeInvariantError, ServeStats, service_ticks_batch,
)
from repro.sim.traces import ColumnarStream


# --------------------------------------------------------------------------
# columnar engine
# --------------------------------------------------------------------------
class ColumnarEngine:
    """``EmulatedEngine`` over task positions: the same slot arrays, but
    the Python free *list* becomes a LIFO free *stack* (an int array +
    fill pointer) and admission takes a position batch with precomputed
    service ticks — no per-job attribute reads on the hot path. Slot
    assignment order, admit sequencing and finish ordering are
    bit-identical to ``EmulatedEngine`` (a batch of k admits pops the
    same k slots the scalar engine's ``free.pop()`` loop would)."""

    def __init__(self, capacity: int, *, tick_s: float = 1.0,
                 max_len: int | None = None):
        self.capacity = capacity
        self.tick_s = tick_s
        self.max_len = max_len
        self._free = np.arange(capacity, dtype=np.int64)
        self._ntop = capacity                      # free-stack fill
        self._nactive = 0
        self._active = np.zeros(capacity, bool)
        self._remaining = np.zeros(capacity, np.int64)
        self._pos = np.full(capacity, -1, np.int64)
        self._admit_seq = np.zeros(capacity, np.int64)
        self._seq = 0
        self.steps = 0

    @property
    def active_count(self) -> int:
        return self._nactive

    def admit_positions(self, pos: np.ndarray, remaining: np.ndarray) -> None:
        """Admit a batch of task positions with their service ticks."""
        k = len(pos)
        if k > self._ntop:
            raise ServeInvariantError(
                "admitted beyond free slots: %d jobs > %d free"
                % (k, self._ntop))
        # the scalar engine pops from the END of its free list one job at
        # a time: a batch of k takes the stack's top k slots, last first
        slots = self._free[self._ntop - k:self._ntop][::-1]
        self._ntop -= k
        self._active[slots] = True
        self._remaining[slots] = remaining
        self._pos[slots] = pos
        self._admit_seq[slots] = self._seq + np.arange(k)
        self._seq += k
        self._nactive += k

    def step(self) -> np.ndarray:
        """One decode tick; returns finished task positions in admission
        order (the scalar engine's finish-event order)."""
        if self._nactive == 0:
            return np.empty(0, np.int64)
        self._remaining[self._active] -= 1
        self.steps += 1
        done = np.nonzero(self._active & (self._remaining <= 0))[0]
        if len(done) == 0:
            return np.empty(0, np.int64)
        done = done[np.argsort(self._admit_seq[done], kind="stable")]
        out = self._pos[done].copy()
        self._active[done] = False
        self._pos[done] = -1
        # freed slots return to the stack in admit-seq order, exactly as
        # the scalar engine extends its free list
        self._free[self._ntop:self._ntop + len(done)] = done
        self._ntop += len(done)
        self._nactive -= len(done)
        return out

    def next_finish_in(self) -> int | None:
        if self._nactive == 0:
            return None
        return int(self._remaining[self._active].min())

    def advance_quiet(self, n: int) -> None:
        if n <= 0:
            return
        nf = self.next_finish_in()
        if nf is None:
            return
        if n >= nf:
            raise ServeInvariantError(
                "quiet advance of %d ticks would jump past a finish due "
                "in %d" % (n, nf))
        self._remaining[self._active] -= n
        self.steps += n


# --------------------------------------------------------------------------
# columnar runtime environment
# --------------------------------------------------------------------------
def _no_scalar_launch(task):
    raise ServeInvariantError(
        "scalar launch path reached from a columnar env — batch dispatch "
        "must go through _launch_positions")


class ColumnarEnv(MTCRuntimeEnv):
    """``MTCRuntimeEnv`` whose trigger monitor, queue and dispatch are
    arrays over a ``ColumnarStream``'s task positions. Everything the
    provider sees — scans, grants, releases, idle accounting, billing —
    is the inherited scalar machinery, byte for byte; only the per-task
    state changed representation:

      - dep counts: one ``int64`` vector (scatter-decremented per finish
        batch), children as a position-indexed CSR built by stable-sorting
        the dep edges (so a parent's children keep scalar track order),
      - the FCFS queue: an append-only index buffer with head/tail
        pointers — every task is enqueued exactly once, so no ring
        wraparound can occur by construction,
      - submit/start/finish times: float vectors (what the parity suite
        reads back against the scalar path's ``Job`` fields).

    Uniform task width + FCFS is REQUIRED (and checked): it is what makes
    batch dispatch a prefix take and the scan decision three summary
    stats. Cross-entry dependency gating matches the scalar trigger
    monitor exactly: a parent finishing before its child's entry arrives
    never decrements that child (the scalar path's documented starvation
    semantics), so divergence is impossible even on adversarial streams.
    """

    def __init__(self, name: str, *, cs: ColumnarStream, width: int,
                 launch_positions, provision: ProvisionService, clock,
                 policy: MgmtPolicy | None = None,
                 fixed_nodes: int | None = None, scheduler=None,
                 lifecycle: LifecycleService | None = None,
                 max_nodes: int | None = None):
        super().__init__(name, provision=provision, clock=clock,
                         launch=_no_scalar_launch, policy=policy,
                         fixed_nodes=fixed_nodes, scheduler=scheduler,
                         lifecycle=lifecycle, max_nodes=max_nodes)
        if self.scheduler is not fcfs:
            raise ValueError(
                "columnar serve requires the FCFS scheduler (batch "
                "dispatch is a queue-prefix take); got "
                f"{getattr(self.scheduler, '__name__', self.scheduler)!r}")
        self._cs = cs
        self._w = int(width)
        self._launch_positions = launch_positions
        n = cs.n_tasks
        self._ndeps_arr = np.diff(cs.dep_ptr).astype(np.int64)
        self._arrived_hi = 0          # positions < this are tracked
        # children CSR: stable sort of dep edges by parent keeps each
        # parent's children in child-position order — which IS the scalar
        # trigger monitor's per-parent list order (children are tracked in
        # position order)
        child_of_edge = np.repeat(np.arange(n, dtype=np.int64),
                                  np.diff(cs.dep_ptr))
        order = np.argsort(cs.dep_idx, kind="stable")
        self._child_idx = child_of_edge[order]
        self._child_ptr = np.concatenate(
            [[0], np.cumsum(np.bincount(cs.dep_idx, minlength=n))]
        ).astype(np.int64)
        # FCFS queue: append-only position buffer (each task queued once)
        self._qbuf = np.empty(n, np.int64)
        self._qhead = 0
        self._qtail = 0
        # per-task timings, read back by the parity suite
        self.submit_t = np.full(n, np.nan)
        self.start_t = np.full(n, np.nan)
        self.finish_t = np.full(n, np.nan)

    # ------------------------------------------------------------- queue
    @property
    def qlen(self) -> int:
        return self._qtail - self._qhead

    def _enqueue(self, pos: np.ndarray) -> None:
        k = len(pos)
        if k == 0:
            return
        self._qbuf[self._qtail:self._qtail + k] = pos
        self._qtail += k
        self.submit_t[pos] = self.clock.now()
        # fixed (dedicated) envs schedule on submission, like the scalar
        # ``submit``; DSP envs load at scan ticks
        if self.mode == "fixed":
            self.schedule()

    def _queue_demand_stats(self) -> tuple[int, int, int]:
        q = self.qlen
        if q == 0:
            return 0, 0, 0
        return q * self._w, self._w, self._w

    # ---------------------------------------------------------- dispatch
    def schedule(self):
        """Uniform-width FCFS in closed form: start exactly
        ``min(queue_len, free // width)`` head-of-queue tasks (the scalar
        prefix-greedy over a uniform queue starts the same set)."""
        cnt = min(self.qlen, self.free // self._w)
        if cnt <= 0:
            return []
        pos = self._qbuf[self._qhead:self._qhead + cnt]
        self._qhead += cnt
        self.start_t[pos] = self.clock.now()
        self._account_idle()
        self.busy += self._w * cnt
        self._launch_positions(pos)
        return pos

    def submit(self, task) -> None:
        raise ServeInvariantError(
            "scalar submit reached a columnar env — arrivals go through "
            "track_arrivals")

    # --------------------------------------------------- trigger monitor
    def track_arrivals(self, e_lo: int, e_hi: int) -> None:
        """Register entries ``[e_lo, e_hi)`` (their tasks become tracked)
        and enqueue the dependency-free roots in position order — exactly
        the scalar loop's track(extend=True) + submit-roots sequence."""
        lo = int(self._cs.entry_ptr[e_lo])
        hi = int(self._cs.entry_ptr[e_hi])
        if hi <= lo:
            return
        self._expected = (self._expected or 0) + (hi - lo)
        self._arrived_hi = hi
        span = np.arange(lo, hi, dtype=np.int64)
        self._enqueue(span[self._ndeps_arr[lo:hi] == 0])

    def finish_positions(self, pos: np.ndarray) -> None:
        """One finish batch (engine finish order): free the slots' node
        units, scatter-decrement the children's dep counts, enqueue the
        newly-ready in scalar submit order, dispatch once."""
        now = self.clock.now()
        self.finish_t[pos] = now
        self._account_idle()
        self.busy -= self._w * len(pos)
        self._completed_n += len(pos)
        # children of the batch, parent-major in finish order, each
        # parent's children in track order (multi-range CSR gather)
        starts = self._child_ptr[pos]
        cnts = self._child_ptr[pos + 1] - starts
        total = int(cnts.sum())
        if total:
            out_off = np.concatenate([[0], np.cumsum(cnts)[:-1]])
            idx = (np.arange(total, dtype=np.int64)
                   - np.repeat(out_off, cnts) + np.repeat(starts, cnts))
            cc = self._child_idx[idx]
            # gate on tracked children only: a parent finishing before its
            # child's entry arrived must NOT decrement it (scalar
            # starvation semantics)
            cc = cc[cc < self._arrived_hi]
            if len(cc):
                np.subtract.at(self._ndeps_arr, cc, 1)
                # a child becomes ready at its LAST occurrence in cc —
                # the batch position where the scalar one-at-a-time loop
                # would have submitted it
                u, rev_first = np.unique(cc[::-1], return_index=True)
                ready_m = self._ndeps_arr[u] == 0
                if ready_m.any():
                    lastpos = len(cc) - 1 - rev_first[ready_m]
                    self._enqueue(u[ready_m][np.argsort(lastpos,
                                                        kind="stable")])
        if not self.all_done:
            self.schedule()


# --------------------------------------------------------------------------
# columnar serve driver
# --------------------------------------------------------------------------
def default_max_ticks_columnar(cs: ColumnarStream, svc: np.ndarray,
                               tick_s: float) -> int:
    """Vectorized ``repro.serve.driver.default_max_ticks``: arrival span
    (the stream is sorted, so the last entry) plus 8x the total service
    ticks — pinned equal to the scalar bound in the regression suite."""
    span = float(cs.entry_arrival[-1]) if cs.n_entries else 0.0
    work = int(svc.sum())
    return int(span / tick_s + 8 * work + 36_000)


class ColumnarServeDriver(ServeDriver):
    """``ServeDriver`` over a ``ColumnarStream``: the inherited run loop,
    control-cycle boundaries, contention replay, event-skipping and
    finalize — with the per-task tick phases (arrival submission, finish
    sequencing, admission flush, invariants) overridden as array batches
    against a ``ColumnarEnv`` + ``ColumnarEngine``. Event-skipping
    defaults ON here (the scalar driver defaults dense): this is the
    trace-scale path.

    Bit-parity contract: on ``cs.to_jobs()`` with the same provider,
    policy, contention and engine geometry, ``run()`` returns a
    ``ServeStats`` identical to the scalar driver's, with identical
    per-task start/finish times (``env.start_t`` / ``env.finish_t``)."""

    def __init__(self, cs: ColumnarStream, *,
                 provider: ProvisionService, engine: ColumnarEngine,
                 policy: MgmtPolicy | None = None,
                 fixed_nodes: int | None = None,
                 name: str = "mtc-serve", scheduler=None,
                 lifecycle: LifecycleService | None = None,
                 tick_s: float = 1.0,
                 contention=(), max_ticks: int | None = None,
                 strict: bool = True, clock: TickClock | None = None,
                 phase: int = 0, slot_width: int = 1,
                 event_skip: bool = True):
        if slot_width < 1:
            raise ValueError(f"slot_width must be >= 1, got {slot_width}")
        if not callable(getattr(engine, "admit_positions", None)):
            raise TypeError(
                "ColumnarServeDriver needs a position-batch engine "
                "(ColumnarEngine); scalar adapters drive ServeDriver")
        if cs.n_entries and np.any(np.diff(cs.entry_arrival) < 0):
            raise ValueError("columnar stream entries must be sorted "
                             "by arrival")
        if cs.n_tasks and not np.all(cs.nodes == slot_width):
            raise ServeInvariantError(
                f"1 MTC task = 1 batching slot (= {slot_width} node "
                f"unit(s) at this tenant's width); stream carries "
                f"other node counts")
        self.cs = cs
        self.stream = ()              # scalar entries never materialized
        self.provider = provider
        self.engine = engine
        self.slot_width = slot_width
        self.tick_s = tick_s
        self.strict = strict
        self.clock = clock if clock is not None else TickClock()
        self.stats = ServeStats(name=name, tick_s=tick_s,
                                slot_width=slot_width,
                                workflows_expected=cs.n_entries)
        self._admit_buf: list[np.ndarray] = []
        self._entry_i = 0             # arrival cursor over stream entries
        self._stream_i = 0            # kept 0/len-compatible via _done
        self._contention = sorted(contention, key=lambda e: e[0])
        self._cont_i = 0
        self._phase = phase
        if policy is not None:
            self._scan_every = max(int(round(policy.scan_interval / tick_s)),
                                   1)
            self._release_every = max(
                int(round(policy.release_interval / tick_s)), 1)
        else:
            self._scan_every = self._release_every = 0
        cap_units = engine.capacity * slot_width
        self.env = ColumnarEnv(
            name, cs=cs, width=slot_width,
            launch_positions=self._launch_positions,
            provision=provider, clock=self.clock, policy=policy,
            fixed_nodes=fixed_nodes, scheduler=scheduler,
            lifecycle=lifecycle, max_nodes=cap_units)
        self.env.grant_listener = self._on_grant
        self.env.track(())            # an empty stream is already all_done
        # per-task service ticks + per-workflow remaining-task counts,
        # both one vector pass
        self._svc = service_ticks_batch(
            cs.decode_len, cs.prompt_len, cs.runtime,
            tick_s=tick_s, max_len=engine.max_len)
        self._wf_left_arr = np.diff(cs.entry_ptr).astype(np.int64)
        if max_ticks is None:
            max_ticks = default_max_ticks_columnar(cs, self._svc, tick_s)
        self.max_ticks = max_ticks
        self.event_skip = bool(event_skip)

    # ------------------------------------------------------- env hooks
    def _launch_positions(self, pos: np.ndarray) -> None:
        # width already validated stream-wide at construction (the scalar
        # per-launch nodes check, hoisted out of the hot path)
        self._admit_buf.append(pos)

    def _buffered(self) -> int:
        return sum(len(a) for a in self._admit_buf)

    # ------------------------------------------------------- tick parts
    def _next_arrival_t(self) -> float | None:
        if self._entry_i < self.cs.n_entries:
            return float(self.cs.entry_arrival[self._entry_i])
        return None

    def _queue_len(self) -> int:
        return self.env.qlen

    def _submit_arrivals(self, now: float) -> None:
        hi = int(np.searchsorted(self.cs.entry_arrival, now + 1e-9,
                                 side="right"))
        if hi > self._entry_i:
            self.env.track_arrivals(self._entry_i, hi)
            self._entry_i = hi

    def _process_finishes(self, finished) -> None:
        pos = np.asarray(finished, np.int64)
        if len(pos) == 0:
            return
        self.env.finish_positions(pos)
        self.stats.tasks_completed += len(pos)
        # workflow roll-up: decrement each finished task's workflow and
        # count the ones that hit zero in this batch
        wfs = np.searchsorted(self.cs.entry_ptr, pos, side="right") - 1
        np.subtract.at(self._wf_left_arr, wfs, 1)
        done_wfs = np.unique(wfs)
        self.stats.workflows_completed += int(
            (self._wf_left_arr[done_wfs] == 0).sum())

    def _flush_admissions(self) -> None:
        if not self._admit_buf:
            return
        pos = (self._admit_buf[0] if len(self._admit_buf) == 1
               else np.concatenate(self._admit_buf))
        w = self.slot_width
        if (self.engine.active_count + len(pos)) * w > self.env.owned:
            self.stats.over_admissions += 1
            if self.strict:
                raise ServeInvariantError(
                    "over-admission: (%d active + %d buffered) slots x "
                    "width %d > %d granted units"
                    % (self.engine.active_count, len(pos), w,
                       self.env.owned))
        self.engine.admit_positions(pos, self._svc[pos])
        self._admit_buf = []

    def _check_invariants(self) -> None:
        active = (self.engine.active_count + self._buffered()) \
            * self.slot_width
        if active > self.env.owned or self.env.busy > self.env.owned:
            self.stats.over_admissions += 1
            if self.strict:
                raise ServeInvariantError(
                    "slots exceed grant: engine %d / env busy %d / owned %d"
                    % (active, self.env.busy, self.env.owned))
        if active != self.env.busy and self.strict:
            raise ServeInvariantError(
                "engine/env divergence: %d active units != %d busy nodes"
                % (active, self.env.busy))

    def _accumulate(self) -> None:
        self.stats.busy_node_ticks += self.env.busy * self.tick_s
        self.stats.owned_node_ticks += self.env.owned * self.tick_s
        self.stats.peak_owned = max(self.stats.peak_owned, self.env.owned)
        self.stats.queue_peak = max(self.stats.queue_peak, self.env.qlen)

    @property
    def _done(self) -> bool:
        return (self._entry_i == self.cs.n_entries and self.env.all_done
                and not self._admit_buf and self.engine.active_count == 0)
