"""Continuous-batching inference engine (the MTC-TRE payload).

Slot-based KV/SSM cache: ``max_batch`` slots of capacity ``max_len``.
Requests are admitted into free slots (prefill writes the slot), then all
active slots decode together each step; finished slots free immediately so
new requests join mid-flight — continuous batching. Greedy sampling.

The admit path is batched (:meth:`Engine.admit_many`): requests admitted
together are grouped by prompt shape and prefilled in one forward pass per
group, then spliced into their slots — a trace-rate driver that buffers a
tick's launches gets one prefill dispatch per prompt length instead of one
per request. Slot accounting (last-token gather, output accumulation,
length bumps, finish detection) is vectorized over NumPy slot arrays; the
only per-request Python is materializing finished requests.

MTC workflows (Montage-style DAGs of inference tasks) are driven by
``repro.core.tre.MTCRuntimeEnv``, which feeds this engine only tasks whose
dependencies completed — the DawningCloud "trigger monitor" role. The env
treats each batching slot as one node; ``repro.serve.driver.ServeDriver``
is the trace-rate driver wiring (engine steps advance a ``TickClock``,
finished requests are reported back via ``env.finish``) and
``examples/serve_workflow.py`` the reference entry point.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import LM, Runtime


@dataclass
class Request:
    rid: int
    tokens: np.ndarray            # (P,) or (P,ncb) prompt tokens
    max_new_tokens: int = 16
    patches: np.ndarray | None = None
    out_tokens: list = field(default_factory=list)
    done: bool = False
    rejected: bool = False        # oversize for the cache: never admitted


class Engine:
    """``prefill_chunk``: run every grouped prefill at this fixed batch
    size (padding the final partial chunk) so the JIT specializes once per
    *prompt shape* instead of once per (prompt shape, group size) pair —
    a multi-tenant fleet's admit windows produce many distinct group
    sizes, and unchunked each would compile its own prefill. ``None``
    keeps the exact-size behavior (single-tenant streams see few sizes)."""

    def __init__(self, lm: LM, params, rt: Runtime, *, max_batch: int,
                 max_len: int, prefill_chunk: int | None = None,
                 page_size: int | None = None):
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        self.prefill_chunk = prefill_chunk
        self.lm, self.params, self.rt = lm, params, rt
        self.max_batch, self.max_len = max_batch, max_len
        self.page_size = page_size
        self.lengths = jnp.zeros((max_batch,), jnp.int32)
        self.active: dict[int, Request] = {}     # slot -> request
        self.free = list(range(max_batch))
        if page_size is None:
            self.pager = None
            self.caches = lm.init_cache(max_batch, max_len)
            self._decode = jax.jit(
                lambda p, t, l, c: lm.decode(p, rt, t, l, c),
                donate_argnums=(3,))
        else:
            # physical paged KV: attention caches live in one shared page
            # pool; a slot's cache is the pages its table row maps. Page 0
            # is the reserved null page — every inactive row's table points
            # at it, so the decode step's unconditional scatter (all rows
            # write every step) can never corrupt a page owned by an
            # active slot.
            if page_size < 1 or max_len % page_size:
                raise ValueError(
                    f"max_len ({max_len}) must be a positive multiple of "
                    f"page_size ({page_size})")
            if rt.decode_kv_shard(lm.cfg) == "seq":
                raise ValueError(
                    "paged KV is incompatible with decode_kv_shard='seq'")
            from repro.serve.paged import PagedKVAllocator
            self.pages_per_slot = max_len // page_size
            n_pages = 1 + max_batch * self.pages_per_slot
            self.pager = PagedKVAllocator(n_pages, page_size=page_size,
                                          reserve_null=True)
            self.caches = lm.init_paged_cache(max_batch, n_pages, page_size)
            self._page_table = np.zeros((max_batch, self.pages_per_slot),
                                        np.int32)
            self._decode = jax.jit(
                lambda p, t, l, c, pt: lm.decode(p, rt, t, l, c,
                                                 page_table=pt),
                donate_argnums=(3,))
        self._prefill = {}
        self.steps = 0
        # ---- vectorized slot accounting ----
        ncb = lm.cfg.n_codebooks
        tok_shape = (max_batch,) if ncb <= 1 else (max_batch, ncb)
        self._active_mask = np.zeros((max_batch,), bool)
        self._last_tok = np.zeros(tok_shape, np.int32)
        # generated tokens per slot (admit writes index 0; step appends).
        # max_new_tokens <= max_len is enforced at admit, +1 covers the
        # prefill token of a budget-1 request
        self._out_buf = np.zeros((max_batch, max_len + 1) + tok_shape[1:],
                                 np.int32)
        self._out_len = np.zeros((max_batch,), np.int64)
        self._budget = np.zeros((max_batch,), np.int64)
        self._admit_seq = np.zeros((max_batch,), np.int64)
        self._seq = 0

    @property
    def active_count(self) -> int:
        return len(self.active)

    # ---------------------------------------------------------- prefill
    def _prefill_fn(self, plen: int, has_patches: bool):
        key = (plen, has_patches)
        if key not in self._prefill:
            def f(params, batch):
                return self.lm.prefill(params, self.rt, batch)
            self._prefill[key] = jax.jit(f)
        return self._prefill[key]

    def _splice_caches(self, slot: int, pre_caches):
        """Write a prefill cache (batch=1, seq=P) into the slot."""
        def splice(dst, src):
            # attn kv: src (R,1,P,KVH,hd) -> dst (R,B,S,KVH,hd) at [:,slot,:P]
            # ssm conv: src (R,1,k-1,ch)  -> dst (R,B,k-1,ch)
            # ssm state: src (R,1,nh,hp,ds) -> dst (R,B,nh,hp,ds)
            src = src.astype(dst.dtype)
            start = (0, slot) + (0,) * (dst.ndim - 2)
            return jax.lax.dynamic_update_slice(dst, src, start)

        if self.pager is None:
            self.caches = jax.tree.map(splice, self.caches, pre_caches)
            return
        # paged: attention KV scatters page-sized chunks of the prefill
        # into the slot's allocated pages; SSM state stays slot-indexed
        ps = self.page_size
        row = self._page_table[slot]

        def splice_paged(dst, src):
            # src (R,1,P,KVH,hd) -> page-sized chunks into
            # dst (R,n_pages,page_size,KVH,hd) at (0, row[j], 0, 0, 0)
            src = src.astype(dst.dtype)
            P = src.shape[2]
            for j0 in range(0, P, ps):
                cs = min(ps, P - j0)
                chunk = jax.lax.dynamic_slice_in_dim(src, j0, cs, axis=2)
                dst = jax.lax.dynamic_update_slice(
                    dst, chunk, (0, int(row[j0 // ps]), 0, 0, 0))
            return dst

        new = {}
        for key, dst in self.caches.items():
            i = int(key[3:])
            if self.lm.cfg.block_kind(i) == "attn":
                new[key] = tuple(splice_paged(d, s)
                                 for d, s in zip(dst, pre_caches[key]))
            else:
                new[key] = jax.tree.map(splice, dst, pre_caches[key])
        self.caches = new

    def admit(self, req: Request) -> bool:
        return bool(self.admit_many([req]))

    def admit_many(self, reqs: list[Request]) -> list[Request]:
        """Admit requests into free slots (as many as fit, in order).

        Admissions are grouped by (prompt length, has-patches) and each
        group runs batched prefill forward passes; per-slot splices then
        scatter the group's caches. Returns the admitted requests — the
        caller keeps the remainder for the next admit window. That
        returned-subset contract is load-bearing: every engine adapter
        (``EmulatedEngine``, ``JaxEngineAdapter``, the fleet's
        ``PartitionedEngine``) returns what it admitted so
        ``ServeDriver._flush_admissions`` can requeue a truncated batch's
        remainder instead of dropping jobs on the floor.

        A request whose prompt + patches + ``max_new_tokens`` exceeds
        ``max_len`` can never be served: it is rejected *individually*
        (``req.rejected = req.done = True``, excluded from the returned
        list, no slot consumed) — never raised. Raising mid-batch used to
        abort the whole admit window, and only requests inside the free
        window were validated at all, so an oversize request parked
        beyond it aborted a *later* window after its slots were popped.

        Without ``prefill_chunk`` each distinct (prompt length, group
        size) pair JIT-specializes the prefill once — keep prompt lengths
        to a small discrete set; with it, groups run in fixed-size
        (padded) chunks, bounding specialization to one per prompt shape.
        """
        groups: dict[tuple[int, bool], list[tuple[int, Request]]] = {}
        admitted: list[Request] = []
        order: dict[int, int] = {}          # slot -> call-order seq
        for req in reqs:
            if not self.free:
                break
            plen = len(req.tokens)
            n_img = self.lm.cfg.n_patches if req.patches is not None else 0
            if plen + n_img + req.max_new_tokens > self.max_len:
                req.rejected = True
                req.done = True
                continue
            slot = self.free.pop()
            if self.pager is not None:
                need = -(-(plen + n_img + req.max_new_tokens)
                         // self.page_size)
                pages = self.pager.alloc(slot, need)
                self._page_table[slot] = 0
                self._page_table[slot, :len(pages)] = pages
            order[slot] = self._seq
            self._seq += 1
            groups.setdefault((len(req.tokens), req.patches is not None),
                              []).append((slot, req))
            admitted.append(req)
        step = self.prefill_chunk
        for (plen, has_patches), members in groups.items():
            for i0 in range(0, len(members), step or len(members)):
                part = members[i0:i0 + step] if step else members
                self._prefill_group(plen, has_patches, part, order,
                                    pad_to=step)
        return admitted

    def _prefill_group(self, plen: int, has_patches: bool, members,
                       order: dict[int, int],
                       pad_to: int | None = None) -> None:
        """One prefill forward pass for same-shape requests; splice each
        row's cache into its slot. ``pad_to`` fixes the batch dimension
        (repeating the last row; padded outputs are discarded) so the
        compiled prefill is reused across admit windows of any size."""
        k = len(members)
        rows = [np.asarray(r.tokens) for _, r in members]
        if pad_to and k < pad_to:
            rows.extend([rows[-1]] * (pad_to - k))
        batch = {"tokens": jnp.asarray(np.stack(rows))}
        if has_patches:
            prows = [np.asarray(r.patches) for _, r in members]
            if pad_to and k < pad_to:
                prows.extend([prows[-1]] * (pad_to - k))
            batch["patches"] = jnp.asarray(np.stack(prows))
        n_img = self.lm.cfg.n_patches if has_patches else 0
        logits, pre_caches, _ = self._prefill_fn(plen, has_patches)(
            self.params, batch)
        toks = np.asarray(jnp.argmax(logits, axis=-1))[:k]  # (k,) or (k,ncb)
        slots = np.array([s for s, _ in members])
        for i, (slot, req) in enumerate(members):
            self._splice_caches(slot, jax.tree.map(
                lambda a, _i=i: jax.lax.dynamic_slice_in_dim(a, _i, 1,
                                                             axis=1),
                pre_caches))
            self.active[slot] = req
            req.out_tokens.append(toks[i])
        self.lengths = self.lengths.at[slots].set(plen + n_img)
        self._last_tok[slots] = toks
        self._out_buf[slots, 0] = toks
        self._out_len[slots] = 1
        self._budget[slots] = [r.max_new_tokens for _, r in members]
        self._active_mask[slots] = True
        # call-order seqs (NOT group order): same-step finishes must
        # come back in admission order across shape groups, matching
        # EmulatedEngine and the emulator's per-slot event queue
        self._admit_seq[slots] = [order[s] for s, _ in members]

    # ----------------------------------------------------------- decode
    def step(self) -> list[Request]:
        """One decode step for all active slots; returns finished requests."""
        if not self.active:
            return []
        ncb = self.lm.cfg.n_codebooks
        toks = (self._last_tok[:, None] if ncb <= 1
                else self._last_tok[:, None, :])
        if self.pager is None:
            logits, self.caches = self._decode(
                self.params, jnp.asarray(toks), self.lengths, self.caches)
        else:
            logits, self.caches = self._decode(
                self.params, jnp.asarray(toks), self.lengths, self.caches,
                jnp.asarray(self._page_table))
        nxt = np.asarray(jnp.argmax(logits, axis=-1))  # (B,) or (B,ncb)
        mask = self._active_mask
        self._last_tok[mask] = nxt[mask]
        self._out_buf[mask, self._out_len[mask]] = nxt[mask]
        self._out_len[mask] += 1
        self.lengths = self.lengths + jnp.asarray(mask.astype(np.int32))
        self.steps += 1
        done = np.nonzero(mask & (self._out_len >= self._budget))[0]
        # finish in admission order: the env observes completions in the
        # same order a per-slot event queue would deliver them
        done = done[np.argsort(self._admit_seq[done], kind="stable")]
        finished = []
        for slot in (int(s) for s in done):
            req = self.active.pop(slot)
            req.done = True
            # materialize the slot's output buffer (admit wrote index 0)
            req.out_tokens = [self._out_buf[slot, i]
                              for i in range(int(self._out_len[slot]))]
            self._active_mask[slot] = False
            if self.pager is not None:
                self.pager.free(slot)
                self._page_table[slot] = 0   # back to the null page
            self.free.append(slot)
            finished.append(req)
        return finished

    def run(self, requests: list[Request]) -> list[Request]:
        """Serve a list of requests to completion (admitting as slots
        free). Oversize requests come back in the result marked
        ``rejected`` with no output tokens."""
        pending = list(requests)
        done: list[Request] = []
        while pending or self.active:
            if pending and self.free:
                window = pending[:len(self.free)]
                taken = {id(r) for r in self.admit_many(window)}
                for req in window:
                    if req.rejected:
                        done.append(req)
                        taken.add(id(req))
                pending = [r for r in pending if id(r) not in taken]
            done.extend(self.step())
        return done
