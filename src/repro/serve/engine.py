"""Continuous-batching inference engine (the MTC-TRE payload).

Slot-based KV/SSM cache: ``max_batch`` slots of capacity ``max_len``.
Requests are admitted into free slots (prefill writes the slot), then all
active slots decode together each step; finished slots free immediately so
new requests join mid-flight — continuous batching. Greedy sampling.

MTC workflows (Montage-style DAGs of inference tasks) are driven by
``repro.core.tre.MTCRuntimeEnv``, which feeds this engine only tasks whose
dependencies completed — the DawningCloud "trigger monitor" role. The env
treats each batching slot as one node; ``examples/serve_workflow.py`` is
the reference driver wiring (engine steps advance a ``TickClock``, finished
requests are reported back via ``env.finish``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import LM, Runtime


@dataclass
class Request:
    rid: int
    tokens: np.ndarray            # (P,) or (P,ncb) prompt tokens
    max_new_tokens: int = 16
    patches: np.ndarray | None = None
    out_tokens: list = field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, lm: LM, params, rt: Runtime, *, max_batch: int,
                 max_len: int):
        self.lm, self.params, self.rt = lm, params, rt
        self.max_batch, self.max_len = max_batch, max_len
        self.caches = lm.init_cache(max_batch, max_len)
        self.lengths = jnp.zeros((max_batch,), jnp.int32)
        self.active: dict[int, Request] = {}     # slot -> request
        self.free = list(range(max_batch))
        self._decode = jax.jit(
            lambda p, t, l, c: lm.decode(p, rt, t, l, c),
            donate_argnums=(3,))
        self._prefill = {}
        self.steps = 0

    # ---------------------------------------------------------- prefill
    def _prefill_fn(self, plen: int, has_patches: bool):
        key = (plen, has_patches)
        if key not in self._prefill:
            def f(params, batch):
                return self.lm.prefill(params, self.rt, batch)
            self._prefill[key] = jax.jit(f)
        return self._prefill[key]

    def _splice_caches(self, slot: int, pre_caches):
        """Write a prefill cache (batch=1, seq=P) into the slot."""
        def splice(dst, src):
            # attn kv: src (R,1,P,KVH,hd) -> dst (R,B,S,KVH,hd) at [:,slot,:P]
            # ssm conv: src (R,1,k-1,ch)  -> dst (R,B,k-1,ch)
            # ssm state: src (R,1,nh,hp,ds) -> dst (R,B,nh,hp,ds)
            src = src.astype(dst.dtype)
            start = (0, slot) + (0,) * (dst.ndim - 2)
            return jax.lax.dynamic_update_slice(dst, src, start)
        self.caches = jax.tree.map(splice, self.caches, pre_caches)

    def admit(self, req: Request) -> bool:
        if not self.free:
            return False
        plen = len(req.tokens)
        n_img = self.lm.cfg.n_patches if req.patches is not None else 0
        if plen + n_img + req.max_new_tokens > self.max_len:
            raise ValueError("request exceeds cache capacity")
        slot = self.free.pop()
        batch = {"tokens": jnp.asarray(req.tokens)[None]}
        if req.patches is not None:
            batch["patches"] = jnp.asarray(req.patches)[None]
        logits, pre_caches, _ = self._prefill_fn(plen, req.patches is not None)(
            self.params, batch)
        self._splice_caches(slot, pre_caches)
        self.lengths = self.lengths.at[slot].set(plen + n_img)
        tok = np.asarray(jnp.argmax(logits, axis=-1))[0]  # () or (ncb,)
        req.out_tokens.append(tok)
        self.active[slot] = req
        return True

    # ----------------------------------------------------------- decode
    def step(self) -> list[Request]:
        """One decode step for all active slots; returns finished requests."""
        if not self.active:
            return []
        ncb = self.lm.cfg.n_codebooks
        tok_shape = (self.max_batch, 1) if ncb <= 1 else (self.max_batch, 1, ncb)
        toks = np.zeros(tok_shape, np.int32)
        for slot, req in self.active.items():
            toks[slot, 0] = req.out_tokens[-1]
        logits, self.caches = self._decode(
            self.params, jnp.asarray(toks), self.lengths, self.caches)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))  # (B,) or (B,ncb)
        upd = np.zeros((self.max_batch,), np.int32)
        finished = []
        for slot, req in list(self.active.items()):
            req.out_tokens.append(nxt[slot])
            upd[slot] = 1
            if len(req.out_tokens) >= req.max_new_tokens:
                req.done = True
                finished.append(req)
                del self.active[slot]
                self.free.append(slot)
        self.lengths = self.lengths + jnp.asarray(upd)
        self.steps += 1
        return finished

    def run(self, requests: list[Request]) -> list[Request]:
        """Serve a list of requests to completion (admitting as slots free)."""
        pending = list(requests)
        done: list[Request] = []
        while pending or self.active:
            while pending and self.free:
                self.admit(pending.pop(0))
            done.extend(self.step())
        return done
