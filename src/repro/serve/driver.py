"""Trace-rate MTC serving driver over the shared resource provider.

This is the live-serving counterpart of the discrete-event emulator: the
same ``MTCRuntimeEnv`` control plane (trigger monitor, FCFS dispatch,
DR1/DR2 negotiation, time-averaged release checks) driving a
continuous-batching engine at *trace rate* — thousands of Montage-shaped
workflows replayed at their trace timestamps on a ``TickClock``:

  - **workflow arrivals** come from ``repro.sim.traces.request_stream``:
    each arrival registers its DAG with the env's trigger monitor
    (``track(extend=True)``) and submits the dependency-free roots; the
    env loads them at scan ticks, exactly like the emulator's DSP mode,
  - **engine slots are provisioned, not assumed**: 1 batching slot = 1
    node. The env's scans emit ``ResourceRequest``s against the shared
    ``repro.core.provider.ResourceProvider``; a contended platform *parks*
    the request and the deferred grant lands between control ticks through
    ``on_grant`` (observed via the env's ``grant_listener``),
  - **admission backpressure**: while a grant is deferred, newly arrived
    workflow roots wait in the env queue — the driver never admits a task
    into the engine beyond the granted slot count (asserted every tick;
    ``ServeStats.over_admissions`` stays 0),
  - **batched admission**: tasks launched during a tick are buffered and
    admitted together at the end of the tick (one prefill dispatch per
    prompt shape via ``Engine.admit_many``); they decode from the next
    tick on, so a task admitted at tick T with ``decode_len`` R finishes
    at T + R — the same timing the emulator's finish events produce,
    which is what makes emulator-vs-live parity bit-exact.

Engines plug in through a 3-method adapter (``capacity`` /
``admit_many(jobs)`` / ``step() -> finished jids``): ``EmulatedEngine`` is
the tick-accurate stand-in used for trace-scale runs and the parity/
property suite; ``JaxEngineAdapter`` drives the real
``repro.serve.engine.Engine`` (prompts synthesized from the jobs'
token-length marks) so the same driver serves actual inference traffic.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

from repro.core.lifecycle import LifecycleService
from repro.core.policy import MgmtPolicy
from repro.core.provision import ProvisionService
from repro.core.tre import MTCRuntimeEnv, TickClock
from repro.core.types import Job
# the tick-grid helpers moved to the tenant module with the protocol
# extraction; re-exported here for the fleet/columnar/test importers
from repro.serve.tenant import Tenant, due_tick_floor, next_boundary  # noqa: F401


class ServeInvariantError(RuntimeError):
    """A serve-path invariant was violated (over-admission, engine/env
    slot-count divergence, or an engine asked to admit beyond its free
    slots). Raised — never ``assert``ed — so zero-over-admission holds
    under ``python -O`` too; the numbers a serve run reports are only
    trustworthy because violating them is an error, not a debug check."""


def service_ticks_batch(decode_len, prompt_len, runtime, *, tick_s: float,
                        max_len: int | None) -> np.ndarray:
    """Vectorized :meth:`EmulatedEngine.service_ticks` over task arrays:
    ``decode_len`` marks (cache-capped via the shared :func:`decode_budget`
    formula when ``max_len`` is set) with the runtime-in-ticks fallback for
    unmarked tasks. One formula for the scalar engine, the columnar
    engine and the columnar max-ticks bound — elementwise equality with
    the scalar method is pinned in tests."""
    dl = np.asarray(decode_len, np.int64)
    rt = np.maximum(np.ceil(np.asarray(runtime, float) / tick_s),
                    1).astype(np.int64)
    if max_len is not None:
        pl = np.minimum(np.maximum(np.asarray(prompt_len, np.int64), 1),
                        max_len - 1)
        room = max_len - pl
        capped = np.maximum(
            np.maximum(np.minimum(dl + 1, room), np.minimum(2, room)) - 1, 1)
    else:
        capped = dl
    return np.where(dl > 0, capped, rt)


def decode_budget(decode_len: int, prompt_len: int, max_len: int) -> int:
    """Token budget a ``max_len``-deep cache can give a request: the
    ``decode_len`` service mark plus the prefill token, capped to the
    cache room left after the prompt, floored at 2 tokens where the room
    allows it (prefill emits token 1 at admit, so a budget of R+1
    finishes after exactly R decode steps).

    The prompt is clamped to ``max_len - 1`` first — the cache needs one
    free position for the decode write, so a prompt at/above ``max_len``
    must be truncated by the caller (``JaxEngineAdapter._request`` does)
    and budgets from it are sized for the truncated prompt. At
    ``prompt_len == max_len - 1`` the room is 1 and the budget is 1: a
    zero-decode job. Its pinned semantics on every backend: the request
    still holds a slot for exactly ONE service tick, because the engine's
    finish check runs after the step's append — ``EmulatedEngine`` /
    ``JaxEngineAdapter`` ``service_ticks`` floor at 1 accordingly. The
    unclamped formula returned 0 or negative budgets here, which drove
    emulated service ticks negative and desynced emulator-vs-jax parity.

    THE one formula every backend must share: ``JaxEngineAdapter`` sizes
    ``max_new_tokens`` with it, a cache-aware ``EmulatedEngine`` caps its
    service ticks to ``max(decode_budget(...) - 1, 1)``, and the columnar
    ``service_ticks_batch`` is its vectorized twin — computing the cap in
    two places is how the long-decode parity bug happened."""
    plen = min(max(prompt_len, 1), max_len - 1)
    room = max_len - plen
    return max(min(decode_len + 1, room), min(2, room))


@dataclass
class ServeStats:
    """One serve run's outcome + the invariants it maintained."""
    name: str
    ticks: int = 0
    tick_s: float = 1.0
    slot_width: int = 1                 # node units one batching slot costs
    workflows_expected: int = 0
    workflows_completed: int = 0
    tasks_completed: int = 0
    makespan_s: float = 0.0
    workflows_per_hour: float = 0.0
    busy_node_ticks: float = 0.0        # integral of serving slots
    owned_node_ticks: float = 0.0       # integral of granted slots
    slot_utilization: float = 0.0       # busy / owned integrals
    node_hours: float = 0.0             # billed (per started lease hour)
    peak_owned: int = 0
    queue_peak: int = 0
    deferred_grants: int = 0            # grants landed via admission queue
    deferred_nodes: int = 0
    over_admissions: int = 0            # ticks where engine > granted (== 0)

    def as_dict(self) -> dict:
        return dict(self.__dict__)


class EmulatedEngine:
    """Tick-accurate engine stand-in: a task admitted at tick T occupies a
    slot and finishes after its service ticks (``decode_len`` marks, else
    ceil(runtime / tick_s)) — slot accounting vectorized over NumPy arrays
    like the real engine's. Used for trace-scale runs and the parity suite
    (service ticks == emulator runtime => identical finish times).

    ``max_len`` emulates a real engine's cache depth: with it set, a
    ``decode_len`` mark is served only up to :func:`decode_budget`'s
    room — the same cap ``JaxEngineAdapter`` applies — so a trace whose
    decode marks exceed the cache keeps emulator-vs-jax finish ticks
    bit-identical. ``None`` (default) serves marks uncapped."""

    def __init__(self, capacity: int, *, tick_s: float = 1.0,
                 max_len: int | None = None):
        self.capacity = capacity
        self.tick_s = tick_s
        self.max_len = max_len
        self.free = list(range(capacity))
        self._active = np.zeros((capacity,), bool)
        self._remaining = np.zeros((capacity,), np.int64)
        self._rid = np.full((capacity,), -1, np.int64)
        self._admit_seq = np.zeros((capacity,), np.int64)
        self._seq = 0
        self.steps = 0

    @property
    def active_count(self) -> int:
        return int(self._active.sum())

    def service_ticks(self, job: Job) -> int:
        if job.decode_len > 0:
            if self.max_len is not None:
                # cap to the cache budget exactly as the jax backend does:
                # budget R+1 tokens = R decode steps in a slot; a
                # zero-decode budget of 1 still holds the slot for one
                # tick (the engine's finish check is post-append)
                return max(decode_budget(job.decode_len, job.prompt_len,
                                         self.max_len) - 1, 1)
            return job.decode_len
        return max(int(math.ceil(job.runtime / self.tick_s)), 1)

    def admit_many(self, jobs: Sequence[Job]) -> Sequence[Job]:
        if len(jobs) > len(self.free):
            raise ServeInvariantError(
                "admitted beyond free slots: %d jobs > %d free"
                % (len(jobs), len(self.free)))
        for job in jobs:
            slot = self.free.pop()
            self._active[slot] = True
            self._remaining[slot] = self.service_ticks(job)
            self._rid[slot] = job.jid
            self._admit_seq[slot] = self._seq
            self._seq += 1
        return jobs

    def step(self) -> list[int]:
        """One decode tick for every active slot; returns finished jids in
        admission order (matching the emulator's finish-event order)."""
        if not self._active.any():
            return []
        self._remaining[self._active] -= 1
        self.steps += 1
        done = np.nonzero(self._active & (self._remaining <= 0))[0]
        done = done[np.argsort(self._admit_seq[done], kind="stable")]
        finished = [int(self._rid[s]) for s in done]
        self._active[done] = False
        self._rid[done] = -1
        self.free.extend(int(s) for s in done)
        return finished

    # ------------------------------------------------- event-skipping
    def next_finish_in(self) -> int | None:
        """Ticks until the earliest active slot finishes (``None`` when
        idle) — the engine-side event horizon ``ServeDriver``'s
        event-skipping consults."""
        if not self._active.any():
            return None
        return int(self._remaining[self._active].min())

    def advance_quiet(self, n: int) -> None:
        """Decrement every active slot by ``n`` ticks in one shot — the
        closed form of ``n`` consecutive :meth:`step` calls that each
        return no finishes. Refuses to jump past a finish: that would
        silently reorder completions, so it is an invariant error, not a
        clamp."""
        if n <= 0:
            return
        nf = self.next_finish_in()
        if nf is None:
            return
        if n >= nf:
            raise ServeInvariantError(
                "quiet advance of %d ticks would jump past a finish due "
                "in %d" % (n, nf))
        self._remaining[self._active] -= n
        self.steps += n


class JaxEngineAdapter:
    """Drives the real continuous-batching ``repro.serve.engine.Engine``:
    each workflow task becomes an inference request whose prompt is
    synthesized (seeded) at its ``prompt_len`` mark and whose decode
    budget is its ``decode_len`` mark, capped to the engine's cache."""

    def __init__(self, engine, *, seed: int = 0):
        from repro.serve.engine import Request   # lazy: keeps jax optional
        self._Request = Request
        self.engine = engine
        self.capacity = engine.max_batch
        cfg = engine.lm.cfg
        self._vocab = cfg.vocab_size
        self._ncb = cfg.n_codebooks
        self.max_len = engine.max_len
        # a physically-paged engine's ledger, surfaced so the fleet's
        # PartitionedEngine can cross-check its own page accounting
        self.pager = getattr(engine, "pager", None)
        self._rng = np.random.default_rng(seed)

    @property
    def active_count(self) -> int:
        return self.engine.active_count

    def service_ticks(self, job: Job) -> int:
        """Decode steps the engine will actually serve — the cache-capped
        budget (floored at one tick for zero-decode jobs: the engine's
        finish check is post-append), so a parity harness's
        ``EmulatedEngine(max_len=...)`` agrees with the live backend on
        every finish tick."""
        return max(decode_budget(job.decode_len, job.prompt_len,
                                 self.max_len) - 1, 1)

    def _request(self, job: Job) -> "Request":
        # prompts at/above the cache depth are truncated to max_len - 1:
        # the budget (>= 1) then always fits, so a synthesized request can
        # never be oversize for the engine
        plen = min(max(job.prompt_len, 1), self.max_len - 1)
        shape = (plen,) if self._ncb <= 1 else (plen, self._ncb)
        toks = self._rng.integers(1, self._vocab, shape).astype(np.int32)
        budget = decode_budget(job.decode_len, plen, self.max_len)
        return self._Request(rid=job.jid, tokens=toks, max_new_tokens=budget)

    def admit_many(self, jobs: Sequence[Job]) -> Sequence[Job]:
        admitted = self.engine.admit_many([self._request(j) for j in jobs])
        if len(admitted) != len(jobs):
            raise ServeInvariantError(
                "admitted beyond free slots: engine took %d of %d"
                % (len(admitted), len(jobs)))
        return jobs

    def step(self) -> list[int]:
        return [req.rid for req in self.engine.step()]


def engine_service_ticks(engine, job: Job) -> int:
    """Decode ticks ``job`` will hold a slot for on ``engine`` — the
    engine's own notion when it has one (``EmulatedEngine``, or a fleet
    slice over one), else the token-length mark."""
    fn = getattr(engine, "service_ticks", None)
    if fn is not None:
        return fn(job)
    return max(job.decode_len, 1)


def default_max_ticks(stream, engine, tick_s: float) -> int:
    """Generous tick budget for a stream: its arrival span plus a fat
    multiple of its total decode work (a starved run cycles; the bound
    surfaces the stall as incomplete counts, not a hang). ``stream`` need
    not be sorted — ``ServeFleet`` passes its tenants' events merged.

    Single pass over the stream (span and work folded together): at 10^5+
    workflows the old two-pass walk cost more than an event-skipped run.
    The returned bound is pinned unchanged by the regression suite."""
    span = 0.0
    work = 0
    for t, jobs in stream:
        if t > span:
            span = t
        for j in jobs:
            work += engine_service_ticks(engine, j)
    return int(span / tick_s + 8 * work + 36_000)


def replay_contention(provider, contention, i: int, now: float,
                      strict: bool) -> int:
    """Replay scripted co-tenant load events due at ``now`` (positive
    delta = request, negative = release) against ``provider``; returns
    the advanced cursor. Shared by ``ServeDriver`` and ``ServeFleet`` so
    the strictness and epsilon semantics cannot drift between the two
    tick bodies."""
    while i < len(contention) and contention[i][0] <= now + 1e-9:
        _, tre, delta = contention[i]
        i += 1
        if delta > 0:
            ok = provider.request(tre, delta, now)
            if not ok and strict:
                raise ServeInvariantError(
                    f"scripted contention rejected: {tre} +{delta} "
                    f"at t={now}")
        elif delta < 0:
            provider.release(tre, -delta, now)
    return i


class ServeDriver(Tenant):
    """Replay a workflow arrival stream through one MTC TRE at trace rate.
    The MTC serve species of the ``repro.serve.tenant.Tenant`` contract:
    the protocol hooks alias the serve-specific phase methods below (see
    the ``Tenant protocol`` section), which subclasses like
    ``ColumnarServeDriver`` override *by name* — the aliases dispatch
    virtually, so the columnar driver inherits the protocol for free.

    stream: ``[(arrival_t, jobs), ...]`` from ``traces.request_stream``
        (globally unique jids, deps remapped, token-length marks).
    provider: the shared provision service — a multi-tenant
        ``ResourceProvider`` gives deferred grants + backpressure; a plain
        ``ProvisionService`` gives the paper's grant-or-reject.
    engine: an engine adapter (``EmulatedEngine`` / ``JaxEngineAdapter``).
    policy / fixed_nodes: exactly one — DSP elasticity vs a dedicated
        engine of a fixed slot count (the baseline).
    contention: ``[(t, tre, delta), ...]`` co-tenant load replayed against
        the provider (positive = request, negative = release) — the "grant
        sequence" a parity test scripts identically into the emulator.
    clock: share a ``TickClock`` across drivers (``ServeFleet`` runs N
        tenant drivers on one clock); default: the driver owns its own.
    phase: control-cycle stagger in ticks — scans fire at
        ``k % scan_every == phase % scan_every`` (releases likewise), so a
        fleet spreads its tenants' cycles out instead of colliding at
        identical instants. The single-tenant default (0) keeps every
        cycle on the global grid, bit-for-bit with the emulator parity.
    slot_width: node units ONE batching slot of this tenant costs — the
        heterogeneous-fleet weight (a big-model tenant's slot is w > 1
        units of the shared pool). Provider grants, ``env.owned``/``busy``
        and every task's ``nodes`` are denominated in units (each task
        must carry ``nodes == slot_width``); the engine adapter still
        counts *slots*, so every engine-vs-grant comparison multiplies by
        the width. The default (1) is bit-identical to the homogeneous
        serve path.
    """

    def __init__(self, stream: Sequence[tuple[float, list[Job]]], *,
                 provider: ProvisionService, engine,
                 policy: MgmtPolicy | None = None,
                 fixed_nodes: int | None = None,
                 name: str = "mtc-serve", scheduler=None,
                 lifecycle: LifecycleService | None = None,
                 tick_s: float = 1.0,
                 contention: Sequence[tuple[float, str, int]] = (),
                 max_ticks: int | None = None, strict: bool = True,
                 clock: TickClock | None = None, phase: int = 0,
                 slot_width: int = 1, event_skip: bool = False):
        if slot_width < 1:
            raise ValueError(f"slot_width must be >= 1, got {slot_width}")
        self.stream = sorted(stream, key=lambda e: e[0])
        self.provider = provider
        self.engine = engine
        self.slot_width = slot_width
        self.tick_s = tick_s
        self.strict = strict
        self.clock = clock if clock is not None else TickClock()
        self.stats = ServeStats(name=name, tick_s=tick_s,
                                slot_width=slot_width,
                                workflows_expected=len(self.stream))
        self._admit_buf: list[Job] = []
        self.tasks: dict[int, Job] = {}
        self._wf_left: dict[int, int] = {}     # wid -> unfinished tasks
        self._stream_i = 0
        self._contention = sorted(contention, key=lambda e: e[0])
        self._cont_i = 0
        self._phase = phase
        if policy is not None:
            self._scan_every = max(int(round(policy.scan_interval / tick_s)),
                                   1)
            self._release_every = max(
                int(round(policy.release_interval / tick_s)), 1)
        else:
            self._scan_every = self._release_every = 0
        # the env's node ceiling, in units: a slot-denominated engine of S
        # slots can serve S * width units; a fleet slice reports the
        # shared pool's unit capacity directly
        cap_units = getattr(engine, "capacity_units", None)
        if cap_units is None:
            cap_units = engine.capacity * slot_width
        self.env = MTCRuntimeEnv(
            name, provision=provider, clock=self.clock, launch=self._launch,
            policy=policy, fixed_nodes=fixed_nodes, scheduler=scheduler,
            lifecycle=lifecycle, max_nodes=cap_units)
        self.env.grant_listener = self._on_grant
        self.env.track(())            # an empty stream is already all_done
        if max_ticks is None:
            max_ticks = default_max_ticks(self.stream, engine, tick_s)
        self.max_ticks = max_ticks
        # event-skipping needs the engine to expose its finish horizon and
        # a closed-form quiet advance; an adapter without them (the live
        # jax engine decodes real tokens every tick) just runs dense
        self.event_skip = bool(event_skip) and callable(
            getattr(engine, "next_finish_in", None)) and callable(
            getattr(engine, "advance_quiet", None))

    # ------------------------------------------------------- env hooks
    def _launch(self, job: Job) -> None:
        # buffered: the tick flushes launches as ONE batched admit, and
        # the task starts decoding next tick — emulator-identical timing
        if job.nodes != self.slot_width:
            raise ServeInvariantError(
                f"1 MTC task = 1 batching slot (= {self.slot_width} node "
                f"unit(s) at this tenant's width); "
                f"got nodes={job.nodes} for {job.name!r}")
        self._admit_buf.append(job)

    def _on_grant(self, nodes: int, t: float, deferred: bool) -> None:
        if deferred:
            self.stats.deferred_grants += 1
            self.stats.deferred_nodes += nodes

    # ------------------------------------------------------- tick parts
    def _submit_arrivals(self, now: float) -> None:
        while (self._stream_i < len(self.stream)
               and self.stream[self._stream_i][0] <= now + 1e-9):
            _, jobs = self.stream[self._stream_i]
            self._stream_i += 1
            if not jobs:
                continue
            self.env.track(jobs, extend=True)
            for j in jobs:
                self._wf_left[j.wid] = self._wf_left.get(j.wid, 0) + 1
                self.tasks[j.jid] = j
                if not j.deps:
                    self.env.submit(j)

    def _replay_contention(self, now: float) -> None:
        self._cont_i = replay_contention(self.provider, self._contention,
                                         self._cont_i, now, self.strict)

    def _maybe_release(self, k: int) -> None:
        if (self._release_every and k > 0
                and k % self._release_every == self._phase
                % self._release_every):
            self.env.release_check()

    def _process_finishes(self, finished: Sequence[int]) -> None:
        """Report a step's finished jids to the env (releasing dependents
        into the queue) and roll up workflow completions."""
        for jid in finished:
            task = self.tasks[jid]
            self.env.finish(task)
            self.stats.tasks_completed += 1
            self._wf_left[task.wid] -= 1
            if self._wf_left[task.wid] == 0:
                self.stats.workflows_completed += 1

    def _maybe_scan(self, k: int) -> None:
        if (self._scan_every and k > 0
                and k % self._scan_every == self._phase % self._scan_every):
            self.env.scan()

    def _flush_admissions(self) -> None:
        if not self._admit_buf:
            return
        w = self.slot_width
        if (self.engine.active_count + len(self._admit_buf)) * w \
                > self.env.owned:
            self.stats.over_admissions += 1
            if self.strict:
                raise ServeInvariantError(
                    "over-admission: (%d active + %d buffered) slots x "
                    "width %d > %d granted units"
                    % (self.engine.active_count, len(self._admit_buf),
                       w, self.env.owned))
        admitted = self.engine.admit_many(self._admit_buf)
        if admitted is None or len(admitted) >= len(self._admit_buf):
            self._admit_buf.clear()
        else:
            # a non-strict pool admitted only what fit its free slots: the
            # remainder stays in the launch buffer and is retried next
            # tick (its env bookkeeping — busy, allocation — is already
            # committed, so dropping it would strand the workflow and
            # spin the run to max_ticks)
            admitted_ids = {id(j) for j in admitted}
            self._admit_buf = [j for j in self._admit_buf
                               if id(j) not in admitted_ids]

    def _check_invariants(self) -> None:
        """End-of-tick consistency: the engine serves exactly the env's
        busy node units, and nothing exceeds the granted unit count. The
        engine counts slots; everything env-side is units, so the
        comparison weights by the tenant's slot width. A task parked back
        in the launch buffer by a non-strict partial admit still counts
        as busy env-side — it has not reached the engine yet, so the
        buffered units are part of the served total."""
        active = self.engine.active_count * self.slot_width
        active += len(self._admit_buf) * self.slot_width
        if active > self.env.owned or self.env.busy > self.env.owned:
            self.stats.over_admissions += 1
            if self.strict:
                raise ServeInvariantError(
                    "slots exceed grant: engine %d / env busy %d / owned %d"
                    % (active, self.env.busy, self.env.owned))
        if active != self.env.busy and self.strict:
            raise ServeInvariantError(
                "engine/env divergence: %d active units != %d busy nodes"
                % (active, self.env.busy))

    def _accumulate(self) -> None:
        self.stats.busy_node_ticks += self.env.busy * self.tick_s
        self.stats.owned_node_ticks += self.env.owned * self.tick_s
        self.stats.peak_owned = max(self.stats.peak_owned, self.env.owned)
        self.stats.queue_peak = max(self.stats.queue_peak,
                                    len(self.env.queue))

    @property
    def _done(self) -> bool:
        return (self._stream_i == len(self.stream) and self.env.all_done
                and not self._admit_buf and self.engine.active_count == 0)

    # --------------------------------------------------- event-skipping
    def _queue_len(self) -> int:
        """Queued-task count for the scan-skippability test (a columnar
        env overrides with its ring-buffer fill)."""
        return len(self.env.queue)

    def _next_arrival_t(self) -> float | None:
        """Timestamp of the next un-submitted stream entry (``None`` when
        the stream is drained) — the arrival horizon for event-skipping."""
        if self._stream_i < len(self.stream):
            return self.stream[self._stream_i][0]
        return None

    def next_event_tick(self, k: int) -> int:
        """Earliest tick after ``k`` at which the tick body could act: an
        arrival or contention event coming due, a release boundary (never
        skippable — the idle window resets even on a zero release, and a
        later decision diverges if it doesn't), a scan boundary with
        anything to negotiate or load (queued tasks or a parked request;
        an idle scan is a pure no-op), a buffered admission retry, or an
        engine finish. Every tick strictly between is *quiet*: nothing but
        the decode countdown and the stats integrals, which
        :meth:`_skip_quiet` applies in closed form."""
        if self._admit_buf:
            return k + 1
        cands = []
        arr_t = self._next_arrival_t()
        if arr_t is not None:
            cands.append(due_tick_floor(arr_t, self.tick_s))
        if self._cont_i < len(self._contention):
            cands.append(due_tick_floor(self._contention[self._cont_i][0],
                                        self.tick_s))
        if self._release_every:
            cands.append(next_boundary(k, self._release_every, self._phase))
        if self._scan_every and (self._queue_len()
                                 or self.env._pending_req is not None):
            cands.append(next_boundary(k, self._scan_every, self._phase))
        fin = self.engine.next_finish_in()
        if fin is not None:
            cands.append(k + fin)
        if not cands:
            return self.max_ticks
        return max(min(cands), k + 1)

    def _skip_quiet(self, dq: int) -> None:
        """Advance ``dq`` provably-quiet ticks in closed form: the decode
        countdown, the busy/owned stats integrals, and the clock. Nothing
        else can change — the engine refuses to advance past a finish, so
        a wrong horizon is an invariant error, not silent drift. With the
        default integral ``tick_s`` the closed form is bit-identical to
        ``dq`` dense accumulations."""
        if self.engine.active_count:
            self.engine.advance_quiet(dq)
        self.stats.busy_node_ticks += self.env.busy * self.tick_s * dq
        self.stats.owned_node_ticks += self.env.owned * self.tick_s * dq
        self.clock.advance(self.tick_s * dq)

    def _tick(self, k: int) -> None:
        """One control tick — THE serve tick body. ``ServeFleet`` replays
        these same phases in the same order across N tenant drivers (with
        one globally-stepped engine between the release and scan phases);
        keep any phase-order change mirrored there or fleet(N=1) parity
        breaks."""
        now = self.clock.now()
        self._submit_arrivals(now)
        self._replay_contention(now)
        self._maybe_release(k)
        self._process_finishes(self.engine.step())
        self._maybe_scan(k)
        self._flush_admissions()
        self._check_invariants()
        self._accumulate()

    # ------------------------------------- Tenant protocol (serve species)
    # ``ServeFleet`` drives lanes through these hooks; they alias the
    # serve phase methods above, which subclasses override by name, so
    # the protocol costs one virtual dispatch and zero behavior change.
    @property
    def name(self) -> str:
        return self.env.name

    def begin_tick(self, now: float) -> None:
        self._submit_arrivals(now)

    def pre_step(self, k: int) -> None:
        self._maybe_release(k)

    def post_step(self, k: int) -> None:
        self._process_finishes(self.engine.step())

    def control(self, k: int) -> None:
        self._maybe_scan(k)

    def flush(self) -> None:
        self._flush_admissions()

    def check_invariants(self) -> None:
        self._check_invariants()

    def accumulate(self) -> None:
        self._accumulate()

    @property
    def retired(self) -> bool:
        return self._done

    def skip_quiet_stats(self, dq: int) -> None:
        """The stats half of :meth:`_skip_quiet` — the fleet advances
        the shared pool and clock itself."""
        self.stats.busy_node_ticks += self.env.busy * self.tick_s * dq
        self.stats.owned_node_ticks += self.env.owned * self.tick_s * dq

    def rollup(self, fleet_stats) -> None:
        ls = self.stats
        fleet_stats.workflows_completed += ls.workflows_completed
        fleet_stats.tasks_completed += ls.tasks_completed
        fleet_stats.busy_node_ticks += ls.busy_node_ticks
        fleet_stats.owned_node_ticks += ls.owned_node_ticks
        fleet_stats.node_hours += ls.node_hours
        fleet_stats.deferred_grants += ls.deferred_grants
        fleet_stats.deferred_nodes += ls.deferred_nodes
        fleet_stats.over_admissions += ls.over_admissions
        fleet_stats.tenants.append(ls.as_dict())

    # -------------------------------------------------------------- run
    def finalize(self, ticks: int) -> ServeStats:
        """Close out the run: derived rates, destroy the TRE (closing
        every lease) and settle the billed node-hours."""
        self.stats.ticks = ticks
        self.stats.makespan_s = self.clock.now()
        if self.stats.makespan_s > 0:
            self.stats.workflows_per_hour = (
                self.stats.workflows_completed
                / (self.stats.makespan_s / 3600.0))
        if self.stats.owned_node_ticks > 0:
            self.stats.slot_utilization = (self.stats.busy_node_ticks
                                           / self.stats.owned_node_ticks)
        if not self.env.destroyed:
            self.env.destroy()
        self.stats.node_hours = self.provider.node_hours(
            self.env.name, now=self.clock.now())
        return self.stats

    def run(self) -> ServeStats:
        """Replay the stream to completion (or the tick bound); destroy
        the TRE (closing every lease) and return the stats. With
        ``event_skip`` the loop jumps the clock over quiet ticks
        (:meth:`next_event_tick`) — landing early is harmless (a no-op
        tick), landing late is impossible, so the stats are bit-identical
        to the dense loop's."""
        k = 0
        self._tick(k)
        while not self._done and k < self.max_ticks:
            if self.event_skip:
                kn = min(self.next_event_tick(k), self.max_ticks)
                dq = kn - k - 1
                if dq > 0:
                    self._skip_quiet(dq)
                    k += dq
            k += 1
            self.clock.advance(self.tick_s)
            self._tick(k)
        return self.finalize(k)
