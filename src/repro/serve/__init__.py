from repro.serve.engine import Engine, Request  # noqa: F401
from repro.serve.driver import (  # noqa: F401
    EmulatedEngine, JaxEngineAdapter, ServeDriver, ServeStats,
)
