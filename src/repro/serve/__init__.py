"""MTC serving: continuous-batching engine, trace-rate driver, fleet.

``Engine``/``Request`` (the jax continuous-batching engine) are
re-exported lazily so that importing the driver/fleet layers — which the
system registry does to register ``dawningcloud-serve-fleet`` — never
pulls jax into emulator-only processes (e.g. the scale-curve bench's
worker pool)."""
from repro.serve.columnar import (  # noqa: F401
    ColumnarEngine, ColumnarEnv, ColumnarServeDriver,
)
from repro.serve.driver import (  # noqa: F401
    EmulatedEngine, JaxEngineAdapter, ServeDriver, ServeInvariantError,
    ServeStats,
)
from repro.serve.fleet import (  # noqa: F401
    FleetStats, PartitionedEngine, ServeFleet, TenantSlice,
)
from repro.serve.paged import PagedKVAllocator, pages_for  # noqa: F401

_LAZY = ("Engine", "Request")


def __getattr__(name: str):
    if name in _LAZY:
        from repro.serve import engine
        return getattr(engine, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_LAZY))
