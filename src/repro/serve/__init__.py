from repro.serve.engine import Engine, Request  # noqa: F401
