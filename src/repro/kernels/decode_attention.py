"""Flash-decoding as a Pallas TPU kernel (single-token GQA vs KV cache).

GPU flash-decoding splits the KV cache across SMs and combines partial
softmaxes. The TPU analogue: the grid is (batch, kv_heads, kv_blocks) with
the kv-block axis innermost/sequential; the (G, hd) output tile for one kv
head's query group plus its fp32 (m, l) accumulators stay resident in VMEM
across the sweep. GQA is exploited directly — queries arrive grouped per
kv head, so no repeated-KV materialization ever touches HBM. Length masking
uses the per-row cache fill (continuous batching: every row differs).

Across-chip sequence sharding of the same computation lives in
repro.parallel.collectives (shard_map + psum combine); this kernel is the
per-shard body's TPU-optimal form.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.parallel.compat import tpu_compiler_params

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, block_s: int, scale: float):
    sj = pl.program_id(2)
    ns = pl.num_programs(2)
    length = len_ref[0]

    @pl.when(sj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(sj * block_s < length)
    def _block():
        q = q_ref[0, 0].astype(jnp.float32)            # (G, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)         # (bs, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)         # (bs, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        pos = sj * block_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(sj == ns - 1)
    def _fin():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention(q, k_cache, v_cache, lengths, *, block_s: int = 512,
                     interpret: bool = False):
    """q: (B,H,hd); k_cache/v_cache: (B,S,KVH,hd); lengths: (B,) valid fill.

    Returns (B,H,hd). H must be a multiple of KVH (GQA groups).
    """
    B, H, hd = q.shape
    S, KVH = k_cache.shape[1], k_cache.shape[2]
    G = H // KVH
    block_s = min(block_s, max(S, 8))
    pad_s = (-S) % block_s
    if pad_s:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
    Sp = S + pad_s
    qg = q.reshape(B, KVH, G, hd)
    grid = (B, KVH, Sp // block_s)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, block_s=block_s,
                          scale=1.0 / (hd ** 0.5)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, j: (b,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, hd), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, block_s, 1, hd), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, block_s, 1, hd), lambda b, h, j: (b, j, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KVH, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            pltpu,
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, k_cache, v_cache)
    return out.reshape(B, H, hd)
