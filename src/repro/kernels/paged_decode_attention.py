"""Paged flash-decoding as a Pallas TPU kernel (KV gathered via page table).

Same online-softmax sweep as kernels/decode_attention.py, but the KV cache
is a shared pool of fixed-size pages — (n_pages, page_size, KVH, hd) — and
each batch row reads its blocks *through* a per-row page table instead of a
contiguous (B, S, KVH, hd) slab. The page table and row lengths ride in as
scalar-prefetch operands (PrefetchScalarGridSpec), so the block index map
itself performs the gather: grid step (b, h, j) fetches physical page
``page_table[b, j]``. No gathered copy of the cache ever materializes in
HBM — the DMA engine walks the table.

With ``page_size`` equal to the contiguous kernel's ``block_s`` the float
op sequence is identical, so outputs are bit-identical to
``decode_attention`` over the equivalent contiguous cache (pinned in
tests/test_paged.py, interpret mode). Rows with ``length == 0`` skip every
block and emit exact zeros — the same zero-fill contract kernels/ref.py
defines.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.parallel.compat import tpu_compiler_params

NEG_INF = -1e30


def _paged_decode_kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                         m_scr, l_scr, acc_scr, *, page_size: int,
                         scale: float):
    del pt_ref  # consumed by the index maps; the body only needs lengths
    b = pl.program_id(0)
    sj = pl.program_id(2)
    ns = pl.num_programs(2)
    length = len_ref[b]

    @pl.when(sj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(sj * page_size < length)
    def _block():
        q = q_ref[0, 0].astype(jnp.float32)            # (G, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)         # (ps, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)         # (ps, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        pos = sj * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(sj == ns - 1)
    def _fin():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q, k_pages, v_pages, page_table, lengths, *,
                           interpret: bool = False):
    """q: (B,H,hd); k_pages/v_pages: (P, page_size, KVH, hd);
    page_table: (B, pages_per_row) int32 physical page ids;
    lengths: (B,) valid fill in tokens.

    Returns (B,H,hd). H must be a multiple of KVH (GQA groups). A row's
    logical cache is its table's pages concatenated in order; positions at
    or beyond ``lengths[b]`` are masked, so garbage in partially-filled or
    null pages never contributes. ``length == 0`` rows return exact zeros.
    """
    B, H, hd = q.shape
    page_size, KVH = k_pages.shape[1], k_pages.shape[2]
    G = H // KVH
    n_pt = page_table.shape[1]
    qg = q.reshape(B, KVH, G, hd)
    grid = (B, KVH, n_pt)
    out = pl.pallas_call(
        functools.partial(_paged_decode_kernel, page_size=page_size,
                          scale=1.0 / (hd ** 0.5)),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, hd),
                             lambda b, h, j, pt, ln: (b, h, 0, 0)),
                pl.BlockSpec((1, page_size, 1, hd),
                             lambda b, h, j, pt, ln: (pt[b, j], 0, h, 0)),
                pl.BlockSpec((1, page_size, 1, hd),
                             lambda b, h, j, pt, ln: (pt[b, j], 0, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, hd),
                                   lambda b, h, j, pt, ln: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KVH, G, hd), q.dtype),
        compiler_params=tpu_compiler_params(
            pltpu,
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(page_table.astype(jnp.int32), lengths.astype(jnp.int32),
      qg, k_pages, v_pages)
    return out.reshape(B, H, hd)
