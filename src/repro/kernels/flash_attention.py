"""Flash attention (prefill/train) as a Pallas TPU kernel.

TPU adaptation of the GPU flash-attention idea: instead of warp-level
softmax reductions, we tile for the MXU — (block_q x head_dim) @
(head_dim x block_k) score tiles with fp32 running-max/denominator scratch
in VMEM. The grid is (batch*heads, q_blocks, kv_blocks) with the kv axis
innermost and marked "arbitrary" (sequential), so the output tile and the
(m, l) accumulators persist in VMEM across the kv sweep — the classic
revisiting trick that keeps HBM traffic at O(S) per row instead of O(S^2).

Causality is handled two ways at once:
  - whole (q, kv) blocks strictly above the diagonal are *skipped*
    (``pl.when`` guard: no MXU work, no VMEM write),
  - the diagonal block applies an element mask.

The jnp oracle lives in kernels/ref.py; repro.models.attention is the
model-side equivalent used under jit/dry-run.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.parallel.compat import tpu_compiler_params

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, block_q: int, block_k: int, causal: bool,
                  seq_k: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    run = (not causal) or (kj * block_k <= qi * block_q + block_q - 1)

    @pl.when(run)
    def _block():
        q = q_ref[0].astype(jnp.float32)            # (bq, hd)
        k = k_ref[0].astype(jnp.float32)            # (bk, hd)
        v = v_ref[0].astype(jnp.float32)            # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < seq_k                         # kv padding
        if causal:
            mask &= qpos >= kpos
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]                          # (bq,)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(kj == nk - 1)
    def _fin():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 256,
                    block_k: int = 256, interpret: bool = False):
    """q, k, v: (BH, S, hd) with kv heads already repeated. Returns (BH, S, hd).

    S is padded to the block size internally; hd should be a multiple of 128
    on real TPUs (any value works in interpret mode).
    """
    BH, S, hd = q.shape
    Sk = k.shape[1]
    block_q = min(block_q, max(S, 8))
    block_k = min(block_k, max(Sk, 8))
    pad_q = (-S) % block_q
    pad_k = (-Sk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))
    Sp, Skp = S + pad_q, Sk + pad_k
    grid = (BH, Sp // block_q, Skp // block_k)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=1.0 / (hd ** 0.5),
                          block_q=block_q, block_k=block_k, causal=causal,
                          seq_k=Sk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            pltpu,
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :S]
