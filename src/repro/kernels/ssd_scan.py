"""Mamba2 SSD (state-space duality) chunked scan as a Pallas TPU kernel.

The SSD dual form maps perfectly onto the MXU: within a chunk of Q tokens
the recurrence is an attention-like pair of (Q x ds) @ (ds x Q) and
(Q x Q) @ (Q x hp) matmuls under a causal decay mask L; across chunks only
an (hp x ds) state matrix flows. We tile the grid as
(batch, heads, chunks) with chunks innermost/sequential: the running state
lives in a VMEM scratch across the chunk sweep — the inter-chunk pass costs
no HBM traffic at all (vs. the GPU implementation's inter-block state
materialization), while every intra-chunk op is MXU-shaped.

fp32 throughout the state path (matching the model's ssd_chunked), bf16
tolerated on the x/B/C inputs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.parallel.compat import tpu_compiler_params


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_ref, state_scr,
                *, chunk: int):
    cj = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(cj == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, :, 0].astype(jnp.float32)        # (Q, hp)
    dt = dt_ref[0, :, 0].astype(jnp.float32)      # (Q,)
    A = a_ref[0]                                  # scalar for this head
    Bm = b_ref[0, :, 0].astype(jnp.float32)       # (Q, ds)
    Cm = c_ref[0, :, 0].astype(jnp.float32)       # (Q, ds)

    dA = dt * A                                   # (Q,) <= 0
    cs = jnp.cumsum(dA)                           # (Q,)
    # intra-chunk: attention-like dual form with decay mask
    L = jnp.exp(cs[:, None] - cs[None, :])
    idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jdx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(idx >= jdx, L, 0.0)
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * L
    xdt = x * dt[:, None]                         # (Q, hp)
    y = jax.lax.dot_general(scores, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # inter-chunk: contribution of the carried state
    state = state_scr[...]                        # (hp, ds)
    y = y + jax.lax.dot_general(Cm, state, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32
                                ) * jnp.exp(cs)[:, None]
    y_ref[0, :, 0] = y.astype(y_ref.dtype)
    # state update: decay + B^T (decay_out * xdt)
    decay_out = jnp.exp(cs[-1] - cs)              # (Q,)
    state_scr[...] = state * jnp.exp(cs[-1]) + jax.lax.dot_general(
        xdt * decay_out[:, None], Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(cj == nc - 1)
    def _fin():
        st_ref[0, 0] = state_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, Bg, Cg, *, chunk: int = 128, interpret: bool = False):
    """x: (B,S,nh,hp); dt: (B,S,nh) f32; A: (nh,) f32; Bg/Cg: (B,S,ng,ds).

    Returns (y (B,S,nh,hp) fp32, final_state (B,nh,hp,ds) fp32).
    S must be a multiple of ``chunk``; ng must divide nh.
    """
    B, S, nh, hp = x.shape
    ng, ds = Bg.shape[-2:]
    assert S % chunk == 0 and nh % ng == 0
    nc = S // chunk
    rep = nh // ng
    grid = (B, nh, nc)
    # group index for each head (B/C shared across the group's heads)
    y, state = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, hp), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, chunk, 1, ds), lambda b, h, c: (b, c, h // rep, 0)),
            pl.BlockSpec((1, chunk, 1, ds), lambda b, h, c: (b, c, h // rep, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, hp), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, hp, ds), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, nh, hp), jnp.float32),
            jax.ShapeDtypeStruct((B, nh, hp, ds), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hp, ds), jnp.float32)],
        compiler_params=tpu_compiler_params(
            pltpu,
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt.astype(jnp.float32), A.astype(jnp.float32), Bg, Cg)
    return y, state
