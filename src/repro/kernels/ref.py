"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """q,k,v: (BH, S, hd) fp32/bf16. Plain materialized softmax attention."""
    f32 = jnp.float32
    S, Sk = q.shape[1], k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(f32), k.astype(f32))
    s = s / (q.shape[-1] ** 0.5)
    if causal:
        mask = jnp.arange(S)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(f32)).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, lengths):
    """q: (B,H,hd); caches: (B,S,KVH,hd); lengths: (B,). GQA decode.

    Rows with ``lengths == 0`` have no valid positions: softmax over an
    all-masked row would silently average garbage (uniform weights over
    NEG_INF logits), so the contract is pinned to exact zero-fill — the
    same semantics the online-softmax kernels produce by skipping every
    block (acc stays 0, the 1e-30 l-clamp divides 0 by it).
    """
    B, H, hd = q.shape
    KVH = k_cache.shape[2]
    G = H // KVH
    f32 = jnp.float32
    qg = q.reshape(B, KVH, G, hd).astype(f32)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache.astype(f32)) / (hd ** 0.5)
    valid = jnp.arange(k_cache.shape[1])[None, :] < lengths[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(f32))
    o = jnp.where((lengths > 0)[:, None, None, None], o, 0.0)
    return o.reshape(B, H, hd).astype(q.dtype)


def ssd_scan_ref(x, dt, A, Bg, Cg, *, chunk: int):
    """Mamba2 SSD oracle (sequential recurrence, token by token).

    x: (B,S,nh,hp); dt: (B,S,nh) f32; A: (nh,); Bg/Cg: (B,S,ng,ds).
    Returns (y (B,S,nh,hp) fp32, state (B,nh,hp,ds) fp32).
    """
    f32 = jnp.float32
    B, S, nh, hp = x.shape
    ng, ds = Bg.shape[-2:]
    rep = nh // ng
    Bh = jnp.repeat(Bg.astype(f32), rep, axis=2)
    Ch = jnp.repeat(Cg.astype(f32), rep, axis=2)
    xf = x.astype(f32)

    def step(state, inp):
        xt, dtt, Bt, Ct = inp          # (B,nh,hp), (B,nh), (B,nh,ds) x2
        decay = jnp.exp(dtt * A)       # (B,nh)
        xdt = xt * dtt[..., None]
        state = state * decay[..., None, None] + jnp.einsum(
            "bhs,bhp->bhps", Bt, xdt)
        y = jnp.einsum("bhs,bhps->bhp", Ct, state)
        return state, y

    state0 = jnp.zeros((B, nh, hp, ds), f32)
    state, ys = jax.lax.scan(
        step, state0,
        (xf.transpose(1, 0, 2, 3), dt.astype(f32).transpose(1, 0, 2),
         Bh.transpose(1, 0, 2, 3), Ch.transpose(1, 0, 2, 3)))
    return ys.transpose(1, 0, 2, 3), state


def moe_gmm_ref(x, w):
    """Grouped expert GEMM oracle. x: (E,C,d); w: (E,d,f) -> (E,C,f)."""
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)
