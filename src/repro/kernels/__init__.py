"""Pallas TPU kernels for the compute hot spots (+ jnp oracles in ref.py).

flash_attention  prefill/train attention (MXU-tiled online softmax)
decode_attention flash-decoding vs a KV cache (per-row lengths, GQA-native)
ssd_scan         Mamba2 chunked state-space dual form (VMEM-carried state)
moe_gmm          grouped expert GEMM (per-expert MXU-tiled matmul)

ops.py picks compiled-vs-interpret per backend; model code under jit uses
the mathematically-identical jnp paths in repro.models (XLA fuses those),
so kernels are exercised through ops.py and validated against ref.py.
"""
