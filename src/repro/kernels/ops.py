"""Dispatch layer: Pallas kernel on TPU, interpret-mode on CPU, oracle check.

``use_pallas()`` gates the kernels into the model code: on a real TPU the
compiled kernels run; on the CPU container the same kernel bodies execute
via ``interpret=True`` (tests) while jit/dry-run paths use the pure-jnp
equivalents in ``repro.models`` (identical math, XLA-fused).
"""
from __future__ import annotations

import jax

from repro.kernels.decode_attention import decode_attention  # noqa: F401
from repro.kernels.flash_attention import flash_attention  # noqa: F401
from repro.kernels.moe_gmm import moe_gmm  # noqa: F401
from repro.kernels.ssd_scan import ssd_scan  # noqa: F401


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def interpret_default() -> bool:
    """Pallas interpret mode is required anywhere but a real TPU."""
    return not on_tpu()


def attention(q, k, v, *, causal: bool = True, block_q: int = 256,
              block_k: int = 256):
    """(BH,S,hd) flash attention with backend-appropriate execution."""
    return flash_attention(q, k, v, causal=causal, block_q=block_q,
                           block_k=block_k, interpret=interpret_default())


def decode(q, k_cache, v_cache, lengths, *, block_s: int = 512):
    return decode_attention(q, k_cache, v_cache, lengths, block_s=block_s,
                            interpret=interpret_default())


def ssd(x, dt, A, Bg, Cg, *, chunk: int = 128):
    return ssd_scan(x, dt, A, Bg, Cg, chunk=chunk,
                    interpret=interpret_default())


def gmm(x, w, **kw):
    return moe_gmm(x, w, interpret=interpret_default(), **kw)
