"""Grouped expert GEMM (MoE) as a Pallas TPU kernel.

After capacity-based dispatch, each chip holds (E_local, C, d) activations
and (E_local, d, f) expert weights. The kernel runs one tiled matmul per
expert with the grid (E, C/bc, f/bf, d/bd): the d axis is innermost and
sequential with an fp32 VMEM accumulator, so every (bc x bd) @ (bd x bf)
tile is a single MXU op and partial products never touch HBM. Tile sizes
default to the MXU-native 128 and clamp to small shapes for tests.

This is the TPU replacement for a GPU "grouped GEMM" library call; the
dense-batched jnp einsum in repro.models.moe is its oracle (ref.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.parallel.compat import tpu_compiler_params


def _gmm_kernel(x_ref, w_ref, o_ref, acc_scr):
    dk = pl.program_id(3)
    nd = pl.num_programs(3)

    @pl.when(dk == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[0]           # (bc, bd)
    w = w_ref[0]           # (bd, bf)
    acc_scr[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(dk == nd - 1)
    def _fin():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_c", "block_f", "block_d", "interpret"))
def moe_gmm(x, w, *, block_c: int = 128, block_f: int = 128,
            block_d: int = 128, interpret: bool = False):
    """x: (E, C, d) dispatched tokens; w: (E, d, f) expert weights.

    Returns (E, C, f) in x.dtype (fp32 accumulation).
    """
    E, C, d = x.shape
    _, _, f = w.shape
    block_c = min(block_c, C)
    block_f = min(block_f, f)
    block_d = min(block_d, d)
    assert C % block_c == 0 and f % block_f == 0 and d % block_d == 0
    grid = (E, C // block_c, f // block_f, d // block_d)
    return pl.pallas_call(
        _gmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_c, block_d), lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, block_d, block_f), lambda e, i, j, k: (e, k, j)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f),
                               lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, C, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
        compiler_params=tpu_compiler_params(
            pltpu,
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(x, w)
