"""Shared layers: RMSNorm, RoPE, MLP variants."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm_init(scope, name, dim):
    scope.param(name, (dim,), ("norm",), init="ones")


def rmsnorm(scale, x, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (...,S,hd/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------- MLP ----------------

def mlp_init(scope, cfg, d_ff: int):
    d = cfg.d_model
    if cfg.mlp_act == "swiglu":
        scope.param("w_in", (d, d_ff), ("embed", "mlp"))
        scope.param("w_gate", (d, d_ff), ("embed", "mlp"))
    else:  # sq_relu (nemotron): plain 2-matrix MLP
        scope.param("w_in", (d, d_ff), ("embed", "mlp"))
    scope.param("w_out", (d_ff, d), ("mlp", "embed"))


def mlp_apply(p, x, act: str):
    h = jnp.einsum("...d,df->...f", x, p["w_in"])
    if act == "swiglu":
        g = jnp.einsum("...d,df->...f", x, p["w_gate"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h
    elif act == "sq_relu":
        h = jnp.square(jax.nn.relu(h))
    else:
        raise ValueError(act)
    return jnp.einsum("...f,fd->...d", h, p["w_out"])


def softcap(x, cap: float):
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap
