from repro.models.lm import LM  # noqa: F401
