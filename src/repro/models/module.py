"""Minimal functional module substrate (no flax).

Params are nested dicts of arrays. Each init function receives a ``Scope``
and registers parameters with *logical axis* annotations; the scope builds
two parallel pytrees: ``params`` (arrays) and ``axes`` (tuples of logical
axis names, consumed by repro.parallel.sharding).

``abstract=True`` scopes produce ``jax.ShapeDtypeStruct`` leaves — this is
how the multi-pod dry-run gets parameter shapes/shardings for trillion-param
configs without allocating a single byte.
"""
from __future__ import annotations

import zlib

import jax
import jax.numpy as jnp


def _fold(key, name: str):
    return jax.random.fold_in(key, zlib.crc32(name.encode()) & 0x7FFFFFFF)


class Scope:
    """Collects (params, axes) trees during init."""

    def __init__(self, key, dtype=jnp.bfloat16, abstract=False):
        self.key = key
        self.dtype = dtype
        self.abstract = abstract
        self.params: dict = {}
        self.axes: dict = {}

    def sub(self, name: str) -> "Scope":
        child = Scope(None if self.abstract else _fold(self.key, name),
                      self.dtype, self.abstract)
        self.params[name] = child.params
        self.axes[name] = child.axes
        return child

    def param(self, name, shape, axes, init="fan_in", scale=1.0, dtype=None):
        assert len(shape) == len(axes), (name, shape, axes)
        dtype = dtype or self.dtype
        if self.abstract:
            v = jax.ShapeDtypeStruct(tuple(shape), dtype)
        elif init == "zeros":
            v = jnp.zeros(shape, dtype)
        elif init == "ones":
            v = jnp.ones(shape, dtype)
        elif init == "fan_in":
            fan = shape[-2] if len(shape) >= 2 else shape[0]
            std = scale / (fan ** 0.5)
            v = (jax.random.normal(_fold(self.key, name), shape, jnp.float32)
                 * std).astype(dtype)
        elif init == "normal":
            v = (jax.random.normal(_fold(self.key, name), shape, jnp.float32)
                 * scale).astype(dtype)
        else:
            raise ValueError(init)
        self.params[name] = v
        self.axes[name] = tuple(axes)
        return v

    def done(self):
        return self.params, self.axes


def is_axes_leaf(x):
    return isinstance(x, tuple) and all(isinstance(y, (str, type(None))) for y in x)


def init_with_axes(init_fn, key, dtype=jnp.bfloat16, abstract=False):
    scope = Scope(key, dtype, abstract)
    init_fn(scope)
    return scope.done()


def stacked_init(init_fn, key, n: int, dtype=jnp.bfloat16, abstract=False,
                 stack_axis_name="layers"):
    """Stack ``n`` independent inits along a leading 'layers' axis."""
    if abstract:
        params, axes = init_with_axes(init_fn, None, dtype, abstract=True)
        params = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), params)
    else:
        keys = jax.random.split(key, n)
        scope = Scope(keys[0], dtype)
        init_fn(scope)
        axes = scope.axes

        def one(k):
            s = Scope(k, dtype)
            init_fn(s)
            return s.params

        params = jax.vmap(one)(keys)
    axes = jax.tree.map(lambda a: (stack_axis_name,) + a, axes,
                        is_leaf=is_axes_leaf)
    return params, axes


def strip_stack_axis(axes_tree):
    """Remove the leading 'layers' logical axis (for per-slice specs)."""
    return jax.tree.map(lambda a: a[1:], axes_tree, is_leaf=is_axes_leaf)


def cast_tree(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype) if hasattr(x, "astype") else x,
                        tree)
