"""Mixture-of-Experts: expert-parallel capacity-based dispatch.

Experts are sharded over the ``model`` mesh axis (expert parallelism). The
baseline dispatch is *token-replicated*: activations entering the MoE block
are replicated over ``model`` (standard in a TP transformer), so each chip
simply gathers the tokens routed to its local experts, runs a batched expert
GEMM, scatter-adds the weighted outputs, and one ``psum`` over ``model``
combines — the same collective cost as a dense Megatron MLP block, with no
all-to-all. An a2a variant is a §Perf alternative.

Routing (softmax -> top-k -> renorm) and the load-balancing/z losses are
computed outside the shard_map in plain pjit ops.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import AXIS_MODEL, batch_axes
from repro.parallel.compat import shard_map


def moe_init(scope, cfg):
    d, f, e = cfg.d_model, cfg.d_ff_expert, cfg.n_experts
    scope.param("router", (d, e), ("embed", "experts"), dtype=jnp.float32)
    scope.param("w_in", (e, d, f), ("experts", "embed", "expert_mlp"))
    scope.param("w_out", (e, f, d), ("experts", "expert_mlp", "embed"))
    if cfg.mlp_act == "swiglu":
        scope.param("w_gate", (e, d, f), ("experts", "embed", "expert_mlp"))


def route(p, cfg, x):
    """x: (B,S,d) -> ids (B,S,K) int32, weights (B,S,K) f32, aux dict."""
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    wts, ids = jax.lax.top_k(probs, cfg.top_k)
    wts = wts / jnp.maximum(jnp.sum(wts, axis=-1, keepdims=True), 1e-9)
    # load-balance loss (Switch): E * sum_e mean_prob_e * frac_assign_e
    counts = jnp.zeros((cfg.n_experts,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    frac = counts / jnp.maximum(jnp.sum(counts), 1.0)
    mean_prob = jnp.mean(probs, axis=(0, 1))
    lb_loss = cfg.n_experts * jnp.sum(mean_prob * frac)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return ids, wts, {"moe_lb_loss": lb_loss, "moe_z_loss": z_loss}


def _capacity(tokens: int, cfg) -> int:
    return max(1, math.ceil(tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor))


def _moe_local(x, ids, wts, w_in, w_gate, w_out, *, cfg, n_local, axis):
    """Per-chip dispatch/compute/combine. x: (Bl,S,d); weights: (E_loc,d,f)."""
    Bl, S, d = x.shape
    K = cfg.top_k
    T = Bl * S
    C = _capacity(T, cfg)
    lo = (jax.lax.axis_index(axis) if axis else 0) * n_local
    xf = x.reshape(T, d)
    idf = ids.reshape(T * K)
    wtf = wts.reshape(T * K).astype(jnp.float32)
    tok = jnp.arange(T * K, dtype=jnp.int32) // K

    local = (idf >= lo) & (idf < lo + n_local)
    e_loc = jnp.where(local, idf - lo, n_local)          # n_local = drop bucket
    onehot = jax.nn.one_hot(e_loc, n_local, dtype=jnp.int32)   # (TK, E_loc)
    pos = jnp.sum((jnp.cumsum(onehot, axis=0) - 1) * onehot, axis=1)  # slot in expert

    # dispatch tables (E_loc, C); OOB rows/cols (drops, remote experts) fall away
    tok_tbl = jnp.full((n_local, C), T, jnp.int32).at[e_loc, pos].set(
        tok, mode="drop")
    g_tbl = jnp.zeros((n_local, C), jnp.float32).at[e_loc, pos].set(
        wtf, mode="drop")

    valid = (tok_tbl < T)[..., None]
    xe = jnp.where(valid, xf[jnp.clip(tok_tbl, 0, T - 1)], 0)    # (E_loc,C,d)
    h = jnp.einsum("ecd,edf->ecf", xe, w_in)
    if cfg.mlp_act == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", xe, w_gate)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h
    else:
        h = jnp.square(jax.nn.relu(h))
    ye = jnp.einsum("ecf,efd->ecd", h, w_out)
    ye = (ye.astype(jnp.float32) * g_tbl[..., None]).astype(x.dtype)

    y = jnp.zeros((T, d), x.dtype).at[tok_tbl.reshape(-1)].add(
        ye.reshape(-1, d), mode="drop")
    if axis:
        y = jax.lax.psum(y, axis)
    return y.reshape(Bl, S, d)


def moe_apply(p, cfg, x, ids, wts, mesh=None):
    """Expert-parallel MoE. Returns (B,S,d)."""
    w_gate = p.get("w_gate", p["w_in"])  # placeholder when not gated
    n_model = mesh.shape.get(AXIS_MODEL, 1) if mesh is not None else 1
    if mesh is None or n_model == 1 or cfg.n_experts % n_model != 0:
        return _moe_local(x, ids, wts, p["w_in"], w_gate, p["w_out"],
                          cfg=cfg, n_local=cfg.n_experts, axis=None)
    n_local = cfg.n_experts // n_model
    bax = batch_axes(mesh)
    btotal = 1
    for a in bax:
        btotal *= mesh.shape[a]
    # replicate batch when it cannot shard (e.g. long-context decode B=1)
    bspec = P(bax if (bax and x.shape[0] % btotal == 0) else None)
    fn = shard_map(
        lambda xx, ii, ww, wi, wg, wo: _moe_local(
            xx, ii, ww, wi, wg, wo, cfg=cfg, n_local=n_local, axis=AXIS_MODEL),
        mesh=mesh,
        in_specs=(P(*bspec, None, None), P(*bspec, None, None), P(*bspec, None, None),
                  P(AXIS_MODEL, None, None), P(AXIS_MODEL, None, None),
                  P(AXIS_MODEL, None, None)),
        out_specs=P(*bspec, None, None),
        check_vma=False,
    )
    return fn(x, ids, wts, p["w_in"], w_gate, p["w_out"])
