"""Decoder LM assembly: embeddings -> scanned blocks -> head(s) + losses.

One class covers all five assigned families:
- dense / MoE / hybrid / SSM backbones via the block pattern in ModelConfig,
- VLM: precomputed patch embeddings (stub frontend) prepended to token
  embeddings, loss masked to text positions,
- audio: ``n_codebooks`` parallel token streams (summed input embeddings,
  one output head per codebook; the delay pattern lives in the data layer).

Layers are scanned over ``n_layers / pattern_period`` repeats of the pattern
(period 1 for homogeneous stacks; e.g. 8 for Jamba's 7:1 mamba:attn
interleave with MoE on alternate layers). Remat wraps the scan body.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models.blocks import block_apply, block_init
from repro.models.layers import rmsnorm, rmsnorm_init
from repro.models.module import (
    Scope, init_with_axes, is_axes_leaf, stacked_init, strip_stack_axis, _fold,
)
from repro.parallel.sharding import AXIS_MODEL, batch_axes, resolve_spec

AUX_KEYS = ("moe_lb_loss", "moe_z_loss")


@dataclass
class Runtime:
    """Static execution context threaded through apply fns."""
    parallel: ParallelConfig = field(default_factory=ParallelConfig)
    mesh: Mesh | None = None
    block_axes: Any = None  # per-pattern-pos axes trees (fsdp re-gather)

    def moe_mesh(self):
        return self.mesh

    def padded_heads(self, n_heads: int) -> int:
        """Heads padded up to a multiple of the model axis so attention
        activations shard cleanly. With H % model != 0 (qwen2's 28,
        qwen3's 40, arctic's 56 over a 16-way axis) GSPMD otherwise shards
        the *contracting* dims and emits an all-reduce inside every
        (q-chunk, kv-chunk) iteration — measured 3x total wire bytes on
        qwen2-14b train_4k. Zero-padded heads are sliced off before w_o."""
        if self.mesh is None or AXIS_MODEL not in self.mesh.axis_names:
            return n_heads
        m = self.mesh.shape[AXIS_MODEL]
        return -(-n_heads // m) * m

    def shard_heads(self, t):
        """Constrain (B, S, H, hd) attention activations to batch x heads."""
        if self.mesh is None:
            return t
        baxes = batch_axes(self.mesh)
        btotal = math.prod(self.mesh.shape[a] for a in baxes) if baxes else 1
        b = baxes if (baxes and t.shape[0] % btotal == 0) else None
        m = (AXIS_MODEL if AXIS_MODEL in self.mesh.axis_names
             and t.shape[2] % self.mesh.shape[AXIS_MODEL] == 0 else None)
        spec = jax.sharding.PartitionSpec(b, None, m, None)
        return jax.lax.with_sharding_constraint(
            t, jax.sharding.NamedSharding(self.mesh, spec))

    def shard_activations(self, x):
        """Pin the residual stream to (batch over data axes, replicated,
        replicated): without this GSPMD happily replicates the batch dim
        inside the layer scan and the saved-for-backward buffers blow up
        16x (measured on qwen3-14b train_4k: 25.6 -> ~3 GiB per device)."""
        if self.mesh is None:
            return x
        baxes = batch_axes(self.mesh)
        if not baxes or x.shape[0] % math.prod(
                self.mesh.shape[a] for a in baxes):
            return x
        spec = jax.sharding.PartitionSpec(baxes, *([None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh, spec))

    def decode_kv_shard(self, cfg) -> str:
        mode = self.parallel.decode_kv_shard
        if mode != "auto":
            return mode
        if self.mesh is None or AXIS_MODEL not in self.mesh.axis_names:
            return "heads"
        return ("heads" if cfg.n_kv_heads >= self.mesh.shape[AXIS_MODEL]
                else "seq")


def _zero_aux():
    return {k: jnp.zeros((), jnp.float32) for k in AUX_KEYS}


def _acc_aux(a, b):
    return {k: a[k] + b.get(k, 0.0) for k in AUX_KEYS}


class LM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------- init
    def init(self, key, abstract: bool = False):
        """Returns (params, axes). abstract=True -> ShapeDtypeStruct leaves."""
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        scope = Scope(key, dtype, abstract)
        ncb = max(1, cfg.n_codebooks)
        scope.param("embed", (ncb, cfg.vocab_padded, cfg.d_model),
                    ("codebooks", "vocab", "embed"), init="normal", scale=0.02)
        scope.param("head", (ncb, cfg.d_model, cfg.vocab_padded),
                    ("codebooks", "embed", "vocab"))
        rmsnorm_init(scope, "final_norm", cfg.d_model)
        period = cfg.pattern_period
        repeats = cfg.n_layers // period
        blocks_p, blocks_a = {}, {}
        for i in range(period):
            k_i = None if abstract else _fold(key, f"blocks{i}")
            p_i, a_i = stacked_init(
                lambda s, i=i: block_init(s, cfg, i), k_i, repeats,
                dtype=dtype, abstract=abstract)
            blocks_p[f"pos{i}"], blocks_a[f"pos{i}"] = p_i, a_i
        params, axes = scope.done()
        params["blocks"], axes["blocks"] = blocks_p, blocks_a
        return params, axes

    def runtime(self, parallel=None, mesh=None):
        _, axes = self.init(None, abstract=True)
        block_axes = {k: strip_stack_axis(v) for k, v in axes["blocks"].items()}
        return Runtime(parallel or ParallelConfig(), mesh, block_axes)

    # ------------------------------------------------------------ embed
    def embed(self, params, batch):
        cfg = self.cfg
        emb = params["embed"]  # (ncb, Vp, d)
        tokens = batch["tokens"]
        if cfg.n_codebooks > 1:  # (B,S,ncb)
            x = jnp.zeros(tokens.shape[:2] + (cfg.d_model,), emb.dtype)
            for c in range(cfg.n_codebooks):
                x = x + emb[c][tokens[..., c]]
        else:
            x = emb[0][tokens]
        if cfg.vision_stub and "patches" in batch:
            x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
        return x

    def logits(self, params, x):
        cfg = self.cfg
        if cfg.n_codebooks > 1:
            return jnp.einsum("bsd,cdv->bscv", x, params["head"])
        return jnp.einsum("bsd,dv->bsv", x, params["head"][0])

    # ---------------------------------------------------------- backbone
    def _maybe_gather(self, rt: Runtime, pos: str, p_slice):
        """FSDP: re-gather a storage-sharded block slice to the TP layout."""
        if (rt.mesh is None or rt.parallel.strategy != "fsdp_tp"
                or rt.block_axes is None):
            return p_slice
        mesh = rt.mesh
        leaves, treedef = jax.tree.flatten(p_slice)
        axes_leaves = jax.tree.leaves(rt.block_axes[pos], is_leaf=is_axes_leaf)
        assert len(leaves) == len(axes_leaves)
        out = [
            jax.lax.with_sharding_constraint(
                p, jax.sharding.NamedSharding(
                    mesh, resolve_spec(a, p.shape, mesh, "tp")))
            for p, a in zip(leaves, axes_leaves)
        ]
        return jax.tree.unflatten(treedef, out)

    def backbone(self, params, rt: Runtime, x, positions, *, collect_cache=False,
                 remat=True):
        cfg = self.cfg
        period = cfg.pattern_period

        def body(carry, layer_params):
            x, aux = carry
            caches = {}
            for i in range(period):
                pp = self._maybe_gather(rt, f"pos{i}", layer_params[f"pos{i}"])
                x, cache_i, aux_i = block_apply(pp, cfg, rt, x, positions, i)
                x = rt.shard_activations(x)
                caches[f"pos{i}"] = cache_i
                aux = _acc_aux(aux, aux_i)
            return (x, aux), (caches if collect_cache else None)

        if remat and rt.parallel.remat != "none":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        (x, aux), caches = jax.lax.scan(body, (x, _zero_aux()), params["blocks"])
        return x, aux, caches

    def decode_backbone(self, params, rt: Runtime, x, lengths, caches,
                        page_table=None):
        """One-token step through all layers, updating caches functionally.

        With ``page_table`` (B, pages_per_row) int32, attention cache
        leaves are a shared page pool (R, n_pages, page_size, KVH, hd)
        (see ``paged_cache_shapes``); SSM caches stay slot-indexed.
        """
        cfg = self.cfg
        period = cfg.pattern_period
        positions = lengths[:, None]
        if page_table is not None and rt.decode_kv_shard(cfg) == "seq":
            raise ValueError(
                "paged decode requires decode_kv_shard != 'seq' "
                "(page tables gather across the sequence axis)")

        def body(x, xs):
            layer_params, layer_caches = xs
            new_caches = {}
            for i in range(period):
                pp = self._maybe_gather(rt, f"pos{i}", layer_params[f"pos{i}"])
                x, cache_i, _ = block_apply(
                    pp, cfg, rt, x, positions, i,
                    cache=layer_caches[f"pos{i}"], lengths=lengths,
                    decode=True, page_table=page_table)
                new_caches[f"pos{i}"] = cache_i
            return x, new_caches

        x, new_caches = jax.lax.scan(body, x, (params["blocks"], caches))
        return x, new_caches

    # ------------------------------------------------------------- train
    def loss(self, params, rt: Runtime, batch):
        """batch: tokens (B,S[,ncb]) int32, targets (same), mask (B,S) f32,
        optional patches (B,Np,d). Returns (loss, metrics)."""
        cfg = self.cfg
        x = rt.shard_activations(self.embed(params, batch))
        B, S = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x, aux, _ = self.backbone(params, rt, x, positions)
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        if cfg.vision_stub and "patches" in batch:
            x = x[:, batch["patches"].shape[1]:]  # loss on text positions only
        logits = self.logits(params, x).astype(jnp.float32)
        targets = batch["targets"]
        mask = batch["mask"].astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
        ce = lse - tgt  # (B,S[,ncb])
        if cfg.n_codebooks > 1:
            ce = jnp.mean(ce, axis=-1)
            lse = jnp.mean(lse, axis=-1)
        mask3 = mask
        denom = jnp.maximum(jnp.sum(mask3), 1.0)
        ce_loss = jnp.sum(ce * mask3) / denom
        loss = (ce_loss + 0.01 * aux["moe_lb_loss"] + 1e-3 * aux["moe_z_loss"])
        metrics = {"ce": ce_loss, **aux,
                   "z": jnp.sum(jnp.square(lse) * mask3) / denom}
        return loss, metrics

    # ------------------------------------------------------------- serve
    def prefill(self, params, rt: Runtime, batch):
        """Full-sequence forward; returns (last_logits, caches, aux)."""
        cfg = self.cfg
        x = self.embed(params, batch)
        B, S = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x, aux, caches = self.backbone(params, rt, x, positions,
                                       collect_cache=True, remat=False)
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = self.logits(params, x[:, -1:])
        return logits[:, 0], caches, aux

    def decode(self, params, rt: Runtime, tokens, lengths, caches,
               page_table=None):
        """tokens: (B,1[,ncb]); lengths: (B,) current cache fill.
        Returns (logits (B,[ncb,]V), new_caches)."""
        x = self.embed(params, {"tokens": tokens})
        x, new_caches = self.decode_backbone(params, rt, x, lengths, caches,
                                             page_table=page_table)
        x = rmsnorm(params["final_norm"], x, self.cfg.norm_eps)
        logits = self.logits(params, x)
        return logits[:, 0], new_caches

    # ------------------------------------------------- cache construction
    def cache_shapes(self, batch_size: int, max_len: int):
        """Abstract cache pytree (ShapeDtypeStructs) for decode cells."""
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        period = cfg.pattern_period
        R = cfg.n_layers // period
        caches = {}
        for i in range(period):
            if cfg.block_kind(i) == "attn":
                kv = jax.ShapeDtypeStruct(
                    (R, batch_size, max_len, cfg.n_kv_heads, cfg.head_dim), dtype)
                caches[f"pos{i}"] = (kv, kv)
            else:
                ch_x = cfg.d_inner
                ch_bc = cfg.ssm_groups * cfg.d_state
                conv = {
                    "x": jax.ShapeDtypeStruct(
                        (R, batch_size, cfg.conv_dim - 1, ch_x), dtype),
                    "B": jax.ShapeDtypeStruct(
                        (R, batch_size, cfg.conv_dim - 1, ch_bc), dtype),
                    "C": jax.ShapeDtypeStruct(
                        (R, batch_size, cfg.conv_dim - 1, ch_bc), dtype),
                }
                state = jax.ShapeDtypeStruct(
                    (R, batch_size, cfg.n_ssm_heads, cfg.ssm_head_dim,
                     cfg.d_state), jnp.float32)
                caches[f"pos{i}"] = (conv, state)
        return caches

    def init_cache(self, batch_size: int, max_len: int):
        shapes = self.cache_shapes(batch_size, max_len)
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)

    def paged_cache_shapes(self, batch_size: int, n_pages: int,
                           page_size: int):
        """Like ``cache_shapes`` but attention KV lives in a shared page
        pool (R, n_pages, page_size, KVH, hd) addressed via a per-row page
        table. SSM state is O(1) per row (no sequence axis), so it stays
        slot-indexed — paging it would buy nothing."""
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        period = cfg.pattern_period
        R = cfg.n_layers // period
        caches = {}
        for i in range(period):
            if cfg.block_kind(i) == "attn":
                kv = jax.ShapeDtypeStruct(
                    (R, n_pages, page_size, cfg.n_kv_heads, cfg.head_dim),
                    dtype)
                caches[f"pos{i}"] = (kv, kv)
            else:
                caches[f"pos{i}"] = self.cache_shapes(
                    batch_size, page_size)[f"pos{i}"]
        return caches

    def init_paged_cache(self, batch_size: int, n_pages: int, page_size: int):
        shapes = self.paged_cache_shapes(batch_size, n_pages, page_size)
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)
