"""Mamba2 (state-space duality) blocks — chunked SSD prefill + recurrent decode.

The chunked dual form is TPU-native: within-chunk attention-like einsums hit
the MXU, the inter-chunk state pass is a short ``lax.scan``. The Pallas
``ssd_scan`` kernel mirrors the same blocking; this jnp path is its oracle and
the dry-run implementation.

Sharding: d_inner (heads) over ``model``; B/C projections are group-shared
(MQA-like, ``ssm_groups``) and replicated.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import rmsnorm, rmsnorm_init


def mamba_init(scope, cfg):
    d, di, ds, nh, ng = (cfg.d_model, cfg.d_inner, cfg.d_state,
                         cfg.n_ssm_heads, cfg.ssm_groups)
    scope.param("w_z", (d, di), ("embed", "ssm_inner"))
    scope.param("w_x", (d, di), ("embed", "ssm_inner"))
    scope.param("w_B", (d, ng * ds), ("embed", "ssm_state"))
    scope.param("w_C", (d, ng * ds), ("embed", "ssm_state"))
    scope.param("w_dt", (d, nh), ("embed", "ssm_inner"))
    scope.param("conv_x", (cfg.conv_dim, di), ("conv", "ssm_inner"))
    scope.param("conv_B", (cfg.conv_dim, ng * ds), ("conv", "ssm_state"))
    scope.param("conv_C", (cfg.conv_dim, ng * ds), ("conv", "ssm_state"))
    scope.param("a_log", (nh,), ("ssm_inner",), init="normal", scale=0.5,
                dtype=jnp.float32)
    scope.param("d_skip", (nh,), ("ssm_inner",), init="ones", dtype=jnp.float32)
    scope.param("dt_bias", (nh,), ("ssm_inner",), init="zeros", dtype=jnp.float32)
    rmsnorm_init(scope, "norm", di)
    scope.param("w_out", (di, d), ("ssm_inner", "embed"))


def causal_conv(x, w, prev=None):
    """Depthwise causal conv. x: (B,S,ch), w: (k,ch). prev: (B,k-1,ch) or None."""
    k = w.shape[0]
    if prev is None:
        prev = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)
    out = jax.lax.conv_general_dilated(
        xp, w[:, None, :].astype(x.dtype),
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NHC", "HIO", "NHC"),
        feature_group_count=x.shape[2])
    return jax.nn.silu(out.astype(jnp.float32)).astype(x.dtype), xp[:, -(k - 1):]


def _expand_groups(t, nh):
    """(B,...,ng,ds) -> (B,...,nh,ds) by repeating groups."""
    ng = t.shape[-2]
    if ng == nh:
        return t
    rep = nh // ng
    return jnp.repeat(t, rep, axis=-2)


def ssd_chunked(xh, dt, A, Bg, Cg, chunk, state0=None):
    """Chunked SSD. xh: (B,S,nh,hp); dt: (B,S,nh) f32; A: (nh,) f32;
    Bg/Cg: (B,S,ng,ds). Returns (y (B,S,nh,hp), final_state (B,nh,hp,ds))."""
    B, S, nh, hp = xh.shape
    ds = Bg.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk
    f32 = jnp.float32
    Bh = _expand_groups(Bg, nh).astype(f32).reshape(B, nc, chunk, nh, ds)
    Ch = _expand_groups(Cg, nh).astype(f32).reshape(B, nc, chunk, nh, ds)
    xc = xh.astype(f32).reshape(B, nc, chunk, nh, hp)
    dtc = dt.reshape(B, nc, chunk, nh)
    if state0 is None:
        state0 = jnp.zeros((B, nh, hp, ds), f32)

    def step(state, inp):
        xb, dtb, Bb, Cb = inp  # (B,Q,nh,hp), (B,Q,nh), (B,Q,nh,ds) x2
        dA = dtb * A  # (B,Q,nh) (<= 0)
        cs = jnp.cumsum(dA, axis=1)
        # intra-chunk (dual / attention-like) term
        L = jnp.exp(cs[:, :, None, :] - cs[:, None, :, :])  # (B,Q,Q,nh)
        idx = jnp.arange(xb.shape[1])
        L = jnp.where((idx[:, None] >= idx[None, :])[None, :, :, None], L, 0.0)
        scores = jnp.einsum("bihs,bjhs->bijh", Cb, Bb) * L
        xdt = xb * dtb[..., None]
        y = jnp.einsum("bijh,bjhp->bihp", scores, xdt)
        # inter-chunk (recurrent) term
        y = y + jnp.einsum("bihs,bhps->bihp", Cb, state) * jnp.exp(cs)[..., None]
        decay_out = jnp.exp(cs[:, -1:, :] - cs)  # (B,Q,nh)
        new_state = state * jnp.exp(cs[:, -1])[:, :, None, None] + jnp.einsum(
            "bjhs,bjhp->bhps", Bb * decay_out[..., None], xdt)
        return new_state, y

    xs = (xc.transpose(1, 0, 2, 3, 4), dtc.transpose(1, 0, 2, 3),
          Bh.transpose(1, 0, 2, 3, 4), Ch.transpose(1, 0, 2, 3, 4))
    final_state, ys = jax.lax.scan(step, state0, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, nh, hp)
    return y.astype(xh.dtype), final_state


def ssd_decode_step(xh, dt, A, Bg, Cg, state):
    """One token. xh: (B,nh,hp); dt: (B,nh); Bg/Cg: (B,ng,ds);
    state: (B,nh,hp,ds) -> (y (B,nh,hp), new_state)."""
    nh = xh.shape[1]
    f32 = jnp.float32
    Bh = _expand_groups(Bg, nh).astype(f32)
    Ch = _expand_groups(Cg, nh).astype(f32)
    dA = jnp.exp(dt * A)  # (B,nh)
    xdt = xh.astype(f32) * dt[..., None]
    new_state = state * dA[..., None, None] + jnp.einsum("bhs,bhp->bhps", Bh, xdt)
    y = jnp.einsum("bhs,bhps->bhp", Ch, new_state)
    return y.astype(xh.dtype), new_state


def mamba_apply(p, cfg, x, *, conv_state=None, ssm_state=None, decode=False):
    """x: (B,S,d) (S==1 token slice when decode) -> (y, (conv_state, ssm_state)).

    conv_state: dict of (B,k-1,ch) buffers; ssm_state: (B,nh,hp,ds).
    """
    B = x.shape[0]
    nh, hp, ds, ng = (cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.d_state,
                      cfg.ssm_groups)
    z = jnp.einsum("bsd,de->bse", x, p["w_z"])
    xs = jnp.einsum("bsd,de->bse", x, p["w_x"])
    Bm = jnp.einsum("bsd,de->bse", x, p["w_B"])
    Cm = jnp.einsum("bsd,de->bse", x, p["w_C"])
    dt = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                    p["w_dt"].astype(jnp.float32))
    cs = conv_state or {}
    xs, cx = causal_conv(xs, p["conv_x"], cs.get("x"))
    Bm, cb = causal_conv(Bm, p["conv_B"], cs.get("B"))
    Cm, cc = causal_conv(Cm, p["conv_C"], cs.get("C"))
    dt = jax.nn.softplus(dt + p["dt_bias"])  # (B,S,nh)
    A = -jnp.exp(p["a_log"])
    S = x.shape[1]
    xh = xs.reshape(B, S, nh, hp)
    Bg = Bm.reshape(B, S, ng, ds)
    Cg = Cm.reshape(B, S, ng, ds)
    if decode:
        y, new_state = ssd_decode_step(xh[:, 0], dt[:, 0], A, Bg[:, 0], Cg[:, 0],
                                       ssm_state)
        y = y[:, None]
    else:
        y, new_state = ssd_chunked(xh, dt, A, Bg, Cg, cfg.ssm_chunk, ssm_state)
    y = y + (xh.astype(jnp.float32) * p["d_skip"][:, None]).astype(y.dtype)
    y = y.reshape(B, S, cfg.d_inner)
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    return out, ({"x": cx, "B": cb, "C": cc}, new_state)
