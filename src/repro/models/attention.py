"""GQA attention: projections + memory-efficient chunked softmax.

Three execution paths:
- ``masked``      double scan over (q-chunk, kv-chunk) with causal masking —
                  computes the full S^2 pair grid (2x causal waste, baseline).
- ``triangular``  scan over the *static lower-triangular list* of chunk pairs
                  — true causal FLOPs in pure JAX (beyond-paper §Perf opt).
- Pallas flash kernel (repro.kernels) on real TPUs; the jnp paths double as
  its oracle and as the dry-run-lowered implementation.

Decode uses grouped-query einsums against the KV cache without materializing
repeated KV heads; the sequence-sharded combine lives in
repro.parallel.collectives.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, rmsnorm, rmsnorm_init

NEG_INF = -1e30


def attn_init(scope, cfg):
    d = cfg.d_model
    scope.param("wq", (d, cfg.q_dim), ("embed", "heads"))
    scope.param("wk", (d, cfg.kv_dim), ("embed", "kv_heads"))
    scope.param("wv", (d, cfg.kv_dim), ("embed", "kv_heads"))
    scope.param("wo", (cfg.q_dim, d), ("heads", "embed"))
    if cfg.qkv_bias:
        scope.param("bq", (cfg.q_dim,), ("heads",), init="zeros")
        scope.param("bk", (cfg.kv_dim,), ("kv_heads",), init="zeros")
        scope.param("bv", (cfg.kv_dim,), ("kv_heads",), init="zeros")
    if cfg.qk_norm:
        rmsnorm_init(scope, "q_norm", cfg.head_dim)
        rmsnorm_init(scope, "k_norm", cfg.head_dim)


def qkv_proj(p, cfg, x, positions):
    """x: (B,S,d) -> q (B,S,H,hd), k/v (B,S,KVH,hd) with rope (+qk-norm)."""
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dq->bsq", x, p["wq"])
    k = jnp.einsum("bsd,dq->bsq", x, p["wk"])
    v = jnp.einsum("bsd,dq->bsq", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def repeat_kv(k, n_heads: int):
    """(B,S,KVH,hd) -> (B,S,H,hd)."""
    B, S, KVH, hd = k.shape
    if KVH == n_heads:
        return k
    rep = n_heads // KVH
    return jnp.broadcast_to(k[:, :, :, None, :], (B, S, KVH, rep, hd)).reshape(
        B, S, n_heads, hd
    )


def _block_attn(qb, kb, vb, mask, scale):
    """One (Bq x Bk) block: returns (o_acc, m, l) in fp32."""
    s = jnp.einsum("bqhd,bkhd->bhqk", qb, kb).astype(jnp.float32) * scale
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1)                      # (B,H,Q)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)                      # (B,H,Q)
    o = jnp.einsum("bhqk,bkhd->bhqd", p.astype(vb.dtype), vb).astype(jnp.float32)
    return o, m, l


def _merge(o1, m1, l1, o2, m2, l2):
    m = jnp.maximum(m1, m2)
    a1 = jnp.exp(m1 - m)
    a2 = jnp.exp(m2 - m)
    o = o1 * a1[..., None] + o2 * a2[..., None]
    l = l1 * a1 + l2 * a2
    return o, m, l


def chunked_attention(q, k, v, *, causal=True, q_chunk=512, kv_chunk=1024,
                      impl="masked"):
    """Memory-efficient attention. q,k,v: (B,S,H,hd) (kv already repeated).

    Returns (B,S,H,hd). Never materializes more than (Bq x Bk) scores.
    """
    B, S, H, hd = q.shape
    Sk = k.shape[1]
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, Sk)
    if (impl == "triangular" and causal and S == Sk and q_chunk == kv_chunk
            and S % q_chunk == 0):
        return _triangular_attention(q, k, v, q_chunk)
    # pad ragged sequences up to chunk multiples; pads are masked below
    S_real, Sk_real = S, Sk
    pad_q = (-S) % q_chunk
    pad_k = (-Sk) % kv_chunk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        S += pad_q
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        Sk += pad_k
    scale = 1.0 / (hd ** 0.5)
    nq, nk = S // q_chunk, Sk // kv_chunk
    qs = q.reshape(B, nq, q_chunk, H, hd)
    ks = k.reshape(B, nk, kv_chunk, H, hd)
    vs = v.reshape(B, nk, kv_chunk, H, hd)

    def q_step(_, qi):
        qb = qs[:, qi]

        def kv_step(carry, kj):
            o, m, l = carry
            kb, vb = ks[:, kj], vs[:, kj]
            kpos = kj * kv_chunk + jnp.arange(kv_chunk)
            kv_valid = kpos < Sk_real
            if causal:
                qpos = qi * q_chunk + jnp.arange(q_chunk)
                mask = (qpos[:, None] >= kpos[None, :]) & kv_valid[None, :]
            else:
                mask = jnp.broadcast_to(kv_valid[None, :], (q_chunk, kv_chunk))
            ob, mb, lb = _block_attn(qb, kb, vb, mask, scale)
            return _merge(o, m, l, ob, mb, lb), None

        o0 = jnp.zeros((B, H, q_chunk, hd), jnp.float32)
        m0 = jnp.full((B, H, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, q_chunk), jnp.float32)
        (o, m, l), _ = jax.lax.scan(kv_step, (o0, m0, l0), jnp.arange(nk))
        out = (o / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        return None, out.transpose(0, 2, 1, 3)  # (B,q_chunk,H,hd)

    _, outs = jax.lax.scan(q_step, None, jnp.arange(nq))  # (nq,B,qc,H,hd)
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)
    return out[:, :S_real] if pad_q else out


def _triangular_attention(q, k, v, chunk):
    """Causal attention scanning only the lower-triangular chunk pairs.

    The (qi, kj) pair list with kj <= qi is static, so the scan trip count is
    nq(nq+1)/2 and no upper-triangle FLOPs are spent (the `masked` impl
    spends 2x). Accumulators for all q rows stay live: (S,H,hd) fp32.
    """
    B, S, H, hd = q.shape
    scale = 1.0 / (hd ** 0.5)
    nq = S // chunk
    pairs = jnp.array([(i, j) for i in range(nq) for j in range(i + 1)],
                      dtype=jnp.int32)  # (npair, 2)
    qs = q.reshape(B, nq, chunk, H, hd)
    ks = k.reshape(B, nq, chunk, H, hd)
    vs = v.reshape(B, nq, chunk, H, hd)

    def step(carry, pair):
        o, m, l = carry  # (B,H,nq,chunk,hd), (B,H,nq,chunk), (B,H,nq,chunk)
        qi, kj = pair[0], pair[1]
        qb = jax.lax.dynamic_index_in_dim(qs, qi, 1, keepdims=False)
        kb = jax.lax.dynamic_index_in_dim(ks, kj, 1, keepdims=False)
        vb = jax.lax.dynamic_index_in_dim(vs, kj, 1, keepdims=False)
        pos = jnp.arange(chunk)
        mask = jnp.where(qi == kj, pos[:, None] >= pos[None, :],
                         jnp.ones((chunk, chunk), bool))
        ob, mb, lb = _block_attn(qb, kb, vb, mask, scale)
        oi = jax.lax.dynamic_index_in_dim(o, qi, 2, keepdims=False)
        mi = jax.lax.dynamic_index_in_dim(m, qi, 2, keepdims=False)
        li = jax.lax.dynamic_index_in_dim(l, qi, 2, keepdims=False)
        on, mn, ln = _merge(oi, mi, li, ob, mb, lb)
        o = jax.lax.dynamic_update_index_in_dim(o, on, qi, 2)
        m = jax.lax.dynamic_update_index_in_dim(m, mn, qi, 2)
        l = jax.lax.dynamic_update_index_in_dim(l, ln, qi, 2)
        return (o, m, l), None

    o0 = jnp.zeros((B, H, nq, chunk, hd), jnp.float32)
    m0 = jnp.full((B, H, nq, chunk), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, nq, chunk), jnp.float32)
    (o, m, l), _ = jax.lax.scan(step, (o0, m0, l0), pairs)
    out = o / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 3, 1, 4).reshape(B, S, H, hd).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, lengths):
    """Single-token grouped-query attention against a cache.

    q: (B,H,hd); k_cache/v_cache: (B,Sk,KVH,hd); lengths: (B,) valid prefix.
    Returns (B,H,hd). No KV repetition is materialized. Rows with
    ``lengths == 0`` are zero-filled (never a softmax over an all-masked
    row) — the same contract as kernels/ref.py and the pallas kernels.
    """
    B, H, hd = q.shape
    KVH = k_cache.shape[2]
    G = H // KVH
    qg = q.reshape(B, KVH, G, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache).astype(jnp.float32)
    s = s / (hd ** 0.5)
    valid = jnp.arange(k_cache.shape[1])[None, :] < lengths[:, None]  # (B,Sk)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache)
    o = jnp.where((lengths > 0)[:, None, None, None], o, 0)
    return o.reshape(B, H, hd)
