"""Transformer / Mamba / MoE block assembly (pre-norm residual)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import moe as moe_lib
from repro.models.attention import (
    attn_init, chunked_attention, decode_attention, qkv_proj, repeat_kv,
)
from repro.models.layers import mlp_apply, mlp_init, rmsnorm, rmsnorm_init
from repro.models.ssm import mamba_apply, mamba_init
from repro.parallel.collectives import seq_sharded_decode_attention
from repro.parallel.sharding import AXIS_MODEL


def block_init(scope, cfg, i: int):
    """Init one block at pattern position i."""
    d = cfg.d_model
    rmsnorm_init(scope, "norm1", d)
    if cfg.block_kind(i) == "attn":
        attn_init(scope.sub("attn"), cfg)
    else:
        mamba_init(scope.sub("mamba"), cfg)
    has_ffn = cfg.d_ff > 0 or cfg.is_moe_layer(i)
    if has_ffn:
        rmsnorm_init(scope, "norm2", d)
    if cfg.is_moe_layer(i):
        moe_lib.moe_init(scope.sub("moe"), cfg)
        if cfg.dense_residual and cfg.d_ff > 0:
            mlp_init(scope.sub("dense_mlp"), cfg, cfg.d_ff)
        if cfg.n_shared_experts > 0:
            mlp_init(scope.sub("shared_mlp"), cfg,
                     cfg.n_shared_experts * cfg.d_ff_expert)
    elif cfg.d_ff > 0:
        mlp_init(scope.sub("mlp"), cfg, cfg.d_ff)


def attn_block(p, cfg, rt, x, positions, cache=None, lengths=None,
               decode=False, page_table=None):
    """Returns (out (B,S,d), new_cache (k,v)).

    With ``page_table`` (B, pages_per_row) the cache leaves are a shared
    page pool (n_pages, page_size, KVH, hd): the new token's K/V scatter
    through the table and attention runs over the gathered per-row view.
    Gathered masked positions contribute exactly 0 probability, so the
    result is bit-identical to the contiguous path over the same tokens.
    """
    B, S, _ = x.shape
    q, k, v = qkv_proj(p, cfg, x, positions)
    if decode:
        assert S == 1
        qd = q[:, 0]  # (B,H,hd)
        k_cache, v_cache = cache
        if page_table is not None:
            ps = k_cache.shape[1]
            bidx = jnp.arange(B)
            page = page_table[bidx, lengths // ps]
            off = lengths % ps
            k_cache = k_cache.at[page, off].set(k[:, 0])
            v_cache = v_cache.at[page, off].set(v[:, 0])
            n_pt = page_table.shape[1]
            k_view = k_cache[page_table].reshape(
                B, n_pt * ps, *k_cache.shape[2:])
            v_view = v_cache[page_table].reshape(
                B, n_pt * ps, *v_cache.shape[2:])
            o = decode_attention(qd, k_view, v_view, lengths + 1)
        elif rt.decode_kv_shard(cfg) == "seq":
            o, k_cache, v_cache = seq_sharded_decode_attention(
                qd, k_cache, v_cache, lengths, k[:, 0], v[:, 0],
                rt.mesh, AXIS_MODEL)
        else:
            bidx = jnp.arange(B)
            k_cache = k_cache.at[bidx, lengths].set(k[:, 0])
            v_cache = v_cache.at[bidx, lengths].set(v[:, 0])
            o = decode_attention(qd, k_cache, v_cache, lengths + 1)
        o = o[:, None]  # (B,1,H,hd)
        new_cache = (k_cache, v_cache)
    else:
        if rt.parallel.attn_seq_parallel and rt.mesh is not None:
            # ring attention: sequence-parallel over the model axis; the
            # unrepeated GQA kv shards rotate via collective_permute
            from repro.parallel.collectives import ring_attention
            o = ring_attention(q, k, v, rt.mesh, AXIS_MODEL, causal=True)
            out = jnp.einsum("bsq,qd->bsd", o.reshape(B, S, cfg.q_dim),
                             p["wo"])
            return out, (k, v)
        kf = repeat_kv(k, cfg.n_heads)
        vf = repeat_kv(v, cfg.n_heads)
        # pad heads to the model-axis multiple so the chunked scans stay
        # collective-free (padded heads are dead weight, sliced off below)
        H = cfg.n_heads
        Hp = rt.padded_heads(H) if hasattr(rt, "padded_heads") else H
        if Hp != H:
            pad = ((0, 0), (0, 0), (0, Hp - H), (0, 0))
            q, kf, vf = (jnp.pad(t, pad) for t in (q, kf, vf))
        q, kf, vf = rt.shard_heads(q), rt.shard_heads(kf), rt.shard_heads(vf)
        o = chunked_attention(
            q, kf, vf, causal=True,
            q_chunk=rt.parallel.attn_q_chunk,
            kv_chunk=rt.parallel.attn_kv_chunk,
            impl=rt.parallel.attn_impl)
        o = rt.shard_heads(o)[:, :, :H] if Hp != H else o
        new_cache = (k, v)
    out = jnp.einsum("bsq,qd->bsd", o.reshape(B, S, cfg.q_dim), p["wo"])
    return out, new_cache


def block_apply(p, cfg, rt, x, positions, i, *, cache=None, lengths=None,
                decode=False, page_table=None):
    """One block. cache: kind-dependent pytree (or None for training).

    Returns (x, new_cache, aux_losses dict).
    """
    aux = {}
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    if cfg.block_kind(i) == "attn":
        out, new_cache = attn_block(p["attn"], cfg, rt, h, positions,
                                    cache=cache, lengths=lengths,
                                    decode=decode, page_table=page_table)
    else:
        conv_state, ssm_state = cache if cache is not None else (None, None)
        out, new_cache = mamba_apply(p["mamba"], cfg, h, conv_state=conv_state,
                                     ssm_state=ssm_state, decode=decode)
    x = x + out
    if cfg.is_moe_layer(i):
        h = rmsnorm(p["norm2"], x, cfg.norm_eps)
        ids, wts, aux = moe_lib.route(p["moe"], cfg, h)
        y = moe_lib.moe_apply(p["moe"], cfg, h, ids, wts, mesh=rt.moe_mesh())
        if cfg.dense_residual and cfg.d_ff > 0:
            y = y + mlp_apply(p["dense_mlp"], h, cfg.mlp_act)
        if cfg.n_shared_experts > 0:
            y = y + mlp_apply(p["shared_mlp"], h, cfg.mlp_act)
        x = x + y
    elif cfg.d_ff > 0:
        h = rmsnorm(p["norm2"], x, cfg.norm_eps)
        x = x + mlp_apply(p["mlp"], h, cfg.mlp_act)
    return x, new_cache, aux
