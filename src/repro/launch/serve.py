"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Brings up the continuous-batching engine (the MTC TRE payload) on the
reduced config and serves a synthetic request stream, reporting throughput
and slot utilization.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_smoke_config
from repro.configs.base import ParallelConfig
from repro.models.lm import LM
from repro.serve.engine import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCHS))
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    lm = LM(cfg)
    rt = lm.runtime(ParallelConfig(attn_q_chunk=16, attn_kv_chunk=16))
    params = lm.init(jax.random.key(0))[0]
    engine = Engine(lm, params, rt, max_batch=args.max_batch,
                    max_len=args.max_len)
    rng = np.random.default_rng(0)

    def make_req(i):
        shape = ((args.prompt_len,) if cfg.n_codebooks <= 1
                 else (args.prompt_len, cfg.n_codebooks))
        req = Request(rid=i, tokens=rng.integers(
            1, cfg.vocab_size, shape).astype(np.int32),
            max_new_tokens=args.new_tokens)
        if cfg.vision_stub:
            req.patches = rng.standard_normal(
                (cfg.n_patches, cfg.d_model)).astype(np.float32)
        return req

    reqs = [make_req(i) for i in range(args.requests)]
    t0 = time.time()
    done = engine.run(reqs)
    dt = time.time() - t0
    toks = sum(len(r.out_tokens) for r in done)
    print(f"arch={args.arch}: served {len(done)} requests, {toks} tokens in "
          f"{dt:.1f}s ({toks/dt:.1f} tok/s, {engine.steps} decode steps)")


if __name__ == "__main__":
    main()
