"""Emulation launcher: ``python -m repro.launch.emulate [--system ...]``.

Runs the paper's consolidated-cloud experiment (same engine as
examples/emulate_cloud.py, exposed as a launcher for scripting).
"""
from __future__ import annotations

import argparse
import json

from repro.core.registry import available_systems
from repro.sim import run_system
from repro.sim.traces import standard_workloads


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--system", nargs="*",
                    choices=available_systems(),
                    default=["dcs", "ssp", "drp", "dawningcloud"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    wls = standard_workloads(args.seed)
    out = {}
    for system in args.system:
        res = run_system(system, wls, mtc_fixed_nodes=166)
        out[system] = {
            "total_node_hours": res.total_node_hours,
            "peak_nodes_per_hour": res.peak_nodes_per_hour,
            "adjust_count": res.adjust_count,
            "per_workload": {k: v.as_dict()
                             for k, v in res.per_workload.items()},
        }
    if args.json:
        print(json.dumps(out, indent=1))
    else:
        for system, r in out.items():
            print(f"{system:14s} total={r['total_node_hours']:.0f} "
                  f"peak={r['peak_nodes_per_hour']} "
                  f"adjusts={r['adjust_count']}")


if __name__ == "__main__":
    main()
