"""Cell construction: (architecture x input shape x mesh) -> lowerable step.

A *cell* bundles everything the dry-run needs: the jitted step function with
explicit in/out shardings and the abstract arguments (ShapeDtypeStructs) to
lower against. No device memory is allocated for any full-size config.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.configs.base import ModelConfig, ParallelConfig, RunConfig, ShapeConfig
from repro.data.synthetic import input_specs
from repro.models.lm import LM
from repro.models.module import is_axes_leaf
from repro.parallel.sharding import (
    AXIS_DATA, AXIS_MODEL, AXIS_POD, batch_axes, resolve_spec,
)
from repro.train.train_step import build_train_step, make_optimizer, state_specs

# param bytes above which storage goes FSDP (gather-per-layer)
FSDP_THRESHOLD_BYTES = 100e9

# archs whose full-attention makes long_500k meaningless (skip per spec)
LONG_OK_FAMILIES = ("ssm", "hybrid")


def default_parallel(cfg: ModelConfig, shape: ShapeConfig,
                     mesh: Mesh | None = None) -> ParallelConfig:
    param_bytes = cfg.param_count() * 2
    strategy = "fsdp_tp" if param_bytes > FSDP_THRESHOLD_BYTES else "tp"
    micro = 1
    if shape.kind == "train" and mesh is not None:
        rows = shape.global_batch
        for a in batch_axes(mesh):
            rows //= mesh.shape[a]
        # TP models: one row of live activations per microbatch minimizes
        # the layer-scan carry (wire is microbatch-independent for them).
        # FSDP *MoE* models re-gather expert weights EVERY microbatch —
        # §Perf measured wire scaling ~linearly with the count (kimi-k2:
        # 17.2 TB @16 -> 6.2 TB @4), so cap them at 4 and pay the
        # activation memory. Dense-FSDP (internvl) keeps 16: its wire is
        # activation-AR-dominated (-21% only) while temp grew 3.3x at 4.
        cap = 4 if (strategy == "fsdp_tp" and cfg.moe) else 16
        micro = max(1, min(rows, cap))
        while rows % micro:
            micro -= 1
    # prefill: sequence-parallel ring attention (unrepeated-GQA kv shards
    # rotate via collective_permute) — §Perf It.6 measured -13..19% wire on
    # the collective-dominated prefill cells. It computes the full masked
    # pair grid, so attn_impl stays "masked" for the flops model; the
    # single-device fallback uses the chunked path.
    ring = shape.kind == "prefill"
    return ParallelConfig(
        strategy=strategy,
        zero1=True,
        remat="block" if shape.kind == "train" else "none",
        microbatches=micro,
        attn_q_chunk=512,
        attn_kv_chunk=1024,
        attn_impl="masked",
        attn_seq_parallel=ring,
    )


def cell_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    if shape.name == "long_500k" and cfg.family not in LONG_OK_FAMILIES:
        return False, "pure full-attention arch: 524k cell skipped per shape rules"
    return True, ""


def _guard_batch_axes(mesh: Mesh, B: int):
    axes = batch_axes(mesh)
    if not axes:
        return None
    total = 1
    for a in axes:
        total *= mesh.shape[a]
    if B % total == 0:
        return axes
    if B % mesh.shape[AXIS_DATA] == 0 and AXIS_DATA in mesh.axis_names:
        return (AXIS_DATA,)
    return None


def batch_shardings(mesh: Mesh, tree, B: int):
    axes = _guard_batch_axes(mesh, B)
    def one(x):
        spec = P(axes, *([None] * (len(x.shape) - 1)))
        return NamedSharding(mesh, spec)
    return jax.tree.map(one, tree)


def params_shardings(lm: LM, axes_tree, mesh: Mesh, strategy: str):
    params_abs, _ = lm.init(None, abstract=True)
    leaves_a = jax.tree.leaves(axes_tree, is_leaf=is_axes_leaf)
    leaves_p, treedef = jax.tree.flatten(params_abs)
    shardings = [
        NamedSharding(mesh, resolve_spec(a, p.shape, mesh, strategy))
        for a, p in zip(leaves_a, leaves_p)
    ]
    return jax.tree.unflatten(treedef, shardings)


def cache_shardings(lm: LM, mesh: Mesh, rt, B: int):
    """Shardings for the decode cache: batch over data axes; KV heads over
    ``model`` when divisible, else *sequence* over ``model`` (flash-decode)."""
    cfg = lm.cfg
    baxes = _guard_batch_axes(mesh, B)
    mode = rt.decode_kv_shard(cfg)
    shapes = lm.cache_shapes(B, 1)  # structure only

    def attn_spec(x):
        # (R, B, S, KVH, hd)
        if mode == "seq":
            return P(None, baxes, AXIS_MODEL, None, None)
        return P(None, baxes, None, AXIS_MODEL, None)

    def build(path_kind, x):
        if path_kind == "kv":
            return NamedSharding(mesh, attn_spec(x))
        if path_kind == "conv":  # (R,B,k-1,ch) ch = d_inner or ng*ds
            ax = AXIS_MODEL if x.shape[-1] % mesh.shape[AXIS_MODEL] == 0 \
                and x.shape[-1] >= mesh.shape[AXIS_MODEL] else None
            return NamedSharding(mesh, P(None, baxes, None, ax))
        # state: (R,B,nh,hp,ds)
        ax = AXIS_MODEL if x.shape[2] % mesh.shape[AXIS_MODEL] == 0 else None
        return NamedSharding(mesh, P(None, baxes, ax, None, None))

    out = {}
    for pos, c in shapes.items():
        if cfg.block_kind(int(pos[3:])) == "attn":
            out[pos] = (build("kv", c[0]), build("kv", c[1]))
        else:
            conv, state = c
            out[pos] = ({k: build("conv", v) for k, v in conv.items()},
                        build("state", state))
    return out


@dataclass
class Cell:
    arch: str
    shape: ShapeConfig
    cfg: ModelConfig
    parallel: ParallelConfig
    jitted: Any          # jit'd fn with shardings
    args: tuple          # abstract args to .lower(*args)
    scan_trips: dict     # name -> trip count (roofline correction)
    kind: str


def build_cell(arch: str, shape_name: str, mesh: Mesh,
               parallel: ParallelConfig | None = None) -> Cell:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    parallel = parallel or default_parallel(cfg, shape, mesh)
    lm = LM(cfg)
    rcfg = RunConfig(model=cfg, shape=shape, parallel=parallel)
    specs = input_specs(cfg, shape)
    _, axes_tree = lm.init(None, abstract=True)

    R = cfg.n_layers // cfg.pattern_period
    S = shape.seq_len
    trips = {"layers": R}

    if shape.kind == "train":
        step_fn, rt, opt = build_train_step(lm, rcfg, mesh)
        sspecs = state_specs(lm, axes_tree, mesh, parallel)
        state_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), sspecs,
                                is_leaf=lambda x: isinstance(x, P))
        state_abs = opt.init_abstract(lm.init(None, abstract=True)[0])
        batch_sh = batch_shardings(mesh, specs, shape.global_batch)
        jitted = jax.jit(step_fn, in_shardings=(state_sh, batch_sh),
                         out_shardings=(state_sh, None),
                         donate_argnums=(0,))
        args = (state_abs, specs)
        trips.update(_attn_trips(cfg, parallel, S, mesh), micro=parallel.microbatches)
    elif shape.kind == "prefill":
        rt = lm.runtime(parallel, mesh)
        p_sh = params_shardings(lm, axes_tree, mesh, parallel.strategy)
        params_abs, _ = lm.init(None, abstract=True)
        batch_sh = batch_shardings(mesh, specs, shape.global_batch)
        c_sh = cache_shardings(lm, mesh, rt, shape.global_batch)

        def prefill_step(params, batch):
            logits, caches, _ = lm.prefill(params, rt, batch)
            return jnp.argmax(logits, axis=-1), caches

        jitted = jax.jit(prefill_step, in_shardings=(p_sh, batch_sh),
                         out_shardings=(None, c_sh))
        args = (params_abs, specs)
        trips.update(_attn_trips(cfg, parallel, S, mesh))
    else:  # decode
        rt = lm.runtime(parallel, mesh)
        p_sh = params_shardings(lm, axes_tree, mesh, parallel.strategy)
        params_abs, _ = lm.init(None, abstract=True)
        B = shape.global_batch
        cache_abs = lm.cache_shapes(B, S)
        c_sh = cache_shardings(lm, mesh, rt, B)
        batch_sh = batch_shardings(mesh, specs, B)

        def serve_step(params, caches, batch):
            logits, new_caches = lm.decode(params, rt, batch["tokens"],
                                           batch["lengths"], caches)
            return jnp.argmax(logits, axis=-1), new_caches

        jitted = jax.jit(serve_step, in_shardings=(p_sh, c_sh, batch_sh),
                         out_shardings=(None, c_sh), donate_argnums=(1,))
        args = (params_abs, cache_abs, specs)

    return Cell(arch=arch, shape=shape, cfg=cfg, parallel=parallel,
                jitted=jitted, args=args, scan_trips=trips, kind=shape.kind)


def _attn_trips(cfg: ModelConfig, parallel: ParallelConfig, S: int,
                mesh: Mesh | None = None) -> dict:
    out = {}
    has_attn = any(cfg.block_kind(i) == "attn" for i in range(cfg.pattern_period))
    has_ssm = any(cfg.block_kind(i) == "ssm" for i in range(cfg.pattern_period))
    if has_attn:
        if parallel.attn_seq_parallel and mesh is not None:
            out["ring_steps"] = mesh.shape.get(AXIS_MODEL, 1)
        elif parallel.attn_impl == "triangular":
            nq = S // min(parallel.attn_q_chunk, S)
            out["attn_pairs"] = nq * (nq + 1) // 2
        else:
            out["attn_q"] = S // min(parallel.attn_q_chunk, S)
            out["attn_kv"] = S // min(parallel.attn_kv_chunk, S)
    if has_ssm:
        out["ssd_chunks"] = max(S // cfg.ssm_chunk, 1)
    return out
