"""Post-partitioning HLO analysis: collective bytes, wire cost, loop nesting.

The compiled module is the *per-device* SPMD program, so all shapes are
local-shard shapes. We extract every collective op, size it from its result
type, reconstruct its replica groups (explicit-list or iota-with-transpose
format) to classify group size and pod-boundary crossing, and scale by the
trip counts of enclosing ``while`` loops (scan bodies are emitted once but
executed trip-count times — XLA's cost_analysis has the same once-only
convention, which benchmarks/roofline.py corrects with the cell's known
static trip counts).

Wire-byte model (ring algorithms, n = group size):
  all-gather        (n-1)/n * result_bytes      (result = gathered)
  reduce-scatter    (n-1)   * result_bytes      (operand = n * result)
  all-reduce        2 (n-1)/n * result_bytes
  all-to-all        (n-1)/n * result_bytes
  collective-permute  result_bytes
"""
from __future__ import annotations

import re
from dataclasses import dataclass

import numpy as np

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(typestr: str) -> int:
    """'bf16[8,512]{1,0}' -> bytes; tuples '(f32[..], s32[..])' -> sum."""
    total = 0
    for m in _SHAPE_RE.finditer(typestr):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveOp:
    kind: str
    result_bytes: int
    group_size: int
    crosses_pod: bool
    computation: str
    trips: int = 1
    dtype: str = ""

    @property
    def tpu_corrected_bytes(self) -> float:
        """XLA:CPU has no native bf16 dot, so dot partial sums materialize
        as f32 and their all-reduces double in size; on TPU the same ARs
        run in bf16. Halve f32 reduction collectives for the TPU estimate."""
        w = self.wire_bytes
        if self.dtype == "f32" and self.kind in ("all-reduce",
                                                 "reduce-scatter"):
            return w / 2
        return w

    @property
    def wire_bytes(self) -> float:
        n = max(self.group_size, 1)
        f = (n - 1) / n
        if self.kind == "all-gather":
            w = f * self.result_bytes
        elif self.kind == "reduce-scatter":
            w = (n - 1) * self.result_bytes
        elif self.kind == "all-reduce":
            w = 2 * f * self.result_bytes
        elif self.kind == "all-to-all":
            w = f * self.result_bytes
        else:  # collective-permute
            w = self.result_bytes
        return w * self.trips


def _parse_groups(attr: str, n_devices: int, pod_size: int):
    """Returns (group_size, crosses_pod) from a replica_groups attribute.

    Handles the explicit form ``{{0,1},{2,3},...}`` and the iota form
    ``[G,S]<=[d0,d1,...]T(p0,p1,...)`` (reshape-transpose-flatten)."""
    m = re.search(r"replica_groups=\{\{([\d,{} ]*)\}\}", attr)
    if m:
        first = m.group(1).split("}")[0]
        ids = [int(x) for x in first.split(",") if x.strip().isdigit()]
        size = max(len(ids), 1)
        crosses = (len({i // pod_size for i in ids}) > 1) if pod_size else False
        return size, crosses
    m = re.search(
        r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?",
        attr)
    if m:
        ngroups, size = int(m.group(1)), int(m.group(2))
        if not pod_size:
            return size, False
        bounds = [int(x) for x in m.group(3).split(",")]
        ids = np.arange(int(np.prod(bounds))).reshape(bounds)
        if m.group(4):
            perm = [int(x) for x in m.group(4).split(",")]
            ids = ids.transpose(perm)
        groups = ids.reshape(ngroups, size)
        crosses = bool(np.any(groups // pod_size !=
                              (groups[:, :1] // pod_size)))
        return size, crosses
    # collective-permute: source_target_pairs instead of replica_groups
    m = re.search(r"source_target_pairs=\{(\{[\d,{} ]*\})\}", attr)
    if m:
        pairs = re.findall(r"\{(\d+),(\d+)\}", m.group(1))
        crosses = (any(int(a) // pod_size != int(b) // pod_size
                       for a, b in pairs) if pod_size else False)
        return 2, crosses
    return n_devices, bool(pod_size)


def parse_collectives(hlo_text: str, n_devices: int, pod_size: int = 0):
    """Returns (list[CollectiveOp], while_callers body->parent pairs)."""
    ops: list[CollectiveOp] = []
    current_comp = "main"
    while_callers: list[tuple[str, str]] = []
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # computation header: `%name (params...) -> type {` or `ENTRY ...`
        if stripped.endswith("{") and "= " not in stripped and (
                stripped.startswith("%") or stripped.startswith("ENTRY")):
            name = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)", stripped)
            if name:
                current_comp = name.group(1)
            continue
        if "= " not in line:
            continue
        mw = re.search(r"body=%?([\w\.\-]+)", line)
        if mw and " while(" in line:
            while_callers.append((mw.group(1), current_comp))
        for kind in _COLLECTIVES:
            if f" {kind}(" not in line and f" {kind}-start(" not in line:
                continue
            _, _, rhs = line.partition("= ")
            # result type(s) precede the op name on the RHS
            type_str = rhs.split(f" {kind}")[0]
            result_bytes = _shape_bytes(type_str)
            mdt = _SHAPE_RE.search(type_str)
            dtype = mdt.group(1) if mdt else ""
            if kind == "all-to-all" and type_str.lstrip().startswith("("):
                # tuple a2a: payload counted once, not per tuple element
                pass
            size, crosses = _parse_groups(line, n_devices, pod_size)
            ops.append(CollectiveOp(kind, result_bytes, size, crosses,
                                    current_comp, dtype=dtype))
            break
    return ops, while_callers


def scale_by_loops(ops, while_callers, trips_by_depth):
    """Multiply each op's trips by the product of enclosing while trips.

    ``trips_by_depth``: outermost-first trip counts (e.g. [micro, layers,
    chunks]). A body nested d levels deep executes prod(trips[:d]) times.
    When the emitted module has fewer while levels than the logical
    schedule (XLA unrolled an inner chunk loop), the surviving levels are
    the outermost ones — collectives live at the layer/microbatch level,
    the unrolled inner loops are local math.
    """
    parent = dict(while_callers)

    def depth_of(comp: str) -> int:
        d = 0
        c = comp
        seen = set()
        while c in parent and c not in seen:
            seen.add(c)
            d += 1
            c = parent[c]
        return d

    n_levels = max((depth_of(op.computation) for op in ops), default=0)
    trips = trips_by_depth[:n_levels]
    for op in ops:
        d = depth_of(op.computation)
        t = 1
        for i in range(min(d, len(trips))):
            t *= trips[i]
        op.trips = t
    return ops


def collective_summary(ops) -> dict:
    out = {
        "n_ops": len(ops),
        "wire_bytes_intra_pod": 0.0,
        "wire_bytes_cross_pod": 0.0,
        "wire_bytes_intra_pod_tpu": 0.0,
        "wire_bytes_cross_pod_tpu": 0.0,
        "by_kind": {},
    }
    for op in ops:
        out["by_kind"].setdefault(op.kind, 0.0)
        out["by_kind"][op.kind] += op.wire_bytes
        if op.crosses_pod:
            out["wire_bytes_cross_pod"] += op.wire_bytes
            out["wire_bytes_cross_pod_tpu"] += op.tpu_corrected_bytes
        else:
            out["wire_bytes_intra_pod"] += op.wire_bytes
            out["wire_bytes_intra_pod_tpu"] += op.tpu_corrected_bytes
    return out
