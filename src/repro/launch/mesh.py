"""Production mesh construction.

Importing this module never touches jax device state; meshes are built only
inside the factory functions. The production target is TPU v5e:
one pod = 16x16 = 256 chips, multi-pod = 2 pods = 512 chips.
"""
from __future__ import annotations

import jax

from repro.parallel.sharding import AXIS_DATA, AXIS_MODEL, AXIS_POD


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = (AXIS_POD, AXIS_DATA, AXIS_MODEL) if multi_pod else (AXIS_DATA,
                                                                AXIS_MODEL)
    return jax.make_mesh(shape, axes)


def make_mesh(data: int, model: int, pod: int = 1):
    """Arbitrary mesh for tests / elastic resizing."""
    if pod > 1:
        return jax.make_mesh((pod, data, model), (AXIS_POD, AXIS_DATA,
                                                  AXIS_MODEL))
    return jax.make_mesh((data, model), (AXIS_DATA, AXIS_MODEL))
