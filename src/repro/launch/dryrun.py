import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST run before any other import — jax locks the
# device count at first init, and the production meshes need 512 placeholder
# host devices (2 pods x 16 x 16). The module docstring therefore lives here:
_DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For every cell this driver records a JSON artifact with:
  - memory_analysis (argument/output/temp/peak bytes per device),
  - cost_analysis  (HLO flops / bytes accessed, once-per-while-body),
  - the parsed collective ops (kind, bytes, group size, pod-crossing) and
    their wire-byte totals after trip-count scaling,
  - the static trip counts used for scaling (layer scan, microbatches,
    attention chunk loops, SSD chunks),
so benchmarks/roofline.py can derive the three roofline terms offline.

Usage:
  python -m repro.launch.dryrun                     # all cells, both meshes
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --list
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCHS, SHAPES, get_config
from repro.launch.cells import build_cell, cell_applicable
from repro.launch.hlo_analysis import (
    collective_summary, parse_collectives, scale_by_loops,
)
from repro.launch.mesh import make_production_mesh

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")


def mesh_for(name: str):
    return make_production_mesh(multi_pod=(name == "multipod"))


def run_cell(arch: str, shape_name: str, mesh_name: str,
             parallel=None) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": why}
    mesh = mesh_for(mesh_name)
    n_dev = mesh.size
    pod_size = 256 if mesh_name == "multipod" else 0
    t0 = time.time()
    cell = build_cell(arch, shape_name, mesh, parallel)
    with mesh:
        lowered = cell.jitted.lower(*cell.args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    hlo = compiled.as_text()
    ops, while_callers = parse_collectives(hlo, n_dev, pod_size)
    trips = cell.scan_trips
    # while nesting, outermost first: microbatch loop (train), layer scan,
    # then intra-layer chunk loops (q-chunk scan wrapping kv-chunk scan for
    # attention; single chunk loop for SSD / triangular attention)
    depth_trips = []
    if cell.kind == "train" and trips.get("micro", 1) > 1:
        depth_trips.append(trips["micro"])
    depth_trips.append(trips.get("layers", 1))
    if "ring_steps" in trips:
        depth_trips.append(max(trips["ring_steps"], trips.get("ssd_chunks", 1)))
    elif "attn_pairs" in trips:
        depth_trips.append(trips["attn_pairs"])
    elif "attn_q" in trips:
        depth_trips.append(max(trips["attn_q"], trips.get("ssd_chunks", 1)))
        depth_trips.append(trips.get("attn_kv", 1))
    elif "ssd_chunks" in trips:
        depth_trips.append(trips["ssd_chunks"])
    scale_by_loops(ops, while_callers, depth_trips)
    summary = collective_summary(ops)
    art = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "status": "ok",
        "n_devices": n_dev,
        "parallel": vars(cell.parallel) if hasattr(cell.parallel, "__dict__")
                    else cell.parallel.__dict__,
        "trips": trips,
        "depth_trips": depth_trips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "generated_code_bytes": ma.generated_code_size_in_bytes,
        },
        "cost": {
            "flops": ca.get("flops", 0.0),
            "bytes_accessed": ca.get("bytes accessed", 0.0),
            "transcendentals": ca.get("transcendentals", 0.0),
        },
        "collectives": summary,
        "param_count": cfg.param_count(),
        "param_count_active": cfg.param_count(active=True),
    }
    return art


def save_artifact(art: dict) -> str:
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    name = f"{art['arch']}__{art['shape']}__{art['mesh']}.json"
    path = os.path.join(ARTIFACT_DIR, name)
    with open(path, "w") as f:
        json.dump(art, f, indent=1)
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCHS), nargs="*")
    ap.add_argument("--shape", default=None, choices=list(SHAPES), nargs="*")
    ap.add_argument("--mesh", default=None, choices=["pod", "multipod"],
                    nargs="*")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()
    archs = args.arch or list(ARCHS)
    shapes = args.shape or list(SHAPES)
    meshes = args.mesh or ["pod", "multipod"]
    cells = [(a, s, m) for a in archs for s in shapes for m in meshes]
    if args.list:
        for c in cells:
            print(*c)
        return
    n_ok = n_skip = n_fail = 0
    for arch, shape, mesh_name in cells:
        tag = f"{arch:22s} {shape:12s} {mesh_name:9s}"
        try:
            art = run_cell(arch, shape, mesh_name)
        except Exception as e:  # a failure here is a sharding bug
            n_fail += 1
            art = {"arch": arch, "shape": shape, "mesh": mesh_name,
                   "status": "failed", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()}
            save_artifact(art)
            print(f"{tag} FAILED  {type(e).__name__}: {e}", flush=True)
            continue
        save_artifact(art)
        if art["status"] == "skipped":
            n_skip += 1
            print(f"{tag} skipped ({art['reason'][:50]})", flush=True)
        else:
            n_ok += 1
            m = art["memory"]
            print(f"{tag} ok  compile={art['compile_s']:6.1f}s "
                  f"temp={m['temp_bytes']/2**30:7.2f}GiB "
                  f"args={m['argument_bytes']/2**30:7.2f}GiB "
                  f"flops={art['cost']['flops']:.2e} "
                  f"wire={art['collectives']['wire_bytes_intra_pod']/2**30:.2f}GiB",
                  flush=True)
    print(f"\n{n_ok} ok, {n_skip} skipped, {n_fail} failed "
          f"of {len(cells)} cells")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
