"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs the fault-tolerant elastic loop on the selected architecture. With
``--smoke`` (default) the reduced config runs on local devices; without it
the full assigned config is used (expects a real TPU pod — on CPU use the
dry-run instead).
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import ARCHS, SHAPES, get_config, get_smoke_config
from repro.configs.base import ParallelConfig, RunConfig, ShapeConfig
from repro.launch.mesh import make_mesh
from repro.train.loop import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCHS))
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--ckpt-dir", default="/tmp/repro-train")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--data", type=int, default=0,
                    help="data-axis size (0 = all local devices)")
    ap.add_argument("--model-axis", type=int, default=1)
    args = ap.parse_args()

    if args.smoke:
        cfg = get_smoke_config(args.arch)
        shape = ShapeConfig("smoke", "train", 64, 8)
        parallel = ParallelConfig(attn_q_chunk=32, attn_kv_chunk=32)
    else:
        cfg = get_config(args.arch)
        shape = SHAPES[args.shape]
        parallel = None  # default_parallel inside the step builder
    n_dev = len(jax.devices())
    data = args.data or max(n_dev // args.model_axis, 1)
    mesh = (make_mesh(data, args.model_axis)
            if data * args.model_axis > 1 else None)
    rcfg = RunConfig(model=cfg, shape=shape,
                     parallel=parallel or ParallelConfig(),
                     total_steps=args.steps)
    print(f"arch={args.arch} params={cfg.param_count()/1e6:.1f}M "
          f"mesh={'1dev' if mesh is None else dict(mesh.shape)}")
    report = train_loop(rcfg, ckpt_dir=args.ckpt_dir, num_steps=args.steps,
                        ckpt_every=args.ckpt_every, mesh=mesh)
    print(f"steps={report.steps_run} restarts={report.restarts} "
          f"loss {report.losses[0]:.3f} -> {report.final_loss:.3f}")


if __name__ == "__main__":
    main()
