from repro.data.synthetic import synthetic_batches, input_specs  # noqa: F401
