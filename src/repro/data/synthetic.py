"""Deterministic synthetic token pipeline + abstract input specs.

``input_specs(model, shape)`` is the single source of truth for what a step
consumes — the dry-run lowers against these ShapeDtypeStructs and the
synthetic pipeline materializes matching concrete batches for smoke tests
and end-to-end examples (with the MusicGen delay pattern applied to
codebook streams, and stub patch embeddings for the VLM).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig


def _token_shape(cfg: ModelConfig, B: int, S: int) -> tuple:
    if cfg.n_codebooks > 1:
        return (B, S, cfg.n_codebooks)
    return (B, S)


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    S_txt = S - cfg.n_patches if cfg.vision_stub else S
    i32 = jnp.int32
    specs = {
        "tokens": jax.ShapeDtypeStruct(_token_shape(cfg, B, S_txt), i32),
        "targets": jax.ShapeDtypeStruct(_token_shape(cfg, B, S_txt), i32),
        "mask": jax.ShapeDtypeStruct((B, S_txt), jnp.float32),
    }
    if cfg.vision_stub:
        specs["patches"] = jax.ShapeDtypeStruct(
            (B, cfg.n_patches, cfg.d_model), jnp.dtype(cfg.dtype))
    return specs


def prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    S_txt = S - cfg.n_patches if cfg.vision_stub else S
    specs = {"tokens": jax.ShapeDtypeStruct(_token_shape(cfg, B, S_txt),
                                            jnp.int32)}
    if cfg.vision_stub:
        specs["patches"] = jax.ShapeDtypeStruct(
            (B, cfg.n_patches, cfg.d_model), jnp.dtype(cfg.dtype))
    return specs


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """One new token against a cache of capacity shape.seq_len."""
    B = shape.global_batch
    return {
        "tokens": jax.ShapeDtypeStruct(_token_shape(cfg, B, 1), jnp.int32),
        "lengths": jax.ShapeDtypeStruct((B,), jnp.int32),
    }


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    if shape.kind == "train":
        return train_input_specs(cfg, shape)
    if shape.kind == "prefill":
        return prefill_input_specs(cfg, shape)
    if shape.kind == "decode":
        return decode_input_specs(cfg, shape)
    raise ValueError(shape.kind)


def apply_delay_pattern(tokens: np.ndarray, pad: int = 0) -> np.ndarray:
    """MusicGen delay pattern: codebook c shifted right by c steps."""
    B, S, C = tokens.shape
    out = np.full_like(tokens, pad)
    for c in range(C):
        out[:, c:, c] = tokens[:, : S - c, c]
    return out


def synthetic_batches(rcfg: RunConfig, mesh=None):
    """Returns batch_fn(step)->batch of concrete arrays (seeded, CPU-sized)."""
    cfg = rcfg.model
    shape = rcfg.shape

    def batch_fn(step: int):
        rng = np.random.default_rng(rcfg.seed * 100003 + step)
        B, S = shape.global_batch, shape.seq_len
        S_txt = S - cfg.n_patches if cfg.vision_stub else S
        # learnable structure: each row is an arithmetic token sequence
        # (stride 1..4, random phase) so CE demonstrably decreases.
        tshape = _token_shape(cfg, B, S_txt + 1)
        phase = rng.integers(0, cfg.vocab_size, (B,) + (1,) * (len(tshape) - 1))
        stride = rng.integers(1, 5, (B,) + (1,) * (len(tshape) - 1))
        t = np.arange(S_txt + 1).reshape(1, S_txt + 1,
                                         *([1] * (len(tshape) - 2)))
        toks = ((phase + stride * t) % cfg.vocab_size).astype(np.int32)
        toks = np.broadcast_to(toks, tshape).copy()
        if cfg.n_codebooks > 1:
            toks = apply_delay_pattern(toks)
        batch = {
            "tokens": jnp.asarray(toks[:, :-1]),
            "targets": jnp.asarray(toks[:, 1:]),
            "mask": jnp.ones((B, S_txt), jnp.float32),
        }
        if cfg.vision_stub:
            patches = rng.standard_normal((B, cfg.n_patches, cfg.d_model),
                                          dtype=np.float32)
            batch["patches"] = jnp.asarray(patches, jnp.dtype(cfg.dtype))
        return batch

    return batch_fn
