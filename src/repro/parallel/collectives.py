"""Explicit-collective building blocks (shard_map).

``seq_sharded_decode_attention`` is the TPU-native analogue of GPU
flash-decoding: the KV cache is sharded along *sequence* over a mesh axis,
each chip computes a partial softmax over its KV slice, and the partials are
combined with one tiny ``psum`` (per-head scalars + one head-dim vector).
This is what lets a 524k-token cache decode on a 16-way axis, and lets GQA
archs with kv_heads < axis size shard their cache at all.

``ring_attention`` is sequence-parallel prefill attention: q/k/v are
sharded along *sequence* over a mesh axis, every chip computes its local
q block against the kv shard it currently holds, and kv rotates around the
ring via ``collective_permute`` — total wire per chip = one pass of the kv
shards ((n-1)/n x kv bytes) instead of the head-parallel formulation's
output all-reduce (2(n-1)/n x activation bytes, which is ~d_model/kv_dim
times larger for GQA models). Online-softmax accumulators merge the per-
shard partials exactly (same math as the flash kernel).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import AXIS_MODEL, batch_axes
from repro.parallel.compat import axis_size, shard_map

NEG_INF = -1e30


def _ring_body(q, k, v, *, axis: str, causal: bool):
    """Per-shard body. q: (B, S_loc, H, hd); k/v: (B, S_loc, KVH, hd) —
    the ring rotates the *unrepeated* GQA kv shards (kv_dim bytes per hop,
    not H x hd: 8x less wire for the kv=8 archs)."""
    n = axis_size(axis)
    idx = jax.lax.axis_index(axis)
    B, Sl, H, hd = q.shape
    KVH = k.shape[2]
    G = H // KVH
    scale = 1.0 / (hd ** 0.5)
    qg = q.astype(jnp.float32).reshape(B, Sl, KVH, G, hd)
    perm = [(j, (j + 1) % n) for j in range(n)]

    def step(i, carry):
        k, v, o, m, l = carry
        src = (idx - i) % n                   # whose kv shard we hold now
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg,
                       k.astype(jnp.float32)) * scale
        if causal:
            qpos = idx * Sl + jnp.arange(Sl)
            kpos = src * Sl + jnp.arange(Sl)
            mask = qpos[:, None] >= kpos[None, :]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
        mb = jnp.max(s, axis=-1)
        mn = jnp.maximum(m, mb)
        alpha = jnp.exp(m - mn)
        p = jnp.exp(s - mn[..., None])
        o = o * alpha[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(v.dtype), v).astype(jnp.float32)
        l = l * alpha + jnp.sum(p, axis=-1)
        k = jax.lax.ppermute(k, axis, perm)
        v = jax.lax.ppermute(v, axis, perm)
        return (k, v, o, mn, l)

    o0 = jnp.zeros((B, KVH, G, Sl, hd), jnp.float32)
    m0 = jnp.full((B, KVH, G, Sl), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KVH, G, Sl), jnp.float32)
    _, _, o, m, l = jax.lax.fori_loop(0, n, step, (k, v, o0, m0, l0))
    out = o / jnp.maximum(l, 1e-30)[..., None]        # (B,KVH,G,Sl,hd)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sl, H, hd).astype(q.dtype)


def ring_attention(q, k, v, mesh, axis=AXIS_MODEL, *, causal=True):
    """Sequence-parallel attention. q: (B,S,H,hd); k/v: (B,S,KVH,hd)
    *unrepeated*; S shards over ``axis``. Returns (B,S,H,hd)."""
    S = q.shape[1]
    if (mesh is None or mesh.shape.get(axis, 1) == 1
            or S % mesh.shape[axis] != 0):
        return _fallback_full(q, k, v, causal)
    bax = batch_axes(mesh)
    btotal = 1
    for a in bax:
        btotal *= mesh.shape[a]
    b = bax if (bax and q.shape[0] % btotal == 0) else None
    spec = P(b, axis, None, None)
    fn = shard_map(
        lambda qq, kk, vv: _ring_body(qq, kk, vv, axis=axis, causal=causal),
        mesh=mesh, in_specs=(spec, spec, spec), out_specs=spec,
        check_vma=False)
    return fn(q, k, v)


def _fallback_full(q, k, v, causal):
    from repro.models.attention import chunked_attention, repeat_kv
    return chunked_attention(q, repeat_kv(k, q.shape[2]),
                             repeat_kv(v, q.shape[2]), causal=causal)


def _partial_decode(q, k, v, lengths, new_k, new_v, axis, seq_total):
    """Per-shard body. q: (B,H,hd); k/v: (B,S_loc,KVH,hd) local slice;
    new_k/new_v: (B,KVH,hd) token to insert at position ``lengths``."""
    B, S_loc, KVH, hd = k.shape
    H = q.shape[1]
    G = H // KVH
    idx = jax.lax.axis_index(axis) if axis else 0
    offset = idx * S_loc
    # ---- insert the new token's KV if it lands in this shard ----
    local_pos = lengths - offset  # (B,)
    in_range = (local_pos >= 0) & (local_pos < S_loc)
    safe_pos = jnp.clip(local_pos, 0, S_loc - 1)
    bidx = jnp.arange(B)
    k = k.at[bidx, safe_pos].set(
        jnp.where(in_range[:, None, None], new_k, k[bidx, safe_pos]))
    v = v.at[bidx, safe_pos].set(
        jnp.where(in_range[:, None, None], new_v, v[bidx, safe_pos]))
    # ---- partial attention over the local slice ----
    qg = q.reshape(B, KVH, G, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k).astype(jnp.float32) / (hd ** 0.5)
    pos = offset + jnp.arange(S_loc)
    valid = pos[None, :] <= lengths[:, None]  # (B,S_loc) — includes new token
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)  # (B,KVH,G)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(v.dtype), v).astype(jnp.float32)
    if axis:
        mx = jax.lax.pmax(m, axis)
        alpha = jnp.exp(m - mx)
        o = jax.lax.psum(o * alpha[..., None], axis)
        l = jax.lax.psum(l * alpha, axis)
    out = (o / jnp.maximum(l, 1e-30)[..., None]).reshape(B, H, hd)
    return out.astype(q.dtype), k, v


def seq_sharded_decode_attention(q, k_cache, v_cache, lengths, new_k, new_v,
                                 mesh, axis=AXIS_MODEL):
    """Decode attention with the cache sharded on seq over ``axis``.

    q: (B,H,hd); caches: (B,S,KVH,hd); lengths: (B,); new_k/new_v: (B,KVH,hd).
    Returns (out (B,H,hd), new_k_cache, new_v_cache).
    """
    if mesh is None or mesh.shape.get(axis, 1) == 1:
        return _partial_decode(q, k_cache, v_cache, lengths, new_k, new_v,
                               None, k_cache.shape[1])
    bax = batch_axes(mesh)
    btotal = 1
    for a in bax:
        btotal *= mesh.shape[a]
    # replicate the batch dim when it cannot shard (e.g. long-context B=1)
    b = bax if (bax and q.shape[0] % btotal == 0) else None
    fn = shard_map(
        lambda qq, kk, vv, ll, nk, nv: _partial_decode(
            qq, kk, vv, ll, nk, nv, axis, k_cache.shape[1]),
        mesh=mesh,
        in_specs=(P(b, None, None), P(b, axis, None, None), P(b, axis, None, None),
                  P(b), P(b, None, None), P(b, None, None)),
        out_specs=(P(b, None, None), P(b, axis, None, None), P(b, axis, None, None)),
        check_vma=False,
    )
    return fn(q, k_cache, v_cache, lengths, new_k, new_v)
