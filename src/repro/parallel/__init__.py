from repro.parallel.sharding import (  # noqa: F401
    AXIS_DATA,
    AXIS_MODEL,
    AXIS_POD,
    batch_axes,
    logical_rules,
    resolve_spec,
    spec_tree,
)
