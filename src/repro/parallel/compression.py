"""Cross-pod int8 gradient compression (beyond-paper distributed-opt trick).

Multi-pod meshes reduce gradients twice: within a pod over the fast ICI
(``data`` axis, handled by GSPMD), and across pods over the slow inter-pod
links (``pod`` axis). We make the *pod* reduction explicit with a
partial-manual ``shard_map`` (``axis_names={"pod"}``; ``data``/``model``
stay GSPMD-auto) and exchange int8-quantized tensors via
``collective_permute`` — 4x fewer inter-pod bytes than an fp32 all-reduce.

Quantization is per-tensor symmetric round-to-nearest. For 2 pods the
dequantize-then-add formulation avoids int8 saturation entirely; >2 pods
fall back to an int32 psum of int8 payloads (XLA still moves int8-scale
bytes only after its own narrowing pass — documented in EXPERIMENTS.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import AXIS_POD
from repro.parallel.compat import shard_map


def _quantize(x):
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _pod_sum_compressed(x, n_pods: int):
    q, scale = _quantize(x)
    if n_pods == 2:
        perm = [(0, 1), (1, 0)]
        q_other = jax.lax.ppermute(q, AXIS_POD, perm)
        s_other = jax.lax.ppermute(scale, AXIS_POD, perm)
        out = q.astype(jnp.float32) * scale + q_other.astype(jnp.float32) * s_other
    else:
        # generic: psum the int8 payload widened to int32; scales pmax'd
        s = jax.lax.pmax(scale, AXIS_POD)
        q = jnp.clip(jnp.round(x.astype(jnp.float32) / s), -127, 127)
        out = jax.lax.psum(q.astype(jnp.int32), AXIS_POD).astype(jnp.float32) * s
    return (out / n_pods).astype(x.dtype)  # mean over pods


def build_pod_compressed_grad_fn(grad_fn, mesh):
    """Wrap a value_and_grad fn so the pod-axis reduction is int8-compressed.

    grad_fn(params, batch) -> ((loss, metrics), grads). Params must be
    pod-replicated (they are: placement only uses data/model axes); batch is
    sharded over pod on dim 0.
    """
    if mesh is None or AXIS_POD not in mesh.axis_names or mesh.shape[AXIS_POD] == 1:
        return grad_fn
    n_pods = mesh.shape[AXIS_POD]

    def wrapped(params, batch):
        def body(params, batch):
            (loss, metrics), grads = grad_fn(params, batch)
            grads = jax.tree.map(lambda g: _pod_sum_compressed(g, n_pods), grads)
            loss = jax.lax.pmean(loss, AXIS_POD)
            metrics = jax.tree.map(lambda m: jax.lax.pmean(m, AXIS_POD), metrics)
            return (loss, metrics), grads

        return shard_map(
            body, mesh=mesh,
            in_specs=(P(), P(AXIS_POD)),   # prefix specs: pod placement only
            out_specs=P(),
            axis_names={AXIS_POD},
            check_vma=False,
        )(params, batch)

    return wrapped
