"""Logical-axis sharding rules (MaxText-style).

Every parameter is annotated with a tuple of *logical* axis names at init
time. A rule table per parallelism strategy maps logical names to mesh axes;
``resolve_spec`` applies the table with divisibility/size guards so the same
model code works on a 1-device CPU mesh, the 16x16 production pod and the
2x16x16 multi-pod mesh.

Strategies
----------
``tp``       params sharded over ``model`` only (Megatron TP); activations
             sharded over batch (``data``/``pod``) and heads/mlp (``model``).
``fsdp_tp``  additionally shards the ``embed``/``expert_in`` logical axes over
             ``data`` for *storage*; the per-layer scan body re-gathers to the
             ``tp`` layout (ZeRO-3 / FSDP). Optimizer state inherits storage
             sharding.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AXIS_POD = "pod"
AXIS_DATA = "data"
AXIS_MODEL = "model"

# logical axis -> mesh axis / tuple of mesh axes (None = replicated)
_TP_RULES: dict[str, object] = {
    "layers": None,        # stacked scan dim
    "stage": None,
    "embed": None,         # d_model
    "heads": "model",      # flattened q_dim / head dim products
    "kv_heads": "model",
    "mlp": "model",        # ffn hidden
    "vocab": "model",
    "experts": "model",    # expert parallelism over model axis
    "expert_mlp": None,
    "ssm_inner": "model",  # mamba d_inner / heads
    "ssm_state": None,
    "conv": None,
    "codebooks": None,
    "norm": None,
}

_FSDP_EXTRA: dict[str, object] = {
    # storage-only: re-gathered per scan step; on the multi-pod mesh the
    # pod axis joins the shard (1T-param optimizer state needs 32-way)
    "embed": ("pod", "data"),
}


def logical_rules(strategy: str) -> dict[str, str | None]:
    if strategy == "tp":
        return dict(_TP_RULES)
    if strategy == "fsdp_tp":
        rules = dict(_TP_RULES)
        rules.update(_FSDP_EXTRA)
        return rules
    raise ValueError(f"unknown strategy: {strategy}")


def mesh_axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    """Mesh axes the batch dim is sharded over (pod folds into data)."""
    axes = tuple(a for a in (AXIS_POD, AXIS_DATA) if a in mesh.axis_names)
    return axes


def resolve_spec(
    axes: tuple[str | None, ...],
    shape: tuple[int, ...],
    mesh: Mesh,
    strategy: str = "tp",
) -> P:
    """Logical axes + concrete shape -> PartitionSpec with size guards.

    A mesh axis is dropped (replicated) when the dim is smaller than the axis
    size — GSPMD tolerates uneven sharding via padding, but sub-axis-size dims
    (e.g. 8 kv-heads over 16-way model axis) would waste >2x, so we replicate
    those instead.
    """
    rules = logical_rules(strategy)
    out: list[Any] = []
    used: set[str] = set()
    for dim, name in zip(shape, axes, strict=True):
        rule = rules.get(name) if name is not None else None
        cand = (rule,) if isinstance(rule, str) else (rule or ())
        mesh_axes = [a for a in cand
                     if a in mesh.axis_names and a not in used]
        # drop axes (outermost first) until the dim shards cleanly
        while mesh_axes:
            total = 1
            for a in mesh_axes:
                total *= mesh.shape[a]
            if dim >= total and dim % total == 0:
                break
            mesh_axes.pop(0)
        if not mesh_axes:
            out.append(None)
            continue
        used.update(mesh_axes)
        out.append(tuple(mesh_axes) if len(mesh_axes) > 1 else mesh_axes[0])
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def spec_tree(axes_tree, shape_tree, mesh: Mesh, strategy: str = "tp"):
    """Map a pytree of logical-axes tuples (+ matching shapes) to PartitionSpecs."""
    return jax.tree.map(
        lambda axes, shp: resolve_spec(tuple(axes), tuple(shp), mesh, strategy),
        axes_tree,
        shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x),
    )


def sharding_tree(axes_tree, shape_tree, mesh: Mesh, strategy: str = "tp"):
    specs = spec_tree(axes_tree, shape_tree, mesh, strategy)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def constrain(x, mesh: Mesh, *axes):
    """with_sharding_constraint by mesh axis names (None entries allowed)."""
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*axes)))
