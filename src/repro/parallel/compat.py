"""jax API compatibility.

``shard_map`` graduated from ``jax.experimental`` to the top level, and
its knobs were renamed on the way (``check_rep`` -> ``check_vma``;
"manual over these axes" went from the complement ``auto=frozenset(...)``
to the direct ``axis_names={...}``). The sharded modules here are written
against the current top-level API; on the older jax pinned in this
container we adapt the call onto the experimental entry point.
"""
from __future__ import annotations

import jax

if hasattr(jax.lax, "axis_size"):
    axis_size = jax.lax.axis_size
else:
    def axis_size(axis_name):
        """``lax.axis_size`` predecessor: ``psum`` of a literal 1 is
        constant-folded to the (static) mapped axis size."""
        return jax.lax.psum(1, axis_name)


def tpu_compiler_params(pltpu, **kw):
    """``pltpu.CompilerParams`` was ``TPUCompilerParams`` before the
    pallas TPU params class dropped its prefix; the kernels are written
    against the current name and adapted here."""
    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams")
    return cls(**kw)


if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map_legacy

    def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
                  check_vma=True):
        auto = (frozenset(mesh.axis_names) - frozenset(axis_names)
                if axis_names is not None else frozenset())
        return _shard_map_legacy(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=check_vma,
                                 auto=auto)
