"""MTC serving: a Montage-shaped DAG of inference tasks through the
continuous-batching engine — the MTC TRE's trigger monitor feeds the
engine only tasks whose dependencies completed.

  PYTHONPATH=src python examples/serve_workflow.py
"""
from __future__ import annotations

import numpy as np

import jax

from repro.configs import get_smoke_config
from repro.configs.base import ParallelConfig
from repro.models.lm import LM
from repro.serve.engine import Engine, Request
from repro.sim.traces import montage_like


def main():
    cfg = get_smoke_config("musicgen-large")
    lm = LM(cfg)
    rt = lm.runtime(ParallelConfig(attn_q_chunk=16, attn_kv_chunk=16))
    params = lm.init(jax.random.key(0))[0]
    engine = Engine(lm, params, rt, max_batch=4, max_len=48)

    # a small Montage-shaped workflow: each task = one generation request
    wl = montage_like(n_project=6)
    tasks = {j.jid: j for j in wl.jobs[:40]}
    children: dict[int, list[int]] = {}
    ndeps = {}
    for j in tasks.values():
        deps = [d for d in j.deps if d in tasks]
        ndeps[j.jid] = len(deps)
        for d in deps:
            children.setdefault(d, []).append(j.jid)
    ready = [jid for jid, n in ndeps.items() if n == 0]
    rng = np.random.default_rng(0)
    done_order = []
    # trigger monitor loop: admit ready tasks, decode, release dependents
    pending: list[int] = list(ready)
    while pending or engine.active:
        while pending and engine.free:
            jid = pending.pop(0)
            toks = rng.integers(1, cfg.vocab_size,
                                (6, cfg.n_codebooks)).astype(np.int32)
            engine.admit(Request(rid=jid, tokens=toks, max_new_tokens=4))
        for req in engine.step():
            done_order.append(req.rid)
            for c in children.get(req.rid, ()):
                ndeps[c] -= 1
                if ndeps[c] == 0:
                    pending.append(c)
    assert len(done_order) == len(tasks), (len(done_order), len(tasks))
    # dependencies respected in completion order
    pos = {jid: i for i, jid in enumerate(done_order)}
    for j in tasks.values():
        for d in j.deps:
            if d in tasks:
                assert pos[d] < pos[j.jid]
    print(f"served {len(done_order)} workflow tasks in {engine.steps} decode "
          f"steps (continuous batching, max_batch=4)")
    print("dependency order respected; MTC TRE trigger-monitor OK")


if __name__ == "__main__":
    main()
