"""MTC serving: a Montage-shaped DAG of inference tasks through the
continuous-batching engine, driven by the unified DSP control plane.

The ``repro.core.tre.MTCRuntimeEnv`` plays the paper's MTC TRE server: its
trigger monitor releases a workflow task into the FCFS queue only when every
dependency has completed, and its scheduler loads ready tasks onto free
engine slots (1 node = 1 continuous-batching slot). The serving engine is
just the *driver*: it advances the tick clock, executes decode steps, and
reports finished requests back to the env — the same driver contract the
discrete-event emulator and the elastic training controller use.

  PYTHONPATH=src python examples/serve_workflow.py
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax

from repro.configs import get_smoke_config
from repro.configs.base import ParallelConfig
from repro.core.provision import ProvisionService
from repro.core.tre import MTCRuntimeEnv, TickClock
from repro.models.lm import LM
from repro.serve.engine import Engine, Request
from repro.sim.traces import montage_like


def main():
    cfg = get_smoke_config("musicgen-large")
    lm = LM(cfg)
    rt = lm.runtime(ParallelConfig(attn_q_chunk=16, attn_kv_chunk=16))
    params = lm.init(jax.random.key(0))[0]
    engine = Engine(lm, params, rt, max_batch=4, max_len=48)

    # a small Montage-shaped workflow: each task = one generation request
    wl = montage_like(n_project=6)
    keep = {j.jid for j in wl.jobs[:40]}
    tasks = {j.jid: dataclasses.replace(
                 j, deps=tuple(d for d in j.deps if d in keep))
             for j in wl.jobs[:40]}
    rng = np.random.default_rng(0)

    def admit(job):
        """env launch hook: one free engine slot = the job's node."""
        toks = rng.integers(1, cfg.vocab_size,
                            (6, cfg.n_codebooks)).astype(np.int32)
        ok = engine.admit(Request(rid=job.jid, tokens=toks, max_new_tokens=4))
        assert ok, "env scheduled beyond free slots"

    clock = TickClock()
    env = MTCRuntimeEnv("montage-serve", provision=ProvisionService(),
                        clock=clock, launch=admit,
                        fixed_nodes=engine.max_batch)
    env.track(tasks.values())
    for j in tasks.values():
        if not j.deps:
            env.submit(j)               # trigger monitor releases the rest

    # driver loop: decode steps advance the clock; finished requests go back
    # to the env, which frees slots and chains newly-ready dependents
    while env.queue or engine.active:
        clock.advance()
        for req in engine.step():
            env.finish(tasks[req.rid])
    assert env.all_done, (len(env.completed), len(tasks))

    # dependencies respected in completion order
    done_order = [j.jid for j in env.completed]
    pos = {jid: i for i, jid in enumerate(done_order)}
    for j in tasks.values():
        for d in j.deps:
            assert pos[d] < pos[j.jid]
    env.destroy()
    print(f"served {len(done_order)} workflow tasks in {engine.steps} decode "
          f"steps (continuous batching, max_batch={engine.max_batch})")
    print("dependency order respected; MTCRuntimeEnv trigger-monitor OK")


if __name__ == "__main__":
    main()
