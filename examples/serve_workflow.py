"""MTC serving: a Montage-shaped DAG of inference tasks through the
continuous-batching engine, driven by the trace-rate serve driver.

This is now a thin entry point into ``repro.serve.driver.ServeDriver`` —
the industrialized form of what used to be an inline driver loop here.
The ``MTCRuntimeEnv`` plays the paper's MTC TRE server (trigger monitor +
FCFS + DR1/DR2 negotiation against a shared ``ResourceProvider``), the
real jax engine serves the requests through ``JaxEngineAdapter``, and the
driver replays the workflow at trace rate with batched admission and
deferred-grant backpressure. ``benchmarks/serve_trace.py`` runs the same
driver at fleet scale.

  PYTHONPATH=src python examples/serve_workflow.py
"""
from __future__ import annotations

import jax

from repro.configs import get_smoke_config
from repro.configs.base import ParallelConfig
from repro.core.policy import MgmtPolicy
from repro.core.provider import ResourceProvider
from repro.models.lm import LM
from repro.serve.driver import JaxEngineAdapter, ServeDriver
from repro.serve.engine import Engine
from repro.sim.traces import montage_like, request_stream


def main():
    cfg = get_smoke_config("musicgen-large")
    lm = LM(cfg)
    rt = lm.runtime(ParallelConfig(attn_q_chunk=16, attn_kv_chunk=16))
    params = lm.init(jax.random.key(0))[0]
    engine = Engine(lm, params, rt, max_batch=4, max_len=48)

    # a small Montage workflow, marked as an inference request DAG
    wl = montage_like(n_project=8)
    stream = request_stream([wl], period=wl.period, seed=0,
                            seconds_per_token=4.0, prompt_lens=(4, 6))
    provider = ResourceProvider(engine.max_batch, coordination="first-come")
    driver = ServeDriver(
        stream, provider=provider, engine=JaxEngineAdapter(engine, seed=0),
        policy=MgmtPolicy(initial=2, ratio=1.0, scan_interval=3.0,
                          release_interval=60.0),
        name="montage-serve")
    stats = driver.run()
    assert stats.workflows_completed == len(stream), stats
    assert stats.over_admissions == 0

    # dependencies respected in completion order
    pos = {j.jid: i for i, j in enumerate(driver.env.completed)}
    for j in driver.env.completed:
        for d in j.deps:
            assert pos[d] < pos[j.jid]
    print(f"served {stats.tasks_completed} workflow tasks in {engine.steps} "
          f"decode steps (continuous batching, max_batch={engine.max_batch})")
    print(f"slot utilization {stats.slot_utilization:.1%}, "
          f"peak slots {stats.peak_owned}, billed {stats.node_hours:.0f} "
          f"node-hours; trigger-monitor order OK")


if __name__ == "__main__":
    main()
