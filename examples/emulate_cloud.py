"""The paper's experiment end-to-end: consolidate two HTC providers (NASA,
BLUE) and one MTC provider (Montage) on one cloud platform and compare the
usage models — the paper's four (DCS / SSP / DRP / DawningCloud-DSP) plus
any scenario registered with ``repro.core.registry`` (``--all`` runs every
registered system, e.g. the beyond-paper ``dawningcloud-backfill`` mix).

  PYTHONPATH=src python examples/emulate_cloud.py [--policy-set paper|tuned]
"""
from __future__ import annotations

import argparse

from repro.core.policy import MgmtPolicy
from repro.core.registry import available_systems
from repro.sim import run_system
from repro.sim.traces import standard_workloads

POLICIES = {
    "paper": {"nasa": MgmtPolicy.htc(40, 1.2), "blue": MgmtPolicy.htc(80, 1.5),
              "montage": MgmtPolicy.mtc(10, 8.0)},
    "tuned": {"nasa": MgmtPolicy.htc(40, 1.0), "blue": MgmtPolicy.htc(40, 1.0),
              "montage": MgmtPolicy.mtc(10, 8.0)},
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy-set", default="tuned", choices=list(POLICIES))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--all", action="store_true",
                    help="run every registered system, not just the paper's")
    args = ap.parse_args()
    wls = standard_workloads(args.seed)
    print("workloads:")
    for wl in wls:
        print(f"  {wl.name:8s} {wl.kind} jobs={len(wl.jobs):5d} "
              f"platform={wl.trace_nodes} util={wl.utilization():.1%}")
    systems = (available_systems() if args.all
               else ("dcs", "ssp", "drp", "dawningcloud"))
    results = {}
    for system in systems:
        results[system] = run_system(
            system, wls, policies=POLICIES[args.policy_set],
            mtc_fixed_nodes=166)
    print(f"\n{'system':22s} {'total node*h':>12s} {'peak/h':>7s} "
          f"{'adjusts':>8s}")
    for system, res in results.items():
        print(f"{system:22s} {res.total_node_hours:>12.0f} "
              f"{res.peak_nodes_per_hour:>7d} {res.adjust_count:>8d}")
    dc = results["dawningcloud"].total_node_hours
    print(f"\nDawningCloud saves {1 - dc/results['dcs'].total_node_hours:.1%}"
          f" vs DCS/SSP and {1 - dc/results['drp'].total_node_hours:.1%} vs"
          f" DRP\n=> the MTC/HTC providers and the resource provider all"
          f" benefit from the economies of scale (paper's conclusion).")


if __name__ == "__main__":
    main()
