"""Quickstart: train a small decoder LM end-to-end with the public API.

This is the end-to-end driver example: config -> model -> fault-tolerant
training loop (checkpoints + auto-resume) -> eval of the loss curve. The
model is a reduced granite-family decoder; pass ``--preset 100m`` for a
~100M-parameter run (same code path, more compute).

  PYTHONPATH=src python examples/quickstart.py --steps 60
  PYTHONPATH=src python examples/quickstart.py --preset 100m --steps 300
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import tempfile

from repro.configs import get_config, get_smoke_config
from repro.configs.base import ParallelConfig, RunConfig, ShapeConfig
from repro.train.loop import train_loop

PRESETS = {
    # ~8M params: CPU-friendly sanity run
    "tiny": dict(n_layers=4, d_model=256, n_heads=8, n_kv_heads=4,
                 head_dim=32, d_ff=512, vocab_size=2048),
    # ~100M params: the "real" quickstart (minutes/step on CPU, fast on TPU)
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 head_dim=64, d_ff=2048, vocab_size=32000),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = dataclasses.replace(get_config("granite-3-8b"),
                              name=f"quickstart-{args.preset}",
                              **PRESETS[args.preset])
    shape = ShapeConfig("quickstart", "train", args.seq, args.batch)
    rcfg = RunConfig(model=cfg, shape=shape,
                     parallel=ParallelConfig(attn_q_chunk=128,
                                             attn_kv_chunk=128),
                     learning_rate=1e-3, warmup_steps=10,
                     total_steps=args.steps)
    print(f"model: {cfg.param_count()/1e6:.1f}M params, "
          f"{shape.tokens} tokens/step")
    ckpt_dir = args.ckpt_dir or os.path.join(tempfile.gettempdir(),
                                             "repro-quickstart")
    report = train_loop(rcfg, ckpt_dir=ckpt_dir, num_steps=args.steps,
                        ckpt_every=max(args.steps // 4, 1))
    print(f"ran {report.steps_run} steps; "
          f"loss {report.losses[0]:.3f} -> {report.final_loss:.3f}")
    assert report.final_loss < report.losses[0], "loss did not decrease"
    print(f"checkpoints under {ckpt_dir}")


if __name__ == "__main__":
    main()
