"""Live DSP elasticity: the paper's policy engine resizing a *real* JAX
training job across meshes.

Eight placeholder host devices model an 8-accelerator TRE allocation. Two
training jobs arrive; the DSP scan grows the allocation, the controller
grows a running job's data-parallel mesh into spare devices (checkpoint ->
re-mesh -> resume, beyond-paper elastic growth), and an injected preemption
is absorbed by restart-from-checkpoint.

  PYTHONPATH=src python examples/elastic_train.py
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses  # noqa: E402
import tempfile  # noqa: E402

import jax  # noqa: E402

from repro.configs import get_smoke_config  # noqa: E402
from repro.configs.base import ParallelConfig, RunConfig, ShapeConfig  # noqa: E402
from repro.core.controller import ElasticController, TrainTask  # noqa: E402
from repro.core.policy import MgmtPolicy  # noqa: E402
from repro.core.provision import ProvisionService  # noqa: E402


def main():
    assert len(jax.devices()) == 8, jax.devices()
    cfg = get_smoke_config("qwen3-14b")
    shape = ShapeConfig("elastic", "train", 64, 8)
    rcfg = RunConfig(model=cfg, shape=shape,
                     parallel=ParallelConfig(attn_q_chunk=32,
                                             attn_kv_chunk=32),
                     total_steps=1000, learning_rate=1e-3, warmup_steps=5)
    provision = ProvisionService(capacity=8)
    ctl = ElasticController(policy=MgmtPolicy.htc(2, 1.0),
                            provision=provision, steps_per_tick=5,
                            elastic_grow=True)
    with tempfile.TemporaryDirectory() as tmp:
        jobs = [TrainTask(f"train-{i}", rcfg, nodes=2, num_steps=25,
                          ckpt_dir=os.path.join(tmp, f"j{i}"))
                for i in range(2)]
        for j in jobs:
            ctl.submit(j)
        ctl.run(fail_at={3: "train-0"})
        ctl.destroy()
    for j in ctl.finished:
        print(f"{j.name}: steps={j.steps_done} resizes={j.resizes} "
              f"restarts={j.restarts} loss {j.losses[0]:.3f} -> "
              f"{j.losses[-1]:.3f}")
    print(f"TRE billed {provision.node_hours(None, ctl._tick):.0f} "
          f"node-lease-units; {provision.adjust_count()} node adjustments")
    assert all(j.done for j in ctl.finished) and len(ctl.finished) == 2
    assert any(j.resizes > 0 for j in ctl.finished), "no elastic resize ran"
    print("elastic DSP training OK: policies resized live JAX meshes")


if __name__ == "__main__":
    main()
