"""CLI: ``python -m tools.dclint [paths...] [--json] [--update-baseline]``.

Exit codes: 0 clean (all findings baselined or none), 1 non-baselined
findings (or shapecheck contract failures), 2 usage error.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from tools.dclint import REPO_ROOT, Violation, collect_files, lint_paths
from tools.dclint import baseline as baseline_mod

JSON_SCHEMA_VERSION = 1


def _as_json(new: list[Violation], baselined: list[Violation],
             stale: list[dict]) -> dict:
    def rows(vs: list[Violation], is_baselined: bool) -> list[dict]:
        return [
            {"path": v.path, "line": v.line, "col": v.col, "code": v.code,
             "message": v.message, "fingerprint": v.fingerprint(),
             "baselined": is_baselined}
            for v in vs
        ]

    return {
        "version": JSON_SCHEMA_VERSION,
        "violations": rows(new, False) + rows(baselined, True),
        "stale_baseline": stale,
        "counts": {"new": len(new), "baselined": len(baselined),
                   "stale_baseline": len(stale)},
    }


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.dclint",
        description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="*", default=["src", "benchmarks"],
                    help="files/directories to lint (default: src "
                         "benchmarks, relative to the repo root)")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable report on stdout (for CI)")
    ap.add_argument("--baseline", type=Path, default=None,
                    help="baseline file (default: tools/dclint/"
                         "baseline.json)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report every finding, ignoring the baseline")
    ap.add_argument("--fix", action="store_true",
                    help="rewrite flagged findings in place (DC101 bare "
                         "asserts into guarded raises, DC201 numpy "
                         "global-RNG calls into seeded default_rng(0) "
                         "generators, DC301 re-entrant provider calls "
                         "onto a CFG-validated post-drain deferral "
                         "list), then re-lint; baseline entries paid "
                         "down by the rewrite are pruned")
    ap.add_argument("--update-baseline", action="store_true",
                    help="prune stale entries from the baseline (burn-"
                         "down); never adds entries unless --rebaseline")
    ap.add_argument("--rebaseline", action="store_true",
                    help="with --update-baseline: rewrite the baseline "
                         "to ALL current findings (accepting new debt — "
                         "use only when introducing a rule)")
    ap.add_argument("--root", type=Path, default=None,
                    help="repo root override (fixture tests)")
    ap.add_argument("--shapecheck", action="store_true",
                    help="also run the eval_shape kernel-contract "
                         "harness (requires jax)")
    args = ap.parse_args(argv)

    root = (args.root or REPO_ROOT).resolve()
    paths = []
    for p in args.paths:
        q = Path(p)
        if not q.is_absolute():
            q = root / q
        if not q.exists():
            print(f"dclint: path not found: {p}", file=sys.stderr)
            return 2
        # a scope containing zero Python files lints vacuously clean —
        # which is how a typo'd path silently passes CI. Usage error.
        if not collect_files([q]):
            print(f"dclint: no Python files under: {p}", file=sys.stderr)
            return 2
        paths.append(q)

    if args.fix:
        from tools.dclint import fix as fix_mod
        n_fixed, n_skipped = fix_mod.fix_paths(paths, root=root)
        if not args.json:
            msg = f"dclint --fix: rewrote {n_fixed} finding(s)"
            if n_skipped:
                msg += (f", skipped {n_skipped} with no mechanical "
                        f"rewrite (fix by hand)")
            print(msg)

    violations = lint_paths(paths, root=root)
    if args.no_baseline:
        new, baselined, stale = violations, [], []
    else:
        data = baseline_mod.load(args.baseline)
        new, baselined, stale = baseline_mod.split(violations, data)

    if args.update_baseline or (args.fix and stale):
        path = args.baseline or baseline_mod.DEFAULT_PATH
        keep = violations if args.rebaseline else baselined
        baseline_mod.write(path, keep)
        stale = []

    if args.json:
        print(json.dumps(_as_json(new, baselined, stale), indent=2))
    else:
        for v in new:
            print(v.render())
        if baselined:
            print(f"dclint: {len(baselined)} baselined finding(s) "
                  f"suppressed (burn-down: tools/dclint/baseline.json)")
        for e in stale:
            print(f"dclint: stale baseline entry (debt paid — run "
                  f"--update-baseline to prune): {e['path']} {e['code']} "
                  f"`{e.get('source_line', '')}`")
        if not new:
            print(f"dclint: clean ({len(new)} new, {len(baselined)} "
                  f"baselined, {len(stale)} stale)")

    rc = 1 if new else 0

    if args.shapecheck:
        from tools.dclint import shapecheck
        src = root / "src"
        if src.exists() and str(src) not in sys.path:
            sys.path.insert(0, str(src))
        rc = max(rc, shapecheck.main(json_out=args.json))
    return rc


if __name__ == "__main__":
    sys.exit(main())
