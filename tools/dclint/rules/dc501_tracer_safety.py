"""DC501 — pallas kernels must be tracer-safe.

Three bug classes that surface as tracer errors at best (and silent
mis-compiles at worst), caught at authoring time:

1. **Python control flow on traced values.** Inside a kernel body
   (any function passed to ``pl.pallas_call``) every positional
   parameter is a ``Ref`` and every ``pl.program_id`` is traced; a
   Python ``if``/``while`` on them evaluates the *tracer*, not the
   value. Use ``pl.when``/``lax.cond``/``lax.fori_loop``. Keyword-only
   parameters are treated as static (the repo binds static kwargs via
   ``functools.partial``, e.g. ``block_s``/``scale``).
2. **Non-static shapes in ``pl.BlockSpec``.** Block shapes must be
   Python ints at trace time: literals, names, ``x.shape[i]`` and
   arithmetic over them are fine; calls (``jnp.*``) or subscripts of
   array values (``lengths[0]``) are traced and flagged.
3. **Mutable default arguments.** A ``jax.jit``-wrapped function
   captures its defaults at trace time; a mutable default (``[]``,
   ``{}``, ``np.zeros(...)``) aliases state across calls and silently
   bakes the first call's contents into the compiled artifact.

The static rule is complemented by the ``--shapecheck`` harness
(``tools.dclint.shapecheck``), which abstractly evaluates every kernel's
shape/dtype contract against the registered model configs via
``jax.eval_shape`` — no accelerator required.
"""
from __future__ import annotations

import ast

CODE = "DC501"
SUMMARY = ("tracer hazard in pallas kernel (python control flow on traced "
           "value / non-static BlockSpec shape / mutable default)")

_STATIC_CALLS = frozenset({"len", "int", "min", "max", "abs", "round"})
_MUTABLE_CALLS = frozenset({"list", "dict", "set", "bytearray",
                            "zeros", "ones", "empty", "full", "array",
                            "zeros_like", "ones_like", "arange"})


def _callee(node: ast.AST) -> str | None:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return None


def _kernel_fn_names(tree: ast.AST) -> set[str]:
    """Names of functions passed (possibly via functools.partial) as the
    first argument of a ``pl.pallas_call``."""
    names: set[str] = set()
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and _callee(node.func) == "pallas_call" and node.args):
            continue
        first = node.args[0]
        if isinstance(first, ast.Call) and _callee(first.func) == "partial":
            first = first.args[0] if first.args else first
        name = _callee(first)
        if name:
            names.add(name)
    return names


def _names_in(node: ast.AST) -> set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def _has_traced_call(node: ast.AST) -> bool:
    """program_id/num_programs (and ref loads x[...] are caught via the
    name taint, not here)."""
    for n in ast.walk(node):
        if (isinstance(n, ast.Call)
                and _callee(n.func) in ("program_id", "num_programs")):
            return True
    return False


def _check_kernel_body(fn: ast.FunctionDef):
    a = fn.args
    traced = {p.arg for p in a.posonlyargs + a.args if p.arg != "self"}
    # kwonly params are static closure config (functools.partial binding).
    # Taint to a fixpoint: ast.walk order is not source order, so one
    # pass could miss a chain assigned "upward" in the tree.
    changed = True
    while changed:
        changed = False
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign):
                if (_names_in(node.value) & traced
                        or _has_traced_call(node.value)):
                    for tgt in node.targets:
                        if isinstance(tgt, ast.Name) and tgt.id not in traced:
                            traced.add(tgt.id)
                            changed = True
    for node in ast.walk(fn):
        if isinstance(node, (ast.If, ast.While)):
            hit = _names_in(node.test) & traced
            if hit or _has_traced_call(node.test):
                what = (f"`{sorted(hit)[0]}`" if hit
                        else "a pl.program_id value")
                kind = "if" if isinstance(node, ast.If) else "while"
                yield (node.lineno, node.col_offset,
                       f"python `{kind}` on traced value {what} inside "
                       f"kernel `{fn.name}`; use pl.when / lax.cond / "
                       f"lax.fori_loop")


def _static_shape_elt(node: ast.AST) -> bool:
    if isinstance(node, ast.Constant):
        return node.value is None or isinstance(node.value, int)
    if isinstance(node, (ast.Name, ast.Attribute)):
        return True
    if isinstance(node, ast.UnaryOp):
        return _static_shape_elt(node.operand)
    if isinstance(node, ast.BinOp):
        return (_static_shape_elt(node.left)
                and _static_shape_elt(node.right))
    if isinstance(node, ast.Subscript):
        # x.shape[0] is static at trace time; lengths[0] is a traced load
        return (isinstance(node.value, ast.Attribute)
                and node.value.attr == "shape")
    if isinstance(node, ast.Call):
        return (_callee(node.func) in _STATIC_CALLS
                and all(_static_shape_elt(x) for x in node.args))
    return False


def _check_blockspecs(tree: ast.AST):
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and _callee(node.func) == "BlockSpec" and node.args):
            continue
        shape = node.args[0]
        elts = shape.elts if isinstance(shape, ast.Tuple) else [shape]
        for elt in elts:
            if not _static_shape_elt(elt):
                yield (elt.lineno, elt.col_offset,
                       f"BlockSpec shape entry `{ast.unparse(elt)}` is "
                       f"not statically resolvable at trace time; block "
                       f"shapes must be python ints (shape attrs and "
                       f"arithmetic over them are fine)")


def _check_mutable_defaults(tree: ast.AST):
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        defaults = list(fn.args.defaults) + [
            d for d in fn.args.kw_defaults if d is not None]
        for d in defaults:
            mutable = isinstance(d, (ast.List, ast.Dict, ast.Set)) or (
                isinstance(d, ast.Call)
                and _callee(d.func) in _MUTABLE_CALLS)
            if mutable:
                yield (d.lineno, d.col_offset,
                       f"mutable default `{ast.unparse(d)}` on "
                       f"`{fn.name}`: jax.jit captures defaults at trace "
                       f"time, aliasing state across calls; default to "
                       f"None and construct inside")


def check(tree: ast.AST, src_lines: list[str], rel: str):
    kernel_names = _kernel_fn_names(tree)
    for node in ast.walk(tree):
        if (isinstance(node, ast.FunctionDef)
                and node.name in kernel_names):
            yield from _check_kernel_body(node)
    yield from _check_blockspecs(tree)
    yield from _check_mutable_defaults(tree)
