"""DC601 — tenant phase discipline.

The ``Tenant`` protocol assigns each tick phase a job, and the fleet's
correctness argument (weighted isolation, event-skip parity, same-tick
preemption-to-grant flow) leans on hooks doing *only* that job:

=================  ====================================================
hook               grant/ledger traffic it may generate
=================  ====================================================
``begin_tick``     none — intake only; it runs before the tick's
                   provider state settles, so it must not even *read*
                   grant state
``pre_step``       releases (``release_check``/``yield_nodes``/
                   ``release``/``preempt``/``cancel``) and elastic
                   ``shrink`` — vacated nodes must drain to parked
                   foreign requests within the same tick
``post_step``      finish accounting (``finish``/``finish_positions``)
                   and the shrink that returns elastic growth
``control``        negotiation (``scan``/``request``/
                   ``submit_request``/``amend``/``acquire``/``grow``)
``flush``          batched admissions (``admit_many``/
                   ``admit_positions``/``admit``)
``check_invariants``, ``accumulate``
                   none — read/raise and stats integrals
``next_event_tick``, ``skip_quiet_stats``
                   none, and **pure** w.r.t. grant/ledger state — the
                   event-skip fast path must be bit-identical to the
                   dense ticks it replaces
=================  ====================================================

Additionally no hook, in any phase, may *assign* grant-ledger state
directly (``env.owned``, ``env.busy``, provider ``allocated``/
``admission_queue``/...) — mutation goes through the env/provider API,
which keeps the idle integrals and the lease ledger consistent.

Detection: tenant classes are found by base name (``Tenant`` anywhere
in the project-resolved MRO) or structurally (three or more hook
definitions); each hook is resolved through the MRO — including
class-level ``hook = _method`` aliases — and walked interprocedurally
through its ``self.`` helper methods (virtual dispatch includes
subclass overrides) and same-module functions. Category calls are
judged at the call site (``self.env.scan()`` is negotiation wherever it
appears); the env/provider bodies themselves are out of scope — they
are the sanctioned API boundary.

``teardown``/``finalize``/``retired``/``rollup`` run outside the tick
and carry no phase restriction.
"""
from __future__ import annotations

import ast

from tools.dclint.flow.dataflow import (
    attr_loads, attr_writes, calls, mutating_calls,
)
from tools.dclint.flow.project import Project

CODE = "DC601"
SUMMARY = ("tenant hook mutates grant/ledger state outside its "
           "assigned phase")

#: API-call categories, by bare method name at the call site
_CATEGORIES = {
    "negotiate": frozenset({"scan", "submit_request", "amend", "acquire",
                            "grow", "request"}),
    "release": frozenset({"release_check", "yield_nodes", "release",
                          "preempt", "cancel", "cancel_pending"}),
    "finish": frozenset({"finish", "finish_positions"}),
    "shrink": frozenset({"shrink"}),
    "admit": frozenset({"admit_many", "admit_positions", "admit"}),
}
#: hook -> categories it may invoke; "pure" additionally bans state
#: writes, "no_reads" bans grant-state *loads* (intake runs first)
_HOOKS: dict = {
    "begin_tick": {"allowed": frozenset(), "no_reads": True},
    "pre_step": {"allowed": frozenset({"release", "shrink"})},
    "post_step": {"allowed": frozenset({"finish", "shrink"})},
    "control": {"allowed": frozenset({"negotiate"})},
    "flush": {"allowed": frozenset({"admit"})},
    "check_invariants": {"allowed": frozenset()},
    "accumulate": {"allowed": frozenset()},
    "next_event_tick": {"allowed": frozenset(), "pure": True},
    "skip_quiet_stats": {"allowed": frozenset(), "pure": True},
}
#: grant/ledger state: env grant bookkeeping + provider/pager ledgers
_GRANT_STATE = frozenset({
    "owned", "busy", "granted", "_pending_req", "allocated",
    "admission_queue", "open_leases", "closed_leases", "quotas",
    "reservations", "_free", "_tenant_of", "_quota",
})
#: receiver segments that mark a call/load as env/provider traffic
_RECV_SEGS = ("env", "provision", "provider", "engine", "pager", "pool")


def _category_of(name: str) -> str | None:
    for cat, names in _CATEGORIES.items():
        if name in names:
            return cat
    return None


def _phases_allowing(cat: str) -> str:
    hooks = sorted(h for h, spec in _HOOKS.items()
                   if cat in spec["allowed"])
    return "/".join(hooks) if hooks else "no tick phase"


def _receiverish(chain) -> bool:
    if not chain:
        return False
    return chain[-1] == "self" or any(
        any(r in seg for r in _RECV_SEGS) for seg in chain)


def _is_tenant_class(project: Project, ci) -> bool:
    if any(m.name == "Tenant" for m in project.mro(ci.name)):
        return True
    if "Tenant" in ci.bases:          # unresolved base, fixtures
        return True
    hooks = set(_HOOKS) | {"teardown", "finalize"}
    defined = sum(1 for m in ci.methods if m in hooks)
    defined += sum(1 for a in ci.aliases if a in hooks)
    return defined >= 3


def _family(project: Project, ci) -> set:
    names = {m.name for m in project.mro(ci.name)}
    names.update(s.name for s in project.subclasses(ci.name))
    names.add(ci.name)
    return names


def _hook_closure(project: Project, ci, hook: str) -> dict:
    """Tenant-side functions reachable from ``ci``'s ``hook``:
    ``{FuncInfo: path}``. Traversal stays inside the class family and
    the same-module helpers — env/provider calls are judged at the call
    site, not entered."""
    entry = project.resolve_method(ci.name, hook)
    family = _family(project, ci)
    paths: dict = {}
    queue = []
    for fi in entry:
        paths[fi] = (f"{ci.name}.{hook}",)
        queue.append(fi)
    while queue:
        fi = queue.pop(0)
        for callee in sorted(project.edges(fi), key=lambda f: f.key):
            in_scope = (callee.cls in family
                        or (callee.cls is None and callee.rel == fi.rel))
            if in_scope and callee not in paths:
                paths[callee] = paths[fi] + (callee.name,)
                queue.append(callee)
    return paths


def _analyze(project: Project) -> list:
    if "dc601" in project._cache:
        return project._cache["dc601"]
    findings: list = []
    seen: set = set()
    tenant_classes = [
        ci for infos in project.classes.values() for ci in infos
        if _is_tenant_class(project, ci)]
    for ci in sorted(tenant_classes, key=lambda c: (c.rel, c.name)):
        for hook, spec in _HOOKS.items():
            for fi, path in _hook_closure(project, ci, hook).items():
                via = (" via " + " -> ".join(path[1:])
                       if len(path) > 1 else "")
                loc = f"hook `{path[0]}`{via}"

                def flag(node, kind, msg):
                    key = (node.lineno, node.col_offset, hook, kind)
                    if key not in seen:
                        seen.add(key)
                        findings.append((fi.rel, node.lineno,
                                         node.col_offset, msg))

                for chain, name, node in calls(fi.node):
                    if chain == ("self",):
                        continue      # helper call: traversed, not judged
                    cat = _category_of(name)
                    if (cat and _receiverish(chain)
                            and cat not in spec["allowed"]):
                        what = ("is event-skip-pure: no grant/ledger "
                                "traffic may originate here"
                                if spec.get("pure") else
                                f"may not {cat}; that belongs in "
                                f"{_phases_allowing(cat)}")
                        flag(node, cat,
                             f"`{ast.unparse(node.func)}()` ({cat}) "
                             f"called from {loc}: `{hook}` {what}")
                for chain, attr, node in attr_writes(fi.node):
                    if attr in _GRANT_STATE and _receiverish(chain):
                        flag(node, "write",
                             f"grant-ledger state `{attr}` assigned "
                             f"from {loc}: mutate through the "
                             f"env/provider API, never directly")
                if spec.get("pure"):
                    for chain, meth, node in mutating_calls(fi.node):
                        touched = _GRANT_STATE.intersection(chain)
                        if touched and _receiverish(chain):
                            flag(node, "mut",
                                 f"grant-ledger state "
                                 f"`{sorted(touched)[0]}` mutated "
                                 f"(`.{meth}()`) from {loc}: "
                                 f"`{hook}` must be pure for "
                                 f"event-skip parity")
                if spec.get("no_reads"):
                    for chain, attr, node in attr_loads(fi.node):
                        if attr in _GRANT_STATE and _receiverish(chain):
                            flag(node, "read",
                                 f"grant state `{attr}` read from "
                                 f"{loc}: intake runs before the "
                                 f"tick's grant state settles — read "
                                 f"it from pre_step onward")
    findings.sort()
    project._cache["dc601"] = findings
    return findings


def check_project(project: Project, tree: ast.AST, src_lines, rel):
    for frel, line, col, msg in _analyze(project):
        if frel == rel:
            yield line, col, msg


def check(tree: ast.AST, src_lines, rel):
    """Single-file fallback: analyze this module as a one-file project."""
    yield from check_project(Project({rel: tree}), tree, src_lines, rel)
