"""DC101 — runtime invariants must be guarded raises, not ``assert``.

``python -O`` strips assert statements. In the control plane every
assert guards ledger/scheduling state (over-admission, lease conservation,
dependency-graph integrity), so under ``-O`` the invariant silently stops
being checked — exactly the failure mode PR 4 fixed for the serve suites
(``ServeInvariantError`` and guarded ``RuntimeError`` raises survive
``-O``; asserts do not). Any ``assert`` in scope is flagged.

Fix pattern::

    # before
    assert extra <= self.free, (extra, self.free)
    # after
    if extra > self.free:
        raise RuntimeError(
            f"grow exceeds free nodes: {extra} > {self.free}")
"""
from __future__ import annotations

import ast

CODE = "DC101"
SUMMARY = ("bare `assert` guards a runtime invariant; use a guarded raise "
           "(ServeInvariantError / RuntimeError) so it survives python -O")


def check(tree: ast.AST, src_lines: list[str], rel: str):
    for node in ast.walk(tree):
        if isinstance(node, ast.Assert):
            cond = ast.unparse(node.test)
            if len(cond) > 60:
                cond = cond[:57] + "..."
            yield (node.lineno, node.col_offset,
                   f"bare assert `{cond}` is stripped under python -O; "
                   f"guard a runtime invariant with an explicit raise")
