"""DC401 — slot counts and node units must not mix without a width.

Since PR 5, provider grants, ``RuntimeEnv.owned``/``busy`` and task
``nodes`` are denominated in *node units* while engines count *batching
slots*; a slot of a width-``w`` tenant costs ``w`` units. The PR 5 bug
class was exactly `active_slots <= granted_units` comparisons that were
only correct at width 1. This rule classifies identifiers by lexicon
(``tools.dclint.config``: ``active``/``*_slots`` are slots; ``owned``/
``granted``/``capacity``/``*_units``/``*_nodes`` are units; ``width``/
``*_width`` are converters) and flags additive arithmetic or comparisons
whose operands classify as SLOT on one side and UNIT on the other.

Multiplying a slot quantity by a width converts it to units (and
dividing units by a width converts back); local assignments propagate
the classification, so::

    active = self.engine.active_count * self.slot_width   # -> UNIT
    if active > self.env.owned:                           # ok

passes, while::

    if self.engine.active_count > self.env.owned:         # DC401

is flagged. Fix pattern: weight by the tenant's width (or route through
a ``width_of(...)`` helper) before comparing.
"""
from __future__ import annotations

import ast

from tools.dclint import config

CODE = "DC401"
SUMMARY = ("slot-count and node-unit quantities mixed without a width "
           "conversion")

SLOT, UNIT, WIDTH = "slot-count", "node-unit", "width"


def _lex(name: str) -> str | None:
    if name in config.WIDTH_NAMES or name.endswith(config.WIDTH_SUFFIXES):
        return WIDTH
    if name in config.SLOT_NAMES or name.endswith(config.SLOT_SUFFIXES):
        return SLOT
    if name in config.UNIT_NAMES or name.endswith(config.UNIT_SUFFIXES):
        return UNIT
    return None


def _mix(a: str | None, b: str | None) -> bool:
    return {a, b} == {SLOT, UNIT}


class _FnChecker(ast.NodeVisitor):
    """One function scope: forward-order classification with assignment
    taint (a local assigned a units expression stays units even if its
    name reads slot-ish, and vice versa)."""

    def __init__(self, report):
        self.env: dict[str, str | None] = {}
        self.report = report

    # ------------------------------------------------- classification
    def classify(self, node: ast.AST) -> str | None:
        if isinstance(node, ast.Name):
            if node.id in self.env:
                return self.env[node.id]
            return _lex(node.id)
        if isinstance(node, ast.Attribute):
            return _lex(node.attr)
        if isinstance(node, ast.Subscript):
            return self.classify(node.value)
        if isinstance(node, ast.UnaryOp):
            return self.classify(node.operand)
        if isinstance(node, ast.Call):
            func = node.func
            name = (func.attr if isinstance(func, ast.Attribute)
                    else func.id if isinstance(func, ast.Name) else None)
            if name in config.WIDTH_CALLS:
                return WIDTH
            return None
        if isinstance(node, ast.IfExp):
            a, b = self.classify(node.body), self.classify(node.orelse)
            return a if a == b else None
        if isinstance(node, ast.BinOp):
            left = self.classify(node.left)
            right = self.classify(node.right)
            if isinstance(node.op, ast.Mult):
                if WIDTH in (left, right):
                    other = right if left == WIDTH else left
                    return WIDTH if other == WIDTH else UNIT
                if UNIT in (left, right):
                    return UNIT
                if SLOT in (left, right):
                    return SLOT
                return None
            if isinstance(node.op, (ast.Div, ast.FloorDiv)):
                if left == UNIT and right == WIDTH:
                    return SLOT
                return left
            if isinstance(node.op, (ast.Add, ast.Sub)):
                if _mix(left, right):
                    self.report(node, left, right)
                return (UNIT if UNIT in (left, right)
                        else SLOT if SLOT in (left, right) else None)
            return None
        return None

    # ------------------------------------------------------ statements
    def visit_Compare(self, node: ast.Compare) -> None:
        operands = [node.left] + list(node.comparators)
        classes = [self.classify(o) for o in operands]
        for (a, an), (b, bn) in zip(zip(classes, operands),
                                    zip(classes[1:], operands[1:])):
            if _mix(a, b):
                self.report(node, a, b)
                break
        self.generic_visit(node)

    def visit_BinOp(self, node: ast.BinOp) -> None:
        self.classify(node)          # reports additive mixes
        self.generic_visit(node)

    def _bind(self, target: ast.AST, cls: str | None) -> None:
        if isinstance(target, ast.Name):
            self.env[target.id] = cls
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._bind(elt, None)

    def visit_Assign(self, node: ast.Assign) -> None:
        self.generic_visit(node)
        cls = self.classify(node.value)
        for tgt in node.targets:
            self._bind(tgt, cls)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        self.generic_visit(node)
        if node.value is not None:
            self._bind(node.target, self.classify(node.value))

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self.generic_visit(node)
        if isinstance(node.op, (ast.Add, ast.Sub)):
            tcls = self.classify(node.target)
            vcls = self.classify(node.value)
            if _mix(tcls, vcls):
                self.report(node, tcls, vcls)

    def visit_For(self, node: ast.For) -> None:
        self._bind(node.target, None)
        self.generic_visit(node)

    def visit_FunctionDef(self, node) -> None:
        pass                          # nested defs get their own scope

    visit_AsyncFunctionDef = visit_FunctionDef
    visit_Lambda = visit_FunctionDef


def check(tree: ast.AST, src_lines: list[str], rel: str):
    found: list[tuple[int, int, str]] = []
    seen: set[tuple[int, int]] = set()

    def report(node: ast.AST, a: str | None, b: str | None) -> None:
        key = (node.lineno, node.col_offset)
        if key in seen:
            return
        seen.add(key)
        expr = ast.unparse(node)
        if len(expr) > 60:
            expr = expr[:57] + "..."
        found.append((node.lineno, node.col_offset,
                      f"`{expr}` mixes a {SLOT} with a {UNIT} without a "
                      f"width conversion (multiply slots by the tenant "
                      f"width, or divide units by it, first)"))

    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            checker = _FnChecker(report)
            for stmt in node.body:
                checker.visit(stmt)
    yield from sorted(found)
