"""DC401 — slot, unit and page quantities must not mix unconverted.

Since PR 5, provider grants, ``RuntimeEnv.owned``/``busy`` and task
``nodes`` are denominated in *node units* while engines count *batching
slots*; a slot of a width-``w`` tenant costs ``w`` units. The PR 5 bug
class was exactly `active_slots <= granted_units` comparisons that were
only correct at width 1. PR 8 adds a third denomination: physical
KV-cache *pages* (``used_pages``, ``n_pages``...), reached from slots or
units through a page rate (``pages_per_slot``, ``pages_per_unit``).

This rule classifies identifiers by lexicon (``tools.dclint.config``)
and flags additive arithmetic or comparisons whose operands classify as
two *different* count denominations (slot/unit, slot/page or unit/page).

Conversions are multiplicative: a slot count times a width is units,
dividing units by a width goes back; a slot or unit count times a page
rate is pages, and a width times a per-unit rate is a per-slot rate.
Local assignments propagate the classification, so::

    active = self.engine.active_count * self.slot_width   # -> UNIT
    if active > self.env.owned:                           # ok
    quota = self.env.granted * self.pager.pages_per_unit  # -> PAGE
    if self.pager.used_pages > quota:                     # ok

pass, while::

    if self.engine.active_count > self.env.owned:         # DC401
    if self.pager.used_pages > self.env.granted:          # DC401

are flagged. Fix pattern: weight by the tenant's width or page rate (or
route through a ``width_of(...)`` helper) before comparing.
"""
from __future__ import annotations

import ast

from tools.dclint import config

CODE = "DC401"
SUMMARY = ("slot-count, node-unit and page-count quantities mixed without "
           "a width or page-rate conversion")

SLOT, UNIT, WIDTH = "slot-count", "node-unit", "width"
PAGE, RATE = "page-count", "page-rate"
_COUNTS = (SLOT, UNIT, PAGE)


def _lex(name: str) -> str | None:
    if name in config.RATE_NAMES or name.endswith(config.RATE_SUFFIXES):
        return RATE
    if name in config.WIDTH_NAMES or name.endswith(config.WIDTH_SUFFIXES):
        return WIDTH
    if name in config.PAGE_NAMES or name.endswith(config.PAGE_SUFFIXES):
        return PAGE
    if name in config.SLOT_NAMES or name.endswith(config.SLOT_SUFFIXES):
        return SLOT
    if name in config.UNIT_NAMES or name.endswith(config.UNIT_SUFFIXES):
        return UNIT
    return None


def _mix(a: str | None, b: str | None) -> bool:
    return a != b and a in _COUNTS and b in _COUNTS


class _FnChecker(ast.NodeVisitor):
    """One function scope: forward-order classification with assignment
    taint (a local assigned a units expression stays units even if its
    name reads slot-ish, and vice versa)."""

    def __init__(self, report):
        self.env: dict[str, str | None] = {}
        self.report = report

    # ------------------------------------------------- classification
    def classify(self, node: ast.AST) -> str | None:
        if isinstance(node, ast.Name):
            if node.id in self.env:
                return self.env[node.id]
            return _lex(node.id)
        if isinstance(node, ast.Attribute):
            return _lex(node.attr)
        if isinstance(node, ast.Subscript):
            return self.classify(node.value)
        if isinstance(node, ast.UnaryOp):
            return self.classify(node.operand)
        if isinstance(node, ast.Call):
            func = node.func
            name = (func.attr if isinstance(func, ast.Attribute)
                    else func.id if isinstance(func, ast.Name) else None)
            if name in config.WIDTH_CALLS:
                return WIDTH
            return None
        if isinstance(node, ast.IfExp):
            a, b = self.classify(node.body), self.classify(node.orelse)
            return a if a == b else None
        if isinstance(node, ast.BinOp):
            left = self.classify(node.left)
            right = self.classify(node.right)
            if isinstance(node.op, ast.Mult):
                if RATE in (left, right):
                    other = right if left == RATE else left
                    if other in (SLOT, UNIT):
                        return PAGE          # count * pages-per-count
                    if other == WIDTH:
                        return RATE          # units/slot * pages/unit
                    return None
                if WIDTH in (left, right):
                    other = right if left == WIDTH else left
                    if other == WIDTH:
                        return WIDTH
                    return PAGE if other == PAGE else UNIT
                if PAGE in (left, right):
                    return PAGE
                if UNIT in (left, right):
                    return UNIT
                if SLOT in (left, right):
                    return SLOT
                return None
            if isinstance(node.op, (ast.Div, ast.FloorDiv)):
                if left == UNIT and right == WIDTH:
                    return SLOT
                if left == PAGE and right == RATE:
                    # pages / pages_per_X -> X; which X is ambiguous here
                    return None
                return left
            if isinstance(node.op, (ast.Add, ast.Sub)):
                if _mix(left, right):
                    self.report(node, left, right)
                return (PAGE if PAGE in (left, right)
                        else UNIT if UNIT in (left, right)
                        else SLOT if SLOT in (left, right) else None)
            return None
        return None

    # ------------------------------------------------------ statements
    def visit_Compare(self, node: ast.Compare) -> None:
        operands = [node.left] + list(node.comparators)
        classes = [self.classify(o) for o in operands]
        for (a, an), (b, bn) in zip(zip(classes, operands),
                                    zip(classes[1:], operands[1:])):
            if _mix(a, b):
                self.report(node, a, b)
                break
        self.generic_visit(node)

    def visit_BinOp(self, node: ast.BinOp) -> None:
        self.classify(node)          # reports additive mixes
        self.generic_visit(node)

    def _bind(self, target: ast.AST, cls: str | None) -> None:
        if isinstance(target, ast.Name):
            self.env[target.id] = cls
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._bind(elt, None)

    def visit_Assign(self, node: ast.Assign) -> None:
        self.generic_visit(node)
        cls = self.classify(node.value)
        for tgt in node.targets:
            self._bind(tgt, cls)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        self.generic_visit(node)
        if node.value is not None:
            self._bind(node.target, self.classify(node.value))

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self.generic_visit(node)
        if isinstance(node.op, (ast.Add, ast.Sub)):
            tcls = self.classify(node.target)
            vcls = self.classify(node.value)
            if _mix(tcls, vcls):
                self.report(node, tcls, vcls)

    def visit_For(self, node: ast.For) -> None:
        self._bind(node.target, None)
        self.generic_visit(node)

    def visit_FunctionDef(self, node) -> None:
        pass                          # nested defs get their own scope

    visit_AsyncFunctionDef = visit_FunctionDef
    visit_Lambda = visit_FunctionDef


def check(tree: ast.AST, src_lines: list[str], rel: str):
    found: list[tuple[int, int, str]] = []
    seen: set[tuple[int, int]] = set()

    def report(node: ast.AST, a: str | None, b: str | None) -> None:
        key = (node.lineno, node.col_offset)
        if key in seen:
            return
        seen.add(key)
        expr = ast.unparse(node)
        if len(expr) > 60:
            expr = expr[:57] + "..."
        found.append((node.lineno, node.col_offset,
                      f"`{expr}` mixes a {a} with a {b} without a "
                      f"conversion (weight by the tenant width or page "
                      f"rate so both sides share a denomination)"))

    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            checker = _FnChecker(report)
            for stmt in node.body:
                checker.visit(stmt)
    yield from sorted(found)
