"""DC301 — grant callbacks must not re-enter the provider ledger.

``ResourceProvider._drain`` walks its admission queue invoking parked
requests' ``on_grant`` callbacks (and ``RuntimeEnv`` forwards grants to a
``grant_listener``). A callback that calls ``request``/``release``/
``amend``/``cancel``/``submit_request`` on the provision service — or
mutates ledger state directly — mutates the very queue/ledger the drain
is iterating. PR 5 pinned this hazard with a hypothesis property
(on_grant amending/cancelling OTHER parked requests); this rule rejects
the code shape outright.

Detection is a lightweight intra-module call-graph walk: roots are
functions passed as ``on_grant=`` keyword arguments, assigned to a
``.grant_listener`` attribute, or named ``on_grant``; edges are direct
calls to module-level functions or ``self.`` methods. Flagged inside the
reachable set: provider/provision-receiver calls to the ledger-mutating
API, and direct writes to ledger attributes (``allocated``,
``open_leases``, ``admission_queue``, ...).

Fix pattern: a callback validates the offer against live need, commits
*its own* bookkeeping, and returns the accepted amount — deferring any
further provider traffic to the next scan tick (see
``RuntimeEnv._apply_grant``).
"""
from __future__ import annotations

import ast

CODE = "DC301"
SUMMARY = ("provider ledger re-entered from an on_grant/grant_listener "
           "callback (the provider may be mid-drain)")

_BANNED_METHODS = frozenset({"request", "release", "amend", "cancel",
                             "submit_request"})
_LEDGER_ATTRS = frozenset({"allocated", "open_leases", "closed_leases",
                           "admission_queue", "adjust_events",
                           "_alloc_curve"})
_PROVIDERISH = ("provision", "provider")


def _chain_names(node: ast.AST) -> list[str]:
    """Name segments of an attribute/subscript chain, outermost first."""
    names: list[str] = []
    while True:
        if isinstance(node, ast.Attribute):
            names.append(node.attr)
            node = node.value
        elif isinstance(node, ast.Subscript):
            node = node.value
        elif isinstance(node, ast.Call):
            node = node.func
        elif isinstance(node, ast.Name):
            names.append(node.id)
            return names
        else:
            return names


def _provider_receiver(names: list[str]) -> bool:
    return any(any(p in seg for p in _PROVIDERISH) for seg in names)


def _callee_name(node: ast.AST) -> str | None:
    """Function a call/reference resolves to, as a bare name."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return None


class _Module:
    """Defs, callback roots and call edges of one module."""

    def __init__(self, tree: ast.AST):
        self.defs: dict[str, list[ast.AST]] = {}
        self.roots: dict[str, str] = {}   # fn name -> how it became a root
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.defs.setdefault(node.name, []).append(node)
                if node.name == "on_grant":
                    self.roots.setdefault(node.name, "def on_grant")
            elif isinstance(node, ast.Call):
                for kw in node.keywords:
                    if kw.arg == "on_grant":
                        n = _callee_name(kw.value)
                        if n:
                            self.roots.setdefault(n, "passed as on_grant=")
            elif isinstance(node, ast.Assign):
                for tgt in node.targets:
                    if (isinstance(tgt, ast.Attribute)
                            and tgt.attr == "grant_listener"):
                        n = _callee_name(node.value)
                        if n:
                            self.roots.setdefault(
                                n, "assigned to .grant_listener")

    def edges(self, fn: ast.AST) -> set[str]:
        out: set[str] = set()
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            func = node.func
            if isinstance(func, ast.Name):
                out.add(func.id)
            elif (isinstance(func, ast.Attribute)
                  and isinstance(func.value, ast.Name)
                  and func.value.id in ("self", "cls")):
                out.add(func.attr)
            # functools.partial(self._fn, ...) keeps the edge
            name = _callee_name(func)
            if name == "partial" and node.args:
                target = _callee_name(node.args[0])
                if target:
                    out.add(target)
        return out


def check(tree: ast.AST, src_lines: list[str], rel: str):
    mod = _Module(tree)
    if not mod.roots:
        return
    # BFS over the intra-module call graph, remembering one call path
    # per function for the diagnostic
    paths: dict[str, tuple[str, ...]] = {}
    queue: list[str] = []
    for root in mod.roots:
        if root in mod.defs and root not in paths:
            paths[root] = (root,)
            queue.append(root)
    while queue:
        name = queue.pop()
        for fn in mod.defs.get(name, ()):
            for callee in mod.edges(fn):
                if callee in mod.defs and callee not in paths:
                    paths[callee] = paths[name] + (callee,)
                    queue.append(callee)

    seen: set[tuple[int, int]] = set()
    for name, path in sorted(paths.items()):
        root = path[0]
        via = (" via " + " -> ".join(path)) if len(path) > 1 else ""
        how = mod.roots[root]
        for fn in mod.defs.get(name, ()):
            for node in ast.walk(fn):
                if isinstance(node, ast.Call):
                    callee = _callee_name(node.func)
                    if (callee in _BANNED_METHODS
                            and isinstance(node.func, ast.Attribute)
                            and _provider_receiver(
                                _chain_names(node.func.value))):
                        key = (node.lineno, node.col_offset)
                        if key not in seen:
                            seen.add(key)
                            yield (node.lineno, node.col_offset,
                                   f"`{ast.unparse(node.func)}()` called "
                                   f"from grant callback `{root}` "
                                   f"({how}){via}: the provider may be "
                                   f"mid-drain; defer to the next scan")
                elif isinstance(node, (ast.Assign, ast.AugAssign,
                                       ast.Delete)):
                    targets = (node.targets if isinstance(node, ast.Assign)
                               else node.targets if isinstance(node,
                                                               ast.Delete)
                               else [node.target])
                    for tgt in targets:
                        names = _chain_names(tgt)
                        hit = _LEDGER_ATTRS.intersection(names)
                        if hit:
                            key = (node.lineno, node.col_offset)
                            if key not in seen:
                                seen.add(key)
                                yield (node.lineno, node.col_offset,
                                       f"ledger state `{sorted(hit)[0]}` "
                                       f"mutated from grant callback "
                                       f"`{root}` ({how}){via}: the "
                                       f"drain loop iterates this state")
