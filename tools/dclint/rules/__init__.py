"""Rule registry. Each rule module exposes ``CODE``, ``SUMMARY`` and
``check(tree, src_lines, rel_path) -> iterable[(line, col, message)]``;
scoping and pragma/baseline handling live in the driver. Flow-based
rules additionally expose ``check_project(project, tree, src_lines,
rel_path)`` — the driver prefers it and passes the shared
:class:`tools.dclint.flow.Project` built over every file being linted,
so interprocedural analyses see the whole control plane at once."""
from __future__ import annotations

from tools.dclint.rules import (
    dc101_invariant_assert,
    dc201_determinism,
    dc301_drain_reentrancy,
    dc302_reentrancy_soundness,
    dc401_unit_discipline,
    dc501_tracer_safety,
    dc601_phase_discipline,
)

RULES = {
    mod.CODE: mod
    for mod in (
        dc101_invariant_assert,
        dc201_determinism,
        dc301_drain_reentrancy,
        dc302_reentrancy_soundness,
        dc401_unit_discipline,
        dc501_tracer_safety,
        dc601_phase_discipline,
    )
}
