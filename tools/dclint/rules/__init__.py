"""Rule registry. Each rule module exposes ``CODE``, ``SUMMARY`` and
``check(tree, src_lines, rel_path) -> iterable[(line, col, message)]``;
scoping and pragma/baseline handling live in the driver."""
from __future__ import annotations

from tools.dclint.rules import (
    dc101_invariant_assert,
    dc201_determinism,
    dc301_drain_reentrancy,
    dc401_unit_discipline,
    dc501_tracer_safety,
)

RULES = {
    mod.CODE: mod
    for mod in (
        dc101_invariant_assert,
        dc201_determinism,
        dc301_drain_reentrancy,
        dc401_unit_discipline,
        dc501_tracer_safety,
    )
}
