"""DC201 — no wall clock or global RNG state in the deterministic core.

Replay parity (emulator-vs-serve bit-parity, ``ServeFleet(N=1)`` ==
``ServeDriver``) and the bench regression gate both depend on runs being
pure functions of their seeds. Wall-clock reads (``time.time()``,
``datetime.now()``) and module-state RNGs (``random.random()``,
``np.random.rand()``/``np.random.seed()``) break that: the same seed no
longer reproduces the same artifact, and the history-window gate compares
noise. ``launch/`` is exempt via config (run dirs and progress logs may
read the clock); benchmarks measuring wall-clock *performance* use
``time.perf_counter()``, which is explicitly a duration clock and is not
flagged.

Fix pattern: thread a seeded ``np.random.default_rng(seed)`` /
``random.Random(seed)`` through, take sim time from the driver's
``Clock``, and time perf with ``time.perf_counter()``.
"""
from __future__ import annotations

import ast

CODE = "DC201"
SUMMARY = ("wall-clock or global-RNG call in deterministic scope; "
           "use a seeded rng / driver clock / perf_counter")

# attr called on the `time` module
_TIME_BANNED = {"time", "time_ns", "localtime", "gmtime", "ctime"}
# attr called on `datetime`/`datetime.datetime`/`datetime.date`
_DATETIME_BANNED = {"now", "utcnow", "today"}
# module-state constructors that are fine on `random`
_RANDOM_ALLOWED = {"Random", "SystemRandom", "getstate", "setstate"}
# seeded-generator API that is fine on `np.random`
_NP_RANDOM_ALLOWED = {"default_rng", "Generator", "SeedSequence", "PCG64",
                      "Philox", "MT19937", "BitGenerator", "RandomState"}
# NB: RandomState(seed) is an explicitly seeded legacy generator object,
# not module state — allowed.


def _dotted(node: ast.AST) -> str | None:
    """'a.b.c' for a Name/Attribute chain, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def check(tree: ast.AST, src_lines: list[str], rel: str):
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            dotted = _dotted(node.func)
            if dotted is None:
                continue
            parts = dotted.split(".")
            head, tail = parts[0], parts[-1]
            if head == "time" and len(parts) == 2 and tail in _TIME_BANNED:
                yield (node.lineno, node.col_offset,
                       f"`{dotted}()` reads the wall clock; replay "
                       f"determinism requires driver-clock time "
                       f"(perf timing: use time.perf_counter())")
            elif (tail in _DATETIME_BANNED and len(parts) >= 2
                  and parts[-2] in ("datetime", "date")):
                yield (node.lineno, node.col_offset,
                       f"`{dotted}()` reads the wall clock; thread sim "
                       f"time from the driver's Clock instead")
            elif (head == "random" and len(parts) == 2
                  and tail not in _RANDOM_ALLOWED):
                yield (node.lineno, node.col_offset,
                       f"`{dotted}()` mutates/reads global RNG state; "
                       f"use a seeded random.Random(seed) instance")
            elif (len(parts) >= 3 and parts[-2] == "random"
                  and parts[-3] in ("np", "numpy")):
                if tail not in _NP_RANDOM_ALLOWED:
                    yield (node.lineno, node.col_offset,
                           f"`{dotted}()` uses numpy's global RNG state; "
                           f"use np.random.default_rng(seed)")
                elif (tail == "default_rng"
                      and not node.args and not node.keywords):
                    # allowed constructor, but with no seed it draws one
                    # from OS entropy — exactly the nondeterminism the
                    # seeded-generator idiom exists to avoid
                    yield (node.lineno, node.col_offset,
                           f"`{dotted}()` without a seed is entropy-"
                           f"seeded; pass an explicit seed")
        elif isinstance(node, ast.ImportFrom):
            if node.module == "time":
                for a in node.names:
                    if a.name in _TIME_BANNED:
                        yield (node.lineno, node.col_offset,
                               f"`from time import {a.name}` imports a "
                               f"wall-clock read into deterministic scope")
            elif node.module == "random":
                for a in node.names:
                    if a.name not in _RANDOM_ALLOWED and a.name != "*":
                        yield (node.lineno, node.col_offset,
                               f"`from random import {a.name}` imports "
                               f"global-RNG-state access; use "
                               f"random.Random(seed)")
