"""DC302 — re-entrancy soundness of grant-callback field writes.

DC301 bans *API re-entry* (``request``/``release``/``amend``/``cancel``)
from grant callbacks, intra-module. DC302 closes the remaining hole with
the flow layer: any method reachable — project-wide, through the call
graph's ``on_grant=``/``grant_listener`` callback edges — from a grant
callback must not *write a ledger field that* ``ResourceProvider._drain``
*'s loop reads*. The drain is iterating ``admission_queue`` and judging
offers against ``headroom()`` (``allocated``/``quotas``/``reservations``/
``capacity``) while the callback runs; a direct field write (assignment,
``del``, or an in-place container mutation like ``admission_queue.
remove(...)``) corrupts the very state the loop is walking. Writes to a
parked request's own arbitration fields (``status``/``nodes``/
``min_useful``/``priority``) are the same hazard — the loop re-reads
them every grant round.

The read set is *computed* from the project — ``_drain`` plus its
self-call closure — so the rule tracks the drain loop as it evolves; the
``PagedKVAllocator`` page-ledger fields ride along as a fixed lexicon
(``check_conservation`` sweeps them between ticks the same way).

The documented mutation channel is exempt by construction: the
amend/cancel/release API *bodies* live in the provider class family
(``ProvisionService``/``ResourceProvider``/``PagedKVAllocator`` or any
class defining ``_drain``), and DC302 never flags writes inside that
family — those methods are the ledger's own, maintained to be
drain-consistent. Callbacks reach them only through calls, which DC301
already polices.

Fix pattern: defer the mutation — validate the offer, commit tenant-
local bookkeeping, and park any provider traffic on a post-drain
application list (``dclint --fix`` performs exactly this hoist for
statement-level DC301 offenders; see ``tools/dclint/fix.py``).
"""
from __future__ import annotations

import ast

from tools.dclint.flow.dataflow import attr_writes, mutating_calls
from tools.dclint.flow.project import Project

CODE = "DC302"
SUMMARY = ("grant-callback-reachable code writes a ledger field the "
           "provider drain loop reads")

#: class names whose internals ARE the documented mutation API
_KNOWN_LEDGER_CLASSES = frozenset({
    "ProvisionService", "ResourceProvider", "PagedKVAllocator",
})
#: page-ledger fields of the paged allocator (conservation-swept)
_PAGER_LEDGER = frozenset({
    "_free", "_owned", "_tenant_of", "_quota", "peak_used",
})
#: parked-request fields the drain loop re-reads every round
_REQ_ATTRS = frozenset({"status", "nodes", "min_useful", "priority"})
_PROVIDERISH = ("provision", "provider", "pager")


def _providerish(chain) -> bool:
    return any(p in seg for p in _PROVIDERISH for seg in chain)


def _reqish(chain) -> bool:
    return any("req" in seg for seg in chain)


def _ledger_class_names(project: Project) -> set:
    names = set(_KNOWN_LEDGER_CLASSES)
    for infos in project.classes.values():
        for ci in infos:
            if "_drain" in ci.methods:
                names.add(ci.name)
                names.update(m.name for m in project.mro(ci.name))
    return names


def _analyze(project: Project) -> list:
    """Full-project findings, memoized on the project:
    ``(rel, line, col, message)`` rows."""
    if "dc302" in project._cache:
        return project._cache["dc302"]
    findings: list = []
    roots: set = set()
    for targets in project.callback_targets.values():
        roots |= targets
    if roots:
        exempt = _ledger_class_names(project)
        ledger = project.drain_read_attrs() | _PAGER_LEDGER
        closure = project.reachable(roots)
        for fi, path in sorted(closure.items(), key=lambda kv: kv[0].key):
            if fi.cls in exempt:
                continue
            via = (" via " + " -> ".join(path)) if len(path) > 1 else ""
            root = path[0]

            def flag(node, what):
                findings.append((
                    fi.rel, node.lineno, node.col_offset,
                    f"{what} in `{fi.qualname}`, reachable from grant "
                    f"callback `{root}`{via}: the provider may be "
                    f"mid-drain and its loop reads this state — go "
                    f"through the amend/cancel/release API, or defer "
                    f"to a post-drain list"))

            for chain, attr, node in attr_writes(fi.node):
                if attr in ledger and _providerish(chain):
                    flag(node, f"ledger field `{attr}` written")
                elif attr in _REQ_ATTRS and _reqish(chain):
                    flag(node, f"parked-request field `{attr}` written")
            for chain, meth, node in mutating_calls(fi.node):
                touched = ledger.intersection(chain)
                if touched and _providerish(chain):
                    flag(node, f"ledger field `{sorted(touched)[0]}` "
                               f"mutated in place (`.{meth}()`)")
    findings.sort()
    project._cache["dc302"] = findings
    return findings


def check_project(project: Project, tree: ast.AST, src_lines, rel):
    for frel, line, col, msg in _analyze(project):
        if frel == rel:
            yield line, col, msg


def check(tree: ast.AST, src_lines, rel):
    """Single-file fallback (no project handed in): analyze this module
    as a one-file project."""
    yield from check_project(Project({rel: tree}), tree, src_lines, rel)
