"""``dclint --fix``: the mechanical DC101 and DC201 rewrites.

DC101's fix pattern (assert -> guarded raise) is purely syntactic, so
the linter can apply it::

    assert COND              ->  if not COND:
                                     raise RuntimeError(
                                         'invariant violated: COND')
    assert COND, "msg"       ->  if not COND:
                                     raise RuntimeError('msg')
    assert COND, EXPR        ->  if not COND:
                                     raise RuntimeError(
                                         'invariant violated: COND: '
                                         + repr(EXPR))
    assert not COND          ->  if COND: ...   (double negation stripped)

The guard is always ``not COND`` — never an inverted comparison — because
comparison inversion is not semantics-preserving (``not (a <= b)`` differs
from ``a > b`` under NaN). Non-string messages go through ``repr`` rather
than an f-string so ``ast.unparse`` never has to re-quote the expression
inside a format literal (fragile before 3.12).

DC201's numpy global-RNG findings are equally mechanical::

    np.random.default_rng()  ->  np.random.default_rng(0)
    np.random.rand(3, 4)     ->  np.random.default_rng(0).random((3, 4))
    np.random.randn(8)       ->  np.random.default_rng(0).standard_normal(8)
    np.random.randint(0, 9)  ->  np.random.default_rng(0).integers(0, 9)
    np.random.choice(a, 3)   ->  np.random.default_rng(0).choice(a, 3)

The legacy varargs shapes of ``rand``/``randn`` become one shape tuple;
every other mapped method keeps its arguments verbatim (the ``Generator``
signatures are compatible). The seed constant 0 makes the call
deterministic and GREPPABLE — a review decides whether 0 is the right
seed or a threaded one, which is exactly the DC201 fix pattern's intent.
Unmapped methods (``np.random.seed``, bit-generator state pokes) and the
wall-clock findings are left flagged for a human. Only *pure* numpy-RNG
expressions are spliced: calls spanning multiple lines, calls nested in
another flagged call, or calls sharing a line with a flagged assert are
skipped this pass (a second ``--fix`` run converges).

DC301's re-entrant provider calls get the flow-analysis hoist the
ROADMAP carried: a statement-level banned call inside a grant callback
(or code it reaches) is deferred onto a post-drain application list::

    self.provision.amend(req, n, t)   # mid-drain: DC301

    ->  self._post_drain = getattr(self, '_post_drain', [])
        self._post_drain.append(
            lambda _f=self.provision.amend, _a=(req, n, t): _f(*_a))

The callee and its arguments are captured *at the callback's own
position* through lambda defaults, so the deferred application sees
exactly the values the re-entrant call would have — the driver applies
the list (``for f in tre._post_drain: f()``) after the triggering
provider call returns, i.e. after ``_drain`` has unwound. The rewrite
is guarded by the CFG: it is only applied when no statement reachable
*after* the offender (rest of its basic block plus every reachable
block — ``flow.cfg.nodes_after``) reads provider/ledger or parked-
request state, because such a read would observe the pre-mutation
ledger once the call is deferred. Offenders that fail the guard, sit
mid-expression, use ``*args``/``**kwargs``, or live outside a method
are skipped for a human.

Only findings the linter itself reports are rewritten — the fix is driven
from ``lint_file`` output, so rule scoping and ``# dclint: disable``
pragmas are honored for free. Asserts that do not start their line
(``if x: assert y``) are skipped and left flagged for a human. Rewrites
are applied bottom-up so earlier positions stay valid; fixed findings
then show up as *stale* baseline entries, which the CLI prunes.
Every fixer is idempotent: its output re-lints clean for the code it
rewrote, so a second ``--fix`` pass finds nothing left to do.
"""
from __future__ import annotations

import ast
from pathlib import Path

from tools.dclint import REPO_ROOT, lint_file
from tools.dclint.flow.cfg import build_cfg, evaluated_parts
from tools.dclint.flow.dataflow import attr_loads

__all__ = ["fix_file", "fix_paths"]

#: receiver segments / attrs whose post-statement reads veto a deferral
_PROVIDERISH = ("provision", "provider", "pager")
_REQ_ATTRS = frozenset({"status", "nodes", "min_useful", "priority",
                        "granted"})

#: legacy ``np.random.<fn>`` -> seeded ``Generator.<method>`` (argument
#: lists pass through verbatim; ``rand``/``randn`` varargs are tupled)
NP_FN_MAP = {
    "rand": "random", "randn": "standard_normal", "randint": "integers",
    "random_sample": "random", "random": "random", "choice": "choice",
    "shuffle": "shuffle", "permutation": "permutation",
    "uniform": "uniform", "normal": "normal",
    "standard_normal": "standard_normal", "exponential": "exponential",
    "lognormal": "lognormal", "poisson": "poisson", "gamma": "gamma",
    "beta": "beta", "binomial": "binomial", "bytes": "bytes",
}
_DIMS_TUPLED = {"rand", "randn"}         # *dims varargs -> one shape tuple


def _guarded_raise(node: ast.Assert) -> str:
    """Render the replacement ``if``/``raise`` block (no indentation)."""
    test = node.test
    if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
        cond: ast.expr = test.operand          # assert not X  ->  if X:
    else:
        cond = ast.UnaryOp(op=ast.Not(), operand=test)
    cond_text = ast.unparse(node.test)
    msg = node.msg
    if msg is None:
        msg = ast.Constant(f"invariant violated: {cond_text}")
    elif not (isinstance(msg, ast.JoinedStr)
              or (isinstance(msg, ast.Constant) and isinstance(msg.value, str))):
        msg = ast.BinOp(
            left=ast.Constant(f"invariant violated: {cond_text}: "),
            op=ast.Add(),
            right=ast.Call(func=ast.Name(id="repr", ctx=ast.Load()),
                           args=[msg], keywords=[]))
    guard = ast.If(
        test=cond,
        body=[ast.Raise(
            exc=ast.Call(func=ast.Name(id="RuntimeError", ctx=ast.Load()),
                         args=[msg], keywords=[]),
            cause=None)],
        orelse=[])
    return ast.unparse(ast.fix_missing_locations(guard))


def _dotted(node: ast.AST) -> str | None:
    """'a.b.c' for a Name/Attribute chain, else None (the DC201 rule's
    resolver, re-stated so the fixer matches what the rule flagged)."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _seeded_rng_call(node: ast.Call) -> str | None:
    """The seeded-generator replacement text for one flagged numpy-RNG
    call, or None when the call has no mechanical rewrite."""
    dotted = _dotted(node.func)
    if dotted is None:
        return None
    parts = dotted.split(".")
    if (len(parts) < 3 or parts[-2] != "random"
            or parts[-3] not in ("np", "numpy")):
        return None                      # a wall-clock/stdlib finding
    prefix = ".".join(parts[:-1])        # 'np.random' as written
    tail = parts[-1]
    if tail == "default_rng":
        if node.args or node.keywords:
            return None                  # already seeded — not ours
        return f"{prefix}.default_rng(0)"
    method = NP_FN_MAP.get(tail)
    if method is None:
        return None                      # np.random.seed & friends
    if tail in _DIMS_TUPLED:
        if node.keywords or any(isinstance(a, ast.Starred)
                                for a in node.args):
            return None
        if not node.args:
            arg_text = ""
        elif len(node.args) == 1:
            arg_text = ast.unparse(node.args[0])
        else:
            arg_text = ("(" + ", ".join(ast.unparse(a) for a in node.args)
                        + ")")
    else:
        if any(isinstance(a, ast.Starred) for a in node.args):
            return None
        pieces = [ast.unparse(a) for a in node.args]
        pieces += [(f"**{ast.unparse(kw.value)}" if kw.arg is None
                    else f"{kw.arg}={ast.unparse(kw.value)}")
                   for kw in node.keywords]
        arg_text = ", ".join(pieces)
    return f"{prefix}.default_rng(0).{method}({arg_text})"


def _post_drain_defer(call: ast.Call) -> str | None:
    """The deferral text for one banned provider call (no indentation),
    or None when the argument shape has no mechanical capture."""
    if any(isinstance(a, ast.Starred) for a in call.args):
        return None
    if any(kw.arg is None for kw in call.keywords):
        return None                      # **kwargs: order/content unknown
    func_src = ast.unparse(call.func)
    arg_text = ", ".join(ast.unparse(a) for a in call.args)
    tup = "(" + arg_text + ("," if len(call.args) == 1 else "") + ")"
    if call.keywords:
        kd = ("{" + ", ".join(f"'{kw.arg}': {ast.unparse(kw.value)}"
                              for kw in call.keywords) + "}")
        lam = f"lambda _f={func_src}, _a={tup}, _k={kd}: _f(*_a, **_k)"
    else:
        lam = f"lambda _f={func_src}, _a={tup}: _f(*_a)"
    return ("self._post_drain = getattr(self, '_post_drain', [])\n"
            "self._post_drain.append(\n"
            f"    {lam})")


def _defer_is_safe(fn: ast.AST, stmt: ast.stmt) -> bool:
    """True when nothing that may execute after ``stmt`` reads provider/
    ledger or parked-request state — the CFG condition under which
    moving the call's *effect* to post-drain is unobservable inside the
    callback's own frame."""
    cfg = build_cfg(fn)
    for node in cfg.nodes_after(stmt):
        for part in evaluated_parts(node):
            for chain, attr, _ in attr_loads(part):
                segs = (*chain, attr)
                if any(p in seg for seg in segs for p in _PROVIDERISH):
                    return False
                if (attr in _REQ_ATTRS
                        and any("req" in seg for seg in chain)):
                    return False
    return True


def fix_file(path: Path, *, root: Path | None = None) -> tuple[int, int]:
    """Rewrite flagged DC101 asserts, DC201 numpy-RNG calls and DC301
    re-entrant provider calls in ``path`` in place.

    -> ``(n_fixed, n_skipped)``; skipped findings are flagged but have
    no safe mechanical rewrite this pass (an assert not starting its
    line, a multi-line or nested RNG call, an unmapped RNG method, a
    provider call whose CFG downstream still reads provider state).
    """
    root = root or REPO_ROOT
    findings = lint_file(path, root=root)
    assert_lines = {v.line for v in findings if v.code == "DC101"}
    rng_marks = {(v.line, v.col) for v in findings if v.code == "DC201"}
    # only the *call* findings are hoistable; direct ledger writes have
    # no one mechanical deferral (the write may feed later reads)
    defer_marks = {(v.line, v.col) for v in findings
                   if v.code == "DC301"
                   and "called from grant callback" in v.message}
    if not assert_lines and not rng_marks and not defer_marks:
        return 0, 0
    src = path.read_text(encoding="utf-8")
    tree = ast.parse(src, filename=str(path))
    lines = src.splitlines(keepends=True)
    fixed = skipped = 0

    # --- DC201: splice seeded-generator expressions, innermost-last.
    # Offsets come from the original source, so a call nested inside
    # another flagged call (its span would go stale after the outer
    # splice) or sharing a line with a flagged assert (the DC101 block
    # rewrite re-renders the whole statement) is skipped this pass.
    calls = [n for n in ast.walk(tree) if isinstance(n, ast.Call)
             and (n.lineno, n.col_offset) in rng_marks]
    spans = {id(n): (n.lineno, n.col_offset, n.end_lineno,
                     n.end_col_offset) for n in calls}
    for node in sorted(calls, key=lambda n: (n.lineno, n.col_offset),
                       reverse=True):
        lo, lc, hi, hc = spans[id(node)]
        nested = any(o is not node
                     and spans[id(o)][:2] <= (lo, lc)
                     and (hi, hc) <= spans[id(o)][2:]
                     for o in calls)
        repl = _seeded_rng_call(node)
        if repl is None or lo != hi or nested or lo in assert_lines:
            skipped += 1
            continue
        raw = lines[lo - 1].encode("utf-8")    # ast cols are byte offsets
        lines[lo - 1] = (raw[:lc] + repl.encode("utf-8")
                         + raw[hc:]).decode("utf-8")
        fixed += 1

    # --- DC301: hoist banned provider calls onto the post-drain list.
    # Only whole-statement calls (`ast.Expr` wrapping the flagged Call)
    # qualify; the offender's innermost enclosing function must be a
    # method (`self` in scope to hold the list) and the CFG guard must
    # hold. Replacements are collected here and applied in the shared
    # bottom-up statement pass below (they change line counts).
    stmt_rewrites: list[tuple[ast.stmt, list[str]]] = []
    if defer_marks:
        fns = [n for n in ast.walk(tree)
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
        exprs = {(n.value.lineno, n.value.col_offset): n
                 for n in ast.walk(tree)
                 if isinstance(n, ast.Expr)
                 and isinstance(n.value, ast.Call)}
        for mark in sorted(defer_marks):
            stmt = exprs.get(mark)
            if stmt is None:                 # mid-expression offender
                skipped += 1
                continue
            enclosing = [f for f in fns
                         if f.lineno <= stmt.lineno
                         and stmt.end_lineno <= f.end_lineno]
            fn = max(enclosing, key=lambda f: f.lineno, default=None)
            indent = lines[stmt.lineno - 1][:stmt.col_offset]
            repl_src = _post_drain_defer(stmt.value)
            if (fn is None or not fn.args.args
                    or fn.args.args[0].arg != "self"
                    or indent.strip() or repl_src is None
                    or any(lo in range(stmt.lineno, stmt.end_lineno + 1)
                           for lo, _ in rng_marks)
                    or not _defer_is_safe(fn, stmt)):
                skipped += 1
                continue
            stmt_rewrites.append(
                (stmt, [indent + ln + "\n"
                        for ln in repl_src.splitlines()]))

    # --- DC101: statement-level assert -> guarded-raise block rewrites,
    # applied together with the DC301 deferrals, bottom-up.
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Assert)
                and node.lineno in assert_lines):
            continue
        indent = lines[node.lineno - 1][:node.col_offset]
        if indent.strip():
            skipped += 1
            continue
        stmt_rewrites.append(
            (node, [indent + ln + "\n"
                    for ln in _guarded_raise(node).splitlines()]))
    for node, repl in sorted(stmt_rewrites,
                             key=lambda t: t[0].lineno, reverse=True):
        lines[node.lineno - 1:node.end_lineno] = repl
        fixed += 1

    if fixed:
        path.write_text("".join(lines), encoding="utf-8")
    return fixed, skipped


def fix_paths(paths: list[Path], *, root: Path | None = None
              ) -> tuple[int, int]:
    """Apply :func:`fix_file` to every ``.py`` file under ``paths``."""
    root = root or REPO_ROOT
    files: list[Path] = []
    for p in paths:
        if p.is_dir():
            files.extend(sorted(q for q in p.rglob("*.py")
                                if "__pycache__" not in q.parts))
        elif p.suffix == ".py":
            files.append(p)
    fixed = skipped = 0
    for f in files:
        nf, ns = fix_file(f, root=root)
        fixed += nf
        skipped += ns
    return fixed, skipped
