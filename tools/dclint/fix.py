"""``dclint --fix``: the mechanical DC101 rewrite (assert -> guarded raise).

DC101's fix pattern is purely syntactic, so the linter can apply it::

    assert COND              ->  if not COND:
                                     raise RuntimeError(
                                         'invariant violated: COND')
    assert COND, "msg"       ->  if not COND:
                                     raise RuntimeError('msg')
    assert COND, EXPR        ->  if not COND:
                                     raise RuntimeError(
                                         'invariant violated: COND: '
                                         + repr(EXPR))
    assert not COND          ->  if COND: ...   (double negation stripped)

The guard is always ``not COND`` — never an inverted comparison — because
comparison inversion is not semantics-preserving (``not (a <= b)`` differs
from ``a > b`` under NaN). Non-string messages go through ``repr`` rather
than an f-string so ``ast.unparse`` never has to re-quote the expression
inside a format literal (fragile before 3.12).

Only findings the linter itself reports are rewritten — the fix is driven
from ``lint_file`` output, so rule scoping and ``# dclint: disable``
pragmas are honored for free. Asserts that do not start their line
(``if x: assert y``) are skipped and left flagged for a human. Rewrites
are applied bottom-up so earlier line numbers stay valid; fixed findings
then show up as *stale* baseline entries, which the CLI prunes.
"""
from __future__ import annotations

import ast
from pathlib import Path

from tools.dclint import REPO_ROOT, lint_file

__all__ = ["fix_file", "fix_paths"]


def _guarded_raise(node: ast.Assert) -> str:
    """Render the replacement ``if``/``raise`` block (no indentation)."""
    test = node.test
    if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
        cond: ast.expr = test.operand          # assert not X  ->  if X:
    else:
        cond = ast.UnaryOp(op=ast.Not(), operand=test)
    cond_text = ast.unparse(node.test)
    msg = node.msg
    if msg is None:
        msg = ast.Constant(f"invariant violated: {cond_text}")
    elif not (isinstance(msg, ast.JoinedStr)
              or (isinstance(msg, ast.Constant) and isinstance(msg.value, str))):
        msg = ast.BinOp(
            left=ast.Constant(f"invariant violated: {cond_text}: "),
            op=ast.Add(),
            right=ast.Call(func=ast.Name(id="repr", ctx=ast.Load()),
                           args=[msg], keywords=[]))
    guard = ast.If(
        test=cond,
        body=[ast.Raise(
            exc=ast.Call(func=ast.Name(id="RuntimeError", ctx=ast.Load()),
                         args=[msg], keywords=[]),
            cause=None)],
        orelse=[])
    return ast.unparse(ast.fix_missing_locations(guard))


def fix_file(path: Path, *, root: Path | None = None) -> tuple[int, int]:
    """Rewrite flagged DC101 asserts in ``path`` in place.

    -> ``(n_fixed, n_skipped)``; skipped asserts are flagged but not
    statement-initial on their line, so a block rewrite can't land.
    """
    root = root or REPO_ROOT
    flagged = {v.line for v in lint_file(path, root=root)
               if v.code == "DC101"}
    if not flagged:
        return 0, 0
    src = path.read_text(encoding="utf-8")
    tree = ast.parse(src, filename=str(path))
    lines = src.splitlines(keepends=True)
    targets = [n for n in ast.walk(tree)
               if isinstance(n, ast.Assert) and n.lineno in flagged]
    fixed = skipped = 0
    for node in sorted(targets, key=lambda n: n.lineno, reverse=True):
        indent = lines[node.lineno - 1][:node.col_offset]
        if indent.strip():
            skipped += 1
            continue
        repl = [indent + ln + "\n"
                for ln in _guarded_raise(node).splitlines()]
        lines[node.lineno - 1:node.end_lineno] = repl
        fixed += 1
    if fixed:
        path.write_text("".join(lines), encoding="utf-8")
    return fixed, skipped


def fix_paths(paths: list[Path], *, root: Path | None = None
              ) -> tuple[int, int]:
    """Apply :func:`fix_file` to every ``.py`` file under ``paths``."""
    root = root or REPO_ROOT
    files: list[Path] = []
    for p in paths:
        if p.is_dir():
            files.extend(sorted(q for q in p.rglob("*.py")
                                if "__pycache__" not in q.parts))
        elif p.suffix == ".py":
            files.append(p)
    fixed = skipped = 0
    for f in files:
        nf, ns = fix_file(f, root=root)
        fixed += nf
        skipped += ns
    return fixed, skipped
