"""Committed-baseline burn-down for legacy violations.

``tools/dclint/baseline.json`` holds fingerprints of known pre-existing
violations. Semantics:

- a current violation whose fingerprint is baselined is *suppressed*
  (reported in the summary as baselined, exit stays 0);
- a current violation NOT in the baseline **fails the run** — new debt
  is rejected at authoring time;
- a baselined fingerprint with no matching current violation is *stale*:
  the debt was paid. Stale entries are reported, and
  ``--update-baseline`` prunes them (it never adds entries unless
  ``--rebaseline`` is also given) — the baseline can only shrink in
  normal operation, which is what makes it a burn-down list rather
  than a mute button.

Fingerprints are line-number-free (code + path + offending source text),
so moving code does not invalidate the baseline but editing the
offending line does.
"""
from __future__ import annotations

import json
from pathlib import Path

from tools.dclint import Violation

DEFAULT_PATH = Path(__file__).resolve().parent / "baseline.json"
SCHEMA_VERSION = 1


def load(path: Path | None = None) -> dict:
    path = path or DEFAULT_PATH
    if not path.exists():
        return {"version": SCHEMA_VERSION, "entries": []}
    data = json.loads(path.read_text(encoding="utf-8"))
    if data.get("version") != SCHEMA_VERSION:
        raise ValueError(f"unsupported baseline version in {path}: "
                         f"{data.get('version')!r}")
    return data


def split(violations: list[Violation], data: dict
          ) -> tuple[list[Violation], list[Violation], list[dict]]:
    """-> (new, baselined, stale_entries).

    Matching is multiset-aware: N identical offending lines need N
    baseline entries, so deleting one of two identical violations
    still prunes one entry.
    """
    budget: dict[str, list[dict]] = {}
    for e in data.get("entries", []):
        budget.setdefault(e["fingerprint"], []).append(e)
    new: list[Violation] = []
    baselined: list[Violation] = []
    for v in violations:
        matches = budget.get(v.fingerprint())
        if matches:
            matches.pop()
            baselined.append(v)
        else:
            new.append(v)
    stale = [e for entries in budget.values() for e in entries]
    return new, baselined, stale


def write(path: Path, violations: list[Violation]) -> dict:
    entries = [
        {"fingerprint": v.fingerprint(), "code": v.code, "path": v.path,
         "line": v.line, "source_line": v.source_line, "message": v.message}
        for v in sorted(violations,
                        key=lambda v: (v.path, v.line, v.col, v.code))
    ]
    data = {"version": SCHEMA_VERSION, "entries": entries}
    path.write_text(json.dumps(data, indent=2) + "\n", encoding="utf-8")
    return data


def prune(path: Path, current: list[Violation]) -> dict:
    """Keep only entries still matched by a current violation."""
    data = load(path)
    _, baselined, _ = split(current, data)
    return write(path, baselined)
