"""``# dclint: disable=DCxxx`` pragma suppression.

Two forms:

- line pragma — ``x = time.time()  # dclint: disable=DC201`` suppresses
  the named codes (comma-separated, or ``all``) on that line only;
- file pragma — ``# dclint: disable-file=DC401`` anywhere at column 0 in
  the first 10 lines suppresses the codes for the whole file.

A pragma is an *argued exception*: the comment should say why the
contract does not apply (e.g. wall-clock timing of a benchmark harness
measuring wall clock). Prefer fixing; baseline legacy debt instead.
"""
from __future__ import annotations

import re

_LINE_RE = re.compile(r"#\s*dclint:\s*disable=([A-Za-z0-9, ]+)")
_FILE_RE = re.compile(r"^#\s*dclint:\s*disable-file=([A-Za-z0-9, ]+)")
_FILE_SCAN_LINES = 10


def _codes(group: str) -> frozenset[str]:
    return frozenset(c.strip().upper() for c in group.split(",") if c.strip())


def collect(src_lines: list[str]) -> dict[int, frozenset[str]]:
    """Map line number -> suppressed codes; line 0 holds file-level codes."""
    out: dict[int, frozenset[str]] = {}
    for text in src_lines[:_FILE_SCAN_LINES]:
        m = _FILE_RE.match(text)
        if m:
            out[0] = out.get(0, frozenset()) | _codes(m.group(1))
    for i, text in enumerate(src_lines, start=1):
        m = _LINE_RE.search(text)
        if m:
            out[i] = out.get(i, frozenset()) | _codes(m.group(1))
    return out


def suppressed(suppressions: dict[int, frozenset[str]], code: str,
               line: int) -> bool:
    for codes in (suppressions.get(0), suppressions.get(line)):
        if codes and (code in codes or "ALL" in codes):
            return True
    return False
