"""Rule scoping: which contracts apply to which part of the tree.

Scopes are path *prefixes* relative to the repo root. A rule runs on a
file iff some prefix in its scope matches and no prefix in its exemption
list does. ``launch/`` is exempt from DC201 by design: launch scripts
legitimately read wall clock (run dirs, progress logging) and never feed
the deterministic replay path.
"""
from __future__ import annotations

from pathlib import Path

# rule -> path prefixes the rule runs on
RULE_SCOPES: dict[str, tuple[str, ...]] = {
    # runtime invariants live in the control plane: emulator core,
    # serve drivers, discrete-event sim — and the linter itself (a
    # stripped assert in dclint would silently un-enforce a contract
    # under ``python -O``)
    "DC101": ("src/repro/core", "src/repro/serve", "src/repro/sim",
              "tools/dclint"),
    # deterministic replay + bench gating cover the control plane AND
    # the benchmarks that gate on its numbers AND the linter (its
    # findings feed CI gates, so its output must be replayable too)
    "DC201": ("src/repro/core", "src/repro/serve", "src/repro/sim",
              "benchmarks", "tools/dclint"),
    # grant callbacks are defined in the control plane
    "DC301": ("src/repro/core", "src/repro/serve", "src/repro/sim"),
    # DC302 widens DC301 project-wide (flow layer): same scope
    "DC302": ("src/repro/core", "src/repro/serve", "src/repro/sim"),
    # slot-vs-node-unit arithmetic happens where engine slots meet
    # provider grants: the serve layer
    "DC401": ("src/repro/serve",),
    # tracer safety is a kernels/ concern
    "DC501": ("src/repro/kernels",),
    # tenant phase discipline: Tenant implementations live in the serve
    # layer (the sim layer's REServer drivers predate the protocol)
    "DC601": ("src/repro/serve",),
}

# rule -> path prefixes exempted even when a scope prefix matches
RULE_EXEMPT: dict[str, tuple[str, ...]] = {
    "DC201": ("src/repro/launch",),
}

# --- DC401 identifier lexicon -------------------------------------------
# Slot counts: how many batching slots an engine is serving.
SLOT_NAMES = frozenset({"active", "slots", "active_count", "active_slots",
                        "free_slots", "n_slots"})
SLOT_SUFFIXES = ("_slots",)
# Node units: the provider's grant denomination (1 slot = `width` units).
# A training gang's world size is denominated in node units too — the
# gang holds `world` provider nodes — so the world-size names join this
# lexicon rather than forming a fourth denomination.
UNIT_NAMES = frozenset({"owned", "granted", "capacity", "capacity_units",
                        "nodes", "units", "busy",
                        "world", "world_min", "world_max", "world_size"})
UNIT_SUFFIXES = ("_units", "_nodes")
# Width: node units per slot — multiplying a slot count by a width IS the
# sanctioned conversion (as is dividing units by a width). (`free` is
# deliberately absent from both lexicons: it is a slot list in the
# engine and a unit count in the env; assignment taint disambiguates.)
WIDTH_NAMES = frozenset({"width", "slot_width"})
WIDTH_SUFFIXES = ("_width",)
# Calls treated as width-valued regardless of receiver
WIDTH_CALLS = frozenset({"width_of"})
# Page counts: physical KV-cache pages (PR 8's paged allocator). Slots,
# units and pages are three distinct denominations; any two of them may
# only meet through a converter.
PAGE_NAMES = frozenset({"n_pages", "pages", "used_pages", "free_pages",
                        "capacity_pages", "page_quota"})
PAGE_SUFFIXES = ("_pages",)
# Page rates: the sanctioned converters into page space. Multiplying a
# slot or unit count by a rate yields pages (``granted * pages_per_unit``);
# a width times a per-unit rate is a per-slot rate.
RATE_NAMES = frozenset({"pages_per_slot", "pages_per_unit"})
RATE_SUFFIXES = ("_per_slot", "_per_unit")


def relpath(path: Path, root: Path) -> str:
    """Posix path relative to the repo root (absolute-posix fallback for
    out-of-tree files, e.g. test fixtures in tmp dirs)."""
    try:
        return path.resolve().relative_to(root.resolve()).as_posix()
    except ValueError:
        return path.resolve().as_posix()


def rules_for(rel: str) -> list[str]:
    out = []
    for code, scopes in sorted(RULE_SCOPES.items()):
        if not any(_covers(s, rel) for s in scopes):
            continue
        if any(_covers(e, rel) for e in RULE_EXEMPT.get(code, ())):
            continue
        out.append(code)
    return out


def _covers(prefix: str, rel: str) -> bool:
    return rel == prefix or rel.startswith(prefix + "/")
