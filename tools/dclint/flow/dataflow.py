"""Dataflow over :mod:`tools.dclint.flow.cfg` CFGs.

Two layers:

* **Lexers** shared by the flow rules — :func:`attr_writes` (every
  field mutated in a subtree, as ``(receiver chain, attr)``),
  :func:`attr_loads` / :func:`attr_reads` (fields read),
  :func:`mutating_calls` (container-method mutation like
  ``ledger.admission_queue.remove(...)``) and :func:`calls` (every call
  with its receiver chain). Receiver chains are leaf-first name
  segments, the same orientation DC301 established:
  ``self.provider.admission_queue`` -> ``("admission_queue",
  "provider", "self")``.

* **Reaching definitions** — the classic forward may-analysis: which
  ``(name, line, col)`` binding sites can reach each block. Worklist
  over the CFG, gen/kill per block from the statements' *evaluated
  parts* (a ``for`` target generates in the loop header, an ``if``
  body's bindings stay in the body block).
"""
from __future__ import annotations

import ast

from tools.dclint.flow.cfg import CFG, evaluated_parts

__all__ = [
    "chain_names", "attr_writes", "attr_loads", "attr_reads",
    "mutating_calls", "calls", "bound_names", "reaching_definitions",
]

#: container methods that mutate their receiver in place
MUTATORS = frozenset({
    "append", "remove", "pop", "clear", "insert", "extend", "update",
    "setdefault", "popitem", "add", "discard",
})


def chain_names(node: ast.AST) -> tuple[str, ...]:
    """Name segments of an attribute/subscript/call chain, leaf-first:
    ``self.a.b[0].c`` -> ``("c", "b", "a", "self")``."""
    names: list[str] = []
    while True:
        if isinstance(node, ast.Attribute):
            names.append(node.attr)
            node = node.value
        elif isinstance(node, ast.Subscript):
            node = node.value
        elif isinstance(node, ast.Call):
            node = node.func
        elif isinstance(node, ast.Name):
            names.append(node.id)
            return tuple(names)
        else:
            return tuple(names)


def _write_targets(node: ast.AST):
    if isinstance(node, ast.Assign):
        return node.targets
    if isinstance(node, (ast.AugAssign, ast.AnnAssign)):
        return [node.target]
    if isinstance(node, ast.Delete):
        return node.targets
    return []


def attr_writes(node: ast.AST) -> list:
    """Every attribute-field mutation in the subtree, as
    ``(receiver_chain, attr, stmt_node)``. Covers plain/augmented/
    annotated assignment and ``del``; a subscript store like
    ``self._work[jid] = v`` counts as a write to ``_work``."""
    out = []
    for n in ast.walk(node):
        for tgt in _write_targets(n):
            t = tgt
            while isinstance(t, (ast.Subscript, ast.Starred)):
                t = t.value
            if isinstance(t, ast.Attribute):
                out.append((chain_names(t.value), t.attr, n))
            elif isinstance(t, ast.Tuple):
                for el in t.elts:
                    e = el
                    while isinstance(e, (ast.Subscript, ast.Starred)):
                        e = e.value
                    if isinstance(e, ast.Attribute):
                        out.append((chain_names(e.value), e.attr, n))
    return out


def attr_loads(node: ast.AST) -> list:
    """Every attribute read in the subtree, as ``(receiver_chain, attr,
    node)``."""
    out = []
    for n in ast.walk(node):
        if isinstance(n, ast.Attribute) and isinstance(n.ctx, ast.Load):
            out.append((chain_names(n.value), n.attr, n))
    return out


def attr_reads(node: ast.AST, base: str = "self") -> set:
    """Attr names read directly on ``base`` (``self.X`` loads)."""
    return {attr for chain, attr, _ in attr_loads(node)
            if chain == (base,)}


def mutating_calls(node: ast.AST) -> list:
    """In-place container mutations: calls to a :data:`MUTATORS` method,
    as ``(receiver_chain, method, call_node)`` — the chain covers the
    whole receiver (``self.provider.admission_queue.remove`` ->
    ``("admission_queue", "provider", "self")``)."""
    out = []
    for n in ast.walk(node):
        if (isinstance(n, ast.Call) and isinstance(n.func, ast.Attribute)
                and n.func.attr in MUTATORS):
            out.append((chain_names(n.func.value), n.func.attr, n))
    return out


def calls(node: ast.AST) -> list:
    """Every call in the subtree as ``(receiver_chain, name, call_node)``
    — the chain is empty for bare-name calls."""
    out = []
    for n in ast.walk(node):
        if not isinstance(n, ast.Call):
            continue
        if isinstance(n.func, ast.Attribute):
            out.append((chain_names(n.func.value), n.func.attr, n))
        elif isinstance(n.func, ast.Name):
            out.append(((), n.func.id, n))
    return out


def bound_names(node: ast.AST) -> list:
    """``(name, line, col)`` for every name *bound* in the subtree
    (assignment targets, loop/with targets, walrus)."""
    out = []
    for n in ast.walk(node):
        if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Store):
            out.append((n.id, n.lineno, n.col_offset))
        elif isinstance(n, ast.NamedExpr):
            t = n.target
            out.append((t.id, t.lineno, t.col_offset))
    return out


def reaching_definitions(cfg: CFG, fn=None) -> dict:
    """Reaching definitions per block: ``{idx: (in_set, out_set)}`` of
    ``(name, line, col)`` binding sites. Pass the ``FunctionDef`` as
    ``fn`` to seed the entry block with the parameter bindings."""
    gen: dict[int, dict[str, set]] = {}
    for b in cfg.blocks:
        g: dict[str, set] = {}
        for stmt in b.stmts:
            for part in evaluated_parts(stmt):
                for name, line, col in bound_names(part):
                    g[name] = {(name, line, col)}   # later defs kill earlier
        gen[b.idx] = g
    if fn is not None:
        a = fn.args
        params = [*a.posonlyargs, *a.args, *a.kwonlyargs]
        if a.vararg:
            params.append(a.vararg)
        if a.kwarg:
            params.append(a.kwarg)
        entry = gen[CFG.ENTRY]
        for p in params:
            entry.setdefault(p.arg, {(p.arg, p.lineno, p.col_offset)})

    preds: dict[int, list[int]] = {b.idx: [] for b in cfg.blocks}
    for b in cfg.blocks:
        for s in b.succ:
            preds[s].append(b.idx)

    in_map: dict[int, set] = {b.idx: set() for b in cfg.blocks}
    out_map: dict[int, set] = {b.idx: set() for b in cfg.blocks}
    work = [b.idx for b in cfg.blocks]
    while work:
        i = work.pop(0)
        new_in: set = set()
        for p in preds[i]:
            new_in |= out_map[p]
        killed_names = set(gen[i])
        new_out = {d for d in new_in if d[0] not in killed_names}
        for defs in gen[i].values():
            new_out |= defs
        if new_in != in_map[i] or new_out != out_map[i]:
            in_map[i] = new_in
            out_map[i] = new_out
            for s in cfg.blocks[i].succ:
                if s not in work:
                    work.append(s)
    return {i: (in_map[i], out_map[i]) for i in in_map}
