"""Per-function control-flow graphs over Python AST.

A :class:`CFG` is a list of basic blocks. Block 0 is the entry, block 1
the (synthetic, empty) exit; every path out of the function — the final
fall-through, each ``return``, each uncaught ``raise`` — edges into the
exit block. Compound statements live in the block where their *header*
is evaluated (an ``if``/``while`` test, a ``for`` iterable); their
bodies get their own blocks with the usual edges:

* ``if``: header -> then-block [-> else-block], both -> join; a missing
  ``else`` adds the header -> join fall-through edge.
* ``while``/``for``: header -> body -> header back-edge, header -> exit
  edge (through the ``else`` suite when one exists); ``break`` edges to
  the loop's after-block, ``continue`` back to the header.
* ``try``: every block materialized while building the body gets an
  exceptional edge to each handler entry (the sound over-approximation:
  any statement in the suite may raise); body/``else``/handler ends
  converge on the ``finally`` suite when present, else on a join block.
* ``with``: linear — the items are evaluated in the current block and
  the body continues in it (exceptional control flow is the enclosing
  ``try``'s concern).
* ``return``/``raise`` terminate their block (raise additionally
  reaches enclosing handlers through the try-range edges above).

The analyses downstream never walk a compound node's body through the
block statement list — :func:`evaluated_parts` names exactly the
sub-expressions a header evaluates, so reaching-defs and the rules see
each expression exactly once, in the block where it executes.
"""
from __future__ import annotations

import ast
import dataclasses

__all__ = ["Block", "CFG", "build_cfg", "evaluated_parts"]


@dataclasses.dataclass
class Block:
    """One basic block: straight-line AST nodes + successor indices."""
    idx: int
    label: str = ""
    stmts: list = dataclasses.field(default_factory=list)
    succ: set = dataclasses.field(default_factory=set)


def evaluated_parts(node: ast.AST) -> list[ast.AST]:
    """The sub-nodes a block statement actually evaluates *at its own
    position* — a compound statement contributes its header only (the
    body has its own blocks)."""
    if isinstance(node, (ast.If, ast.While)):
        return [node.test]
    if isinstance(node, (ast.For, ast.AsyncFor)):
        return [node.iter, node.target]
    if isinstance(node, (ast.With, ast.AsyncWith)):
        out: list[ast.AST] = []
        for item in node.items:
            out.append(item.context_expr)
            if item.optional_vars is not None:
                out.append(item.optional_vars)
        return out
    if isinstance(node, ast.Return):
        return [node.value] if node.value is not None else []
    if isinstance(node, ast.Raise):
        return [p for p in (node.exc, node.cause) if p is not None]
    if isinstance(node, (ast.Try, ast.FunctionDef, ast.AsyncFunctionDef,
                         ast.ClassDef)):
        return []          # nothing evaluated at the header itself
    if isinstance(node, ast.Match):
        return [node.subject]
    return [node]


class CFG:
    ENTRY = 0
    EXIT = 1

    def __init__(self, blocks: list[Block]):
        self.blocks = blocks

    # ------------------------------------------------------------ queries
    def shape(self) -> list[tuple[int, str, tuple[int, ...]]]:
        """Stable golden form: ``(idx, label, sorted successors)`` rows.
        Labels are ``entry``/``exit`` or the comma-joined AST type names
        of the block's statements (empty join blocks render as ``.``)."""
        out = []
        for b in self.blocks:
            if b.label:
                label = b.label
            elif b.stmts:
                label = ",".join(type(s).__name__ for s in b.stmts)
            else:
                label = "."
            out.append((b.idx, label, tuple(sorted(b.succ))))
        return out

    def reachable_from(self, idx: int) -> set:
        """Block indices reachable through successor edges (not
        including ``idx`` itself unless a cycle returns to it)."""
        seen: set[int] = set()
        work = sorted(self.blocks[idx].succ)
        while work:
            i = work.pop()
            if i in seen:
                continue
            seen.add(i)
            work.extend(self.blocks[i].succ)
        return seen

    def find(self, node: ast.AST) -> tuple[int, int] | None:
        """``(block idx, position)`` of a statement, by identity."""
        for b in self.blocks:
            for i, s in enumerate(b.stmts):
                if s is node:
                    return b.idx, i
        return None

    def nodes_after(self, node: ast.AST) -> list:
        """Every block statement that may still execute after ``node``
        completes: the rest of its block plus all blocks reachable from
        it (a loop back-edge re-includes the whole block)."""
        where = self.find(node)
        if where is None:
            return []
        bi, pos = where
        reach = self.reachable_from(bi)
        out = list(self.blocks[bi].stmts[pos + 1:])
        for i in sorted(reach):
            out.extend(self.blocks[i].stmts)
        return out


class _Builder:
    def __init__(self):
        self.blocks: list[Block] = []

    def new_block(self, label: str = "") -> int:
        b = Block(idx=len(self.blocks), label=label)
        self.blocks.append(b)
        return b.idx

    def edge(self, a: int, b: int) -> None:
        self.blocks[a].succ.add(b)

    def add(self, idx: int, node: ast.AST) -> None:
        self.blocks[idx].stmts.append(node)

    # ``loops`` is a stack of (header idx, after idx); ``cur`` is the
    # open block. Returns the falling-through block or None when every
    # path out of the suite terminated (return/raise/break/continue).
    def seq(self, stmts, cur: int, loops: list) -> int | None:
        for node in stmts:
            if cur is None:       # unreachable code after a terminator:
                cur = self.new_block()   # still modeled, no predecessors
            if isinstance(node, (ast.If,)):
                cur = self._if(node, cur, loops)
            elif isinstance(node, (ast.While, ast.For, ast.AsyncFor)):
                cur = self._loop(node, cur, loops)
            elif isinstance(node, ast.Try):
                cur = self._try(node, cur, loops)
            elif isinstance(node, (ast.With, ast.AsyncWith)):
                self.add(cur, node)
                cur = self.seq(node.body, cur, loops)
            elif isinstance(node, ast.Match):
                cur = self._match(node, cur, loops)
            elif isinstance(node, (ast.Return, ast.Raise)):
                self.add(cur, node)
                self.edge(cur, CFG.EXIT)
                cur = None
            elif isinstance(node, ast.Break):
                if loops:
                    self.edge(cur, loops[-1][1])
                else:
                    self.edge(cur, CFG.EXIT)
                cur = None
            elif isinstance(node, ast.Continue):
                if loops:
                    self.edge(cur, loops[-1][0])
                else:
                    self.edge(cur, CFG.EXIT)
                cur = None
            else:
                self.add(cur, node)
        return cur

    def _if(self, node: ast.If, cur: int, loops: list) -> int | None:
        self.add(cur, node)
        ends = []
        then = self.new_block()
        self.edge(cur, then)
        te = self.seq(node.body, then, loops)
        if te is not None:
            ends.append(te)
        if node.orelse:
            els = self.new_block()
            self.edge(cur, els)
            ee = self.seq(node.orelse, els, loops)
            if ee is not None:
                ends.append(ee)
        else:
            ends.append(cur)      # false edge falls through
        if not ends:
            return None
        join = self.new_block()
        for e in ends:
            self.edge(e, join)
        return join

    def _loop(self, node, cur: int, loops: list) -> int:
        header = self.new_block()
        self.edge(cur, header)
        self.add(header, node)
        after = self.new_block()
        body = self.new_block()
        self.edge(header, body)
        if node.orelse:
            els = self.new_block()
            self.edge(header, els)
            ee = self.seq(node.orelse, els, loops)
            if ee is not None:
                self.edge(ee, after)
        else:
            self.edge(header, after)
        be = self.seq(node.body, body, loops + [(header, after)])
        if be is not None:
            self.edge(be, header)
        return after

    def _try(self, node: ast.Try, cur: int, loops: list) -> int | None:
        self.add(cur, node)
        body = self.new_block()
        self.edge(cur, body)
        lo = len(self.blocks)
        be = self.seq(node.body, body, loops)
        if be is not None and node.orelse:
            be = self.seq(node.orelse, be, loops)
        hi = len(self.blocks)
        h_entries = [self.new_block() for _ in node.handlers]
        # any statement in the try suite may raise: every block built for
        # it (plus the suite's entry block) edges to each handler
        for bi in [body] + list(range(lo, hi)):
            for h in h_entries:
                self.edge(bi, h)
        ends = [be] if be is not None else []
        for h, handler in zip(h_entries, node.handlers):
            self.blocks[h].stmts.extend(
                [handler.type] if handler.type is not None else [])
            he = self.seq(handler.body, h, loops)
            if he is not None:
                ends.append(he)
        if node.finalbody:
            fin = self.new_block()
            for e in ends:
                self.edge(e, fin)
            if not ends:          # finally still runs on the raise path
                self.edge(body, fin)
            return self.seq(node.finalbody, fin, loops)
        if not ends:
            return None
        join = self.new_block()
        for e in ends:
            self.edge(e, join)
        return join

    def _match(self, node, cur: int, loops: list) -> int | None:
        self.add(cur, node)
        ends = [cur]              # no case may match: fall through
        for case in node.cases:
            cb = self.new_block()
            self.edge(cur, cb)
            ce = self.seq(case.body, cb, loops)
            if ce is not None:
                ends.append(ce)
        join = self.new_block()
        for e in ends:
            self.edge(e, join)
        return join


def build_cfg(fn) -> CFG:
    """CFG of one ``FunctionDef``/``AsyncFunctionDef``."""
    b = _Builder()
    b.new_block("entry")          # idx 0
    b.new_block("exit")           # idx 1
    end = b.seq(fn.body, CFG.ENTRY, [])
    if end is not None:
        b.edge(end, CFG.EXIT)
    return CFG(b.blocks)
