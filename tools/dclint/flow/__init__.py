"""dcflow — the intra+interprocedural dataflow layer under dclint.

Three stdlib-only pieces, each usable on its own:

``flow.cfg``
    per-function control-flow graphs over Python AST: basic blocks and
    edges for branches, loops, ``try``/``with``, ``break``/``continue``
    and early returns. ``build_cfg(fn).shape()`` is a stable golden form
    for tests; ``reachable_from`` / ``nodes_after`` answer the "what can
    still execute after this statement" queries the DC301 fixer needs.

``flow.dataflow``
    reaching definitions over a CFG (worklist, gen/kill per block) and
    the field-write/read lexers the rules share: ``attr_writes`` (every
    ``self.X`` / ``obj.attr`` mutation in a subtree), ``attr_reads``,
    ``mutating_calls`` (``ledger.append/remove/pop/...``).

``flow.project``
    a project-wide index over many modules: classes with cross-module
    MRO resolution, per-function call edges (bare names within a module,
    ``self.``/``cls.`` methods virtually dispatched through the class
    family, ``functools.partial``), and the callback edges that make
    grant plumbing analyzable — ``on_grant=`` keyword wiring and
    ``.grant_listener =`` assignment connect ``provider._drain``'s
    ``req.on_grant(...)`` invocation to the tenant methods it lands in.

DC302 (re-entrancy soundness) and DC601 (tenant phase discipline) are
built on this layer; see ``tools/dclint/README.md`` for the rule-author
API walkthrough.
"""
from __future__ import annotations

from tools.dclint.flow.cfg import CFG, Block, build_cfg
from tools.dclint.flow.dataflow import (
    attr_reads, attr_writes, mutating_calls, reaching_definitions,
)
from tools.dclint.flow.project import FuncInfo, ClassInfo, Project

__all__ = [
    "CFG", "Block", "build_cfg",
    "attr_reads", "attr_writes", "mutating_calls", "reaching_definitions",
    "FuncInfo", "ClassInfo", "Project",
]
