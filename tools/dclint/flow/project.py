"""Project index + call graph over many modules.

:class:`Project` parses (or is handed) a set of modules and indexes
every function and class. Call edges are resolved conservatively:

* bare-name calls -> a module-level function of the *same* module,
* ``self.X()`` / ``cls.X()`` -> ``X`` virtually dispatched through the
  enclosing class *family* (MRO by base-name resolution across modules,
  plus subclass overrides — the sound answer for a driver that calls a
  hook its subclass overrides),
* ``functools.partial(self.X, ...)`` keeps the edge to ``X``,
* ``SomeClass.method(obj, ...)`` -> the explicit base-call edge when
  ``SomeClass`` names a known class,
* **callback edges**: a call through an attribute named ``on_grant`` or
  ``grant_listener`` (``req.on_grant(offer, t)``, ``self.
  grant_listener(...)``) edges to every function wired *anywhere in the
  project* via ``on_grant=<fn>`` keyword arguments or
  ``<obj>.grant_listener = <fn>`` / ``on_grant = <fn>`` assignment.
  This is what connects ``ResourceProvider._drain`` to
  ``RuntimeEnv._apply_grant`` and on into the tenants' ``_on_grant``
  listeners without importing anything.

The index is syntactic — no imports are executed — so name collisions
across modules resolve to *all* same-named candidates. For the rules
this over-approximation errs exactly the right way: reachability may
include a method it shouldn't, never miss one it should.
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path

__all__ = ["FuncInfo", "ClassInfo", "Project", "CALLBACK_NAMES"]

#: attribute/keyword names that wire grant callbacks
CALLBACK_NAMES = ("on_grant", "grant_listener")


@dataclasses.dataclass(eq=False)
class FuncInfo:
    """One function or method definition."""
    rel: str                     # module path (repo-relative posix)
    name: str                    # bare function name
    qualname: str                # "Class.name" for methods, else name
    cls: str | None              # enclosing class name, or None
    node: ast.AST                # the FunctionDef/AsyncFunctionDef

    @property
    def key(self) -> str:
        return f"{self.rel}::{self.qualname}"


@dataclasses.dataclass(eq=False)
class ClassInfo:
    """One class definition: methods, base names, hook aliases."""
    rel: str
    name: str
    bases: tuple
    methods: dict               # name -> FuncInfo (last def wins)
    aliases: dict               # class-level ``hook = method`` renames
    node: ast.AST


def _base_name(node: ast.AST) -> str | None:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return None


def _callee_name(node: ast.AST) -> str | None:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return None


class Project:
    def __init__(self, sources: dict):
        """``sources`` maps repo-relative path -> parsed ``ast.Module``."""
        self.modules: dict[str, ast.AST] = dict(sources)
        self.classes: dict[str, list[ClassInfo]] = {}
        self.module_functions: dict[str, dict[str, FuncInfo]] = {}
        self.functions: list[FuncInfo] = []
        #: callback kind -> set[FuncInfo] wired to it anywhere
        self.callback_targets: dict[str, set] = {
            k: set() for k in CALLBACK_NAMES}
        self._callgraph: dict | None = None
        self._cache: dict = {}    # scratch space for rule memoization
        for rel, tree in self.modules.items():
            self._index_module(rel, tree)
        for rel, tree in self.modules.items():
            self._collect_callbacks(rel, tree)

    # ------------------------------------------------------ construction
    @classmethod
    def from_paths(cls, files, *, root: Path) -> "Project":
        from tools.dclint import config
        sources = {}
        for f in files:
            rel = config.relpath(f, root)
            try:
                tree = ast.parse(f.read_text(encoding="utf-8"),
                                 filename=str(f))
            except (OSError, UnicodeDecodeError, SyntaxError):
                continue          # lint_file reports these as DC000
            sources[rel] = tree
        return cls(sources)

    def _index_module(self, rel: str, tree: ast.AST) -> None:
        mod_fns: dict[str, FuncInfo] = {}
        self.module_functions[rel] = mod_fns

        def visit(node, cls_name, qual_prefix):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                    qual = (f"{qual_prefix}.{child.name}" if qual_prefix
                            else child.name)
                    fi = FuncInfo(rel=rel, name=child.name, qualname=qual,
                                  cls=cls_name, node=child)
                    self.functions.append(fi)
                    if cls_name is None and not qual_prefix:
                        mod_fns[child.name] = fi
                    if cls_name is not None and qual_prefix == cls_name:
                        self.classes[cls_name][-1].methods[child.name] = fi
                    visit(child, cls_name, qual)
                elif isinstance(child, ast.ClassDef):
                    ci = ClassInfo(
                        rel=rel, name=child.name,
                        bases=tuple(b for b in map(_base_name, child.bases)
                                    if b),
                        methods={}, aliases={}, node=child)
                    self.classes.setdefault(child.name, []).append(ci)
                    for stmt in child.body:
                        if (isinstance(stmt, ast.Assign)
                                and isinstance(stmt.value, ast.Name)):
                            for tgt in stmt.targets:
                                if isinstance(tgt, ast.Name):
                                    ci.aliases[tgt.id] = stmt.value.id
                    visit(child, child.name, child.name)

        visit(tree, None, "")

    def _collect_callbacks(self, rel: str, tree: ast.AST) -> None:
        # enclosing-class context matters for resolving ``self._fn``
        def visit(node, cls_name):
            for child in ast.iter_child_nodes(node):
                inner_cls = (child.name if isinstance(child, ast.ClassDef)
                             else cls_name)
                if isinstance(child, ast.Call):
                    for kw in child.keywords:
                        if kw.arg in CALLBACK_NAMES:
                            self._wire(kw.arg, kw.value, cls_name, rel)
                elif isinstance(child, ast.Assign):
                    for tgt in child.targets:
                        name = None
                        if isinstance(tgt, ast.Attribute):
                            name = tgt.attr
                        elif isinstance(tgt, ast.Name):
                            name = tgt.id
                        if name in CALLBACK_NAMES:
                            self._wire(name, child.value, cls_name, rel)
                visit(child, inner_cls)

        visit(tree, None)
        # a literal ``def on_grant`` is a root by definition
        for fi in self.functions:
            if fi.rel == rel and fi.name in CALLBACK_NAMES:
                self.callback_targets[fi.name].add(fi)

    def _wire(self, kind: str, value: ast.AST, cls_name: str | None,
              rel: str) -> None:
        # unwrap functools.partial(fn, ...)
        if (isinstance(value, ast.Call)
                and _callee_name(value.func) == "partial" and value.args):
            value = value.args[0]
        for fi in self._resolve_ref(value, cls_name, rel):
            self.callback_targets[kind].add(fi)

    def _resolve_ref(self, value: ast.AST, cls_name: str | None,
                     rel: str) -> list:
        """Functions a reference expression may denote."""
        if isinstance(value, ast.Attribute):
            base = value.value
            if isinstance(base, ast.Name) and base.id in ("self", "cls") \
                    and cls_name is not None:
                return self.resolve_method(cls_name, value.attr,
                                           virtual=True)
            # obj.method on an unknown receiver: every method of that
            # name anywhere (conservative)
            return [fi for fi in self.functions if fi.name == value.attr
                    and fi.cls is not None]
        if isinstance(value, ast.Name):
            fi = self.module_functions.get(rel, {}).get(value.id)
            if fi is not None:
                return [fi]
            return [f for f in self.functions if f.name == value.id
                    and f.cls is None]
        return []

    # -------------------------------------------------------- resolution
    def mro(self, cls_name: str) -> list:
        """All ClassInfos of ``cls_name`` plus its (transitive) bases,
        nearest-first, by project-wide base-name matching."""
        out, seen, work = [], set(), [cls_name]
        while work:
            name = work.pop(0)
            if name in seen:
                continue
            seen.add(name)
            for ci in self.classes.get(name, ()):
                out.append(ci)
                work.extend(ci.bases)
        return out

    def subclasses(self, cls_name: str) -> list:
        """ClassInfos that (transitively) list ``cls_name`` as a base."""
        out = []
        for name, infos in self.classes.items():
            if name == cls_name:
                continue
            for ci in infos:
                if any(m.name == cls_name for m in self.mro(name)[1:]
                       ) or cls_name in ci.bases:
                    out.append(ci)
                    break
        return out

    def resolve_method(self, cls_name: str, meth: str, *,
                       virtual: bool = False) -> list:
        """Defs of ``meth`` for a ``self.meth`` call inside ``cls_name``:
        the nearest MRO definition (following class-level aliases), plus
        every subclass override when ``virtual``."""
        out: list[FuncInfo] = []
        for ci in self.mro(cls_name):
            meth = ci.aliases.get(meth, meth)
            if meth in ci.methods:
                out.append(ci.methods[meth])
                break
        if virtual:
            for ci in self.subclasses(cls_name):
                if meth in ci.methods:
                    out.append(ci.methods[meth])
        return out

    # --------------------------------------------------------- call graph
    def edges(self, fi: FuncInfo) -> set:
        """Outgoing call edges of one function (see module docstring
        for the resolution rules)."""
        out: set[FuncInfo] = set()
        for node in ast.walk(fi.node):
            if not isinstance(node, ast.Call):
                continue
            func = node.func
            if isinstance(func, ast.Name):
                tgt = self.module_functions.get(fi.rel, {}).get(func.id)
                if tgt is not None:
                    out.add(tgt)
            elif isinstance(func, ast.Attribute):
                if func.attr in CALLBACK_NAMES:
                    out |= self.callback_targets[func.attr]
                if isinstance(func.value, ast.Name):
                    recv = func.value.id
                    if recv in ("self", "cls") and fi.cls is not None:
                        out.update(self.resolve_method(
                            fi.cls, func.attr, virtual=True))
                    elif recv in self.classes:
                        out.update(self.resolve_method(recv, func.attr))
            # functools.partial(self._fn, ...) keeps the edge
            if _callee_name(func) == "partial" and node.args:
                out.update(self._resolve_ref(node.args[0], fi.cls, fi.rel))
        return out

    def callgraph(self) -> dict:
        """``{caller key: set of callee keys}`` over every function.
        Keys are ``"<rel>::<qualname>"`` strings (see FuncInfo.key)."""
        if self._callgraph is None:
            self._callgraph = {
                fi.key: {t.key for t in self.edges(fi)}
                for fi in self.functions}
        return self._callgraph

    def reachable(self, roots) -> dict:
        """BFS closure from root FuncInfos: ``{FuncInfo: call path}``
        where the path is a tuple of function names root-first (the
        DC301-style ``via a -> b -> c`` diagnostic)."""
        paths: dict[FuncInfo, tuple] = {}
        queue = []
        for r in sorted(roots, key=lambda f: f.key):
            if r not in paths:
                paths[r] = (r.name,)
                queue.append(r)
        while queue:
            fi = queue.pop(0)
            for callee in sorted(self.edges(fi), key=lambda f: f.key):
                if callee not in paths:
                    paths[callee] = paths[fi] + (callee.name,)
                    queue.append(callee)
        return paths

    # -------------------------------------------------- drain read model
    def drain_read_attrs(self) -> frozenset:
        """The provider ledger fields ``_drain``'s loop reads, computed
        from the project: every ``self.X`` load in ``_drain`` and the
        self-methods it calls (``headroom`` -> allocated/quotas/
        reservations/capacity), minus the class family's own method
        names. Falls back to the documented set when no ``_drain``
        exists in the project (single-file fixture runs)."""
        key = "drain_read_attrs"
        if key in self._cache:
            return self._cache[key]
        from tools.dclint.flow.dataflow import attr_reads
        drains = [fi for fi in self.functions
                  if fi.name == "_drain" and fi.cls is not None]
        reads: set[str] = set()
        for d in drains:
            family = self.mro(d.cls)
            method_names = {m for ci in family for m in ci.methods}
            closure = self.reachable([d])
            for fi in closure:
                if fi.cls is None or not any(
                        ci.name == fi.cls for ci in family):
                    continue      # stay inside the provider class family
                reads |= attr_reads(fi.node, "self")
            reads -= method_names
        if not reads:
            reads = set(DEFAULT_DRAIN_READS)
        out = frozenset(reads)
        self._cache[key] = out
        return out


#: fallback when the linted set of files does not contain ``_drain``
DEFAULT_DRAIN_READS = frozenset({
    "_draining", "admission_queue", "allocated", "quotas",
    "reservations", "capacity", "policy",
})
