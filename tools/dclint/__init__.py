"""dclint — repo-native static analysis for the DSP serve-path contracts.

The serve path's correctness claims (zero over-admission, weighted
isolation ``sum(active_i*width_i) <= capacity``, deterministic replay,
re-entrancy-safe provider drains, tracer-safe pallas kernels) are enforced
at runtime by guarded raises and pinned by property tests — but every one
of those guards was added *after* a bug shipped. dclint rejects the bug
classes at authoring time instead:

=====  ======================================================
code   contract
=====  ======================================================
DC101  runtime invariants must be guarded raises, not ``assert``
       (asserts are stripped under ``python -O``)
DC201  control-plane + benchmark code must be deterministic
       (no wall clock, no global RNG module state)
DC301  ``on_grant``/``grant_listener`` callbacks must not re-enter
       the provider ledger (request/release/amend/cancel or direct
       ledger mutation) — the provider may be mid-drain
DC302  nothing *reachable* from a grant callback (project call
       graph, flow layer) may write a ledger field the drain loop
       reads, except through the documented amend/cancel/release API
DC401  slot counts and node units must not mix arithmetically
       without passing through a width conversion
DC501  pallas kernels must be tracer-safe (no Python control flow
       on traced values, static BlockSpec shapes, no mutable
       default args under ``jax.jit``)
DC601  Tenant phase discipline: hooks mutate grant/ledger state
       only in their assigned phase; ``next_event_tick``/
       ``skip_quiet_stats`` stay pure for event-skip parity
=====  ======================================================

Run ``python -m tools.dclint src benchmarks`` (stdlib only; the optional
``--shapecheck`` harness additionally needs jax for ``eval_shape``).
Suppress a finding in place with ``# dclint: disable=DCxxx`` or park
legacy findings in ``tools/dclint/baseline.json`` to burn down.
"""
from __future__ import annotations

import ast
import dataclasses
import hashlib
from pathlib import Path

__all__ = [
    "Violation", "lint_file", "lint_paths", "fingerprint", "REPO_ROOT",
]

# repo root = parent of the tools/ package this file lives in
REPO_ROOT = Path(__file__).resolve().parent.parent.parent


@dataclasses.dataclass(frozen=True)
class Violation:
    """One finding: a contract violation at ``path:line``."""
    path: str          # repo-relative posix path
    line: int
    col: int
    code: str          # DCxxx
    message: str
    source_line: str = ""   # stripped text of the offending line

    def fingerprint(self) -> str:
        """Line-number-free identity used by the baseline: moving code
        around must not invalidate a baselined finding, but changing the
        offending line (or fixing it) must."""
        h = hashlib.sha1()
        h.update(self.code.encode())
        h.update(b"\0")
        h.update(self.path.encode())
        h.update(b"\0")
        h.update(self.source_line.encode())
        return h.hexdigest()[:16]

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.code} {self.message}"


def fingerprint(v: Violation) -> str:
    return v.fingerprint()


def _source_line(src_lines: list[str], lineno: int) -> str:
    if 1 <= lineno <= len(src_lines):
        return src_lines[lineno - 1].strip()
    return ""


def lint_file(path: Path, *, root: Path | None = None,
              project=None) -> list[Violation]:
    """Run every rule whose scope covers ``path``; pragma-suppressed
    findings are dropped here (the baseline is applied by the caller).

    Flow-based rules (those exposing ``check_project``) receive a
    :class:`tools.dclint.flow.Project`. ``lint_paths`` builds one over
    every file being linted and passes it down; a direct ``lint_file``
    call without one analyzes the file as a one-module project (the
    fixture-test mode)."""
    from tools.dclint import config, pragmas
    from tools.dclint.rules import RULES

    root = root or REPO_ROOT
    rel = config.relpath(path, root)
    codes = config.rules_for(rel)
    if not codes:
        return []
    try:
        src = path.read_text(encoding="utf-8")
    except (OSError, UnicodeDecodeError) as e:
        return [Violation(rel, 1, 0, "DC000", f"unreadable: {e}")]
    try:
        tree = ast.parse(src, filename=str(path))
    except SyntaxError as e:
        return [Violation(rel, e.lineno or 1, e.offset or 0, "DC000",
                          f"syntax error: {e.msg}")]
    src_lines = src.splitlines()
    suppressions = pragmas.collect(src_lines)
    out: list[Violation] = []
    for code in codes:
        rule = RULES[code]
        project_check = getattr(rule, "check_project", None)
        if project_check is not None:
            if project is None:
                from tools.dclint.flow import Project
                project = Project({rel: tree})
            found = project_check(project, tree, src_lines, rel)
        else:
            found = rule.check(tree, src_lines, rel)
        for line, col, msg in found:
            if pragmas.suppressed(suppressions, code, line):
                continue
            out.append(Violation(rel, line, col, code, msg,
                                 _source_line(src_lines, line)))
    out.sort(key=lambda v: (v.path, v.line, v.col, v.code))
    return out


def collect_files(paths: list[Path]) -> list[Path]:
    """The ``.py`` files under the given files/directories, sorted,
    ``__pycache__`` skipped — the linter's single path-expansion rule
    (the CLI uses it to reject an empty scope as a usage error)."""
    files: list[Path] = []
    for p in paths:
        if p.is_dir():
            files.extend(sorted(q for q in p.rglob("*.py")
                                if "__pycache__" not in q.parts))
        elif p.suffix == ".py":
            files.append(p)
    return files


def lint_paths(paths: list[Path], *, root: Path | None = None
               ) -> list[Violation]:
    """Lint every ``.py`` file under the given files/directories. The
    interprocedural rules see one shared Project spanning all of them —
    callback wiring in one module resolves callees in another."""
    from tools.dclint.flow import Project

    root = root or REPO_ROOT
    files = collect_files(paths)
    project = Project.from_paths(files, root=root)
    out: list[Violation] = []
    for f in files:
        out.extend(lint_file(f, root=root, project=project))
    return out
