"""Abstract-interpretation smoke harness for the pallas kernel contracts.

``jax.eval_shape`` traces each kernel with shape/dtype-only abstract
values — no accelerator, no FLOPs — and the result is checked against the
kernel's documented contract, instantiated for every registered model
config (``repro.configs.registry``):

- ``flash_attention``: (BH,S,hd) x (BH,Sk,hd)^2 -> (BH,S,hd), q dtype
- ``decode_attention``: (B,H,hd) x (B,S,KVH,hd)^2 + (B,) lengths
  -> (B,H,hd), q dtype
- ``paged_decode_attention``: (B,H,hd) x (NP,ps,KVH,hd)^2 pools +
  (B,n_pt) page table + (B,) lengths -> (B,H,hd), q dtype
- ``moe_gmm`` (MoE configs): (E,C,d) x (E,d,f) -> (E,C,f), x dtype
- ``ssd_scan`` (SSM/hybrid configs): (B,S,nh,hp)... -> y (B,S,nh,hp)
  fp32 + state (B,nh,hp,ds) fp32

A kernel edit that breaks a shape/dtype contract for ANY registered
config fails here before a TPU ever sees it. Gated on jax being
importable so the static linter stays stdlib-only.
"""
from __future__ import annotations

import json

BATCH, SEQ = 2, 64      # abstract sizes; S must cover chunk/block minima


def _checks(cfg):
    """Yield (kernel_name, fn, arg_specs, expected (shape, dtype) list)
    for one model config. Imports stay inside so jax loads lazily."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.decode_attention import decode_attention
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.moe_gmm import moe_gmm
    from repro.kernels.paged_decode_attention import paged_decode_attention
    from repro.kernels.ssd_scan import ssd_scan

    S = jax.ShapeDtypeStruct
    hd = cfg.head_dim or cfg.d_model // cfg.n_heads
    H, KVH = cfg.n_heads, cfg.n_kv_heads or cfg.n_heads
    for dtype in (jnp.float32, jnp.bfloat16):
        q = S((BATCH * H, SEQ, hd), dtype)
        kv = S((BATCH * H, SEQ, hd), dtype)
        yield ("flash_attention",
               lambda q, k, v: flash_attention(
                   q, k, v, causal=True, block_q=32, block_k=32,
                   interpret=True),
               (q, kv, kv), [((BATCH * H, SEQ, hd), dtype)])
        dq = S((BATCH, H, hd), dtype)
        cache = S((BATCH, SEQ, KVH, hd), dtype)
        lengths = S((BATCH,), jnp.int32)
        yield ("decode_attention",
               lambda q, k, v, l: decode_attention(
                   q, k, v, l, block_s=32, interpret=True),
               (dq, cache, cache, lengths), [((BATCH, H, hd), dtype)])
        ps, n_pt = 32, SEQ // 32
        pool = S((BATCH * n_pt + 1, ps, KVH, hd), dtype)
        ptab = S((BATCH, n_pt), jnp.int32)
        yield ("paged_decode_attention",
               lambda q, k, v, pt, l: paged_decode_attention(
                   q, k, v, pt, l, interpret=True),
               (dq, pool, pool, ptab, lengths),
               [((BATCH, H, hd), dtype)])
        if cfg.moe and cfg.n_experts:
            E, C = cfg.n_experts, 32
            x = S((E, C, cfg.d_model), dtype)
            w = S((E, cfg.d_model, cfg.d_ff_expert), dtype)
            yield ("moe_gmm",
                   lambda x, w: moe_gmm(x, w, block_c=32, block_f=32,
                                        block_d=32, interpret=True),
                   (x, w), [((E, C, cfg.d_ff_expert), dtype)])
        if cfg.ssm:
            d_inner = cfg.d_model * cfg.ssm_expand
            nh = max(d_inner // cfg.ssm_head_dim, 1)
            hp, ds = cfg.ssm_head_dim, cfg.d_state
            chunk = min(cfg.ssm_chunk, SEQ)
            seq = chunk * max(SEQ // chunk, 1)
            x = S((BATCH, seq, nh, hp), dtype)
            dt = S((BATCH, seq, nh), jnp.float32)
            A = S((nh,), jnp.float32)
            bg = S((BATCH, seq, 1, ds), dtype)
            yield ("ssd_scan",
                   lambda x, dt, A, b, c, _ck=chunk: ssd_scan(
                       x, dt, A, b, c, chunk=_ck, interpret=True),
                   (x, dt, A, bg, bg),
                   [((BATCH, seq, nh, hp), jnp.float32),
                    ((BATCH, nh, hp, ds), jnp.float32)])


def run(archs=None) -> list[dict]:
    import jax

    from repro.configs.registry import ARCHS, get_smoke_config

    results: list[dict] = []
    for arch in sorted(archs or ARCHS):
        cfg = get_smoke_config(arch)
        for name, fn, specs, expected in _checks(cfg):
            row = {"arch": arch, "kernel": name,
                   "dtype": str(specs[0].dtype), "ok": True, "detail": ""}
            try:
                out = jax.eval_shape(fn, *specs)
            except Exception as e:  # tracer/shape error IS the finding
                row["ok"] = False
                row["detail"] = f"{type(e).__name__}: {e}"
                results.append(row)
                continue
            leaves = jax.tree_util.tree_leaves(out)
            got = [(tuple(x.shape), x.dtype) for x in leaves]
            want = [(tuple(s), d) for s, d in expected]
            if got != want:
                row["ok"] = False
                row["detail"] = f"expected {want}, got {got}"
            results.append(row)
    return results


def main(json_out: bool = False) -> int:
    try:
        import jax  # noqa: F401
    except Exception as e:
        print(f"dclint shapecheck: jax unavailable ({e}); skipping")
        return 0
    results = run()
    bad = [r for r in results if not r["ok"]]
    if json_out:
        print(json.dumps({"shapecheck": results,
                          "failures": len(bad)}, indent=2))
    else:
        for r in bad:
            print(f"dclint shapecheck: {r['arch']}/{r['kernel']} "
                  f"[{r['dtype']}]: {r['detail']}")
        print(f"dclint shapecheck: {len(results) - len(bad)}/"
              f"{len(results)} kernel contracts hold")
    return 1 if bad else 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
