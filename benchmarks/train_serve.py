"""Train+serve consolidation benchmark: does preemptible HTC training
soaking the serve troughs push consolidated billing below dedicated pools
WITHOUT violating serve isolation?

The paper's economies-of-scale claim (§2, §5) is about consolidating
heterogeneous workloads on one platform; ``benchmarks/serve_fleet.py``
answers it for N MTC serve tenants, this benchmark adds the HTC species:
gang-scheduled elastic training tenants (``repro.serve.tenant.
TrainTenant``) sharing the provider pool with the serve lanes through the
``dawningcloud-train-serve`` scenario. Training gangs grow into serve
troughs (elastic up to each job's ``world_max``), checkpoint-and-vacate
when serve demand parks in the admission queue, and resume from the last
checkpoint — so every cell reports the churn (preemptions / resumes /
rollback steps) next to the billing.

Each cell compares:

  - **consolidated**: serve streams + one training tenant on ONE pool
    (capacity = the serve plan + the training gang floor);
  - **dedicated**: each serve tenant on its own fixed width-sized engine
    (the ``serve_fleet.py`` baseline) PLUS a dedicated training pool of
    ``max(world_max)`` nodes driven standalone through the same tenant
    hooks (``drive_tenant``).

Hard gates (``_require``): every serve workflow AND every training step
completes, zero isolation violations / over-admissions, every preemption
eventually resumes, and consolidated billing lands under dedicated.
``benchmarks/check_regression.py`` gates the emitted
``BENCH_train_serve.json`` against the committed baseline + history.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core.policy import MgmtPolicy
from repro.core.provision import ProvisionService
from repro.serve.fleet import TrainServeFleetSystem
from repro.serve.tenant import TrainTenant, TrainTenantSpec, drive_tenant
from repro.sim.traces import TRAIN_PROFILES, train_stream

from serve_fleet import (  # noqa: E402  (sibling benchmark module)
    _require, eager_peak_slots, parse_mix, tenant_streams,
)
from repro.serve.driver import EmulatedEngine, ServeDriver


def run_dedicated_serve(streams, widths, *, policy: MgmtPolicy) -> dict:
    """N dedicated serve engines, one per tenant (the ``serve_fleet.py``
    baseline shape): fixed width-sized slots, no negotiation."""
    total = {"node_hours": 0.0, "slots": 0, "workflows": 0}
    for i, (stream, w) in enumerate(zip(streams, widths)):
        slots = max(eager_peak_slots(stream), policy.initial)
        drv = ServeDriver(stream, provider=ProvisionService(),
                          engine=EmulatedEngine(slots),
                          fixed_nodes=slots * w, slot_width=w,
                          name=f"dedicated-t{i}")
        st = drv.run()
        _require(st.workflows_completed == st.workflows_expected,
                 f"dedicated serve tenant {i} completed "
                 f"{st.workflows_completed}/{st.workflows_expected}")
        total["node_hours"] += st.node_hours
        total["slots"] += slots * w
        total["workflows"] += st.workflows_completed
    return total


def run_dedicated_train(jobs) -> dict:
    """A dedicated HTC training pool: fixed nodes sized at the widest
    gang's ``world_max`` (jobs queue behind each other but every gang can
    reach its full elastic width), driven standalone through the same
    ``Tenant`` hooks the fleet uses. Never preempted — nothing shares the
    pool — so its billing is the pure cost of NOT consolidating."""
    cap = max(j.world_max for j in jobs)
    tenant = TrainTenant(jobs, provider=ProvisionService(),
                         fixed_nodes=cap, name="dedicated-train")
    st = drive_tenant(tenant)
    _require(st.jobs_completed == st.jobs_expected,
             f"dedicated train completed {st.jobs_completed}"
             f"/{st.jobs_expected} jobs")
    _require(st.steps_done == st.steps_expected,
             f"dedicated train ran {st.steps_done}"
             f"/{st.steps_expected} steps")
    _require(st.preemptions == 0,
             f"dedicated train pool preempted itself {st.preemptions}x")
    return {"node_hours": st.node_hours, "nodes": cap,
            "makespan_s": st.makespan_s,
            "slot_utilization": st.slot_utilization}


def run_cell(mix_spec: str, n_serve: int, n_train: int, *,
             workflows: int, seed: int, jobs_scale: float,
             period: float, train_period: float,
             train_scan_s: float = 60.0,
             event_skip: bool = True) -> dict:
    """One (mix, N serve tenants, M training jobs) consolidation cell.

    ``train_scan_s`` is the training tenant's management cadence (scan =
    yield check). The full-size sweep keeps the HTC default (60 s); the
    smoke compresses the arrival windows, so it compresses the cadence
    with them — that is what lets a CI-sized cell still exercise the
    grow-into-trough / preempt-on-burst cycle.
    """
    mix = parse_mix(mix_spec)
    streams, widths = tenant_streams(n_serve, workflows, seed, jobs_scale,
                                     period, mix=mix)
    jobs = train_stream(n_train, seed=seed + 17, period=train_period)
    floor = max(j.world_min for j in jobs)
    spec = TrainTenantSpec(
        jobs=tuple(jobs),
        policy=MgmtPolicy(initial=floor, ratio=2.0,
                          scan_interval=train_scan_s,
                          release_interval=3600.0),
        preempt_check_s=train_scan_s)
    system = TrainServeFleetSystem()

    t0 = time.perf_counter()
    fs = system.serve(streams, train_specs=[spec], widths=widths,
                      event_skip=event_skip,
                      name=f"train-serve-n{n_serve}-m{n_train}")
    wall = time.perf_counter() - t0

    train_rows = [t for t in fs.tenants if "steps_expected" in t]
    _require(len(train_rows) == 1, "expected exactly one training tenant")
    tr = train_rows[0]

    _require(fs.workflows_completed == fs.workflows_expected,
             f"consolidated serve completed {fs.workflows_completed}"
             f"/{fs.workflows_expected} workflows (mix={mix_spec})")
    _require(fs.over_admissions == 0,
             f"over-admissions: {fs.over_admissions}")
    _require(fs.isolation_violations == 0,
             f"isolation violations: {fs.isolation_violations}")
    _require(tr["jobs_completed"] == tr["jobs_expected"],
             f"training completed {tr['jobs_completed']}"
             f"/{tr['jobs_expected']} jobs")
    _require(tr["steps_done"] == tr["steps_expected"],
             f"training ran {tr['steps_done']}/{tr['steps_expected']} steps")
    _require(tr["preemptions"] == tr["resumes"],
             f"{tr['preemptions']} preemptions but {tr['resumes']} resumes "
             f"— a vacated gang never relaunched")

    # identical inputs, separate pools
    streams, widths = tenant_streams(n_serve, workflows, seed, jobs_scale,
                                     period, mix=mix)
    jobs = train_stream(n_train, seed=seed + 17, period=train_period)
    ded_serve = run_dedicated_serve(streams, widths,
                                    policy=system.default_policy())
    ded_train = run_dedicated_train(jobs)
    ded_hours = ded_serve["node_hours"] + ded_train["node_hours"]

    row = {
        "mix": mix_spec,
        "n_tenants": n_serve,
        "train_jobs": n_train,
        "widths": widths,
        "capacity": fs.capacity,
        "workflows": fs.workflows_completed,
        "serve_incomplete": fs.workflows_expected - fs.workflows_completed,
        "train_steps": tr["steps_done"],
        "train_steps_incomplete": tr["steps_expected"] - tr["steps_done"],
        "preemptions": tr["preemptions"],
        "resumes": tr["resumes"],
        "unresumed_preemptions": tr["preemptions"] - tr["resumes"],
        "rollback_steps": tr["rollback_steps"],
        "grow_nodes": tr["grow_nodes"],
        "shrink_nodes": tr["shrink_nodes"],
        "train_peak_owned": tr["peak_owned"],
        "train_busy_node_ticks": tr["busy_node_ticks"],
        "billed_node_hours": fs.node_hours,
        "dedicated_node_hours": ded_hours,
        "dedicated_serve_node_hours": ded_serve["node_hours"],
        "dedicated_train_node_hours": ded_train["node_hours"],
        "billed_vs_dedicated": fs.node_hours / max(ded_hours, 1e-12),
        "slot_utilization": fs.slot_utilization,
        "pool_utilization": fs.pool_utilization,
        "over_admissions": fs.over_admissions,
        "isolation_violations": fs.isolation_violations,
        "makespan_s": fs.makespan_s,
        "wall_s": wall,
    }
    _require(row["billed_vs_dedicated"] < 1.0,
             f"consolidated train+serve bills "
             f"{row['billed_vs_dedicated']:.2f}x dedicated "
             f"(mix={mix_spec} N={n_serve} M={n_train})")
    return row


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tenants", type=int, default=3,
                    help="serve tenants per cell")
    ap.add_argument("--train-jobs", type=int, nargs="+", default=[2, 4, 8],
                    help="training-job counts to sweep (the trough-soak "
                         "curve axis)")
    ap.add_argument("--workflows", type=int, default=12,
                    help="workflows per serve tenant")
    ap.add_argument("--jobs-scale", type=float, default=0.04)
    ap.add_argument("--period", type=float, default=3600.0,
                    help="serve arrival window (s)")
    ap.add_argument("--train-period", type=float, default=7200.0,
                    help="training arrival window (s)")
    ap.add_argument("--train-scan", type=float, default=60.0,
                    help="training tenant scan/yield cadence (s)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mixes", nargs="+", default=["1/2/4"],
                    help="serve width mixes (cycled across tenants)")
    ap.add_argument("--no-event-skip", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized sweep: fewer jobs, smaller mosaics")
    ap.add_argument("--out", default="BENCH_train_serve.json")
    args = ap.parse_args(argv)

    if args.smoke:
        args.tenants = 3
        args.train_jobs = [2, 8]
        args.workflows = 6
        args.jobs_scale = 0.04
        args.period = 1800.0
        args.train_period = 3600.0
        args.train_scan = 6.0   # cadence compressed with the windows

    runs = [run_cell(mix_spec, args.tenants, m,
                     workflows=args.workflows, seed=args.seed,
                     jobs_scale=args.jobs_scale, period=args.period,
                     train_period=args.train_period,
                     train_scan_s=args.train_scan,
                     event_skip=not args.no_event_skip)
            for mix_spec in args.mixes for m in args.train_jobs]

    out = {
        "benchmark": "train_serve",
        "config": {"tenants": args.tenants, "train_jobs": args.train_jobs,
                   "workflows": args.workflows,
                   "jobs_scale": args.jobs_scale, "period_s": args.period,
                   "train_period_s": args.train_period,
                   "train_scan_s": args.train_scan, "seed": args.seed,
                   "mixes": args.mixes, "smoke": args.smoke,
                   "train_profiles": sorted(TRAIN_PROFILES)},
        "runs": runs,
    }
    with open(args.out, "w") as fh:
        json.dump(out, fh, indent=2)
    print(f"wrote {args.out} ({len(runs)} cells)")
    for r in runs:
        print(f"  mix={r['mix']:>6s} M={r['train_jobs']} "
              f"billed/dedic={r['billed_vs_dedicated']:.3f} "
              f"steps={r['train_steps']} preempt={r['preemptions']} "
              f"rollback={r['rollback_steps']} "
              f"iso={r['isolation_violations']} wall={r['wall_s']:.2f}s")
    return out


if __name__ == "__main__":
    main()
