"""Multi-tenant serve-fleet benchmark: the economies-of-scale curve for
the SERVING path — N tenant streams consolidated on one engine pool vs N
dedicated engines, for homogeneous AND heterogeneous width mixes.

For each tenant count N, width mix (``--mixes``, e.g. ``1`` = every
tenant a width-1 small model, ``1/2/4`` = small/medium/large model
classes cycled across tenants — ``sim.traces.SERVE_PROFILES``) and
coordination policy (``first-come`` vs ``coordinated``):

  - **dedicated baseline**: every tenant gets its own fixed engine sized
    at its own *eager-execution peak* — the slot count that serves every
    workflow with zero queueing delay, the serving analogue of the
    paper's DCS configuration (Montage's "accumulated parallel demand
    ~166 nodes") — at the tenant's width (a width-w tenant's dedicated
    engine bills w node units per slot), replayed through a standalone
    ``ServeDriver`` with no negotiation; billed node-hours = its
    width-sized engine held for its whole run.
  - **consolidated fleet**: the same N streams on ONE
    ``PartitionedEngine`` pool sized at the *fleet-wide* width-weighted
    peak hourly-averaged offered decode load (statistical multiplexing:
    the peak of the sum grows sublinearly while the sum of peaks is
    linear), node units partitioned by the provider's coordination
    policy, DSP management policies per tenant (elastic grow/release,
    B priced at the tenant's width), deferred grants through the
    admission queue, finished tenants destroyed mid-run so their units
    serve the stragglers.

Every consolidated cell must complete every workflow with ZERO
over-admissions and ZERO weighted-isolation violations (``strict=True``
raises on either at the offending tick — checks that survive
``python -O``), and for N >= 3 its per-tenant billed node-hours must
come in under the dedicated baseline under BOTH policies and EVERY mix —
asserted, not just reported.

Output: ``BENCH_serve_fleet.json`` (CI uploads it as an artifact and
``benchmarks/check_regression.py`` gates it against the committed
baseline and the rolling history window).
"""
from __future__ import annotations

import argparse
import json
import os
import time
from concurrent.futures import ProcessPoolExecutor

from repro.core.policy import MgmtPolicy
from repro.core.provision import ProvisionService
from repro.serve.driver import EmulatedEngine, ServeDriver
from repro.serve.fleet import ServeFleet, ServeFleetSystem, rekey_disjoint
from repro.sim.traces import SERVE_PROFILES, workload_family


def _require(cond: bool, msg: str) -> None:
    """Acceptance-gate check that survives ``python -O`` (unlike assert)."""
    if not cond:
        raise RuntimeError(f"serve_fleet gate: {msg}")


#: tokens per KV page for the paged ledger riding under every
#: consolidated cell (and for the physical engine on the ``--real`` leg)
PAGE_SIZE = 8


def fleet_depth(streams, page_size: int = PAGE_SIZE) -> int:
    """Cache depth serving every request's full decode mark —
    ``max(prompt + decode + 1)`` over all jobs, rounded up to a page
    multiple. At this depth ``decode_budget`` never caps a mark, so a
    ``max_len``-capped (and paged) engine's service ticks — and therefore
    every ``FleetStats`` field — are identical to the uncapped engine's."""
    need = 2
    for stream in streams:
        for _, jobs in stream:
            for j in jobs:
                need = max(need, max(j.prompt_len, 1) + j.decode_len + 1)
    return -(-need // page_size) * page_size


def eager_peak_slots(stream) -> int:
    """Peak instantaneous slot demand of the stream under eager execution
    (every task decodes the moment its dependencies finish): the engine
    size a dedicated provider must own to serve with zero queueing delay
    — the DCS-configuration analogue for the serving path."""
    events: list[tuple[float, int]] = []
    for t0, jobs in stream:
        start: dict[int, float] = {}
        end: dict[int, float] = {}
        remaining = list(jobs)
        while remaining:
            rest = []
            for j in remaining:
                if all(d in end for d in j.deps):
                    s = max((end[d] for d in j.deps), default=0.0)
                    start[j.jid] = s
                    end[j.jid] = s + max(j.decode_len, 1)
                else:
                    rest.append(j)
            if len(rest) == len(remaining):
                raise ValueError("dependency cycle in stream entry")
            remaining = rest
        for j in jobs:
            events.append((t0 + start[j.jid], 1))
            events.append((t0 + end[j.jid], -1))
    events.sort()
    peak = level = 0
    for _, d in events:
        level += d
        peak = max(peak, level)
    return max(peak, 1)


def parse_mix(spec: str) -> list[int]:
    """``"1/2/4"`` -> ``[1, 2, 4]`` (widths cycled across the tenants);
    every width must name a ``SERVE_PROFILES`` model class."""
    widths = [int(tok) for tok in spec.replace(",", "/").split("/") if tok]
    if not widths:
        raise ValueError(f"empty width mix {spec!r}")
    unknown = [w for w in widths if w not in SERVE_PROFILES]
    if unknown:
        raise ValueError(f"no serve profile for widths {unknown} "
                         f"(known: {sorted(SERVE_PROFILES)})")
    return widths


def tenant_streams(n_tenants: int, workflows: int, seed: int,
                   jobs_scale: float, period: float,
                   mix: list[int] | None = None):
    """One workflow arrival stream per tenant (disjoint jid ranges): each
    tenant is its own MTC service provider with its own seeded
    ``workload_family`` of Montage-shaped mosaics, marked by its width
    class's serve profile (cycled through ``mix``). Returns
    ``(streams, widths)``."""
    mix = mix or [1]
    streams, widths = [], []
    for t in range(n_tenants):
        fam = workload_family(0, workflows, seed=seed * 1009 + t,
                              jobs_scale=jobs_scale)
        profile = SERVE_PROFILES[mix[t % len(mix)]]
        streams.append(profile.stream(fam, period=period, seed=seed + t))
        widths.append(profile.width)
    return rekey_disjoint(streams), widths


def tenant_policy(base: MgmtPolicy, width: int) -> MgmtPolicy:
    """The fleet policy priced at the tenant's width (B in node units)."""
    return MgmtPolicy(initial=base.initial * width, ratio=base.ratio,
                      scan_interval=base.scan_interval,
                      release_interval=base.release_interval)


def run_dedicated(streams, widths, *, policy: MgmtPolicy,
                  max_len: int | None = None) -> dict:
    """N dedicated engines: per-tenant fixed width-sized slots, no
    negotiation — a width-w tenant's engine bills w units per slot.
    ``max_len`` caps decode marks to a cache depth, matching a real
    engine baseline (the ``--real`` leg compares like with like)."""
    t0 = time.perf_counter()
    total = {"node_hours": 0.0, "slots": 0, "workflows": 0, "tasks": 0,
             "over_admissions": 0, "busy": 0.0, "owned": 0.0,
             "makespan_s": 0.0}
    for i, (stream, w) in enumerate(zip(streams, widths)):
        # slot floor: the consolidated tenant's B is initial * w units ==
        # `initial` slots at this width, so the floor is width-invariant
        slots = max(eager_peak_slots(stream), policy.initial)
        drv = ServeDriver(stream, provider=ProvisionService(),
                          engine=EmulatedEngine(slots, max_len=max_len),
                          fixed_nodes=slots * w, slot_width=w,
                          name=f"dedicated-t{i}")
        st = drv.run()
        _require(st.workflows_completed == st.workflows_expected,
                 f"dedicated tenant {i} completed {st.workflows_completed}"
                 f"/{st.workflows_expected} workflows")
        _require(st.over_admissions == 0,
                 f"dedicated tenant {i} over-admitted {st.over_admissions}")
        total["node_hours"] += st.node_hours
        total["slots"] += slots * w
        total["workflows"] += st.workflows_completed
        total["tasks"] += st.tasks_completed
        total["busy"] += st.busy_node_ticks
        total["owned"] += st.owned_node_ticks
        total["makespan_s"] = max(total["makespan_s"], st.makespan_s)
    total["slot_utilization"] = (total["busy"] / total["owned"]
                                 if total["owned"] else 0.0)
    total["wall_s"] = time.perf_counter() - t0
    return total


def run_consolidated(streams, widths, *, coordination: str,
                     policy: MgmtPolicy, event_skip: bool = True) -> dict:
    """The fleet: one pool sized at the fleet-wide weighted hourly decode
    peak. Event-skipping is on by default — pinned bit-identical to the
    dense loop by the parity suite, so it changes wall clock only.

    Every cell runs with the physical page ledger underneath
    (``page_size=PAGE_SIZE`` over a ``fleet_depth``-deep cache): admits
    allocate real KV pages under their tenant's quota and conservation is
    swept every tick, yet because the depth serves every mark in full the
    stats stay field-for-field identical to the unpaged PR 7 cells."""
    n = len(streams)
    policies = [tenant_policy(policy, w) for w in widths]
    # size the pool exactly as the registered scenario would: one source
    # of truth for the hourly-peak estimate and the liveness floor
    capacity = ServeFleetSystem().default_capacity(streams, policies,
                                                   widths=widths)
    depth = fleet_depth(streams)
    fleet = ServeFleet(streams,
                       engine=EmulatedEngine(capacity, max_len=depth),
                       coordination=coordination, policies=policies,
                       widths=widths, name=f"fleet-{coordination}-n{n}",
                       event_skip=event_skip, page_size=PAGE_SIZE)
    t0 = time.perf_counter()
    fs = fleet.run()
    wall = time.perf_counter() - t0
    _require(fs.workflows_completed == fs.workflows_expected,
             f"{coordination} N={n} completed {fs.workflows_completed}"
             f"/{fs.workflows_expected} workflows")
    _require(fs.over_admissions == 0,
             f"{coordination} N={n} over-admitted {fs.over_admissions}")
    _require(fs.isolation_violations == 0,
             f"{coordination} N={n} had {fs.isolation_violations} "
             f"slot-isolation violations")
    pager = fleet.pool.pager
    pager.check_conservation()
    _require(pager.used_pages == 0,
             f"{coordination} N={n} leaked {pager.used_pages} KV pages "
             f"past the last finish")
    out = fs.as_dict()
    out["wall_s"] = wall
    out["page_size"] = PAGE_SIZE
    out["pool_pages"] = pager.capacity_pages
    out["peak_pages_used"] = pager.peak_used
    return out


def run_cell(streams, widths, *, mix: str, coordination: str,
             policy: MgmtPolicy, dedicated: dict,
             event_skip: bool = True) -> dict:
    n = len(streams)
    fleet = run_consolidated(streams, widths, coordination=coordination,
                             policy=policy, event_skip=event_skip)
    row = {
        "n_tenants": n,
        "policy": coordination,
        "mix": mix,
        "widths": widths,
        "capacity": fleet["capacity"],
        "dedicated_slots": dedicated["slots"],
        "slots_vs_dedicated": fleet["capacity"] / max(dedicated["slots"], 1),
        "billed_node_hours": fleet["node_hours"],
        "dedicated_node_hours": dedicated["node_hours"],
        "billed_vs_dedicated": (fleet["node_hours"]
                                / max(dedicated["node_hours"], 1e-12)),
        "billed_per_tenant": fleet["node_hours"] / n,
        "slot_utilization": fleet["slot_utilization"],
        "pool_utilization": fleet["pool_utilization"],
        "dedicated_utilization": dedicated["slot_utilization"],
        "workflows": fleet["workflows_completed"],
        "tasks": fleet["tasks_completed"],
        "makespan_s": fleet["makespan_s"],
        "makespan_vs_dedicated": (fleet["makespan_s"]
                                  / max(dedicated["makespan_s"], 1e-12)),
        "deferred_grants": fleet["deferred_grants"],
        "deferred_nodes": fleet["deferred_nodes"],
        "over_admissions": fleet["over_admissions"],
        "isolation_violations": fleet["isolation_violations"],
        "peak_pool_active": fleet["peak_pool_active"],
        "page_size": fleet["page_size"],
        "pool_pages": fleet["pool_pages"],
        "peak_pages_used": fleet["peak_pages_used"],
        "page_utilization": (fleet["peak_pages_used"]
                             / max(fleet["pool_pages"], 1)),
        "wall_s": fleet["wall_s"],
        "workflows_per_sec": (fleet["workflows_completed"]
                              / max(fleet["wall_s"], 1e-12)),
        "dedicated_wall_s": dedicated["wall_s"],
    }
    # the acceptance gate: consolidation must pay off at fleet scale,
    # for the heterogeneous mixes exactly as for the homogeneous one
    if n >= 3:
        _require(row["billed_vs_dedicated"] < 1.0,
                 f"consolidated fleet bills "
                 f"{row['billed_vs_dedicated']:.2f}x dedicated at N={n} "
                 f"mix={mix} under {coordination}")
    return row


# hourly release windows: dynamic blocks live at least one billing
# unit, so elastic growth does not thrash fresh lease-hours (§4.4(2))
FLEET_POLICY = MgmtPolicy(initial=2, ratio=2.0, scan_interval=3.0,
                          release_interval=3600.0)

# --real leg sizing: a smoke-config musicgen engine, 8 batch slots over a
# 48-token cache = 48 / PAGE_SIZE pages per unit in the physical pool
REAL_MAX_BATCH, REAL_MAX_LEN = 8, 48


def _real_fleet_run(args, mix_spec: str, *, page_size: int | None,
                    seed: int) -> tuple[dict, dict]:
    """One heterogeneous fleet over the REAL jax engine (paged when
    ``page_size`` is set, contiguous otherwise). Streams are regenerated
    from the seed so every run replays the identical workload. Returns
    ``(FleetStats.as_dict(), extras)``."""
    import jax

    from repro.configs import get_smoke_config
    from repro.configs.base import ParallelConfig
    from repro.models.lm import LM
    from repro.serve.driver import JaxEngineAdapter
    from repro.serve.engine import Engine

    cfg = get_smoke_config("musicgen-large")
    lm = LM(cfg)
    rt = lm.runtime(ParallelConfig(attn_q_chunk=16, attn_kv_chunk=16))
    params = lm.init(jax.random.key(0))[0]
    engine = Engine(lm, params, rt, max_batch=REAL_MAX_BATCH,
                    max_len=REAL_MAX_LEN, page_size=page_size)
    adapter = JaxEngineAdapter(engine, seed=seed)

    mix = parse_mix(mix_spec)
    streams, widths = tenant_streams(len(mix), args.workflows, seed,
                                     args.jobs_scale, args.period, mix=mix)
    base = MgmtPolicy(initial=1, ratio=2.0, scan_interval=3.0,
                      release_interval=60.0)
    fleet = ServeFleet(streams, engine=adapter, coordination="coordinated",
                       policies=[tenant_policy(base, w) for w in widths],
                       widths=widths, event_skip=False,
                       # one name for the paged, contiguous and emulated
                       # runs: stats must match bit-for-bit, labels included
                       name="real-fleet", page_size=page_size)
    t0 = time.perf_counter()
    fs = fleet.run()
    wall = time.perf_counter() - t0
    _require(fs.workflows_completed == fs.workflows_expected,
             f"real mix={mix_spec} paged={bool(page_size)} completed "
             f"{fs.workflows_completed}/{fs.workflows_expected}")
    extras = {"wall_s": wall, "decode_steps": engine.steps,
              "widths": widths}
    if page_size is not None:
        fleet.pool.pager.check_conservation()
        _require(engine.pager.used_pages == fleet.pool.pager.used_pages,
                 "engine/pool page ledgers diverged post-run")
        extras["pool_pages"] = fleet.pool.pager.capacity_pages
        extras["peak_pages_used"] = fleet.pool.pager.peak_used
    return fs.as_dict(), extras


def run_real_fleet(args) -> dict:
    """The ``--real`` leg: the heterogeneous 1/2/4 fleet on the PHYSICAL
    paged engine, pinned three ways —

    - **emulator parity**: an ``EmulatedEngine(max_len=REAL_MAX_LEN)``
      twin fleet replays the identical streams; every deterministic
      ``FleetStats`` field must match the live-jax run bit-for-bit
      (``parity_mismatches == 0``).
    - **paged vs contiguous**: the same fleet on a contiguous-cache
      ``Engine`` must reproduce the paged stats field-for-field
      (``paged_vs_contiguous_mismatches == 0``) — paging is a memory
      layout, never a scheduling input.
    - **economics**: billed node-hours under a width-capped dedicated
      baseline (``billed_vs_dedicated``), the paper's consolidation
      claim surviving contact with a real engine.
    """
    rows = []
    for mix_spec in args.mixes:
        seed = args.seed
        paged, paged_x = _real_fleet_run(args, mix_spec,
                                         page_size=PAGE_SIZE, seed=seed)
        contig, contig_x = _real_fleet_run(args, mix_spec,
                                           page_size=None, seed=seed)

        mix = parse_mix(mix_spec)
        streams, widths = tenant_streams(len(mix), args.workflows, seed,
                                         args.jobs_scale, args.period,
                                         mix=mix)
        base = MgmtPolicy(initial=1, ratio=2.0, scan_interval=3.0,
                          release_interval=60.0)
        twin = ServeFleet(streams,
                          engine=EmulatedEngine(REAL_MAX_BATCH,
                                                max_len=REAL_MAX_LEN),
                          coordination="coordinated",
                          policies=[tenant_policy(base, w) for w in widths],
                          widths=widths, event_skip=False,
                          name="real-fleet", page_size=PAGE_SIZE)
        emu = twin.run().as_dict()

        streams, widths = tenant_streams(len(mix), args.workflows, seed,
                                         args.jobs_scale, args.period,
                                         mix=mix)
        dedicated = run_dedicated(streams, widths, policy=base,
                                  max_len=REAL_MAX_LEN)

        parity = [k for k in emu if emu[k] != paged.get(k)]
        pvc = [k for k in paged if paged[k] != contig.get(k)]
        row = {
            "mix": mix_spec,
            "n_tenants": len(mix),
            "widths": paged_x["widths"],
            "workflows": paged["workflows_completed"],
            "tasks": paged["tasks_completed"],
            "parity_mismatches": len(parity),
            "parity_fields": parity,
            "paged_vs_contiguous_mismatches": len(pvc),
            "paged_vs_contiguous_fields": pvc,
            "over_admissions": paged["over_admissions"],
            "isolation_violations": paged["isolation_violations"],
            "billed_node_hours": paged["node_hours"],
            "dedicated_node_hours": dedicated["node_hours"],
            "billed_vs_dedicated": (paged["node_hours"]
                                    / max(dedicated["node_hours"], 1e-12)),
            "page_size": PAGE_SIZE,
            "pool_pages": paged_x["pool_pages"],
            "peak_pages_used": paged_x["peak_pages_used"],
            "decode_steps": paged_x["decode_steps"],
            "contiguous_decode_steps": contig_x["decode_steps"],
            "wall_s": paged_x["wall_s"],
            "contiguous_wall_s": contig_x["wall_s"],
            "decode_steps_per_sec": (paged_x["decode_steps"]
                                     / max(paged_x["wall_s"], 1e-12)),
        }
        _require(row["parity_mismatches"] == 0,
                 f"emulator-vs-real stats diverged on {parity} "
                 f"(mix={mix_spec})")
        _require(row["paged_vs_contiguous_mismatches"] == 0,
                 f"paged-vs-contiguous stats diverged on {pvc} "
                 f"(mix={mix_spec})")
        rows.append(row)
    return {
        "benchmark": "serve_fleet_real",
        "config": {"workflows": args.workflows,
                   "jobs_scale": args.jobs_scale, "period_s": args.period,
                   "seed": args.seed, "mixes": args.mixes,
                   "arch": "musicgen-large", "max_batch": REAL_MAX_BATCH,
                   "max_len": REAL_MAX_LEN, "page_size": PAGE_SIZE},
        "runs": rows,
    }


def run_matrix_cell(cell: tuple) -> list[dict]:
    """One ``(mix, N)`` point of the sweep — a dedicated baseline plus
    both coordination policies. Top-level (picklable) so ``--procs``
    shards the matrix across a worker pool, exactly as
    ``benchmarks/scale_curve.py`` shards providers; cells are
    seed-deterministic, so sharding cannot change any number."""
    mix_spec, n, workflows, seed, jobs_scale, period, event_skip = cell
    mix = parse_mix(mix_spec)
    streams, widths = tenant_streams(n, workflows, seed, jobs_scale,
                                     period, mix=mix)
    dedicated = run_dedicated(streams, widths, policy=FLEET_POLICY)
    return [run_cell(streams, widths, mix=mix_spec,
                     coordination=coordination, policy=FLEET_POLICY,
                     dedicated=dedicated, event_skip=event_skip)
            for coordination in ("first-come", "coordinated")]


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tenants", type=int, nargs="+", default=[1, 3, 6, 12])
    ap.add_argument("--workflows", type=int, default=24,
                    help="workflows per tenant")
    ap.add_argument("--jobs-scale", type=float, default=0.05)
    ap.add_argument("--period", type=float, default=3600.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mixes", nargs="+", default=["1", "1/2/4"],
                    help="width mixes to sweep (cycled across tenants); "
                         "'1' = the homogeneous PR 4 fleet")
    ap.add_argument("--procs", type=int, default=None,
                    help="process-pool width over (mix, N) cells "
                         "(default: min(cells, cpu count))")
    ap.add_argument("--no-event-skip", action="store_true",
                    help="dense tick loop (the reference; results are "
                         "bit-identical either way, only wall differs)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized sweep: fewer tenants, smaller mosaics")
    ap.add_argument("--real", action="store_true",
                    help="heterogeneous fleet on the real jax engine "
                         "(paged + contiguous + emulated twin), pinning "
                         "emulator-vs-real and paged-vs-contiguous parity")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    if args.out is None:
        args.out = ("BENCH_serve_fleet_real.json" if args.real
                    else "BENCH_serve_fleet.json")

    if args.smoke:
        args.tenants = [1, 3, 6]
        args.workflows = 10
        args.jobs_scale = 0.04
        args.period = 3600.0

    if args.real:
        args.workflows = min(args.workflows, 4)
        out = run_real_fleet(args)
        with open(args.out, "w") as fh:
            json.dump(out, fh, indent=2)
        print(f"wrote {args.out} ({len(out['runs'])} real-engine cells)")
        for r in out["runs"]:
            print(f"  mix={r['mix']:>6s} parity={r['parity_mismatches']} "
                  f"paged-vs-contig={r['paged_vs_contiguous_mismatches']} "
                  f"billed/dedic={r['billed_vs_dedicated']:.3f} "
                  f"pages={r['peak_pages_used']}/{r['pool_pages']} "
                  f"steps={r['decode_steps']} wall={r['wall_s']:.1f}s")
        return out

    policy = FLEET_POLICY
    cells = [(mix_spec, n, args.workflows, args.seed, args.jobs_scale,
              args.period, not args.no_event_skip)
             for mix_spec in args.mixes for n in args.tenants]
    procs = args.procs or min(len(cells), os.cpu_count() or 1)
    if procs > 1:
        with ProcessPoolExecutor(max_workers=procs) as pool:
            per_cell = list(pool.map(run_matrix_cell, cells))
    else:
        per_cell = [run_matrix_cell(c) for c in cells]
    runs = [row for rows in per_cell for row in rows]

    out = {
        "benchmark": "serve_fleet",
        "config": {"tenants": args.tenants, "workflows": args.workflows,
                   "jobs_scale": args.jobs_scale, "period_s": args.period,
                   "seed": args.seed, "smoke": args.smoke,
                   "mixes": args.mixes, "procs": procs,
                   "policy": {"initial": policy.initial,
                              "ratio": policy.ratio,
                              "release_interval": policy.release_interval}},
        "runs": runs,
    }
    with open(args.out, "w") as fh:
        json.dump(out, fh, indent=2)

    n_tasks = sum(r["tasks"] for r in runs)
    print(f"wrote {args.out} ({n_tasks} tasks across {len(runs)} cells)")
    print(f"{'N':>4s} {'mix':>6s} {'policy':>12s} {'pool':>5s} "
          f"{'dedic':>6s} {'billed':>8s} {'vs-dedic':>9s} {'util':>6s} "
          f"{'defer':>6s}")
    for r in runs:
        print(f"{r['n_tenants']:>4d} {r['mix']:>6s} {r['policy']:>12s} "
              f"{r['capacity']:>5d} {r['dedicated_slots']:>6d} "
              f"{r['billed_node_hours']:>8.0f} "
              f"{r['billed_vs_dedicated']:>9.3f} "
              f"{r['slot_utilization']:>6.1%} {r['deferred_grants']:>6d}")
    return out


if __name__ == "__main__":
    main()
