"""Multi-tenant serve-fleet benchmark: the economies-of-scale curve for
the SERVING path — N tenant streams consolidated on one engine pool vs N
dedicated engines, for homogeneous AND heterogeneous width mixes.

For each tenant count N, width mix (``--mixes``, e.g. ``1`` = every
tenant a width-1 small model, ``1/2/4`` = small/medium/large model
classes cycled across tenants — ``sim.traces.SERVE_PROFILES``) and
coordination policy (``first-come`` vs ``coordinated``):

  - **dedicated baseline**: every tenant gets its own fixed engine sized
    at its own *eager-execution peak* — the slot count that serves every
    workflow with zero queueing delay, the serving analogue of the
    paper's DCS configuration (Montage's "accumulated parallel demand
    ~166 nodes") — at the tenant's width (a width-w tenant's dedicated
    engine bills w node units per slot), replayed through a standalone
    ``ServeDriver`` with no negotiation; billed node-hours = its
    width-sized engine held for its whole run.
  - **consolidated fleet**: the same N streams on ONE
    ``PartitionedEngine`` pool sized at the *fleet-wide* width-weighted
    peak hourly-averaged offered decode load (statistical multiplexing:
    the peak of the sum grows sublinearly while the sum of peaks is
    linear), node units partitioned by the provider's coordination
    policy, DSP management policies per tenant (elastic grow/release,
    B priced at the tenant's width), deferred grants through the
    admission queue, finished tenants destroyed mid-run so their units
    serve the stragglers.

Every consolidated cell must complete every workflow with ZERO
over-admissions and ZERO weighted-isolation violations (``strict=True``
raises on either at the offending tick — checks that survive
``python -O``), and for N >= 3 its per-tenant billed node-hours must
come in under the dedicated baseline under BOTH policies and EVERY mix —
asserted, not just reported.

Output: ``BENCH_serve_fleet.json`` (CI uploads it as an artifact and
``benchmarks/check_regression.py`` gates it against the committed
baseline and the rolling history window).
"""
from __future__ import annotations

import argparse
import json
import os
import time
from concurrent.futures import ProcessPoolExecutor

from repro.core.policy import MgmtPolicy
from repro.core.provision import ProvisionService
from repro.serve.driver import EmulatedEngine, ServeDriver
from repro.serve.fleet import ServeFleet, ServeFleetSystem, rekey_disjoint
from repro.sim.traces import SERVE_PROFILES, workload_family


def _require(cond: bool, msg: str) -> None:
    """Acceptance-gate check that survives ``python -O`` (unlike assert)."""
    if not cond:
        raise RuntimeError(f"serve_fleet gate: {msg}")


def eager_peak_slots(stream) -> int:
    """Peak instantaneous slot demand of the stream under eager execution
    (every task decodes the moment its dependencies finish): the engine
    size a dedicated provider must own to serve with zero queueing delay
    — the DCS-configuration analogue for the serving path."""
    events: list[tuple[float, int]] = []
    for t0, jobs in stream:
        start: dict[int, float] = {}
        end: dict[int, float] = {}
        remaining = list(jobs)
        while remaining:
            rest = []
            for j in remaining:
                if all(d in end for d in j.deps):
                    s = max((end[d] for d in j.deps), default=0.0)
                    start[j.jid] = s
                    end[j.jid] = s + max(j.decode_len, 1)
                else:
                    rest.append(j)
            if len(rest) == len(remaining):
                raise ValueError("dependency cycle in stream entry")
            remaining = rest
        for j in jobs:
            events.append((t0 + start[j.jid], 1))
            events.append((t0 + end[j.jid], -1))
    events.sort()
    peak = level = 0
    for _, d in events:
        level += d
        peak = max(peak, level)
    return max(peak, 1)


def parse_mix(spec: str) -> list[int]:
    """``"1/2/4"`` -> ``[1, 2, 4]`` (widths cycled across the tenants);
    every width must name a ``SERVE_PROFILES`` model class."""
    widths = [int(tok) for tok in spec.replace(",", "/").split("/") if tok]
    if not widths:
        raise ValueError(f"empty width mix {spec!r}")
    unknown = [w for w in widths if w not in SERVE_PROFILES]
    if unknown:
        raise ValueError(f"no serve profile for widths {unknown} "
                         f"(known: {sorted(SERVE_PROFILES)})")
    return widths


def tenant_streams(n_tenants: int, workflows: int, seed: int,
                   jobs_scale: float, period: float,
                   mix: list[int] | None = None):
    """One workflow arrival stream per tenant (disjoint jid ranges): each
    tenant is its own MTC service provider with its own seeded
    ``workload_family`` of Montage-shaped mosaics, marked by its width
    class's serve profile (cycled through ``mix``). Returns
    ``(streams, widths)``."""
    mix = mix or [1]
    streams, widths = [], []
    for t in range(n_tenants):
        fam = workload_family(0, workflows, seed=seed * 1009 + t,
                              jobs_scale=jobs_scale)
        profile = SERVE_PROFILES[mix[t % len(mix)]]
        streams.append(profile.stream(fam, period=period, seed=seed + t))
        widths.append(profile.width)
    return rekey_disjoint(streams), widths


def tenant_policy(base: MgmtPolicy, width: int) -> MgmtPolicy:
    """The fleet policy priced at the tenant's width (B in node units)."""
    return MgmtPolicy(initial=base.initial * width, ratio=base.ratio,
                      scan_interval=base.scan_interval,
                      release_interval=base.release_interval)


def run_dedicated(streams, widths, *, policy: MgmtPolicy) -> dict:
    """N dedicated engines: per-tenant fixed width-sized slots, no
    negotiation — a width-w tenant's engine bills w units per slot."""
    t0 = time.perf_counter()
    total = {"node_hours": 0.0, "slots": 0, "workflows": 0, "tasks": 0,
             "over_admissions": 0, "busy": 0.0, "owned": 0.0,
             "makespan_s": 0.0}
    for i, (stream, w) in enumerate(zip(streams, widths)):
        # slot floor: the consolidated tenant's B is initial * w units ==
        # `initial` slots at this width, so the floor is width-invariant
        slots = max(eager_peak_slots(stream), policy.initial)
        drv = ServeDriver(stream, provider=ProvisionService(),
                          engine=EmulatedEngine(slots),
                          fixed_nodes=slots * w, slot_width=w,
                          name=f"dedicated-t{i}")
        st = drv.run()
        _require(st.workflows_completed == st.workflows_expected,
                 f"dedicated tenant {i} completed {st.workflows_completed}"
                 f"/{st.workflows_expected} workflows")
        _require(st.over_admissions == 0,
                 f"dedicated tenant {i} over-admitted {st.over_admissions}")
        total["node_hours"] += st.node_hours
        total["slots"] += slots * w
        total["workflows"] += st.workflows_completed
        total["tasks"] += st.tasks_completed
        total["busy"] += st.busy_node_ticks
        total["owned"] += st.owned_node_ticks
        total["makespan_s"] = max(total["makespan_s"], st.makespan_s)
    total["slot_utilization"] = (total["busy"] / total["owned"]
                                 if total["owned"] else 0.0)
    total["wall_s"] = time.perf_counter() - t0
    return total


def run_consolidated(streams, widths, *, coordination: str,
                     policy: MgmtPolicy, event_skip: bool = True) -> dict:
    """The fleet: one pool sized at the fleet-wide weighted hourly decode
    peak. Event-skipping is on by default — pinned bit-identical to the
    dense loop by the parity suite, so it changes wall clock only."""
    n = len(streams)
    policies = [tenant_policy(policy, w) for w in widths]
    # size the pool exactly as the registered scenario would: one source
    # of truth for the hourly-peak estimate and the liveness floor
    capacity = ServeFleetSystem().default_capacity(streams, policies,
                                                   widths=widths)
    fleet = ServeFleet(streams, engine=EmulatedEngine(capacity),
                       coordination=coordination, policies=policies,
                       widths=widths, name=f"fleet-{coordination}-n{n}",
                       event_skip=event_skip)
    t0 = time.perf_counter()
    fs = fleet.run()
    wall = time.perf_counter() - t0
    _require(fs.workflows_completed == fs.workflows_expected,
             f"{coordination} N={n} completed {fs.workflows_completed}"
             f"/{fs.workflows_expected} workflows")
    _require(fs.over_admissions == 0,
             f"{coordination} N={n} over-admitted {fs.over_admissions}")
    _require(fs.isolation_violations == 0,
             f"{coordination} N={n} had {fs.isolation_violations} "
             f"slot-isolation violations")
    out = fs.as_dict()
    out["wall_s"] = wall
    return out


def run_cell(streams, widths, *, mix: str, coordination: str,
             policy: MgmtPolicy, dedicated: dict,
             event_skip: bool = True) -> dict:
    n = len(streams)
    fleet = run_consolidated(streams, widths, coordination=coordination,
                             policy=policy, event_skip=event_skip)
    row = {
        "n_tenants": n,
        "policy": coordination,
        "mix": mix,
        "widths": widths,
        "capacity": fleet["capacity"],
        "dedicated_slots": dedicated["slots"],
        "slots_vs_dedicated": fleet["capacity"] / max(dedicated["slots"], 1),
        "billed_node_hours": fleet["node_hours"],
        "dedicated_node_hours": dedicated["node_hours"],
        "billed_vs_dedicated": (fleet["node_hours"]
                                / max(dedicated["node_hours"], 1e-12)),
        "billed_per_tenant": fleet["node_hours"] / n,
        "slot_utilization": fleet["slot_utilization"],
        "pool_utilization": fleet["pool_utilization"],
        "dedicated_utilization": dedicated["slot_utilization"],
        "workflows": fleet["workflows_completed"],
        "tasks": fleet["tasks_completed"],
        "makespan_s": fleet["makespan_s"],
        "makespan_vs_dedicated": (fleet["makespan_s"]
                                  / max(dedicated["makespan_s"], 1e-12)),
        "deferred_grants": fleet["deferred_grants"],
        "deferred_nodes": fleet["deferred_nodes"],
        "over_admissions": fleet["over_admissions"],
        "isolation_violations": fleet["isolation_violations"],
        "peak_pool_active": fleet["peak_pool_active"],
        "wall_s": fleet["wall_s"],
        "workflows_per_sec": (fleet["workflows_completed"]
                              / max(fleet["wall_s"], 1e-12)),
        "dedicated_wall_s": dedicated["wall_s"],
    }
    # the acceptance gate: consolidation must pay off at fleet scale,
    # for the heterogeneous mixes exactly as for the homogeneous one
    if n >= 3:
        _require(row["billed_vs_dedicated"] < 1.0,
                 f"consolidated fleet bills "
                 f"{row['billed_vs_dedicated']:.2f}x dedicated at N={n} "
                 f"mix={mix} under {coordination}")
    return row


# hourly release windows: dynamic blocks live at least one billing
# unit, so elastic growth does not thrash fresh lease-hours (§4.4(2))
FLEET_POLICY = MgmtPolicy(initial=2, ratio=2.0, scan_interval=3.0,
                          release_interval=3600.0)


def run_matrix_cell(cell: tuple) -> list[dict]:
    """One ``(mix, N)`` point of the sweep — a dedicated baseline plus
    both coordination policies. Top-level (picklable) so ``--procs``
    shards the matrix across a worker pool, exactly as
    ``benchmarks/scale_curve.py`` shards providers; cells are
    seed-deterministic, so sharding cannot change any number."""
    mix_spec, n, workflows, seed, jobs_scale, period, event_skip = cell
    mix = parse_mix(mix_spec)
    streams, widths = tenant_streams(n, workflows, seed, jobs_scale,
                                     period, mix=mix)
    dedicated = run_dedicated(streams, widths, policy=FLEET_POLICY)
    return [run_cell(streams, widths, mix=mix_spec,
                     coordination=coordination, policy=FLEET_POLICY,
                     dedicated=dedicated, event_skip=event_skip)
            for coordination in ("first-come", "coordinated")]


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tenants", type=int, nargs="+", default=[1, 3, 6, 12])
    ap.add_argument("--workflows", type=int, default=24,
                    help="workflows per tenant")
    ap.add_argument("--jobs-scale", type=float, default=0.05)
    ap.add_argument("--period", type=float, default=3600.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mixes", nargs="+", default=["1", "1/2/4"],
                    help="width mixes to sweep (cycled across tenants); "
                         "'1' = the homogeneous PR 4 fleet")
    ap.add_argument("--procs", type=int, default=None,
                    help="process-pool width over (mix, N) cells "
                         "(default: min(cells, cpu count))")
    ap.add_argument("--no-event-skip", action="store_true",
                    help="dense tick loop (the reference; results are "
                         "bit-identical either way, only wall differs)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized sweep: fewer tenants, smaller mosaics")
    ap.add_argument("--out", default="BENCH_serve_fleet.json")
    args = ap.parse_args(argv)

    if args.smoke:
        args.tenants = [1, 3, 6]
        args.workflows = 10
        args.jobs_scale = 0.04
        args.period = 3600.0

    policy = FLEET_POLICY
    cells = [(mix_spec, n, args.workflows, args.seed, args.jobs_scale,
              args.period, not args.no_event_skip)
             for mix_spec in args.mixes for n in args.tenants]
    procs = args.procs or min(len(cells), os.cpu_count() or 1)
    if procs > 1:
        with ProcessPoolExecutor(max_workers=procs) as pool:
            per_cell = list(pool.map(run_matrix_cell, cells))
    else:
        per_cell = [run_matrix_cell(c) for c in cells]
    runs = [row for rows in per_cell for row in rows]

    out = {
        "benchmark": "serve_fleet",
        "config": {"tenants": args.tenants, "workflows": args.workflows,
                   "jobs_scale": args.jobs_scale, "period_s": args.period,
                   "seed": args.seed, "smoke": args.smoke,
                   "mixes": args.mixes, "procs": procs,
                   "policy": {"initial": policy.initial,
                              "ratio": policy.ratio,
                              "release_interval": policy.release_interval}},
        "runs": runs,
    }
    with open(args.out, "w") as fh:
        json.dump(out, fh, indent=2)

    n_tasks = sum(r["tasks"] for r in runs)
    print(f"wrote {args.out} ({n_tasks} tasks across {len(runs)} cells)")
    print(f"{'N':>4s} {'mix':>6s} {'policy':>12s} {'pool':>5s} "
          f"{'dedic':>6s} {'billed':>8s} {'vs-dedic':>9s} {'util':>6s} "
          f"{'defer':>6s}")
    for r in runs:
        print(f"{r['n_tenants']:>4d} {r['mix']:>6s} {r['policy']:>12s} "
              f"{r['capacity']:>5d} {r['dedicated_slots']:>6d} "
              f"{r['billed_node_hours']:>8.0f} "
              f"{r['billed_vs_dedicated']:>9.3f} "
              f"{r['slot_utilization']:>6.1%} {r['deferred_grants']:>6d}")
    return out


if __name__ == "__main__":
    main()
