"""Multi-tenant serve-fleet benchmark: the economies-of-scale curve for
the SERVING path — N tenant streams consolidated on one engine pool vs N
dedicated engines.

For each tenant count N and coordination policy (``first-come`` vs
``coordinated``):

  - **dedicated baseline**: every tenant gets its own fixed engine sized
    at its own *eager-execution peak* — the slot count that serves every
    workflow with zero queueing delay, the serving analogue of the
    paper's DCS configuration (Montage's "accumulated parallel demand
    ~166 nodes") — and replays its workflow stream through a standalone
    ``ServeDriver`` with no negotiation; billed node-hours = its engine
    held for its whole run.
  - **consolidated fleet**: the same N streams on ONE
    ``PartitionedEngine`` pool sized at the *fleet-wide* peak
    hourly-averaged offered decode load (statistical multiplexing: the
    peak of the sum grows sublinearly while the sum of peaks is linear),
    slots partitioned by the provider's coordination policy, DSP
    management policies per tenant (elastic grow/release), deferred
    grants through the admission queue, finished tenants destroyed
    mid-run so their slots serve the stragglers.

Every consolidated cell must complete every workflow with ZERO
over-admissions and ZERO isolation violations (``strict=True`` raises on
either at the offending tick — checks that survive ``python -O``), and
for N >= 3 its per-tenant billed node-hours must come in under the
dedicated baseline under BOTH policies — asserted, not just reported.

Output: ``BENCH_serve_fleet.json`` (CI uploads it as an artifact and
``benchmarks/check_regression.py`` gates it against the committed
baseline).
"""
from __future__ import annotations

import argparse
import json
import math
import time

from repro.core.policy import MgmtPolicy
from repro.core.provision import ProvisionService
from repro.serve.driver import EmulatedEngine, ServeDriver
from repro.serve.fleet import ServeFleet, ServeFleetSystem, rekey_disjoint
from repro.sim.traces import request_stream, workload_family


def _require(cond: bool, msg: str) -> None:
    """Acceptance-gate check that survives ``python -O`` (unlike assert)."""
    if not cond:
        raise RuntimeError(f"serve_fleet gate: {msg}")


def eager_peak_slots(stream) -> int:
    """Peak instantaneous slot demand of the stream under eager execution
    (every task decodes the moment its dependencies finish): the engine
    size a dedicated provider must own to serve with zero queueing delay
    — the DCS-configuration analogue for the serving path."""
    events: list[tuple[float, int]] = []
    for t0, jobs in stream:
        start: dict[int, float] = {}
        end: dict[int, float] = {}
        remaining = list(jobs)
        while remaining:
            rest = []
            for j in remaining:
                if all(d in end for d in j.deps):
                    s = max((end[d] for d in j.deps), default=0.0)
                    start[j.jid] = s
                    end[j.jid] = s + max(j.decode_len, 1)
                else:
                    rest.append(j)
            if len(rest) == len(remaining):
                raise ValueError("dependency cycle in stream entry")
            remaining = rest
        for j in jobs:
            events.append((t0 + start[j.jid], 1))
            events.append((t0 + end[j.jid], -1))
    events.sort()
    peak = level = 0
    for _, d in events:
        level += d
        peak = max(peak, level)
    return max(peak, 1)


def tenant_streams(n_tenants: int, workflows: int, seed: int,
                   jobs_scale: float, period: float):
    """One workflow arrival stream per tenant (disjoint jid ranges): each
    tenant is its own MTC service provider with its own seeded
    ``workload_family`` of Montage-shaped mosaics."""
    streams = []
    for t in range(n_tenants):
        fam = workload_family(0, workflows, seed=seed * 1009 + t,
                              jobs_scale=jobs_scale)
        streams.append(request_stream(fam, period=period, seed=seed + t))
    return rekey_disjoint(streams)


def run_dedicated(streams, *, policy: MgmtPolicy) -> dict:
    """N dedicated engines: per-tenant fixed slots, no negotiation."""
    t0 = time.perf_counter()
    total = {"node_hours": 0.0, "slots": 0, "workflows": 0, "tasks": 0,
             "over_admissions": 0, "busy": 0.0, "owned": 0.0,
             "makespan_s": 0.0}
    for i, stream in enumerate(streams):
        slots = max(eager_peak_slots(stream), policy.initial)
        drv = ServeDriver(stream, provider=ProvisionService(),
                          engine=EmulatedEngine(slots), fixed_nodes=slots,
                          name=f"dedicated-t{i}")
        st = drv.run()
        _require(st.workflows_completed == st.workflows_expected,
                 f"dedicated tenant {i} completed {st.workflows_completed}"
                 f"/{st.workflows_expected} workflows")
        _require(st.over_admissions == 0,
                 f"dedicated tenant {i} over-admitted {st.over_admissions}")
        total["node_hours"] += st.node_hours
        total["slots"] += slots
        total["workflows"] += st.workflows_completed
        total["tasks"] += st.tasks_completed
        total["busy"] += st.busy_node_ticks
        total["owned"] += st.owned_node_ticks
        total["makespan_s"] = max(total["makespan_s"], st.makespan_s)
    total["slot_utilization"] = (total["busy"] / total["owned"]
                                 if total["owned"] else 0.0)
    total["wall_s"] = time.perf_counter() - t0
    return total


def run_consolidated(streams, *, coordination: str,
                     policy: MgmtPolicy) -> dict:
    """The fleet: one pool sized at the fleet-wide hourly decode peak."""
    n = len(streams)
    policies = [policy] * n
    # size the pool exactly as the registered scenario would: one source
    # of truth for the hourly-peak estimate and the liveness floor
    capacity = ServeFleetSystem().default_capacity(streams, policies)
    fleet = ServeFleet(streams, engine=EmulatedEngine(capacity),
                       coordination=coordination, policies=policies,
                       name=f"fleet-{coordination}-n{n}")
    t0 = time.perf_counter()
    fs = fleet.run()
    wall = time.perf_counter() - t0
    _require(fs.workflows_completed == fs.workflows_expected,
             f"{coordination} N={n} completed {fs.workflows_completed}"
             f"/{fs.workflows_expected} workflows")
    _require(fs.over_admissions == 0,
             f"{coordination} N={n} over-admitted {fs.over_admissions}")
    _require(fs.isolation_violations == 0,
             f"{coordination} N={n} had {fs.isolation_violations} "
             f"slot-isolation violations")
    out = fs.as_dict()
    out["wall_s"] = wall
    return out


def run_cell(streams, *, coordination: str, policy: MgmtPolicy,
             dedicated: dict) -> dict:
    n = len(streams)
    fleet = run_consolidated(streams, coordination=coordination,
                             policy=policy)
    row = {
        "n_tenants": n,
        "policy": coordination,
        "capacity": fleet["capacity"],
        "dedicated_slots": dedicated["slots"],
        "slots_vs_dedicated": fleet["capacity"] / max(dedicated["slots"], 1),
        "billed_node_hours": fleet["node_hours"],
        "dedicated_node_hours": dedicated["node_hours"],
        "billed_vs_dedicated": (fleet["node_hours"]
                                / max(dedicated["node_hours"], 1e-12)),
        "billed_per_tenant": fleet["node_hours"] / n,
        "slot_utilization": fleet["slot_utilization"],
        "pool_utilization": fleet["pool_utilization"],
        "dedicated_utilization": dedicated["slot_utilization"],
        "workflows": fleet["workflows_completed"],
        "tasks": fleet["tasks_completed"],
        "makespan_s": fleet["makespan_s"],
        "makespan_vs_dedicated": (fleet["makespan_s"]
                                  / max(dedicated["makespan_s"], 1e-12)),
        "deferred_grants": fleet["deferred_grants"],
        "deferred_nodes": fleet["deferred_nodes"],
        "over_admissions": fleet["over_admissions"],
        "isolation_violations": fleet["isolation_violations"],
        "peak_pool_active": fleet["peak_pool_active"],
        "wall_s": fleet["wall_s"],
    }
    # the acceptance gate: consolidation must pay off at fleet scale
    if n >= 3:
        _require(row["billed_vs_dedicated"] < 1.0,
                 f"consolidated fleet bills "
                 f"{row['billed_vs_dedicated']:.2f}x dedicated at N={n} "
                 f"under {coordination}")
    return row


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tenants", type=int, nargs="+", default=[1, 3, 6, 12])
    ap.add_argument("--workflows", type=int, default=24,
                    help="workflows per tenant")
    ap.add_argument("--jobs-scale", type=float, default=0.05)
    ap.add_argument("--period", type=float, default=3600.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized sweep: fewer tenants, smaller mosaics")
    ap.add_argument("--out", default="BENCH_serve_fleet.json")
    args = ap.parse_args(argv)

    if args.smoke:
        args.tenants = [1, 3, 6]
        args.workflows = 10
        args.jobs_scale = 0.04
        args.period = 3600.0

    # hourly release windows: dynamic blocks live at least one billing
    # unit, so elastic growth does not thrash fresh lease-hours (§4.4(2))
    policy = MgmtPolicy(initial=2, ratio=2.0, scan_interval=3.0,
                        release_interval=3600.0)
    runs = []
    for n in args.tenants:
        streams = tenant_streams(n, args.workflows, args.seed,
                                 args.jobs_scale, args.period)
        dedicated = run_dedicated(streams, policy=policy)
        for coordination in ("first-come", "coordinated"):
            runs.append(run_cell(streams, coordination=coordination,
                                 policy=policy, dedicated=dedicated))

    out = {
        "benchmark": "serve_fleet",
        "config": {"tenants": args.tenants, "workflows": args.workflows,
                   "jobs_scale": args.jobs_scale, "period_s": args.period,
                   "seed": args.seed, "smoke": args.smoke,
                   "policy": {"initial": policy.initial,
                              "ratio": policy.ratio,
                              "release_interval": policy.release_interval}},
        "runs": runs,
    }
    with open(args.out, "w") as fh:
        json.dump(out, fh, indent=2)

    n_tasks = {r["n_tenants"]: r["tasks"] for r in runs}
    print(f"wrote {args.out} "
          f"({sum(n_tasks.values())} tasks across {len(runs)} cells)")
    print(f"{'N':>4s} {'policy':>12s} {'pool':>5s} {'dedic':>6s} "
          f"{'billed':>8s} {'vs-dedic':>9s} {'util':>6s} {'defer':>6s}")
    for r in runs:
        print(f"{r['n_tenants']:>4d} {r['policy']:>12s} "
              f"{r['capacity']:>5d} {r['dedicated_slots']:>6d} "
              f"{r['billed_node_hours']:>8.0f} "
              f"{r['billed_vs_dedicated']:>9.3f} "
              f"{r['slot_utilization']:>6.1%} {r['deferred_grants']:>6d}")
    return out


if __name__ == "__main__":
    main()
