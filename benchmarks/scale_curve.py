"""Fleet-scale economies-of-scale harness: the paper's headline question
("do MTC or HTC service providers benefit from the economies of scale?")
answered at N providers instead of three.

For each provider count N the harness generates a heterogeneous
``workload_family`` (balanced NASA/BLUE/Montage mix), runs the DCS
baseline (every provider owns a dedicated cluster) and the multi-tenant
``dawningcloud-coordinated`` scenario (one shared platform sized at the
peak *hourly-averaged* aggregate demand, admission queueing, PhoenixCloud
-style arbitration), and reports the economies-of-scale curve:

  - **platform node-hours per provider** — what the consolidated resource
    provider must host (capacity x window) divided by N. Statistical
    multiplexing makes this fall monotonically as N grows, while the DCS
    baseline per provider is flat: the provider-side economies of scale.
    Both sides bill over the *workload window*, the paper's §4.3
    convention (DCS is config x period even though some DCS jobs also
    finish past the window); completion tails are reported separately as
    ``max_makespan_h`` so the queueing-delay cost stays visible.
  - **tenant-billed node-hours per provider** — the Tables 2-4 metric
    summed over leases; stays well below DCS at every N (tenants keep
    their DawningCloud savings) at a modest queueing-delay premium that
    is also reported (makespans, completion).
  - **peak nodes-per-hour per provider** (Fig 13 at fleet scale).

(N, seed) cells run process-pool parallel. The post-simulation accounting
(``node_hours`` / ``peak_nodes_per_hour``) dominates at fleet scale, so
the harness also times the NumPy-vectorized accounting against the
retained per-lease Python reference (``*_loop``) on an N-provider lease
ledger and records the speedup per N.

Output: ``BENCH_scale_curve.json`` (CI uploads it as an artifact so the
perf trajectory accumulates across PRs).
"""
from __future__ import annotations

import argparse
import json
import math
import os
import time
from concurrent.futures import ProcessPoolExecutor

from repro.core.provision import ProvisionService
from repro.sim.systems import run_system
from repro.sim.traces import workload_family


def family_for(n_providers: int, seed: int, jobs_scale: float):
    """Balanced mix: one MTC provider per triple (2 HTC + 1 MTC), matching
    the paper's consolidated workload composition at any N."""
    n_mtc = max(n_providers // 3, 1) if n_providers >= 3 else 0
    n_htc = n_providers - n_mtc
    return workload_family(n_htc, n_mtc, seed=seed, jobs_scale=jobs_scale)


def run_cell(args: tuple) -> dict:
    """One (N, seed) cell: DCS baseline vs coordinated consolidation."""
    n_providers, seed, jobs_scale = args
    fam = family_for(n_providers, seed, jobs_scale)
    t0 = time.perf_counter()
    dcs = run_system("dcs", fam)
    t_dcs = time.perf_counter() - t0
    t0 = time.perf_counter()
    coord = run_system("dawningcloud-coordinated", fam)
    t_coord = time.perf_counter() - t0
    window_h = math.ceil(coord.window_s / 3600.0)
    n = n_providers
    completed = sum(r.completed_total for r in coord.per_workload.values())
    expected = sum(len(wl.jobs) for wl in fam)
    return {
        "n_providers": n,
        "seed": seed,
        "capacity": coord.capacity,
        "window_h": window_h,
        "dcs_total_node_hours": dcs.total_node_hours,
        "dcs_per_provider": dcs.total_node_hours / n,
        "coord_platform_node_hours": coord.capacity * window_h,
        "coord_platform_per_provider": coord.capacity * window_h / n,
        "coord_billed_node_hours": coord.total_node_hours,
        "coord_billed_per_provider": coord.total_node_hours / n,
        "coord_peak_nodes_per_hour": coord.peak_nodes_per_hour,
        "dcs_peak_nodes_per_hour": dcs.peak_nodes_per_hour,
        "coord_adjust_count": coord.adjust_count,
        "completed": completed,
        "expected": expected,
        "max_makespan_h": max((r.makespan for r in
                               coord.per_workload.values()), default=0) / 3600,
        "wall_s_dcs": t_dcs,
        "wall_s_coord": t_coord,
    }


# --------------------------------------------------------------------------
# accounting micro-benchmark: vectorized vs per-lease Python loops
# --------------------------------------------------------------------------
def _ledger_for(n_providers: int, seed: int, jobs_scale: float
                ) -> tuple[ProvisionService, float]:
    """Replay an N-provider family as an eager per-job lease ledger (the
    DRP shape: one lease per job) in event order — the densest realistic
    accounting workload at this N."""
    fam = family_for(n_providers, seed, jobs_scale)
    events = []
    for wl in fam:
        for j in wl.jobs:
            end = j.arrival + j.runtime
            events.append((j.arrival, 0, wl.name, j.nodes))
            events.append((end, 1, wl.name, j.nodes))
    events.sort()
    prov = ProvisionService()
    for t, kind, name, nodes in events:
        if kind == 0:
            prov.request(name, nodes, t)
        else:
            prov.release(name, nodes, t)
    horizon = max(t for t, *_ in events)
    return prov, horizon


def bench_accounting(n_providers: int, seed: int, jobs_scale: float,
                     repeats: int = 5) -> dict:
    prov, horizon = _ledger_for(n_providers, seed, jobs_scale)

    def best(fn):
        times = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            fn()
            times.append(time.perf_counter() - t0)
        return min(times)

    nh_vec = best(lambda: prov.node_hours(None, now=horizon))
    nh_loop = best(lambda: prov.node_hours_loop(None, now=horizon))
    pk_vec = best(lambda: prov.peak_nodes_per_hour(horizon))
    pk_loop = best(lambda: prov.peak_nodes_per_hour_loop(horizon))
    assert prov.node_hours(None, now=horizon) == \
        prov.node_hours_loop(None, now=horizon)
    assert prov.peak_nodes_per_hour(horizon) == \
        prov.peak_nodes_per_hour_loop(horizon)
    return {
        "n_providers": n_providers,
        "leases": len(prov.closed_leases),
        "alloc_events": len(prov._alloc_curve),
        "node_hours_vec_s": nh_vec,
        "node_hours_loop_s": nh_loop,
        "node_hours_speedup": nh_loop / nh_vec,
        "peak_vec_s": pk_vec,
        "peak_loop_s": pk_loop,
        "peak_speedup": pk_loop / pk_vec,
        "vectorized_beats_loop": nh_vec < nh_loop and pk_vec < pk_loop,
    }


def summarize(runs: list[dict]) -> list[dict]:
    """Seed-averaged curve per N."""
    curve = []
    for n in sorted({r["n_providers"] for r in runs}):
        cell = [r for r in runs if r["n_providers"] == n]
        k = len(cell)
        mean = lambda key: sum(r[key] for r in cell) / k  # noqa: E731
        curve.append({
            "n_providers": n,
            "seeds": k,
            "dcs_per_provider": mean("dcs_per_provider"),
            "coord_platform_per_provider": mean("coord_platform_per_provider"),
            "coord_billed_per_provider": mean("coord_billed_per_provider"),
            "platform_vs_dcs": (mean("coord_platform_per_provider")
                                / mean("dcs_per_provider")),
            "billed_vs_dcs": (mean("coord_billed_per_provider")
                              / mean("dcs_per_provider")),
            "coord_peak_per_provider": mean("coord_peak_nodes_per_hour") / n,
            "completed_fraction": (sum(r["completed"] for r in cell)
                                   / max(sum(r["expected"] for r in cell), 1)),
            "mean_wall_s_coord": mean("wall_s_coord"),
        })
    return curve


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--providers", type=int, nargs="+",
                    default=[3, 6, 12, 24])
    ap.add_argument("--seeds", type=int, nargs="+", default=[0, 100])
    ap.add_argument("--jobs-scale", type=float, default=1.0)
    ap.add_argument("--procs", type=int, default=None,
                    help="process-pool width (default: cpu count)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized sweep: fewer jobs, one seed")
    ap.add_argument("--out", default="BENCH_scale_curve.json")
    args = ap.parse_args(argv)

    if args.smoke:
        args.providers = [3, 6, 8]
        args.seeds = [0]
        args.jobs_scale = 0.25

    cells = [(n, s, args.jobs_scale)
             for n in args.providers for s in args.seeds]
    procs = args.procs or min(len(cells), os.cpu_count() or 1)
    t0 = time.perf_counter()
    if procs > 1:
        with ProcessPoolExecutor(max_workers=procs) as pool:
            runs = list(pool.map(run_cell, cells))
    else:
        runs = [run_cell(c) for c in cells]
    wall = time.perf_counter() - t0

    # accounting timing at N=8 (the acceptance point) + the sweep extremes
    acct_ns = sorted({8, min(args.providers), max(args.providers)})
    accounting = [bench_accounting(n, args.seeds[0], args.jobs_scale)
                  for n in acct_ns]

    out = {
        "benchmark": "scale_curve",
        "config": {"providers": args.providers, "seeds": args.seeds,
                   "jobs_scale": args.jobs_scale, "procs": procs,
                   "smoke": args.smoke},
        "wall_s_total": wall,
        "runs": runs,
        "curve": summarize(runs),
        "accounting": accounting,
    }
    with open(args.out, "w") as fh:
        json.dump(out, fh, indent=2)

    print(f"wrote {args.out} ({len(runs)} runs, {wall:.1f}s wall, "
          f"{procs} procs)")
    print(f"{'N':>4s} {'dcs/prov':>10s} {'platform/prov':>14s} "
          f"{'billed/prov':>12s} {'plat/dcs':>9s} {'done':>6s}")
    for row in out["curve"]:
        print(f"{row['n_providers']:>4d} {row['dcs_per_provider']:>10.0f} "
              f"{row['coord_platform_per_provider']:>14.0f} "
              f"{row['coord_billed_per_provider']:>12.0f} "
              f"{row['platform_vs_dcs']:>9.3f} "
              f"{row['completed_fraction']:>6.1%}")
    for a in accounting:
        print(f"accounting N={a['n_providers']}: node_hours "
              f"{a['node_hours_speedup']:.1f}x, peak "
              f"{a['peak_speedup']:.1f}x over per-lease loops "
              f"({a['leases']} leases)")
    return out


if __name__ == "__main__":
    main()
