"""Cross-PR benchmark regression gate.

Compares a freshly produced ``BENCH_*.json`` against the committed
baseline in ``benchmarks/baselines/`` and fails (exit 1) when a key
metric regresses beyond tolerance.  Metrics are directional:

  - ``lower``  is better (billed ratios): fail when
    ``current > baseline * (1 + tol)``
  - ``higher`` is better (utilization, throughput): fail when
    ``current < baseline * (1 - tol)``
  - ``zero``   is an invariant (over-admissions, isolation violations):
    fail when nonzero, regardless of tolerance

Baselines are generated with ``--smoke`` (the CI configuration); the
checker refuses to compare runs whose configs differ, so a smoke run is
never judged against a full-sweep baseline.

Usage (what CI runs, one line per benchmark)::

    python benchmarks/check_regression.py BENCH_serve_fleet.json
    python benchmarks/check_regression.py BENCH_scale_curve.json --tol 0.2

To refresh a baseline after an intentional change, rerun the benchmark
with ``--smoke`` and copy the JSON into ``benchmarks/baselines/``.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

BASELINE_DIR = Path(__file__).resolve().parent / "baselines"

# benchmark name -> (row extractor, row key fields, {metric: direction})
# The extractor returns a list of comparable rows; rows are matched
# between current and baseline by the key fields.
SPECS: dict[str, dict] = {
    "serve_fleet": {
        "rows": lambda d: d["runs"],
        "key": ("n_tenants", "policy"),
        "metrics": {
            "billed_vs_dedicated": "lower",
            "slots_vs_dedicated": "lower",
            "slot_utilization": "higher",
            "over_admissions": "zero",
            "isolation_violations": "zero",
        },
    },
    "scale_curve": {
        "rows": lambda d: d["curve"],
        "key": ("n_providers",),
        "metrics": {
            "billed_vs_dcs": "lower",
            "platform_vs_dcs": "lower",
            "completed_fraction": "higher",
        },
    },
    "serve_trace": {
        # single-cell benchmark: synthesize one row from the top level
        "rows": lambda d: [{
            "cell": "dsp-vs-dedicated",
            "utilization_gain": d["utilization_gain"],
            "throughput_ratio": d["throughput_ratio"],
            "billed_ratio": d["billed_ratio"],
            "over_admissions": d["dsp"]["over_admissions"],
        }],
        "key": ("cell",),
        "metrics": {
            "utilization_gain": "higher",
            "throughput_ratio": "higher",
            "billed_ratio": "lower",
            "over_admissions": "zero",
        },
    },
}


# execution details that vary by machine without affecting results
CONFIG_IGNORE = ("procs",)


def _row_key(row: dict, fields: tuple[str, ...]) -> tuple:
    return tuple(row[f] for f in fields)


def _comparable_config(d: dict) -> dict:
    cfg = dict(d.get("config") or {})
    for k in CONFIG_IGNORE:
        cfg.pop(k, None)
    return cfg


def compare(current: dict, baseline: dict, tol: float) -> list[str]:
    """Return a list of human-readable regression messages (empty = ok)."""
    name = current.get("benchmark")
    if name != baseline.get("benchmark"):
        return [f"benchmark mismatch: current={name!r} "
                f"baseline={baseline.get('benchmark')!r}"]
    spec = SPECS.get(name)
    if spec is None:
        return [f"no regression spec for benchmark {name!r} "
                f"(known: {sorted(SPECS)})"]
    cur_cfg, base_cfg = _comparable_config(current), _comparable_config(baseline)
    if cur_cfg != base_cfg:
        return [f"config mismatch for {name}: refusing to compare "
                f"(current={cur_cfg} baseline={base_cfg}); regenerate the "
                f"baseline with the same flags"]

    failures: list[str] = []
    base_rows = {_row_key(r, spec["key"]): r for r in spec["rows"](baseline)}
    cur_rows = {_row_key(r, spec["key"]): r for r in spec["rows"](current)}
    for key in base_rows.keys() - cur_rows.keys():
        failures.append(f"{name}{key}: row missing from current run")
    for key, cur in sorted(cur_rows.items(), key=str):
        base = base_rows.get(key)
        if base is None:
            continue  # new row (e.g. an added N): nothing to regress against
        for metric, direction in spec["metrics"].items():
            c, b = cur[metric], base[metric]
            if direction == "zero":
                if c != 0:
                    failures.append(f"{name}{key}: {metric} = {c} "
                                    f"(invariant: must be 0)")
            elif direction == "lower":
                if c > b * (1 + tol):
                    failures.append(f"{name}{key}: {metric} rose "
                                    f"{b:.4g} -> {c:.4g} "
                                    f"(tolerance {tol:.0%})")
            elif direction == "higher":
                if c < b * (1 - tol):
                    failures.append(f"{name}{key}: {metric} fell "
                                    f"{b:.4g} -> {c:.4g} "
                                    f"(tolerance {tol:.0%})")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("current", help="freshly produced BENCH_*.json")
    ap.add_argument("--baseline", default=None,
                    help="baseline JSON (default: benchmarks/baselines/"
                         "<same filename>)")
    ap.add_argument("--tol", type=float, default=0.15,
                    help="relative tolerance for directional metrics")
    args = ap.parse_args(argv)

    cur_path = Path(args.current)
    base_path = (Path(args.baseline) if args.baseline
                 else BASELINE_DIR / cur_path.name)
    if not base_path.exists():
        print(f"check_regression: no baseline at {base_path}; "
              f"commit one to enable the gate", file=sys.stderr)
        return 1
    current = json.loads(cur_path.read_text())
    baseline = json.loads(base_path.read_text())

    failures = compare(current, baseline, args.tol)
    if failures:
        print(f"check_regression: {cur_path.name} REGRESSED "
              f"vs {base_path}:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    name = current["benchmark"]
    n_rows = len(SPECS[name]["rows"](current))
    print(f"check_regression: {cur_path.name} ok "
          f"({n_rows} rows within {args.tol:.0%} of {base_path})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
