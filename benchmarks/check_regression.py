"""Cross-PR benchmark regression gate with a rolling trajectory window.

Compares a freshly produced ``BENCH_*.json`` against (a) the committed
baseline in ``benchmarks/baselines/`` and (b) the *median* of the rolling
last-K history window committed under ``benchmarks/baselines/history/``,
and fails (exit 1) when a key metric regresses beyond tolerance.
Metrics are directional:

  - ``lower``  is better (billed ratios): fail when
    ``current > baseline * (1 + tol)``
  - ``higher`` is better (utilization, throughput): fail when
    ``current < baseline * (1 - tol)``
  - ``zero``   is an invariant (over-admissions, isolation violations):
    fail when nonzero, regardless of tolerance

Wall-clock metrics (``workflows_per_sec``) take a per-metric tolerance
multiplier (``tol_mult`` in the spec) — timing on shared CI runners is
far noisier than the deterministic economics, so those metrics gate only
order-of-magnitude collapses, not jitter. Metrics absent from a row (or
from an older baseline that predates them) are skipped, not failed, so
adding a metric never invalidates committed history.

The history window exists because a single committed baseline ratchets:
each PR may slip a metric by just under the tolerance, and refreshing the
baseline bakes the slip in — K PRs later the metric has drifted K
tolerances with every gate green. Gating against the window *median*
bounds total drift to one tolerance per ~K/2 PRs: a slow leak has to beat
the majority of recent history, not just its own predecessor.

Baselines are generated with ``--smoke`` (the CI configuration); the
checker refuses to compare runs whose configs differ, so a smoke run is
never judged against a full-sweep baseline. History entries whose config
differs (an intentional benchmark change) are skipped with a note — the
window re-fills over the next PRs.

Usage (what CI runs, one line per benchmark)::

    python benchmarks/check_regression.py BENCH_serve_fleet.json
    python benchmarks/check_regression.py BENCH_scale_curve.json --tol 0.2

To refresh a baseline after an intentional change, rerun the benchmark
with ``--smoke``, copy the JSON into ``benchmarks/baselines/``, and
append it to the rolling window with ``--update-history`` (prunes to the
last K = 5 entries); commit both.
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys
from pathlib import Path

BASELINE_DIR = Path(__file__).resolve().parent / "baselines"
HISTORY_DIR = BASELINE_DIR / "history"
HISTORY_K = 5

# benchmark name -> (row extractor, row key fields, {metric: direction})
# The extractor returns a list of comparable rows; rows are matched
# between current and baseline by the key fields.
SPECS: dict[str, dict] = {
    "serve_fleet": {
        "rows": lambda d: d["runs"],
        "key": ("n_tenants", "policy", "mix"),
        "metrics": {
            "billed_vs_dedicated": "lower",
            "slots_vs_dedicated": "lower",
            "slot_utilization": "higher",
            "over_admissions": "zero",
            "isolation_violations": "zero",
            "workflows_per_sec": "higher",
        },
        "tol_mult": {"workflows_per_sec": 4.0},
    },
    "serve_fleet_real": {
        # heterogeneous fleet on the physical paged jax engine: the
        # parity counts are invariants (emulator == real == contiguous,
        # field for field), the economics gate like the emulated matrix,
        # and decode throughput tolerates CI timing noise
        "rows": lambda d: d["runs"],
        "key": ("mix",),
        "metrics": {
            "parity_mismatches": "zero",
            "paged_vs_contiguous_mismatches": "zero",
            "over_admissions": "zero",
            "isolation_violations": "zero",
            "billed_vs_dedicated": "lower",
            "decode_steps_per_sec": "higher",
        },
        "tol_mult": {"decode_steps_per_sec": 4.0},
    },
    "scale_curve": {
        "rows": lambda d: d["curve"],
        "key": ("n_providers",),
        "metrics": {
            "billed_vs_dcs": "lower",
            "platform_vs_dcs": "lower",
            "completed_fraction": "higher",
        },
    },
    "serve_trace": {
        # single-cell benchmark: synthesize one row from the top level
        # (dict-comprehension guard: older artifacts predate wf/s)
        "rows": lambda d: [dict({
            "cell": "dsp-vs-dedicated",
            "utilization_gain": d["utilization_gain"],
            "throughput_ratio": d["throughput_ratio"],
            "billed_ratio": d["billed_ratio"],
            "over_admissions": d["dsp"]["over_admissions"],
        }, **({"workflows_per_sec": d["dsp"]["workflows_per_sec"]}
              if "workflows_per_sec" in d["dsp"] else {}))],
        "key": ("cell",),
        "metrics": {
            "utilization_gain": "higher",
            "throughput_ratio": "higher",
            "billed_ratio": "lower",
            "over_admissions": "zero",
            "workflows_per_sec": "higher",
        },
        "tol_mult": {"workflows_per_sec": 4.0},
    },
    "train_serve": {
        # mixed train+serve consolidation: completion and isolation are
        # invariants (every serve workflow, every training step, zero
        # violations, every preemption resumed), the billing ratio gates
        # directionally like the serve fleet's
        "rows": lambda d: d["runs"],
        "key": ("mix", "n_tenants", "train_jobs"),
        "metrics": {
            "billed_vs_dedicated": "lower",
            "serve_incomplete": "zero",
            "train_steps_incomplete": "zero",
            "unresumed_preemptions": "zero",
            "over_admissions": "zero",
            "isolation_violations": "zero",
            "slot_utilization": "higher",
        },
    },
    "serve_scale": {
        # columnar-vs-scalar throughput at 1e5 workflows; rows keyed by
        # execution mode. ``stats_mismatches`` only exists on the
        # columnar row (missing metrics are skipped, not failed).
        "rows": lambda d: d["runs"],
        "key": ("mode",),
        "metrics": {
            "workflows_per_sec": "higher",
            "over_admissions": "zero",
            "stats_mismatches": "zero",
        },
        "tol_mult": {"workflows_per_sec": 4.0},
    },
}


# execution details that vary by machine without affecting results
CONFIG_IGNORE = ("procs",)


def _row_key(row: dict, fields: tuple[str, ...]) -> tuple:
    return tuple(row[f] for f in fields)


def _comparable_config(d: dict) -> dict:
    cfg = dict(d.get("config") or {})
    for k in CONFIG_IGNORE:
        cfg.pop(k, None)
    return cfg


def compare(current: dict, baseline: dict, tol: float) -> list[str]:
    """Return a list of human-readable regression messages (empty = ok)."""
    name = current.get("benchmark")
    if name != baseline.get("benchmark"):
        return [f"benchmark mismatch: current={name!r} "
                f"baseline={baseline.get('benchmark')!r}"]
    spec = SPECS.get(name)
    if spec is None:
        return [f"no regression spec for benchmark {name!r} "
                f"(known: {sorted(SPECS)})"]
    cur_cfg, base_cfg = _comparable_config(current), _comparable_config(baseline)
    if cur_cfg != base_cfg:
        return [f"config mismatch for {name}: refusing to compare "
                f"(current={cur_cfg} baseline={base_cfg}); regenerate the "
                f"baseline with the same flags"]

    failures: list[str] = []
    base_rows = {_row_key(r, spec["key"]): r for r in spec["rows"](baseline)}
    cur_rows = {_row_key(r, spec["key"]): r for r in spec["rows"](current)}
    for key in base_rows.keys() - cur_rows.keys():
        failures.append(f"{name}{key}: row missing from current run")
    for key, cur in sorted(cur_rows.items(), key=str):
        base = base_rows.get(key)
        if base is None:
            continue  # new row (e.g. an added N): nothing to regress against
        for metric, direction in spec["metrics"].items():
            if metric not in cur:
                continue  # metric absent from this row (e.g. the
                # scalar serve_scale row carries no mismatch counter)
            c = cur[metric]
            if direction == "zero":
                if c != 0:
                    failures.append(f"{name}{key}: {metric} = {c} "
                                    f"(invariant: must be 0)")
                continue
            if metric not in base:
                continue  # older baseline predates the metric
            b = base[metric]
            mtol = tol * spec.get("tol_mult", {}).get(metric, 1.0)
            if direction == "lower":
                if c > b * (1 + mtol):
                    failures.append(f"{name}{key}: {metric} rose "
                                    f"{b:.4g} -> {c:.4g} "
                                    f"(tolerance {mtol:.0%})")
            elif direction == "higher":
                if c < b * (1 - mtol):
                    failures.append(f"{name}{key}: {metric} fell "
                                    f"{b:.4g} -> {c:.4g} "
                                    f"(tolerance {mtol:.0%})")
    return failures


def history_paths(bench_file: str) -> list[Path]:
    """The committed rolling-window entries for one benchmark artifact,
    oldest first (entries are ``history/<stem>/NNNN.json``)."""
    d = HISTORY_DIR / Path(bench_file).stem
    if not d.is_dir():
        return []
    return sorted(d.glob("[0-9]" * 4 + ".json"))


def load_history(bench_file: str, current: dict) -> tuple[list[dict], int]:
    """Config-compatible window entries + the count skipped for config or
    benchmark-name mismatch (an intentional benchmark change empties the
    window; it re-fills over the following PRs)."""
    cfg = _comparable_config(current)
    entries, skipped = [], 0
    for p in history_paths(bench_file):
        entry = json.loads(p.read_text())
        if (entry.get("benchmark") == current.get("benchmark")
                and _comparable_config(entry) == cfg):
            entries.append(entry)
        else:
            skipped += 1
    return entries, skipped


def compare_to_history(current: dict, entries: list[dict],
                       tol: float) -> list[str]:
    """Gate the current run's directional metrics against the rolling
    window's per-row *median* (zero-invariants are already absolute in
    :func:`compare`; rows or metrics absent from the whole window are
    skipped — nothing to drift from)."""
    name = current.get("benchmark")
    spec = SPECS.get(name)
    if spec is None or not entries:
        return []
    window: dict[tuple, dict[str, list[float]]] = {}
    for entry in entries:
        for row in spec["rows"](entry):
            per_metric = window.setdefault(_row_key(row, spec["key"]), {})
            for metric, direction in spec["metrics"].items():
                if direction != "zero" and metric in row:
                    per_metric.setdefault(metric, []).append(row[metric])
    failures: list[str] = []
    for row in spec["rows"](current):
        key = _row_key(row, spec["key"])
        for metric, values in window.get(key, {}).items():
            if metric not in row:
                continue
            med = statistics.median(values)
            c = row[metric]
            direction = spec["metrics"][metric]
            mtol = tol * spec.get("tol_mult", {}).get(metric, 1.0)
            if direction == "lower" and c > med * (1 + mtol):
                failures.append(
                    f"{name}{key}: {metric} = {c:.4g} above the "
                    f"last-{len(values)} window median {med:.4g} "
                    f"(tolerance {mtol:.0%})")
            elif direction == "higher" and c < med * (1 - mtol):
                failures.append(
                    f"{name}{key}: {metric} = {c:.4g} below the "
                    f"last-{len(values)} window median {med:.4g} "
                    f"(tolerance {mtol:.0%})")
    return failures


def update_history(cur_path: Path, k: int = HISTORY_K) -> Path:
    """Append the current artifact to the rolling window and prune it to
    the newest ``k`` entries. Entries keep monotonically increasing
    sequence numbers so pruning never renumbers committed files."""
    d = HISTORY_DIR / cur_path.stem
    d.mkdir(parents=True, exist_ok=True)
    existing = history_paths(cur_path.name)
    nxt = (int(existing[-1].stem) + 1) if existing else 1
    dst = d / f"{nxt:04d}.json"
    dst.write_text(cur_path.read_text())
    for stale in history_paths(cur_path.name)[:-k]:
        stale.unlink()
    return dst


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("current", help="freshly produced BENCH_*.json")
    ap.add_argument("--baseline", default=None,
                    help="baseline JSON (default: benchmarks/baselines/"
                         "<same filename>)")
    ap.add_argument("--tol", type=float, default=0.15,
                    help="relative tolerance for directional metrics")
    ap.add_argument("--update-history", action="store_true",
                    help="after a passing check, append this artifact to "
                         "benchmarks/baselines/history/ and prune to the "
                         "last K entries (commit the result)")
    ap.add_argument("--history-k", type=int, default=HISTORY_K,
                    help="rolling window size kept by --update-history")
    args = ap.parse_args(argv)

    cur_path = Path(args.current)
    base_path = (Path(args.baseline) if args.baseline
                 else BASELINE_DIR / cur_path.name)
    if not base_path.exists():
        print(f"check_regression: no baseline at {base_path}; "
              f"commit one to enable the gate", file=sys.stderr)
        return 1
    current = json.loads(cur_path.read_text())
    baseline = json.loads(base_path.read_text())

    failures = compare(current, baseline, args.tol)
    entries, skipped = load_history(cur_path.name, current)
    failures += compare_to_history(current, entries, args.tol)
    if failures:
        print(f"check_regression: {cur_path.name} REGRESSED "
              f"vs {base_path}:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    name = current["benchmark"]
    n_rows = len(SPECS[name]["rows"](current))
    window = (f", window median of {len(entries)}"
              if entries else ", no history window")
    note = f" ({skipped} incompatible history entries skipped)" \
        if skipped else ""
    print(f"check_regression: {cur_path.name} ok "
          f"({n_rows} rows within {args.tol:.0%} of {base_path}"
          f"{window}){note}")
    if args.update_history:
        dst = update_history(cur_path, args.history_k)
        print(f"check_regression: appended to rolling window: {dst}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
