"""Paper Figs 12-14: resource-provider metrics across systems.

Fig 12 — total resource consumption (node*hours, consolidated),
Fig 13 — peak resource consumption (nodes per hour),
Fig 14 — accumulated size of node adjustments + the management overhead
they imply at the measured 15.743 s per adjusted node (§4.5.4).
"""
from __future__ import annotations

from benchmarks.emulation import run_all

PAPER = {
    # ratios the paper reports for the provider
    "total_saving_vs_dcs": 0.297,
    "total_saving_vs_drp": 0.290,
    "peak_vs_dcs": 1.06,
    "peak_vs_drp": 0.21,
    "overhead_s_per_hour": 341.0,
}


def provider_metrics(policy_set: str = "tuned"):
    results = run_all(policy_set)
    rows = {}
    for system, res in results.items():
        rows[system] = {
            "total_node_hours": round(res.total_node_hours),
            "peak_nodes_per_hour": res.peak_nodes_per_hour,
            "adjust_count": res.adjust_count,
            "overhead_s_per_hour": round(res.overhead_s_per_hour, 1),
        }
    dc, dcs, drp = rows["dawningcloud"], rows["dcs"], rows["drp"]
    derived = {
        "total_saving_vs_dcs": round(
            1 - dc["total_node_hours"] / dcs["total_node_hours"], 3),
        "total_saving_vs_drp": round(
            1 - dc["total_node_hours"] / drp["total_node_hours"], 3),
        "peak_vs_dcs": round(
            dc["peak_nodes_per_hour"] / dcs["peak_nodes_per_hour"], 2),
        "peak_vs_drp": round(
            dc["peak_nodes_per_hour"] / drp["peak_nodes_per_hour"], 2),
        "overhead_s_per_hour": dc["overhead_s_per_hour"],
    }
    return rows, derived


def main():
    rows, derived = provider_metrics()
    print("== Figs 12-14 (resource provider) ==")
    print(f"{'system':14s} {'total n*h':>10s} {'peak/h':>7s} "
          f"{'adjusts':>8s} {'ovh s/h':>8s}")
    for system, r in rows.items():
        print(f"{system:14s} {r['total_node_hours']:>10} "
              f"{r['peak_nodes_per_hour']:>7} {r['adjust_count']:>8} "
              f"{r['overhead_s_per_hour']:>8}")
    print("\nderived (ours vs paper):")
    for k, v in derived.items():
        print(f"  {k:24s} ours={v:<8} paper={PAPER[k]}")


if __name__ == "__main__":
    main()
