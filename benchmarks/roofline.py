"""Roofline analysis per (arch x shape x mesh) from the dry-run artifacts.

Three terms, each "seconds if that resource were the only limit":

  compute    EXEC_FLOPS / (chips * 197e12 bf16 FLOP/s)
  memory     HBM_BYTES  / (chips * 819e9 B/s)
  collective wire_bytes_per_chip / link budget

EXEC_FLOPS / HBM_BYTES come from the analytic model in benchmarks/flops.py
(cost_analysis counts while bodies once — the artifact keeps the raw value
and trip counts as a cross-check). Collective bytes come from the compiled
HLO's collective ops, trip-scaled (exact nesting known per op).

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM. ICI: ~50
GB/s/link; on the 2D intra-pod torus a ring reduction streams over one
link direction at a time, so the per-chip collective budget is 50 GB/s
(conservative single-link model; documented). Cross-pod (DCI) budget is
taken as 10 GB/s/chip — an assumption, flagged in EXPERIMENTS.md.

The dominant term is the bottleneck; MODEL_FLOPS/EXEC_FLOPS exposes
remat/causal/capacity waste. Roofline fraction = compute / max(all terms):
the share of peak MXU throughput this cell could reach if perfectly
overlapped.
"""
from __future__ import annotations

import json
import os

from benchmarks.flops import cell_model
from repro.configs import ARCHS, SHAPES, get_config
from repro.configs.base import ParallelConfig

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / chip, intra-pod (single-link ring model)
DCI_BW = 10e9                # B/s / chip, cross-pod (assumption)

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load_artifacts(mesh: str = "pod") -> list[dict]:
    arts = []
    if not os.path.isdir(ART_DIR):
        return arts
    for name in sorted(os.listdir(ART_DIR)):
        if name.endswith(f"__{mesh}.json"):
            with open(os.path.join(ART_DIR, name)) as f:
                arts.append(json.load(f))
    return arts


def roofline_row(art: dict) -> dict | None:
    if art.get("status") != "ok":
        return {"arch": art["arch"], "shape": art["shape"],
                "status": art.get("status"),
                "note": art.get("reason", art.get("error", ""))[:70]}
    cfg = get_config(art["arch"])
    shape = SHAPES[art["shape"]]
    parallel = ParallelConfig(**{
        k: v for k, v in art["parallel"].items()
        if k in ParallelConfig.__dataclass_fields__})
    chips = art["n_devices"]
    m = cell_model(cfg, shape, parallel)
    compute_s = m.exec_flops / (chips * PEAK_FLOPS)
    memory_s = m.hbm_bytes / (chips * HBM_BW)
    coll = art["collectives"]
    # _tpu variants halve f32 reduction collectives (XLA:CPU materializes
    # f32 dot partials; TPU reduces in bf16) — use them when present
    wire_intra = coll.get("wire_bytes_intra_pod_tpu",
                          coll["wire_bytes_intra_pod"])
    wire_cross = coll.get("wire_bytes_cross_pod_tpu",
                          coll["wire_bytes_cross_pod"])
    collective_s = wire_intra / ICI_BW + wire_cross / DCI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    return {
        "arch": art["arch"], "shape": art["shape"], "status": "ok",
        "chips": chips,
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "roofline_fraction": compute_s / bound if bound else 0.0,
        "model_flops": m.model_flops,
        "exec_flops": m.exec_flops,
        "useful_ratio": m.model_flops / m.exec_flops if m.exec_flops else 0.0,
        "temp_gib": art["memory"]["temp_bytes"] / 2**30,
        "args_gib": art["memory"]["argument_bytes"] / 2**30,
        "fits_hbm": (art["memory"]["temp_bytes"]
                     + art["memory"]["argument_bytes"]) < 16 * 2**30,
        "hlo_flops_raw": art["cost"]["flops"],
        "wire_intra_gib": wire_intra / 2**30,
        "wire_cross_gib": wire_cross / 2**30,
    }


def table(mesh: str = "pod") -> list[dict]:
    return [r for a in load_artifacts(mesh) if (r := roofline_row(a))]


def main():
    for mesh in ("pod", "multipod"):
        rows = table(mesh)
        if not rows:
            print(f"(no {mesh} artifacts — run python -m repro.launch.dryrun)")
            continue
        print(f"\n== Roofline ({mesh}) ==")
        print(f"{'arch':22s} {'shape':12s} {'comp_s':>9s} {'mem_s':>9s} "
              f"{'coll_s':>9s} {'dom':>5s} {'roof%':>6s} {'useful':>7s} "
              f"{'fits':>5s}")
        for r in rows:
            if r.get("status") != "ok":
                print(f"{r['arch']:22s} {r['shape']:12s} -- {r['status']}: "
                      f"{r.get('note', '')}")
                continue
            print(f"{r['arch']:22s} {r['shape']:12s} {r['compute_s']:9.4f} "
                  f"{r['memory_s']:9.4f} {r['collective_s']:9.4f} "
                  f"{r['dominant'][:4]:>5s} {r['roofline_fraction']:6.1%} "
                  f"{r['useful_ratio']:7.2f} "
                  f"{'y' if r['fits_hbm'] else 'N':>5s}")


if __name__ == "__main__":
    main()
