"""Benchmark orchestrator — one module per paper table/figure.

  tables          Tables 2-4 (per-service-provider metrics, 4 systems)
  fig9_11_params  Figs 9-11 (B/R parameter sweeps)
  fig12_14        Figs 12-14 (provider totals, peaks, adjustment overhead)
  tco             §4.5.5 TCO (DCS vs EC2-priced SSP)
  roofline        §Roofline terms from the dry-run artifacts (launch/dryrun)

``python -m benchmarks.run [name ...]`` runs all (or the named) benchmarks.
"""
from __future__ import annotations

import sys
import time

from benchmarks import fig9_11_params, fig12_14_provider, roofline, tables, tco

BENCHES = {
    "tables": tables.main,
    "fig9_11_params": fig9_11_params.main,
    "fig12_14": fig12_14_provider.main,
    "tco": tco.main,
    "roofline": roofline.main,
}


def main() -> None:
    names = sys.argv[1:] or list(BENCHES)
    for name in names:
        t0 = time.perf_counter()
        print(f"\n{'=' * 72}\n# benchmark: {name}\n{'=' * 72}")
        BENCHES[name]()
        print(f"[{name}: {time.perf_counter() - t0:.1f}s]")


if __name__ == "__main__":
    main()
