"""Trace-scale MTC serving benchmark: the serve driver vs a dedicated
engine on the same workflow arrival stream.

Hundreds-to-thousands of Montage-shaped workflows (``workload_family``
MTC providers, merged into one trace-rate arrival stream by
``request_stream``) are replayed through ``repro.serve.driver.ServeDriver``
in two configurations:

  - **dedicated**: a fixed engine of the full slot count held for the
    whole run — the DCS-style baseline (no negotiation, no backpressure),
  - **dsp**: the DawningCloud serve path — slots granted by a shared
    finite ``ResourceProvider`` under DR1/DR2 scans, co-tenant contention
    waves parking requests in the admission queue (deferred grants land
    through ``on_grant``), workflow roots queuing in the env under
    backpressure, and time-averaged release checks shrinking the slot
    pool when the trace goes quiet.

Both runs must complete every workflow with ZERO over-admissions (the
engine never holds more requests than granted slots) — asserted, not just
reported. The emitted ``BENCH_serve_trace.json`` carries workflows/hour,
slot utilization, billed node-hours and deferred-grant counts for both
sides; CI uploads it next to the scale-curve artifact so the serving-path
trajectory accumulates across PRs.

``--real`` additionally drives a small stream through the actual jax
continuous-batching engine (musicgen smoke config) to pin the emulated
slot model to the real stack.
"""
from __future__ import annotations

import argparse
import json
import time

from repro.core.policy import MgmtPolicy
from repro.core.provider import ResourceProvider
from repro.core.provision import ProvisionService
from repro.serve.driver import EmulatedEngine, JaxEngineAdapter, ServeDriver
from repro.sim.traces import request_stream, workload_family


def build_stream(n_workflows: int, seed: int, jobs_scale: float,
                 period: float):
    fam = workload_family(0, n_workflows, seed=seed, jobs_scale=jobs_scale)
    return request_stream(fam, period=period, seed=seed)


def contention_waves(slots: int, period: float) -> list[tuple[float, str, int]]:
    """Co-tenant load on the shared platform: neighbors grab three
    quarters of the slots early — fewer than the stream's sustained demand
    remain, so the env saturates its headroom and its DR1 parks — then
    release in two waves; each release drains the admission queue into
    deferred grants."""
    hold = 3 * slots // 4
    return [(31.0, "neighbors", hold),
            (0.5 * period, "neighbors", -(hold // 2)),
            (0.75 * period, "neighbors", -(hold - hold // 2))]


def timed_run(driver, profile: "Profiler | None" = None) -> tuple:
    """``driver.run()`` under the wall clock (and optionally the
    profiler); returns ``(stats, wall_s)``."""
    t0 = time.perf_counter()
    if profile is not None:
        with profile:
            stats = driver.run()
    else:
        stats = driver.run()
    return stats, time.perf_counter() - t0


def throughput_row(stats, mode: str, wall: float) -> dict:
    """A ``ServeStats`` row extended with the trajectory metrics the
    regression gate windows: wall clock and serving rate."""
    out = stats.as_dict()
    out["mode"] = mode
    out["wall_s"] = wall
    out["workflows_per_sec"] = (stats.workflows_completed / wall
                                if wall > 0 else 0.0)
    return out


class Profiler:
    """``--profile``: cProfile the serve run(s) and write the top-N
    cumulative hot spots as a text table (CI uploads it as an artifact,
    so tick-loop regressions are diagnosable from the run page)."""

    def __init__(self, top: int, out_path: str):
        import cProfile
        self.top = top
        self.out_path = out_path
        self._prof = cProfile.Profile()

    def __enter__(self):
        self._prof.enable()
        return self

    def __exit__(self, *exc):
        self._prof.disable()
        return False

    def write(self, header: str) -> None:
        import io
        import pstats
        buf = io.StringIO()
        stats = pstats.Stats(self._prof, stream=buf)
        stats.sort_stats("cumulative").print_stats(self.top)
        with open(self.out_path, "w") as fh:
            fh.write(header + "\n" + buf.getvalue())
        print(f"wrote {self.out_path} (top {self.top} hot spots)")


def run_mode(stream, *, mode: str, slots: int, policy: MgmtPolicy,
             contention=(), profile: Profiler | None = None) -> dict:
    if mode == "dsp":
        provider = ResourceProvider(slots, coordination="first-come")
        driver = ServeDriver(stream, provider=provider,
                             engine=EmulatedEngine(slots), policy=policy,
                             contention=contention)
    else:
        driver = ServeDriver(stream, provider=ProvisionService(),
                             engine=EmulatedEngine(slots),
                             fixed_nodes=slots)
    stats, wall = timed_run(driver, profile)
    # the acceptance gate: everything served, nothing over-admitted
    assert stats.workflows_completed == stats.workflows_expected, (
        mode, stats.workflows_completed, stats.workflows_expected)
    assert stats.over_admissions == 0, (mode, stats.over_admissions)
    return throughput_row(stats, mode, wall)


def run_real(n_workflows: int, seed: int) -> dict:
    """Small-stream sanity run on the actual jax engine."""
    import jax
    from repro.configs import get_smoke_config
    from repro.configs.base import ParallelConfig
    from repro.models.lm import LM
    from repro.serve.engine import Engine

    cfg = get_smoke_config("musicgen-large")
    lm = LM(cfg)
    rt = lm.runtime(ParallelConfig(attn_q_chunk=16, attn_kv_chunk=16))
    params = lm.init(jax.random.key(0))[0]
    engine = Engine(lm, params, rt, max_batch=4, max_len=48)
    fam = workload_family(0, n_workflows, seed=seed, jobs_scale=0.05)
    stream = request_stream(fam, period=600.0, seed=seed,
                            seconds_per_token=4.0, prompt_lens=(4, 6))
    provider = ResourceProvider(4, coordination="first-come")
    driver = ServeDriver(
        stream, provider=provider, engine=JaxEngineAdapter(engine, seed=seed),
        policy=MgmtPolicy(initial=2, ratio=1.0, scan_interval=3.0,
                          release_interval=60.0))
    stats, wall = timed_run(driver)
    assert stats.workflows_completed == stats.workflows_expected
    assert stats.over_admissions == 0
    out = throughput_row(stats, "real-jax", wall)
    out["decode_steps"] = engine.steps
    return out


def _require(cond: bool, msg: str) -> None:
    """Acceptance-gate check that survives ``python -O`` (unlike assert)."""
    if not cond:
        raise RuntimeError(f"serve_trace gate: {msg}")


def run_scale(args, profile: Profiler | None = None) -> dict:
    """The trace-scale leg (``--scale-smoke``): 10^5 generated Montage
    workflows through the columnar driver (event-skipping on) AND the
    dense scalar reference on the SAME workload — the ``ServeStats`` must
    be bit-identical, and the columnar path must sustain a large
    workflows/sec multiple (the wall-clock metrics feed the history
    window, the hard floor here only catches collapses)."""
    from repro.serve.columnar import ColumnarEngine, ColumnarServeDriver
    from repro.sim.traces import montage_stream_columnar

    policy = MgmtPolicy(initial=64, ratio=2.0, scan_interval=3.0,
                        release_interval=300.0)
    t0 = time.perf_counter()
    cs = montage_stream_columnar(args.scale_workflows, n_project=2,
                                 seed=args.seed, period=args.period)
    generate_wall = time.perf_counter() - t0

    provider = ResourceProvider(args.slots, coordination="first-come")
    driver = ColumnarServeDriver(cs, provider=provider,
                                 engine=ColumnarEngine(args.slots),
                                 policy=policy, name="scale-serve")
    col_stats, col_wall = timed_run(driver, profile)
    _require(col_stats.workflows_completed == cs.n_entries,
             f"columnar completed {col_stats.workflows_completed}"
             f"/{cs.n_entries} workflows")
    _require(col_stats.over_admissions == 0,
             f"columnar over-admitted {col_stats.over_admissions}")
    columnar = throughput_row(col_stats, "columnar", col_wall)

    t0 = time.perf_counter()
    stream = cs.to_jobs()
    materialize_wall = time.perf_counter() - t0
    provider = ResourceProvider(args.slots, coordination="first-come")
    ref = ServeDriver(stream, provider=provider,
                      engine=EmulatedEngine(args.slots), policy=policy,
                      name="scale-serve", event_skip=False)
    ref_stats, ref_wall = timed_run(ref)
    scalar = throughput_row(ref_stats, "scalar", ref_wall)

    # the tentpole contract: same workload, bit-identical serving record
    mismatch = [k for k in col_stats.as_dict()
                if col_stats.as_dict()[k] != ref_stats.as_dict()[k]]
    _require(not mismatch,
             f"columnar/scalar ServeStats diverge on {mismatch}")
    columnar["stats_mismatches"] = len(mismatch)
    speedup = (columnar["workflows_per_sec"]
               / max(scalar["workflows_per_sec"], 1e-12))
    _require(speedup >= 5.0,
             f"columnar+event-skipping only {speedup:.1f}x the scalar "
             f"reference (acceptance floor: 10x, hard floor: 5x)")

    out = {
        "benchmark": "serve_scale",
        "config": {"workflows": args.scale_workflows, "tasks": cs.n_tasks,
                   "n_project": 2, "period_s": args.period,
                   "slots": args.slots, "seed": args.seed},
        "runs": [columnar, scalar],
        "speedup_vs_scalar": speedup,
        "generate_wall_s": generate_wall,
        "materialize_wall_s": materialize_wall,
    }
    with open(args.out, "w") as fh:
        json.dump(out, fh, indent=2)
    print(f"wrote {args.out} ({args.scale_workflows} workflows, "
          f"{cs.n_tasks} tasks)")
    for row in (columnar, scalar):
        print(f"{row['mode']:>10s}: {row['workflows_per_sec']:10.0f} wf/s  "
              f"wall {row['wall_s']:6.2f}s  ticks {row['ticks']:6d}  "
              f"over-adm {row['over_admissions']}")
    print(f"columnar vs scalar: {speedup:.1f}x workflows/sec, "
          f"stats bit-identical "
          f"(+{materialize_wall:.1f}s scalar stream materialization)")
    return out


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--workflows", type=int, default=1000)
    ap.add_argument("--jobs-scale", type=float, default=0.1)
    ap.add_argument("--period", type=float, default=7200.0)
    ap.add_argument("--slots", type=int, default=512)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: 500 workflows, smaller mosaics")
    ap.add_argument("--scale-smoke", action="store_true",
                    help="trace-scale leg: 10^5 generated workflows, "
                         "columnar+event-skipping vs the dense scalar "
                         "reference, bit-identical stats required "
                         "(writes BENCH_serve_scale.json)")
    ap.add_argument("--scale-workflows", type=int, default=100_000)
    ap.add_argument("--real", type=int, default=0, metavar="N",
                    help="also serve N workflows on the real jax engine")
    ap.add_argument("--profile", type=int, default=0, metavar="N",
                    help="cProfile the serve run and write the top-N "
                         "cumulative hot spots (CI artifact)")
    ap.add_argument("--profile-out", default="BENCH_serve_profile.txt")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    if args.out is None:
        args.out = ("BENCH_serve_scale.json" if args.scale_smoke
                    else "BENCH_serve_trace.json")

    if args.scale_smoke:
        args.period = 10_000.0
        args.slots = 4096
        profile = (Profiler(args.profile, args.profile_out)
                   if args.profile else None)
        out = run_scale(args, profile)
        if profile is not None:
            profile.write(
                f"# cProfile of the columnar --scale-smoke serve run "
                f"({args.scale_workflows} workflows)")
        return out

    if args.smoke:
        args.workflows = 500
        args.jobs_scale = 0.05
        args.period = 3600.0
        args.slots = 256

    stream = build_stream(args.workflows, args.seed, args.jobs_scale,
                          args.period)
    n_tasks = sum(len(jobs) for _, jobs in stream)
    policy = MgmtPolicy(initial=16, ratio=1.2, scan_interval=3.0,
                        release_interval=300.0)
    profile = (Profiler(args.profile, args.profile_out)
               if args.profile else None)
    dedicated = run_mode(stream, mode="dedicated", slots=args.slots,
                         policy=policy)
    dsp = run_mode(stream, mode="dsp", slots=args.slots, policy=policy,
                   contention=contention_waves(args.slots, args.period),
                   profile=profile)
    if profile is not None:
        profile.write(f"# cProfile of the dsp serve run "
                      f"({args.workflows} workflows, {n_tasks} tasks)")
    out = {
        "benchmark": "serve_trace",
        "config": {"workflows": args.workflows, "tasks": n_tasks,
                   "jobs_scale": args.jobs_scale, "period_s": args.period,
                   "slots": args.slots, "seed": args.seed,
                   "smoke": args.smoke},
        "dedicated": dedicated,
        "dsp": dsp,
        "utilization_gain": (dsp["slot_utilization"]
                             / max(dedicated["slot_utilization"], 1e-12)),
        "throughput_ratio": (dsp["workflows_per_hour"]
                             / max(dedicated["workflows_per_hour"], 1e-12)),
        "billed_ratio": (dsp["node_hours"]
                         / max(dedicated["node_hours"], 1e-12)),
    }
    if args.real:
        out["real"] = run_real(args.real, args.seed)
    with open(args.out, "w") as fh:
        json.dump(out, fh, indent=2)

    print(f"wrote {args.out} ({args.workflows} workflows, {n_tasks} tasks)")
    for row in (dedicated, dsp) + ((out["real"],) if args.real else ()):
        print(f"{row['mode']:>10s}: {row['workflows_per_hour']:8.1f} wf/h  "
              f"util {row['slot_utilization']:6.1%}  "
              f"billed {row['node_hours']:8.0f} node-h  "
              f"deferred {row['deferred_grants']:4d}  "
              f"over-adm {row['over_admissions']}  "
              f"wall {row['wall_s']:.1f}s "
              f"({row['workflows_per_sec']:.0f} wf/s)")
    print(f"dsp vs dedicated: {out['utilization_gain']:.2f}x utilization at "
          f"{out['throughput_ratio']:.2f}x throughput, "
          f"{out['billed_ratio']:.2f}x billed node-hours")
    return out


if __name__ == "__main__":
    main()
