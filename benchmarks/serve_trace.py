"""Trace-scale MTC serving benchmark: the serve driver vs a dedicated
engine on the same workflow arrival stream.

Hundreds-to-thousands of Montage-shaped workflows (``workload_family``
MTC providers, merged into one trace-rate arrival stream by
``request_stream``) are replayed through ``repro.serve.driver.ServeDriver``
in two configurations:

  - **dedicated**: a fixed engine of the full slot count held for the
    whole run — the DCS-style baseline (no negotiation, no backpressure),
  - **dsp**: the DawningCloud serve path — slots granted by a shared
    finite ``ResourceProvider`` under DR1/DR2 scans, co-tenant contention
    waves parking requests in the admission queue (deferred grants land
    through ``on_grant``), workflow roots queuing in the env under
    backpressure, and time-averaged release checks shrinking the slot
    pool when the trace goes quiet.

Both runs must complete every workflow with ZERO over-admissions (the
engine never holds more requests than granted slots) — asserted, not just
reported. The emitted ``BENCH_serve_trace.json`` carries workflows/hour,
slot utilization, billed node-hours and deferred-grant counts for both
sides; CI uploads it next to the scale-curve artifact so the serving-path
trajectory accumulates across PRs.

``--real`` additionally drives a small stream through the actual jax
continuous-batching engine (musicgen smoke config) to pin the emulated
slot model to the real stack.
"""
from __future__ import annotations

import argparse
import json
import time

from repro.core.policy import MgmtPolicy
from repro.core.provider import ResourceProvider
from repro.core.provision import ProvisionService
from repro.serve.driver import EmulatedEngine, JaxEngineAdapter, ServeDriver
from repro.sim.traces import request_stream, workload_family


def build_stream(n_workflows: int, seed: int, jobs_scale: float,
                 period: float):
    fam = workload_family(0, n_workflows, seed=seed, jobs_scale=jobs_scale)
    return request_stream(fam, period=period, seed=seed)


def contention_waves(slots: int, period: float) -> list[tuple[float, str, int]]:
    """Co-tenant load on the shared platform: neighbors grab three
    quarters of the slots early — fewer than the stream's sustained demand
    remain, so the env saturates its headroom and its DR1 parks — then
    release in two waves; each release drains the admission queue into
    deferred grants."""
    hold = 3 * slots // 4
    return [(31.0, "neighbors", hold),
            (0.5 * period, "neighbors", -(hold // 2)),
            (0.75 * period, "neighbors", -(hold - hold // 2))]


def run_mode(stream, *, mode: str, slots: int, policy: MgmtPolicy,
             contention=()) -> dict:
    if mode == "dsp":
        provider = ResourceProvider(slots, coordination="first-come")
        driver = ServeDriver(stream, provider=provider,
                             engine=EmulatedEngine(slots), policy=policy,
                             contention=contention)
    else:
        driver = ServeDriver(stream, provider=ProvisionService(),
                             engine=EmulatedEngine(slots),
                             fixed_nodes=slots)
    t0 = time.perf_counter()
    stats = driver.run()
    wall = time.perf_counter() - t0
    # the acceptance gate: everything served, nothing over-admitted
    assert stats.workflows_completed == stats.workflows_expected, (
        mode, stats.workflows_completed, stats.workflows_expected)
    assert stats.over_admissions == 0, (mode, stats.over_admissions)
    out = stats.as_dict()
    out["mode"] = mode
    out["wall_s"] = wall
    return out


def run_real(n_workflows: int, seed: int) -> dict:
    """Small-stream sanity run on the actual jax engine."""
    import jax
    from repro.configs import get_smoke_config
    from repro.configs.base import ParallelConfig
    from repro.models.lm import LM
    from repro.serve.engine import Engine

    cfg = get_smoke_config("musicgen-large")
    lm = LM(cfg)
    rt = lm.runtime(ParallelConfig(attn_q_chunk=16, attn_kv_chunk=16))
    params = lm.init(jax.random.key(0))[0]
    engine = Engine(lm, params, rt, max_batch=4, max_len=48)
    fam = workload_family(0, n_workflows, seed=seed, jobs_scale=0.05)
    stream = request_stream(fam, period=600.0, seed=seed,
                            seconds_per_token=4.0, prompt_lens=(4, 6))
    provider = ResourceProvider(4, coordination="first-come")
    driver = ServeDriver(
        stream, provider=provider, engine=JaxEngineAdapter(engine, seed=seed),
        policy=MgmtPolicy(initial=2, ratio=1.0, scan_interval=3.0,
                          release_interval=60.0))
    t0 = time.perf_counter()
    stats = driver.run()
    wall = time.perf_counter() - t0
    assert stats.workflows_completed == stats.workflows_expected
    assert stats.over_admissions == 0
    out = stats.as_dict()
    out["mode"] = "real-jax"
    out["wall_s"] = wall
    out["decode_steps"] = engine.steps
    return out


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--workflows", type=int, default=1000)
    ap.add_argument("--jobs-scale", type=float, default=0.1)
    ap.add_argument("--period", type=float, default=7200.0)
    ap.add_argument("--slots", type=int, default=512)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: 500 workflows, smaller mosaics")
    ap.add_argument("--real", type=int, default=0, metavar="N",
                    help="also serve N workflows on the real jax engine")
    ap.add_argument("--out", default="BENCH_serve_trace.json")
    args = ap.parse_args(argv)

    if args.smoke:
        args.workflows = 500
        args.jobs_scale = 0.05
        args.period = 3600.0
        args.slots = 256

    stream = build_stream(args.workflows, args.seed, args.jobs_scale,
                          args.period)
    n_tasks = sum(len(jobs) for _, jobs in stream)
    policy = MgmtPolicy(initial=16, ratio=1.2, scan_interval=3.0,
                        release_interval=300.0)
    dedicated = run_mode(stream, mode="dedicated", slots=args.slots,
                         policy=policy)
    dsp = run_mode(stream, mode="dsp", slots=args.slots, policy=policy,
                   contention=contention_waves(args.slots, args.period))
    out = {
        "benchmark": "serve_trace",
        "config": {"workflows": args.workflows, "tasks": n_tasks,
                   "jobs_scale": args.jobs_scale, "period_s": args.period,
                   "slots": args.slots, "seed": args.seed,
                   "smoke": args.smoke},
        "dedicated": dedicated,
        "dsp": dsp,
        "utilization_gain": (dsp["slot_utilization"]
                             / max(dedicated["slot_utilization"], 1e-12)),
        "throughput_ratio": (dsp["workflows_per_hour"]
                             / max(dedicated["workflows_per_hour"], 1e-12)),
        "billed_ratio": (dsp["node_hours"]
                         / max(dedicated["node_hours"], 1e-12)),
    }
    if args.real:
        out["real"] = run_real(args.real, args.seed)
    with open(args.out, "w") as fh:
        json.dump(out, fh, indent=2)

    print(f"wrote {args.out} ({args.workflows} workflows, {n_tasks} tasks)")
    for row in (dedicated, dsp) + ((out["real"],) if args.real else ()):
        print(f"{row['mode']:>10s}: {row['workflows_per_hour']:8.1f} wf/h  "
              f"util {row['slot_utilization']:6.1%}  "
              f"billed {row['node_hours']:8.0f} node-h  "
              f"deferred {row['deferred_grants']:4d}  "
              f"over-adm {row['over_admissions']}  "
              f"wall {row['wall_s']:.1f}s")
    print(f"dsp vs dedicated: {out['utilization_gain']:.2f}x utilization at "
          f"{out['throughput_ratio']:.2f}x throughput, "
          f"{out['billed_ratio']:.2f}x billed node-hours")
    return out


if __name__ == "__main__":
    main()
