"""Paper Figs 9-11: resource consumption & throughput vs (B, R) parameters.

The paper tunes DawningCloud's two policy knobs — initial resources B and
threshold ratio R — per workload and picks the configuration that saves
resources without hurting throughput. We run the same sweep on our traces;
benchmarks/emulation.py's TUNED_POLICIES record the chosen points.
"""
from __future__ import annotations

from repro.core.policy import MgmtPolicy
from repro.core.provision import ProvisionService
from repro.sim.engine import Sim
from repro.sim.systems import REServer
from repro.sim.traces import montage_like, nasa_ipsc_like, sdsc_blue_like

HTC_B = (10, 20, 40, 60, 80)
HTC_R = (1.0, 1.2, 1.5, 2.0)
MTC_B = (10, 20, 40, 80)
MTC_R = (2.0, 4.0, 8.0, 16.0)


def sweep(workload_fn, kind: str):
    Bs, Rs = (HTC_B, HTC_R) if kind == "htc" else (MTC_B, MTC_R)
    rows = []
    for B in Bs:
        for R in Rs:
            wl = workload_fn()
            sim = Sim()
            prov = ProvisionService()
            policy = (MgmtPolicy.htc(B, R) if kind == "htc"
                      else MgmtPolicy.mtc(B, R))
            tre = REServer(sim, wl, prov, mode="dsp", policy=policy)
            sim.run()
            nh = prov.node_hours(wl.name, now=sim.t)
            done = sum(1 for j in tre.completed if j.finish <= wl.period)
            makespan = (max(j.finish for j in tre.completed)
                        - min(j.submit_time for j in tre.completed))
            rows.append({
                "B": B, "R": R, "node_hours": round(nh),
                "completed": done,
                "tasks_per_second": round(len(tre.completed) / makespan, 2),
            })
    return rows


def fig9_blue():
    return sweep(sdsc_blue_like, "htc")


def fig10_nasa():
    return sweep(nasa_ipsc_like, "htc")


def fig11_montage():
    return sweep(montage_like, "mtc")


def main():
    for name, fn, perf in (("Fig 10 (NASA)", fig10_nasa, "completed"),
                           ("Fig 9 (BLUE)", fig9_blue, "completed"),
                           ("Fig 11 (Montage)", fig11_montage,
                            "tasks_per_second")):
        rows = sorted(fn(), key=lambda r: r["node_hours"])
        print(f"\n== {name} (best 5 of {len(rows)}) ==")
        for row in rows[:5]:
            print(f"  B{row['B']}_R{row['R']}: node*h={row['node_hours']} "
                  f"{perf}={row[perf]}")
        # the paper's criterion: save resources WITHOUT hurting throughput
        best_perf = max(r[perf] for r in rows)
        ok = [r for r in rows if r[perf] >= 0.99 * best_perf]
        best = min(ok, key=lambda r: r["node_hours"])
        print(f"  chosen (min node*h at >=99% best {perf}): "
              f"B{best['B']}_R{best['R']}")


if __name__ == "__main__":
    main()
