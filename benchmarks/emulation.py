"""Shared emulation runner: all four systems over the consolidated workloads.

Both the paper-parameter policies (B40_R1.2 / B80_R1.5 / B10_R8) and the
policies tuned on *our* traces by the Fig 9-11 sweep procedure are run;
tables report both so the reproduction and the calibration gap are visible.

Systems resolve through the ``repro.core.registry`` plugin registry (the
four below are the paper's; registered scenarios beyond the paper, e.g.
``dawningcloud-backfill``, run through the same ``run_system`` path).
"""
from __future__ import annotations

import copy
import functools

from repro.core.policy import MgmtPolicy
from repro.sim import run_system
from repro.sim.traces import standard_workloads

PAPER_POLICIES = {
    "nasa": MgmtPolicy.htc(40, 1.2),
    "blue": MgmtPolicy.htc(80, 1.5),
    "montage": MgmtPolicy.mtc(10, 8.0),
}

# chosen by the same procedure the paper uses (benchmarks/fig9_11_params.py)
TUNED_POLICIES = {
    "nasa": MgmtPolicy.htc(40, 1.0),
    "blue": MgmtPolicy.htc(40, 1.0),
    "montage": MgmtPolicy.mtc(10, 8.0),   # ties B10_R2..R16 at equal throughput
}

SYSTEMS = ("dcs", "ssp", "drp", "dawningcloud")

PAPER_TABLES = {
    "dcs": {"nasa": 43008, "blue": 48384, "montage": 166},
    "ssp": {"nasa": 43008, "blue": 48384, "montage": 166},
    "drp": {"nasa": 54118, "blue": 35838, "montage": 662},
    "dawningcloud": {"nasa": 29014, "blue": 35201, "montage": 166},
}
PAPER_PERF = {
    "dcs": {"nasa": 2603, "blue": 2649, "montage": 2.49},
    "ssp": {"nasa": 2603, "blue": 2649, "montage": 2.49},
    "drp": {"nasa": 2603, "blue": 2657, "montage": 2.71},
    "dawningcloud": {"nasa": 2603, "blue": 2653, "montage": 2.49},
}


@functools.lru_cache(maxsize=None)
def _run_all_cached(policy_set: str = "tuned", seed: int = 0):
    wls = standard_workloads(seed)
    policies = TUNED_POLICIES if policy_set == "tuned" else PAPER_POLICIES
    return {
        system: run_system(system, wls, policies=policies,
                           mtc_fixed_nodes=166)
        for system in SYSTEMS
    }


def run_all(policy_set: str = "tuned", seed: int = 0):
    """Returns {system: SystemResult} for the consolidated experiment.

    The emulation itself is cached, but every caller gets a defensive deep
    copy: ``SystemResult``/``WorkloadResult`` are mutable dataclasses, and
    handing the cached instances to multiple callers (tables.py and
    fig12_14_provider.py share this entry point) would let one report's
    post-processing silently corrupt another's inputs."""
    return copy.deepcopy(_run_all_cached(policy_set, seed))


def saved_vs_dcs(results, system: str, workload: str) -> float:
    dcs = results["dcs"].per_workload[workload].node_hours
    ours = results[system].per_workload[workload].node_hours
    return 1.0 - ours / dcs
