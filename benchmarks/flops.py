"""Analytic FLOP/byte models per (arch x shape x parallel) cell.

``cost_analysis()`` on a scanned module counts each while body once, so raw
HLO numbers cannot give totals without knowing the per-body split (the
artifact records them + trip counts as a cross-check). The roofline's
compute and memory terms instead come from this explicit model, which
mirrors the module math exactly:

  MODEL_FLOPS   the classic 6*N*D (train) / 2*N_active*D (decode) headline,
  EXEC_FLOPS    what the executed schedule really spends: + attention pair
                grids (masked impl computes the full S x Sk grid — 2x causal
                waste; triangular computes the true lower triangle),
                + MoE capacity-factor padding, + remat recomputation,
  HBM_BYTES     parameter + activation + cache traffic per device.

All totals are *global*; callers divide by chip count.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig, ParallelConfig, ShapeConfig


def matmul_params(cfg: ModelConfig, active: bool = False) -> int:
    """Parameters that participate in per-token matmuls (excludes the input
    embedding gather, includes the logits head)."""
    d = cfg.d_model
    ncb = max(1, cfg.n_codebooks)
    total = ncb * cfg.vocab_padded * d            # output head(s)
    for i in range(cfg.n_layers):
        if cfg.block_kind(i) == "attn":
            total += d * (cfg.q_dim + 2 * cfg.kv_dim) + cfg.q_dim * d
        else:
            di, ds, nh, ng = (cfg.d_inner, cfg.d_state, cfg.n_ssm_heads,
                              cfg.ssm_groups)
            total += d * (2 * di + 2 * ng * ds + nh) + di * d
        n_mlp = 3 if cfg.mlp_act == "swiglu" else 2
        if cfg.is_moe_layer(i):
            e = cfg.top_k if active else cfg.n_experts
            mult = cfg.capacity_factor if active else 1.0
            total += int(e * mult) * n_mlp * d * cfg.d_ff_expert if active \
                else e * n_mlp * d * cfg.d_ff_expert
            total += cfg.n_shared_experts * n_mlp * d * cfg.d_ff_expert
            if cfg.dense_residual and cfg.d_ff > 0:
                total += n_mlp * d * cfg.d_ff
        elif cfg.d_ff > 0:
            total += n_mlp * d * cfg.d_ff
    return total


def _attn_layers(cfg: ModelConfig) -> int:
    return sum(1 for i in range(cfg.n_layers) if cfg.block_kind(i) == "attn")


def _ssm_layers(cfg: ModelConfig) -> int:
    return cfg.n_layers - _attn_layers(cfg)


def attention_pair_flops(cfg: ModelConfig, S: int, Sk: int, B: int,
                         impl: str) -> float:
    """Score+PV matmul flops for one full forward over all attn layers."""
    L = _attn_layers(cfg)
    if impl == "triangular" and S == Sk:
        pairs = S * (S + 1) / 2
    else:
        pairs = float(S) * Sk          # masked impl: full grid
    return 4.0 * B * L * cfg.n_heads * cfg.head_dim * pairs  # QK^T + PV


def ssd_flops(cfg: ModelConfig, S: int, B: int) -> float:
    """Chunked SSD dual-form flops for one forward over all ssm layers."""
    L = _ssm_layers(cfg)
    if L == 0:
        return 0.0
    Q = min(cfg.ssm_chunk, S)
    nh, hp, ds = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.d_state
    per_tok = 2 * Q * ds + 2 * Q * hp + 4 * ds * hp   # scores, y, state io
    return float(B) * S * L * nh * per_tok


@dataclass
class CellModel:
    model_flops: float       # 6ND-style headline
    exec_flops: float        # what the schedule really executes
    hbm_bytes: float         # per-step global HBM traffic
    tokens: int


def cell_model(cfg: ModelConfig, shape: ShapeConfig,
               parallel: ParallelConfig) -> CellModel:
    B, S = shape.global_batch, shape.seq_len
    P_mm = matmul_params(cfg)
    P_act = matmul_params(cfg, active=True) if cfg.moe else P_mm
    N_all = cfg.param_count()
    N_act = cfg.param_count(active=True)
    impl = parallel.attn_impl
    if shape.kind == "train":
        tokens = B * S
        model = 6.0 * N_act * tokens
        # fwd + bwd(2x) + remat re-fwd
        mult = 4.0 if parallel.remat != "none" else 3.0
        cap = cfg.capacity_factor if cfg.moe else 1.0
        exec_ = 2.0 * P_act * cap * tokens * mult
        # attention/ssd are matmuls too: same fwd/remat/bwd multiplier
        # (attention_pair_flops is one forward; mult = fwd + remat + 2 bwd)
        exec_ += attention_pair_flops(cfg, S, S, B, impl) * mult
        exec_ += ssd_flops(cfg, S, B) * mult
        # params read fwd+bwd+remat + grads written/reduced + opt state
        hbm = (3 * 2.0 * N_all) + (2.0 * N_all * 2) + (2.0 * 2 * N_all * 2)
        hbm += tokens * cfg.d_model * 2.0 * cfg.n_layers * 4  # act streams
    elif shape.kind == "prefill":
        tokens = B * S
        model = 2.0 * N_act * tokens
        cap = cfg.capacity_factor if cfg.moe else 1.0
        exec_ = 2.0 * P_act * cap * tokens
        exec_ += attention_pair_flops(cfg, S, S, B, impl)
        exec_ += ssd_flops(cfg, S, B)
        kv_bytes = (2 * _attn_layers(cfg) * B * S * cfg.kv_dim * 2.0)
        hbm = 2.0 * N_all + tokens * cfg.d_model * 2.0 * cfg.n_layers * 2 \
            + kv_bytes
    else:  # decode: one token against an S-deep cache
        tokens = B
        model = 2.0 * N_act * tokens
        cap = cfg.capacity_factor if cfg.moe else 1.0
        exec_ = 2.0 * P_act * cap * tokens
        exec_ += 4.0 * B * _attn_layers(cfg) * cfg.n_kv_heads * cfg.head_dim * S
        L_ssm = _ssm_layers(cfg)
        exec_ += 4.0 * B * L_ssm * cfg.n_ssm_heads * cfg.ssm_head_dim * cfg.d_state
        kv_read = 2.0 * _attn_layers(cfg) * B * S * cfg.kv_dim * 2.0
        ssm_read = (L_ssm * B * cfg.n_ssm_heads * cfg.ssm_head_dim
                    * cfg.d_state * 4.0 * 2)
        hbm = 2.0 * N_act + kv_read + ssm_read
    return CellModel(model_flops=model, exec_flops=exec_, hbm_bytes=hbm,
                     tokens=tokens)
