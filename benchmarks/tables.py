"""Paper Tables 2-4: per-service-provider metrics for each system.

Table 2 — NASA iPSC trace (HTC), Table 3 — SDSC BLUE trace (HTC),
Table 4 — Montage workflow (MTC). Each row: performance metric (completed
jobs / tasks-per-second), resource consumption (node*hours) and saved
resources vs the DCS baseline, printed next to the paper's values.
"""
from __future__ import annotations

from benchmarks.emulation import (
    PAPER_PERF, PAPER_TABLES, run_all, saved_vs_dcs,
)


def _table(workload: str, perf_key: str, policy_set: str) -> list[dict]:
    results = run_all(policy_set)
    rows = []
    for system in ("dcs", "ssp", "drp", "dawningcloud"):
        r = results[system].per_workload[workload]
        perf = (r.completed_in_window if perf_key == "jobs"
                else round(r.tasks_per_second, 2))
        rows.append({
            "system": system,
            "performance": perf,
            "paper_performance": PAPER_PERF[system][workload],
            "node_hours": round(r.node_hours),
            "paper_node_hours": PAPER_TABLES[system][workload],
            "saved_vs_dcs": round(saved_vs_dcs(results, system, workload), 3),
            "paper_saved_vs_dcs": round(
                1 - PAPER_TABLES[system][workload]
                / PAPER_TABLES["dcs"][workload], 3),
        })
    return rows


def table2_nasa(policy_set: str = "tuned"):
    return _table("nasa", "jobs", policy_set)


def table3_blue(policy_set: str = "tuned"):
    return _table("blue", "jobs", policy_set)


def table4_montage(policy_set: str = "tuned"):
    return _table("montage", "tps", policy_set)


def main():
    for name, fn in (("Table 2 (NASA)", table2_nasa),
                     ("Table 3 (BLUE)", table3_blue),
                     ("Table 4 (Montage)", table4_montage)):
        print(f"\n== {name} ==")
        print(f"{'system':14s} {'perf':>8s} {'paper':>8s} {'node*h':>8s} "
              f"{'paper':>8s} {'saved':>7s} {'paper':>7s}")
        for row in fn():
            print(f"{row['system']:14s} {row['performance']:>8} "
                  f"{row['paper_performance']:>8} {row['node_hours']:>8} "
                  f"{row['paper_node_hours']:>8} "
                  f"{row['saved_vs_dcs']:>7.1%} {row['paper_saved_vs_dcs']:>7.1%}")


if __name__ == "__main__":
    main()
