"""Paper §4.5.5: total cost of ownership, DCS vs SSP (EC2 pricing).

Reproduces the arithmetic of the paper's real case exactly:
  DCS — 15-node cluster, $120,000 CapEx over an 8-year depreciation cycle,
        $30,000 total maintenance over the cycle, $1,600/month energy+space.
  SSP — 30 EC2 instances at $0.1/instance-hour (matching the DCS compute),
        <=1,000 GB/month inbound at $0.1/GB.
"""
from __future__ import annotations

DCS_CAPEX = 120_000.0
DCS_DEPRECIATION_YEARS = 8
DCS_MAINTENANCE_TOTAL = 30_000.0
DCS_ENERGY_SPACE_MONTH = 1_600.0

EC2_INSTANCES = 30
EC2_PRICE_HOUR = 0.1
EC2_INBOUND_GB = 1_000
EC2_INBOUND_PRICE_GB = 0.1

PAPER_TCO_DCS = 3_160.0
PAPER_TCO_SSP = 2_260.0


def tco_dcs_per_month() -> float:
    months = DCS_DEPRECIATION_YEARS * 12
    return (DCS_CAPEX / months + DCS_MAINTENANCE_TOTAL / months
            + DCS_ENERGY_SPACE_MONTH)


def tco_ssp_per_month() -> float:
    instances = 30 * 24 * EC2_INSTANCES * EC2_PRICE_HOUR
    inbound = EC2_INBOUND_GB * EC2_INBOUND_PRICE_GB
    return instances + inbound


def main():
    dcs = tco_dcs_per_month()
    ssp = tco_ssp_per_month()
    print("== TCO (paper 4.5.5) ==")
    print(f"DCS: ${dcs:,.0f}/month (paper ${PAPER_TCO_DCS:,.0f})")
    print(f"SSP: ${ssp:,.0f}/month (paper ${PAPER_TCO_SSP:,.0f})")
    print(f"SSP/DCS = {ssp/dcs:.1%} (paper 71.5%)")
    assert abs(dcs - PAPER_TCO_DCS) < 5.0, dcs
    assert abs(ssp - PAPER_TCO_SSP) < 5.0, ssp


if __name__ == "__main__":
    main()
