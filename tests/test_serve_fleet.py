"""Multi-tenant serving fleet: N=1 bit-parity with ServeDriver, slot
partitioning/isolation properties, guarded-raise invariants (the checks
that must survive ``python -O``), and the registered scenario.

The fleet parity contract (tests/README.md): ``ServeFleet`` replays
``ServeDriver._tick``'s phases phase-major across tenants with one
fleet-wide decode step, so a fleet of ONE tenant must be bit-identical to
a standalone ``ServeDriver`` on the same stream and grant sequence —
same lease adjustments at the same instants, same task times, same
completion order, same ``ServeStats``. The partitioning property: at
every tick, ``sum(tenant.active) <= engine.capacity`` and
``tenant.active <= tenant.granted`` per tenant.
"""
from __future__ import annotations

from dataclasses import replace

import pytest

from tests.conftest import given, settings, st
from tests.test_serve_driver import (
    PARITY_CAPACITY, PARITY_CONTENTION, PARITY_POLICY, PARITY_W1, PARITY_W2,
    _dag_from_spec, montage_mini,
)

from repro.core.policy import MgmtPolicy
from repro.core.provider import ResourceProvider
from repro.core.provision import ProvisionService
from repro.core.registry import available_systems, get_system
from repro.core.types import Job
from repro.serve.driver import EmulatedEngine, ServeDriver, ServeInvariantError
from repro.serve.fleet import (
    PartitionedEngine, ServeFleet, aggregate_decode_peak,
)


# ---------------------------------------------------------------- helpers
class RecordingFleet(ServeFleet):
    """Record the partition state after every tick so the property is
    checked from OUTSIDE the fleet's own invariant machinery. Samples are
    width-weighted node units; for an all-width-1 fleet units == slots,
    so the weighted property IS the PR 4 partitioning property."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.samples: list[tuple[int, list[tuple[int, int]]]] = []

    def _tick(self, k):
        super()._tick(k)
        per_tenant = [(self.pool.units_of(lane.env.name), lane.env.owned)
                      for lane in self.lanes]
        self.samples.append((self.pool.active_units, per_tenant))


def _assert_partition_property(fleet: RecordingFleet) -> None:
    cap = fleet.stats.capacity
    for total, per_tenant in fleet.samples:
        assert total <= cap
        assert total == sum(units for units, _ in per_tenant)
        for units, granted in per_tenant:
            assert units <= granted


def _tenant_dags(specs: list[list[tuple[int, int]]]) -> list[list]:
    """Per-tenant single-workflow streams with disjoint jid ranges."""
    streams, base = [], 0
    for w, spec in enumerate(specs):
        jobs = _dag_from_spec(spec, wid=w, base=base)
        base += len(jobs)
        streams.append([(0.0, jobs)])
    return streams


FLEET_POLICY = MgmtPolicy(initial=1, ratio=1.0, scan_interval=3.0,
                          release_interval=60.0)


# ----------------------------------------------------------------- parity
def test_fleet_of_one_is_bit_identical_to_serve_driver():
    """ServeFleet(N=1) vs ServeDriver on the same two-workflow stream and
    the same scripted co-tenant grant sequence: identical lease
    adjustments (values AND instants), task start/finish times,
    completion order, and the full per-tenant stats record."""
    w1 = [j.fresh() for j in PARITY_W1]
    w2 = [j.fresh() for j in PARITY_W2]
    prov = ResourceProvider(PARITY_CAPACITY, coordination="first-come")
    drv = ServeDriver([(0.0, w1), (31.0, w2)], provider=prov,
                      engine=EmulatedEngine(PARITY_CAPACITY),
                      policy=PARITY_POLICY, name="parity-serve",
                      contention=PARITY_CONTENTION)
    ref = drv.run()

    f1 = [j.fresh() for j in PARITY_W1]
    f2 = [j.fresh() for j in PARITY_W2]
    fleet = ServeFleet([[(0.0, f1), (31.0, f2)]],
                       engine=EmulatedEngine(PARITY_CAPACITY),
                       coordination="first-come", policies=PARITY_POLICY,
                       names=["parity-serve"],
                       contention=PARITY_CONTENTION)
    fs = fleet.run()

    assert ([(e.t, e.delta) for e in prov.adjust_events
             if e.tre == "parity-serve"]
            == [(e.t, e.delta) for e in fleet.provider.adjust_events
                if e.tre == "parity-serve"])
    assert ([j.name for j in drv.env.completed]
            == [j.name for j in fleet.lanes[0].env.completed])
    assert ({j.name: (j.start, j.finish) for j in w1 + w2}
            == {j.name: (j.start, j.finish) for j in f1 + f2})
    assert ref.as_dict() == fleet.lanes[0].stats.as_dict()
    assert fs.over_admissions == 0 and fs.isolation_violations == 0
    assert fs.workflows_completed == 2 and fs.deferred_grants == 1


def test_fleet_shares_one_pool_and_retires_finished_tenants():
    """Three tenants on one pool, both coordination policies: everything
    completes, zero over-admissions, zero isolation violations, every
    lease closed (finished tenants are destroyed mid-run, returning
    their slots to the pool), and the partition property holds at every
    tick."""
    for coordination in ("first-come", "coordinated"):
        streams = [
            [(0.0, montage_mini(0, 0.0, 0))],
            [(7.0, montage_mini(100, 7.0, 1))],
            [(13.0, montage_mini(200, 13.0, 2))],
        ]
        fleet = RecordingFleet(streams, engine=EmulatedEngine(6),
                               coordination=coordination,
                               policies=FLEET_POLICY)
        fs = fleet.run()
        assert fs.workflows_completed == 3
        assert fs.tasks_completed == 3 * len(montage_mini())
        assert fs.over_admissions == 0 and fs.isolation_violations == 0
        assert fleet.provider.total_allocated == 0
        assert fs.node_hours > 0 and fs.slot_utilization > 0
        _assert_partition_property(fleet)
        # consolidation was real: the whole pool served at some tick, and
        # no single tenant ever owned it all
        assert fs.peak_pool_active == 6
        assert max(t["peak_owned"] for t in fs.tenants) < 6
        # tenants finished at different instants -> earlier finishers
        # were destroyed (their lanes' makespans differ from the fleet's)
        makespans = sorted(t["makespan_s"] for t in fs.tenants)
        assert makespans[0] < fs.makespan_s


def test_cutoff_stragglers_do_not_bill_zero_duration_leases():
    """Regression: at the tick-budget cutoff, finalizing straggler lanes
    one at a time let one lane's ``destroy`` (which drains the admission
    queue as it releases nodes) grant ANOTHER straggler's still-parked
    request at the cutoff instant — a zero-duration lease billed a whole
    hour per node. All parked requests must be withdrawn (``drain=False``)
    before the finalize loop, as the emulator teardown does; billed
    node-hours at cutoff must equal one lease-hour per initially-held
    slot, nothing more."""
    streams, base = [], 0
    for w in range(3):                       # 3 starved tenants, wide work
        jobs = _dag_from_spec([(100, 0)] * 6, wid=w, base=base)
        base += len(jobs)
        streams.append([(0.0, jobs)])
    pol = MgmtPolicy(initial=1, ratio=1.0, scan_interval=3.0,
                     release_interval=60.0)
    fleet = ServeFleet(streams, engine=EmulatedEngine(3),
                       policies=[pol] * 3, max_ticks=20, strict=True)
    fs = fleet.run()
    assert fs.workflows_completed == 0       # genuinely cut off mid-run
    assert fs.node_hours == 3.0              # 3 initial slots x 1 h, no
    assert fleet.provider.total_allocated == 0  # phantom cutoff grants


# --------------------------------------------------------- heterogeneous
def _wide_dag(spec, wid, base, width):
    """``_dag_from_spec`` at a tenant's slot width (nodes == width)."""
    return [replace(j, nodes=width) for j in _dag_from_spec(spec, wid, base)]


def test_all_width_one_fleet_is_bit_identical_to_unweighted():
    """The homogeneous pin: an explicit widths=[1,...] fleet must be
    bit-identical to the default (PR 4) fleet — same stats record, same
    lease adjustments at the same instants."""
    def build(widths):
        streams = [
            [(0.0, montage_mini(0, 0.0, 0))],
            [(7.0, montage_mini(100, 7.0, 1))],
            [(13.0, montage_mini(200, 13.0, 2))],
        ]
        fleet = ServeFleet(streams, engine=EmulatedEngine(6),
                           coordination="coordinated",
                           policies=FLEET_POLICY, widths=widths)
        fs = fleet.run()
        return fs, [(e.t, e.tre, e.delta)
                    for e in fleet.provider.adjust_events]
    ref, ref_events = build(None)
    pin, pin_events = build([1, 1, 1])
    assert ref.as_dict() == pin.as_dict()
    assert ref_events == pin_events
    assert pin.widths == [1, 1, 1]
    assert pin.peak_pool_units == pin.peak_pool_active


def test_hetero_fleet_mixed_widths_completes_and_isolates():
    """The tentpole end-to-end: three tenants of widths 1/2/4 share one
    weighted pool — everything completes under both coordination
    policies with zero over-admissions and zero weighted-isolation
    violations, the weighted partition property holds at every tick, and
    the big-model tenant's billing is unit-denominated (wider than its
    slot count)."""
    spec = [(3, 0)] * 5 + [(2, 1)] * 3
    widths = [1, 2, 4]
    for coordination in ("first-come", "coordinated"):
        streams = [[(0.0, _wide_dag(spec, 0, 0, 1))],
                   [(5.0, _wide_dag(spec, 1, 100, 2))],
                   [(11.0, _wide_dag(spec, 2, 200, 4))]]
        policies = [MgmtPolicy(initial=w, ratio=1.0, scan_interval=3.0,
                               release_interval=60.0) for w in widths]
        fleet = RecordingFleet(streams, engine=EmulatedEngine(14),
                               coordination=coordination,
                               policies=policies, widths=widths)
        fs = fleet.run()
        assert fs.workflows_completed == 3
        assert fs.tasks_completed == 3 * len(spec)
        assert fs.over_admissions == 0 and fs.isolation_violations == 0
        assert fs.widths == widths
        assert fleet.provider.total_allocated == 0
        _assert_partition_property(fleet)
        # weighted accounting is real: the width-4 tenant's peak owned
        # units reach beyond what a slot-count ledger would show
        t4 = fs.tenants[2]
        assert t4["slot_width"] == 4
        assert t4["peak_owned"] >= 4
        assert fs.peak_pool_units <= 14
        assert fs.peak_pool_units >= fs.peak_pool_active


def test_partitioned_engine_weighted_isolation():
    """Width-weighted slot accounting: a width-3 tenant's admit is
    checked in units (slots x width) against its granted units, and the
    pool check is ``sum(active_i * width_i) <= capacity``."""
    jobs = [Job(jid=i, arrival=0.0, runtime=2.0, nodes=1, decode_len=2)
            for i in range(8)]
    pool = PartitionedEngine(EmulatedEngine(8))
    va, vb = pool.view("a", width=3), pool.view("b", width=1)
    granted = {"a": 6, "b": 3}
    pool.bind("a", lambda: granted["a"])
    pool.bind("b", lambda: granted["b"])
    assert va.width == 3 and vb.width == 1
    va.admit_many(jobs[:2])               # 2 slots x 3 = 6 units: exact fit
    assert pool.units_of("a") == 6 and pool.active_of("a") == 2
    with pytest.raises(ServeInvariantError, match="another tenant's slots"):
        va.admit_many(jobs[2:3])          # (2+1) x 3 = 9 > 6 granted units
    # b's grant allows 3 slots, but the weighted pool only has 2 units
    with pytest.raises(ServeInvariantError, match="beyond the pool"):
        vb.admit_many(jobs[3:6])          # 6 + 3 > 8 capacity units
    vb.admit_many(jobs[3:5])              # 6 + 2 = 8: full
    assert pool.active_units == 8 and pool.active_total == 4
    # a grant ceiling dropping below the tenant's active UNITS is caught
    pool.check_isolation()
    granted["a"] = 5                      # 6 active units > 5 granted
    with pytest.raises(ServeInvariantError, match="foreign slots"):
        pool.check_isolation()
    with pytest.raises(ValueError, match="exceeds the pool"):
        pool.view("huge", width=9)


def test_nonstrict_admit_truncation_returns_subset_and_requeues():
    """Satellite regression (fails pre-fix): non-strict ``admit_many``
    used to truncate a batch to the pool's free slots and DROP the
    remainder — the lane never learned its jobs were not admitted, so
    counting-mode fleets lost workflows and spun to max_ticks. The pool
    must return the admitted subset, and the driver must requeue the
    rest in its launch buffer until slots free."""
    jobs = [Job(jid=i, arrival=0.0, runtime=2.0, nodes=1, decode_len=2,
                name=f"j{i}") for i in range(4)]
    lax = PartitionedEngine(EmulatedEngine(2), strict=False)
    va = lax.view("a")
    lax.bind("a", lambda: 4)              # overstated grant: pool is 2
    admitted = va.admit_many(jobs)
    assert admitted is not None and [j.jid for j in admitted] == [0, 1]
    assert lax.isolation_violations == 1 and lax.active_of("a") == 2

    # end to end: a driver over a too-small non-strict pool completes
    # EVERY workflow because the truncated remainder is retried, and the
    # buffered tasks still count in the engine/env consistency check
    pool = PartitionedEngine(EmulatedEngine(2), strict=False)
    view = pool.view("t")
    pool.bind("t", lambda: 4)
    drv = ServeDriver(
        [(0.0, [j.fresh() for j in jobs])], provider=ProvisionService(),
        engine=view, fixed_nodes=4, strict=False, name="t")

    # route the pool's fleet-style step through the driver's tick loop
    k = 0
    drv._tick(0)
    while not drv._done and k < drv.max_ticks:
        k += 1
        drv.clock.advance(1.0)
        pool.step_all()
        drv._tick(k)
    stats = drv.finalize(k)
    assert stats.workflows_completed == stats.workflows_expected == 1
    assert stats.tasks_completed == 4
    assert pool.isolation_violations > 0  # truncation really happened


def test_nonstrict_fleet_pool_shrink_loses_no_workflows():
    """Fleet-level companion: shrink the pool under a running non-strict
    fleet (simulated capacity loss after grants) — admits truncate and
    requeue instead of dropping, so every workflow still completes."""
    streams = [[(0.0, montage_mini(0, 0.0, 0))],
               [(5.0, montage_mini(100, 5.0, 1))]]
    fleet = ServeFleet(streams, engine=EmulatedEngine(6),
                       policies=FLEET_POLICY, strict=False)
    fleet.pool.capacity = 2
    fs = fleet.run()
    assert fs.workflows_completed == fs.workflows_expected == 2
    assert fs.tasks_completed == 2 * len(montage_mini())
    assert fleet.pool.isolation_violations > 0


def test_aggregate_decode_peak_is_width_weighted():
    """Capacity planning charges a width-w task at w units per service
    tick — the same hour of decode work at width 2 needs twice the pool."""
    def jobs(width):
        return [Job(jid=i, arrival=0.0, runtime=1.0, nodes=width,
                    decode_len=1800) for i in range(2)]
    narrow = [[(0.0, jobs(1)[:1]), (10.0, jobs(1)[1:])]]
    wide = [[(0.0, jobs(2)[:1]), (10.0, jobs(2)[1:])]]
    assert aggregate_decode_peak(narrow) == 1
    assert aggregate_decode_peak(wide) == 2


def test_serve_hetero_system_registered_and_serves():
    assert "dawningcloud-serve-hetero" in available_systems()
    impl = get_system("dawningcloud-serve-hetero")
    assert impl.tenant_widths(5) == [1, 2, 4, 1, 2]
    spec = [(3, 0)] * 4
    streams = [[(0.0, _wide_dag(spec, 0, 0, 1))],
               [(3.0, _wide_dag(spec, 1, 100, 2))],
               [(7.0, _wide_dag(spec, 2, 200, 4))]]
    fs = impl.serve(streams, names=["s", "m", "l"])
    assert fs.widths == [1, 2, 4]
    assert fs.coordination == "coordinated"
    assert fs.workflows_completed == 3
    assert fs.over_admissions == 0 and fs.isolation_violations == 0
    # B is priced at each tenant's width, and the liveness floor covers
    # every B plus one widest slot
    assert [t["slot_width"] for t in fs.tenants] == [1, 2, 4]
    assert fs.capacity >= (4 * 1 + 4 * 2 + 4 * 4) + 4


# ------------------------------------------------------------- isolation
def test_partitioned_engine_blocks_cross_tenant_admission():
    """Tenant A can never admit into tenant B's granted slots: the pool
    has room, but A's grant is exhausted — the admit raises (strict) or
    counts (non-strict) instead of silently stealing B's slots."""
    jobs = [Job(jid=i, arrival=0.0, runtime=2.0, nodes=1, decode_len=2)
            for i in range(4)]
    pool = PartitionedEngine(EmulatedEngine(4))
    va, vb = pool.view("a"), pool.view("b")
    granted = {"a": 1, "b": 3}
    pool.bind("a", lambda: granted["a"])
    pool.bind("b", lambda: granted["b"])
    va.admit_many(jobs[:1])
    with pytest.raises(ServeInvariantError, match="another tenant's slots"):
        va.admit_many(jobs[1:3])          # a: 1 active + 2 > 1 granted
    vb.admit_many(jobs[1:3])              # b's own slots are fine
    assert pool.active_of("a") == 1 and pool.active_of("b") == 2

    lax = PartitionedEngine(EmulatedEngine(4), strict=False)
    va = lax.view("a")
    lax.bind("a", lambda: 1)
    va.admit_many(jobs[2:])               # over-grant: counted, not raised
    assert lax.isolation_violations == 1 and lax.active_of("a") == 2


def test_check_isolation_catches_post_admit_grant_shrink():
    """A grant ceiling that drops below a tenant's active slots (e.g. a
    release-check bug) is caught by the per-tick isolation sweep."""
    jobs = [Job(jid=i, arrival=0.0, runtime=2.0, nodes=1, decode_len=2)
            for i in range(2)]
    pool = PartitionedEngine(EmulatedEngine(4))
    va = pool.view("a")
    granted = {"a": 2}
    pool.bind("a", lambda: granted["a"])
    va.admit_many(jobs)
    pool.check_isolation()                # fine: 2 active <= 2 granted
    granted["a"] = 1
    with pytest.raises(ServeInvariantError, match="foreign slots"):
        pool.check_isolation()


def test_emulated_engine_admit_beyond_free_raises():
    """The engine-level guard is a raise, not an assert: it survives
    ``python -O`` (the CI leg that runs this suite optimized)."""
    eng = EmulatedEngine(2)
    jobs = [Job(jid=i, arrival=0.0, runtime=1.0, nodes=1, decode_len=1)
            for i in range(3)]
    with pytest.raises(ServeInvariantError, match="beyond free slots"):
        eng.admit_many(jobs)
    assert eng.active_count == 0 and len(eng.free) == 2


def test_fleet_rejects_duplicate_jids_and_capacity_mismatch():
    dup = [[(0.0, montage_mini(0, 0.0, 0))], [(0.0, montage_mini(0, 0.0, 1))]]
    with pytest.raises(ValueError, match="globally unique jids"):
        ServeFleet(dup, engine=EmulatedEngine(4), policies=FLEET_POLICY)
    with pytest.raises(ValueError, match="1 batching slot = 1 node"):
        ServeFleet([[(0.0, montage_mini())]], engine=EmulatedEngine(4),
                   provider=ResourceProvider(8), policies=FLEET_POLICY)


# ------------------------------------------------------------ properties
@given(st.lists(st.lists(st.tuples(st.integers(1, 9), st.integers(0, 3)),
                         min_size=1, max_size=10),
                min_size=2, max_size=4),
       st.integers(3, 8),
       st.sampled_from(["first-come", "coordinated"]))
@settings(max_examples=25, deadline=None)
def test_property_fleet_partitioning(specs, capacity, coordination):
    """For all tick sequences: ``sum(tenant.active) <= engine.capacity``
    and ``tenant.active <= tenant.granted`` per tenant — and every task
    of every tenant completes with zero over-admissions."""
    fleet = RecordingFleet(_tenant_dags(specs),
                           engine=EmulatedEngine(capacity),
                           coordination=coordination, policies=FLEET_POLICY)
    fs = fleet.run()
    assert fs.tasks_completed == sum(len(s) for s in specs)
    assert fs.over_admissions == 0 and fs.isolation_violations == 0
    assert fleet.provider.total_allocated == 0
    _assert_partition_property(fleet)


def test_fleet_partitioning_deterministic():
    """Shim-proof companion of the partitioning property: fixed tenant
    mixes on tight and ample pools, both policies."""
    cases = [
        ([[(3, 0)] * 6, [(2, 1)] * 8], 3),          # wide + chain, starved
        ([[(4, 0), (2, 1), (2, 2)], [(1, 0)] * 10, [(5, 1)] * 4], 4),
        ([[(2, 0)] * 5] * 4, 8),                     # four equal tenants
    ]
    for specs, capacity in cases:
        for coordination in ("first-come", "coordinated"):
            fleet = RecordingFleet(_tenant_dags(specs),
                                   engine=EmulatedEngine(capacity),
                                   coordination=coordination,
                                   policies=FLEET_POLICY)
            fs = fleet.run()
            assert fs.tasks_completed == sum(len(s) for s in specs)
            assert fs.over_admissions == 0
            assert fs.isolation_violations == 0
            _assert_partition_property(fleet)


# ------------------------------------------------------------- scenario
def test_serve_fleet_system_registered_and_serves():
    assert "dawningcloud-serve-fleet" in available_systems()
    impl = get_system("dawningcloud-serve-fleet")
    with pytest.raises(NotImplementedError, match="tick-driven"):
        impl.build(None, None)
    streams = [[(0.0, montage_mini(0, 0.0, 0))],
               [(5.0, montage_mini(100, 5.0, 1))]]
    fs = impl.serve(streams, names=["t0", "t1"])
    assert fs.coordination == "coordinated"
    assert fs.workflows_completed == 2
    assert fs.over_admissions == 0 and fs.isolation_violations == 0
    # the default pool covers the liveness floor (sum of Bs + 1)
    assert fs.capacity >= 2 * impl.default_policy().initial + 1


def test_aggregate_decode_peak_hourly_buckets():
    jobs = [Job(jid=i, arrival=0.0, runtime=1.0, nodes=1, decode_len=1800)
            for i in range(4)]
    # two workflows in hour 0 (3600 ticks of work -> 1 slot-hour each),
    # two in hour 1 — the peak hour offers 2 slots of sustained decode
    streams = [[(0.0, jobs[:1]), (10.0, jobs[1:2])],
               [(3700.0, jobs[2:3]), (3800.0, jobs[3:4])]]
    assert aggregate_decode_peak(streams) == 1
    both = [[(0.0, jobs[:1]), (10.0, jobs[1:2]),
             (20.0, jobs[2:3]), (30.0, jobs[3:4])]]
    assert aggregate_decode_peak(both) == 2
