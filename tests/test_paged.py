"""Paged-KV engine contracts: allocator conservation, kernel parity,
physical-engine bit-parity, and the paged fleet's field-for-field
equivalence with the slot-arithmetic fleet.

The layering mirrors the serve stack: ``PagedKVAllocator`` (pure-python
ledger) -> ``paged_decode_attention`` (pallas, interpret mode on CPU) ->
``Engine(page_size=...)`` (real jax serving) -> ``ServeFleet(page_size=)``
(emulated fleet with the physical ledger underneath). Each layer's
contract is pinned against the layer below's un-paged twin: paging is a
memory layout, never a scheduling or numerics input.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.policy import MgmtPolicy
from repro.core.provision import ProvisionService
from repro.serve.driver import (
    EmulatedEngine, JaxEngineAdapter, ServeDriver, ServeInvariantError,
    decode_budget,
)
from repro.serve.paged import PagedKVAllocator, pages_for
from repro.core.types import Job
from repro.sim.traces import SERVE_PROFILES, workload_family
from tests.conftest import given, settings, st

jax = pytest.importorskip("jax")
jnp = jax.numpy


# ===================================================================
# allocator: deterministic companion (runs under python -O and without
# hypothesis — the guarded raises are ServeInvariantError, not assert)
# ===================================================================
def test_pages_for_rounds_up_and_floors_at_one():
    assert pages_for(0, 8) == 1          # a slot always owns >= 1 page
    assert pages_for(1, 8) == 1
    assert pages_for(8, 8) == 1
    assert pages_for(9, 8) == 2
    assert pages_for(48, 8) == 6
    with pytest.raises(ValueError):
        pages_for(4, 0)


def test_allocator_lifecycle_and_guarded_raises():
    g = PagedKVAllocator(9, page_size=8, reserve_null=True)
    assert g.capacity_pages == 8 and g.free_pages == 8 and g.used_pages == 0

    a = g.alloc("a", 3)
    b = g.alloc("b", 2)
    assert len(a) == 3 and len(b) == 2
    assert g.used_pages == 5 and sorted(g.owners()) == ["a", "b"]
    assert 0 not in a + b                      # null page never handed out
    g.check_conservation()

    with pytest.raises(ServeInvariantError):   # double-own
        g.alloc("a", 1)
    with pytest.raises(ServeInvariantError):   # exhaustion (3 free)
        g.alloc("c", 4)
    with pytest.raises(ServeInvariantError):   # nonsense size
        g.alloc("c", 0)
    with pytest.raises(ServeInvariantError):   # unknown owner
        g.free("zzz")

    freed = g.free("a")
    assert sorted(freed) == sorted(a)
    assert g.used_pages == 2
    # LIFO: the freshly freed pages are first out again (cache-warm)
    c = g.alloc("c", 3)
    assert sorted(c) == sorted(freed)
    g.preempt("c")                             # preempt is free, physically
    g.free("b")
    assert g.used_pages == 0 and g.peak_used == 5
    g.check_conservation()


def test_allocator_tenant_quota_tracks_live_supplier():
    g = PagedKVAllocator(13, page_size=8, pages_per_unit=2)
    granted = {"m": 2}                               # units, live
    g.set_quota("m", lambda: granted["m"] * g.pages_per_unit)
    g.alloc("j1", 3, tenant="m")
    with pytest.raises(ServeInvariantError):         # 3 + 2 > 2*2
        g.alloc("j2", 2, tenant="m")
    granted["m"] = 4                                 # a grant arrived
    g.alloc("j2", 2, tenant="m")
    g.check_conservation()
    granted["m"] = 1                                 # shrink below usage:
    with pytest.raises(ServeInvariantError):         # the sweep catches it
        g.check_conservation()
    g.free("j1")
    g.free("j2")
    g.check_conservation()


@given(st.lists(st.tuples(st.integers(0, 2), st.integers(0, 5),
                          st.integers(1, 4)),
                min_size=1, max_size=60))
@settings(max_examples=60, deadline=None)
def test_allocator_conservation_property(ops):
    """Random admit/finish/preempt interleavings over two quota'd tenants:
    no page is ever double-mapped, freed pages return to the pool, and no
    tenant's usage exceeds its quota — swept after every op."""
    g = PagedKVAllocator(17, page_size=4, pages_per_unit=2,
                         reserve_null=True)
    quotas = {"t0": 3, "t1": 2}                       # units
    for t, q in quotas.items():
        g.set_quota(t, lambda t=t: quotas[t] * g.pages_per_unit)
    live: dict[int, str] = {}
    for i, (kind, key, n) in enumerate(ops):
        tenant = f"t{key % 2}"
        if kind == 0 and key not in live:             # admit
            try:
                g.alloc(key, n, tenant=tenant)
                live[key] = tenant
            except ServeInvariantError:
                pass                                  # quota/pool refusal
        elif kind == 1 and live:                      # finish
            victim = sorted(live)[key % len(live)]
            g.free(victim)
            del live[victim]
        elif kind == 2 and live:                      # preempt
            victim = sorted(live)[key % len(live)]
            g.preempt(victim)
            del live[victim]
        g.check_conservation()
        for t in quotas:
            assert g.tenant_pages(t) <= quotas[t] * g.pages_per_unit
    for owner in list(live):
        g.free(owner)
    assert g.used_pages == 0
    g.check_conservation()


# ===================================================================
# kernel: paged gather-through-page-table vs the contiguous kernels
# ===================================================================
def _paged_views(cache, page_size, *, shuffle_seed=None):
    """Cut a contiguous (B,S,KVH,hd) cache into a (NP,ps,KVH,hd) pool +
    page table (page 0 reserved as a poisoned null page)."""
    B, S, KVH, hd = cache.shape
    n_pt = S // page_size
    perm = np.arange(B * n_pt)
    if shuffle_seed is not None:        # physical placement is arbitrary
        np.random.default_rng(shuffle_seed).shuffle(perm)
    pool = np.full((1 + B * n_pt, page_size, KVH, hd), np.nan,
                   dtype=cache.dtype)
    table = np.zeros((B, n_pt), np.int32)
    for b in range(B):
        for j in range(n_pt):
            p = 1 + int(perm[b * n_pt + j])
            pool[p] = cache[b, j * page_size:(j + 1) * page_size]
            table[b, j] = p
    return jnp.asarray(pool), jnp.asarray(table)


@pytest.mark.parametrize("B,H,KVH,hd,S,ps",
                         [(4, 4, 2, 16, 64, 16),
                          (3, 8, 8, 32, 96, 32),
                          (2, 4, 1, 64, 64, 16)])
def test_paged_decode_bitwise_matches_contiguous_kernel(B, H, KVH, hd, S,
                                                        ps):
    """With ``page_size == block_s`` the paged kernel walks the same
    blocks in the same order as the contiguous kernel — outputs must be
    bit-identical, regardless of where pages physically live."""
    from repro.kernels.decode_attention import decode_attention
    from repro.kernels.paged_decode_attention import paged_decode_attention
    from repro.kernels.ref import decode_attention_ref

    r = np.random.default_rng(7)
    q = jnp.asarray(r.standard_normal((B, H, hd)), jnp.float32)
    k = jnp.asarray(r.standard_normal((B, S, KVH, hd)), jnp.float32)
    v = jnp.asarray(r.standard_normal((B, S, KVH, hd)), jnp.float32)
    lengths = jnp.asarray(r.integers(1, S + 1, (B,)), jnp.int32)

    contiguous = decode_attention(q, k, v, lengths, block_s=ps,
                                  interpret=True)
    k_pool, table = _paged_views(np.asarray(k), ps, shuffle_seed=3)
    v_pool, _ = _paged_views(np.asarray(v), ps, shuffle_seed=3)
    paged = paged_decode_attention(q, k_pool, v_pool, table, lengths,
                                   interpret=True)
    np.testing.assert_array_equal(np.asarray(paged),
                                  np.asarray(contiguous))
    ref = decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(paged), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_zero_length_rows_are_exact_zero_in_all_decode_kernels():
    """Satellite contract: a ``length == 0`` row (empty slot sharing the
    decode batch) yields EXACTLY zero from the ref oracle, the contiguous
    kernel and the paged kernel — never a softmax over garbage — while
    live rows in the same batch stay unperturbed."""
    from repro.kernels.decode_attention import decode_attention
    from repro.kernels.paged_decode_attention import paged_decode_attention
    from repro.kernels.ref import decode_attention_ref

    B, H, KVH, hd, S, ps = 4, 4, 2, 16, 64, 16
    r = np.random.default_rng(11)
    q = jnp.asarray(r.standard_normal((B, H, hd)), jnp.float32)
    k = jnp.asarray(r.standard_normal((B, S, KVH, hd)), jnp.float32)
    v = jnp.asarray(r.standard_normal((B, S, KVH, hd)), jnp.float32)
    lengths = jnp.asarray([0, 17, 0, S], jnp.int32)

    ref = np.asarray(decode_attention_ref(q, k, v, lengths))
    contiguous = np.asarray(decode_attention(q, k, v, lengths, block_s=ps,
                                             interpret=True))
    k_pool, table = _paged_views(np.asarray(k), ps)
    v_pool, _ = _paged_views(np.asarray(v), ps)
    paged = np.asarray(paged_decode_attention(q, k_pool, v_pool, table,
                                              lengths, interpret=True))
    for name, out in [("ref", ref), ("contiguous", contiguous),
                      ("paged", paged)]:
        assert np.all(out[0] == 0.0), name
        assert np.all(out[2] == 0.0), name
        assert np.all(np.isfinite(out)), name
    np.testing.assert_array_equal(paged[1], contiguous[1])
    np.testing.assert_array_equal(paged[3], contiguous[3])
    np.testing.assert_allclose(paged[[1, 3]], ref[[1, 3]],
                               rtol=2e-5, atol=2e-5)


# ===================================================================
# physical engine: paged vs contiguous serving, page hygiene on reject
# ===================================================================
@pytest.fixture(scope="module")
def musicgen_lm():
    from repro.configs import get_smoke_config
    from repro.configs.base import ParallelConfig
    from repro.models.lm import LM

    cfg = get_smoke_config("musicgen-large")
    lm = LM(cfg)
    rt = lm.runtime(ParallelConfig(attn_q_chunk=16, attn_kv_chunk=16))
    params = lm.init(jax.random.key(0))[0]
    return lm, params, rt


def _requests(lm, n, seed, *, plen=5, budget=6):
    from repro.serve.engine import Request

    r = np.random.default_rng(seed)
    ncb = lm.cfg.n_codebooks
    return [Request(rid=i,
                    tokens=r.integers(1, lm.cfg.vocab_size,
                                      (plen, ncb)).astype(np.int32),
                    max_new_tokens=budget) for i in range(n)]


def test_paged_engine_bitwise_matches_contiguous_engine(musicgen_lm):
    """The tentpole pin: a paged ``Engine`` (page-table splice + paged
    decode reads) must reproduce the contiguous engine's greedy tokens
    BIT-FOR-BIT and its finish order exactly, across multiple admission
    waves that force page reuse."""
    from repro.serve.engine import Engine

    lm, params, rt = musicgen_lm
    contiguous = Engine(lm, params, rt, max_batch=4, max_len=48)
    paged = Engine(lm, params, rt, max_batch=4, max_len=48, page_size=8)
    assert paged.pager.capacity_pages == 4 * 6

    def serve(eng, seed):
        reqs = _requests(lm, 9, seed)      # > 2 full batches: slot reuse
        order, pending = [], list(reqs)
        while pending or eng.active:
            admitted = eng.admit_many(pending[:len(eng.free)])
            pending = pending[len(admitted):]
            order.extend(r.rid for r in eng.step())
        return reqs, order

    ref_reqs, ref_order = serve(contiguous, 23)
    pg_reqs, pg_order = serve(paged, 23)
    assert pg_order == ref_order
    for a, b in zip(pg_reqs, ref_reqs):
        np.testing.assert_array_equal(np.asarray(a.out_tokens),
                                      np.asarray(b.out_tokens))
    # every page returned once the batch drained; ledger still consistent
    assert paged.pager.used_pages == 0
    paged.pager.check_conservation()


def test_oversize_rejects_leak_neither_slots_nor_pages(musicgen_lm):
    """Satellite regression at engine scale (fails pre-fix): a mid-batch
    oversize request must be rejected individually — later requests still
    admit, no slot is consumed, and on the paged engine no page is ever
    allocated for it."""
    from repro.serve.engine import Engine, Request

    lm, params, rt = musicgen_lm
    eng = Engine(lm, params, rt, max_batch=4, max_len=48, page_size=8)
    r = np.random.default_rng(5)
    ncb = lm.cfg.n_codebooks

    def req(rid, plen, budget):
        toks = r.integers(1, lm.cfg.vocab_size,
                          (plen, ncb)).astype(np.int32)
        return Request(rid=rid, tokens=toks, max_new_tokens=budget)

    batch = [req(0, 5, 4), req(1, 40, 40), req(2, 6, 3), req(3, 47, 2)]
    admitted = eng.admit_many(batch)
    assert [q.rid for q in admitted] == [0, 2]
    assert batch[1].rejected and batch[1].done
    assert batch[3].rejected and batch[3].done
    assert len(eng.free) == 2                      # only 2 slots consumed
    assert eng.pager.used_pages == pages_for(5 + 4, 8) + pages_for(6 + 3, 8)
    while eng.active:
        eng.step()
    assert eng.pager.used_pages == 0
    eng.pager.check_conservation()


def test_decode_budget_clamp_parity_near_full_cache(musicgen_lm):
    """Satellite regression (fails pre-fix): jobs whose prompts land AT
    or BEYOND the cache depth used to drive ``decode_budget`` to <= 0 —
    the jax adapter then built an inadmissible request and raised, while
    the emulator happily served them. Post-fix both backends clamp to the
    same >= 1 budget and finish on identical ticks."""
    from repro.serve.engine import Engine

    lm, params, rt = musicgen_lm
    cap = 48

    def jobs():
        return [Job(jid=0, arrival=0.0, runtime=1.0, nodes=1, wid=0,
                    prompt_len=cap - 1, decode_len=9, name="at-edge"),
                Job(jid=1, arrival=0.0, runtime=1.0, nodes=1, wid=0,
                    prompt_len=cap + 20, decode_len=5, name="beyond"),
                Job(jid=2, arrival=0.0, runtime=1.0, nodes=1, wid=0,
                    prompt_len=cap, decode_len=0, name="zero-decode")]

    assert decode_budget(9, cap - 1, cap) == 1     # clamp floor binds
    assert decode_budget(5, cap + 20, cap) == 1
    assert decode_budget(0, 7, cap) == 2           # room=41: floor min(2,..)

    def run(engine):
        js = jobs()
        drv = ServeDriver([(0.0, js)], provider=ProvisionService(),
                          engine=engine, fixed_nodes=4)
        stats = drv.run()
        assert stats.tasks_completed == 3 and stats.over_admissions == 0
        return {j.name: (j.start, j.finish) for j in js}

    eng = Engine(lm, params, rt, max_batch=4, max_len=cap, page_size=8)
    jax_times = run(JaxEngineAdapter(eng, seed=0))
    emu_times = run(EmulatedEngine(4, max_len=cap))
    assert jax_times == emu_times
    # a clamped budget of 1 is one decode step = one slot-tick, both sides
    assert emu_times["at-edge"][1] - emu_times["at-edge"][0] == 1.0
    assert eng.pager.used_pages == 0
    eng.pager.check_conservation()


# ===================================================================
# fleet: the paged ledger under the weighted pool
# ===================================================================
def _fleet_streams(mix, *, n_tenants, workflows=4, seed=0):
    from repro.serve.fleet import rekey_disjoint

    streams, widths = [], []
    for t in range(n_tenants):
        fam = workload_family(0, workflows, seed=seed * 1009 + t,
                              jobs_scale=0.04)
        profile = SERVE_PROFILES[mix[t % len(mix)]]
        streams.append(profile.stream(fam, period=1800.0, seed=seed + t))
        widths.append(profile.width)
    return rekey_disjoint(streams), widths


def _depth(streams, ps=8):
    need = max(max(j.prompt_len, 1) + j.decode_len + 1
               for s in streams for _, jobs in s for j in jobs)
    return -(-need // ps) * ps


def _run_fleet(streams, widths, *, page_size=None):
    from repro.serve.fleet import ServeFleet

    policies = [MgmtPolicy(initial=2 * w, ratio=2.0, scan_interval=3.0,
                           release_interval=3600.0) for w in widths]
    cap = sum(2 * w for w in widths) + 4
    eng = (EmulatedEngine(cap, max_len=_depth(streams))
           if page_size else EmulatedEngine(cap))
    fleet = ServeFleet(streams, engine=eng, coordination="coordinated",
                       policies=policies, widths=widths, event_skip=True,
                       name="paged-fleet-test", page_size=page_size)
    fs = fleet.run()
    return fs, fleet


def test_width1_paged_fleet_matches_unpaged_field_for_field():
    """Acceptance pin: the all-width-1 paged fleet reproduces the PR 7
    fleet's ``FleetStats`` field for field — the physical ledger rides
    underneath without perturbing a single admit or finish."""
    ref_fs, _ = _run_fleet(*_fleet_streams([1], n_tenants=3))
    pg_fs, fleet = _run_fleet(*_fleet_streams([1], n_tenants=3),
                              page_size=8)
    assert pg_fs.as_dict() == ref_fs.as_dict()
    assert fleet.pool.pager.used_pages == 0
    assert fleet.pool.pager.peak_used > 0
    fleet.pool.pager.check_conservation()


def test_hetero_paged_fleet_isolates_in_pages():
    """Width mix 1/2/4 under the physical ledger: every admit maps real
    pages under its tenant's quota, the sweep stays clean for the whole
    run, and the stats still match the unpaged heterogeneous fleet."""
    ref_fs, _ = _run_fleet(*_fleet_streams([1, 2, 4], n_tenants=3))
    pg_fs, fleet = _run_fleet(*_fleet_streams([1, 2, 4], n_tenants=3),
                              page_size=8)
    assert pg_fs.as_dict() == ref_fs.as_dict()
    assert pg_fs.over_admissions == 0
    assert pg_fs.isolation_violations == 0
    pager = fleet.pool.pager
    assert pager.used_pages == 0 and pager.peak_used > 0
    pager.check_conservation()
