"""Serving engine (continuous batching) + live elastic controller tests."""
from __future__ import annotations

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.controller import ElasticController, TrainTask
from repro.core.policy import MgmtPolicy
from repro.core.provision import ProvisionService
from repro.models.lm import LM
from repro.serve.engine import Engine, Request
from tests.conftest import SMOKE_PARALLEL, smoke_runconfig


@pytest.fixture(scope="module")
def granite_engine():
    cfg = get_smoke_config("granite-3-8b")
    lm = LM(cfg)
    rt = lm.runtime(SMOKE_PARALLEL)
    params = lm.init(jax.random.key(0))[0]
    return lm, params, rt


def _req(rid, plen=8, n=4):
    return Request(rid=rid, tokens=(np.arange(plen) % 7 + 1).astype(np.int32),
                   max_new_tokens=n)


def test_engine_continuous_batching(granite_engine):
    lm, params, rt = granite_engine
    eng = Engine(lm, params, rt, max_batch=2, max_len=32)
    done = eng.run([_req(i) for i in range(5)])
    assert len(done) == 5
    assert all(len(r.out_tokens) == 4 for r in done)
    # slots freed: engine reusable
    assert len(eng.free) == 2 and not eng.active


def test_batching_does_not_change_results(granite_engine):
    """Greedy output of a request must not depend on its batch-mates."""
    lm, params, rt = granite_engine
    eng1 = Engine(lm, params, rt, max_batch=1, max_len=32)
    solo = eng1.run([_req(0, plen=6, n=5)])[0]
    eng2 = Engine(lm, params, rt, max_batch=3, max_len=32)
    reqs = [_req(0, plen=6, n=5), _req(1, plen=9, n=3), _req(2, plen=4, n=5)]
    batched = {r.rid: r for r in eng2.run(reqs)}
    np.testing.assert_array_equal(np.asarray(solo.out_tokens),
                                  np.asarray(batched[0].out_tokens))


def test_engine_rejects_oversized_request(granite_engine):
    """Oversize requests are rejected individually, never raised: the
    request comes back marked ``rejected`` with no output and the engine
    keeps its slot free for admissible work."""
    lm, params, rt = granite_engine
    eng = Engine(lm, params, rt, max_batch=1, max_len=16)
    req = _req(0, plen=14, n=8)
    assert not eng.admit(req)
    assert req.rejected and req.done and not req.out_tokens
    assert len(eng.free) == 1 and not eng.active


def test_controller_runs_queue_with_failures(tmp_path):
    rcfg = smoke_runconfig("qwen2-7b", total_steps=100)
    prov = ProvisionService(capacity=8)
    ctl = ElasticController(policy=MgmtPolicy.htc(1, 1.0), provision=prov,
                            steps_per_tick=4, elastic_grow=False)
    tasks = [TrainTask(f"job-{i}", rcfg, nodes=1, num_steps=8,
                       ckpt_dir=str(tmp_path / f"j{i}")) for i in range(2)]
    for t in tasks:
        ctl.submit(t)
    ctl.run(fail_at={2: "job-0"})
    ctl.destroy()
    assert len(ctl.finished) == 2
    assert all(t.done for t in ctl.finished)
    assert tasks[0].restarts == 1
    # DSP accounting happened: initial lease + any dynamic grants all closed
    assert prov.total_allocated == 0
    assert prov.adjust_count() >= 2


def test_controller_policy_grows_for_queue(tmp_path):
    """Two 1-node jobs + B=1: the DSP scan must lease a second node.

    CPU has one device; the controller's node bookkeeping is exercised by
    padding the device list (each 1-node task still runs on mesh=None)."""
    rcfg = smoke_runconfig("qwen2-7b", total_steps=100)
    prov = ProvisionService(capacity=4)
    ctl = ElasticController(policy=MgmtPolicy.htc(1, 1.0), provision=prov,
                            steps_per_tick=4, elastic_grow=False,
                            devices=jax.devices() * 4)
    for i in range(3):
        ctl.submit(TrainTask(f"j{i}", rcfg, nodes=1, num_steps=4,
                             ckpt_dir=str(tmp_path / f"g{i}")))
    ctl.tick()
    assert ctl.owned >= 2   # grew beyond the single initial node
    ctl.run()
    assert len(ctl.finished) == 3
