"""The scale axis: workload families, Table 2-4 parity through the
multi-tenant ResourceProvider, and the economies-of-scale curve."""
from __future__ import annotations

import math

import pytest

from repro.core.policy import MgmtPolicy
from repro.core.registry import available_systems
from repro.sim.systems import (
    aggregate_demand_peak, aggregate_hourly_peak, run_system,
)
from repro.sim.traces import standard_workloads, workload_family

TUNED_POLICIES = {
    "nasa": MgmtPolicy.htc(40, 1.0),
    "blue": MgmtPolicy.htc(40, 1.0),
    "montage": MgmtPolicy.mtc(10, 8.0),
}

# PR 1's Table 2-4 node-hours (tuned policy set, seed 0) — the parity
# anchor for every refactor of the provisioning layer
PR1_TABLES = {
    "dcs": {"nasa": 43008, "blue": 48384, "montage": 166},
    "ssp": {"nasa": 43008, "blue": 48384, "montage": 166},
    "drp": {"nasa": 51914, "blue": 34107, "montage": 662},
    "dawningcloud": {"nasa": 34784, "blue": 35248, "montage": 166},
}


# ------------------------------------------------------------- families
def test_family_canonical_trio_is_standard_workloads():
    """A (2 HTC + 1 MTC) family IS the paper's trio, job for job."""
    fam = workload_family(2, 1, seed=0)
    std = standard_workloads(0)
    assert [wl.name for wl in fam] == ["nasa", "blue", "montage"]
    for a, b in zip(fam, std):
        assert [(j.arrival, j.nodes, j.runtime, j.deps) for j in a.jobs] == \
               [(j.arrival, j.nodes, j.runtime, j.deps) for j in b.jobs]


def test_family_scales_heterogeneously():
    fam = workload_family(5, 2, seed=3)
    names = [wl.name for wl in fam]
    assert len(names) == len(set(names)) == 7
    kinds = [wl.kind for wl in fam]
    assert kinds.count("htc") == 5 and kinds.count("mtc") == 2
    # variants differ from the canonical generators (volume jitter)
    base = workload_family(5, 2, seed=3)
    assert [len(w.jobs) for w in fam] == [len(w.jobs) for w in base]  # determin.
    counts = [len(w.jobs) for w in fam if w.kind == "htc"]
    assert len(set(counts)) > 1           # not N clones of one trace
    # every job fits its provider's machine (DCS configs stay schedulable)
    for wl in fam:
        assert wl.max_job_nodes <= wl.trace_nodes


def test_family_jobs_scale_shrinks_volume():
    small = workload_family(2, 1, seed=0, jobs_scale=0.25)
    full = workload_family(2, 1, seed=0)
    for s, f in zip(small, full):
        assert len(s.jobs) < len(f.jobs)


# ------------------------------------------------------- demand sizing
def test_aggregate_peak_multiplexes_below_sum_of_peaks():
    fam = workload_family(4, 2, seed=0)
    peak = aggregate_demand_peak(fam)
    sum_of_peaks = sum(wl.trace_nodes for wl in fam)
    assert peak < sum_of_peaks
    assert peak >= max(wl.trace_nodes for wl in fam)


def test_hourly_peak_at_most_instantaneous_peak():
    fam = workload_family(4, 2, seed=0)
    assert aggregate_hourly_peak(fam) <= aggregate_demand_peak(fam)


# ------------------------------------------------------------ parity
def test_registry_has_multitenant_scenarios():
    assert {"dawningcloud-coordinated", "dawningcloud-quota"} <= \
        set(available_systems())


@pytest.mark.parametrize("system", ["dcs", "ssp", "drp", "dawningcloud"])
def test_first_come_single_family_reproduces_pr1_tables(system):
    """With coordination='first-come', quotas unset, and the N=1 family,
    the four paper systems route through the multi-tenant
    ResourceProvider and still reproduce PR 1's Table 2-4 numbers
    exactly — the admission queue is bit-for-bit invisible when nothing
    contends."""
    res = run_system(system, workload_family(2, 1, seed=0),
                     policies=TUNED_POLICIES, mtc_fixed_nodes=166,
                     coordination="first-come")
    for wl_name, expected in PR1_TABLES[system].items():
        assert res.per_workload[wl_name].node_hours == expected, wl_name
    plain = run_system(system, standard_workloads(0),
                       policies=TUNED_POLICIES, mtc_fixed_nodes=166)
    assert res.total_node_hours == plain.total_node_hours
    assert res.adjust_count == plain.adjust_count
    assert res.peak_nodes_per_hour == plain.peak_nodes_per_hour


# ------------------------------------------------- economies of scale
def test_economies_of_scale_curve_monotone_improving():
    """The headline: as more providers consolidate onto the coordinated
    platform, the platform the resource provider must host *per tenant*
    shrinks monotonically (statistical multiplexing of the hourly demand
    peak), improving steadily over the per-provider DCS baseline — while
    every tenant's workload still completes and tenants keep billing
    below their dedicated-cluster cost."""
    prev_platform = None
    for n in (3, 6, 12):
        fam = workload_family(n - n // 3, n // 3, seed=0)
        dcs = run_system("dcs", fam)
        coord = run_system("dawningcloud-coordinated", fam)
        for wl, res in zip(fam, coord.per_workload.values()):
            assert res.completed_total == len(wl.jobs), wl.name
        window_h = math.ceil(coord.window_s / 3600.0)
        platform_pp = coord.capacity * window_h / n
        assert platform_pp < dcs.total_node_hours / n
        if prev_platform is not None:
            assert platform_pp < prev_platform, n
        prev_platform = platform_pp
        # tenants, not only the platform, stay ahead of dedicated clusters
        assert coord.total_node_hours < dcs.total_node_hours
        # the shared platform is truly finite and honored
        assert coord.peak_nodes_per_hour <= coord.capacity


def test_coordinated_capacity_per_provider_decreases():
    """The capacity model itself (peak hourly-averaged aggregate demand)
    multiplexes: per-provider platform size falls with N for every seed."""
    for seed in (0, 100):
        caps = []
        for n in (3, 6, 12):
            fam = workload_family(n - n // 3, n // 3, seed=seed)
            coord = run_system("dawningcloud-coordinated", fam)
            caps.append(coord.capacity / n)
        assert caps[0] > caps[1] > caps[2], (seed, caps)
