"""Per-assigned-architecture smoke tests: reduced config, one train step +
prefill + decode on CPU, asserting output shapes and finiteness."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.configs.base import ParallelConfig
from repro.data.synthetic import synthetic_batches
from repro.models.lm import LM
from repro.train.train_step import build_train_step
from tests.conftest import SMOKE_PARALLEL, smoke_runconfig

ALL_ARCHS = sorted(ARCHS)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    assert cfg.name == arch
    assert cfg.param_count() > 0
    # every full config must be dry-runnable (abstract init only)
    params, axes = LM(cfg).init(None, abstract=True)
    assert jax.tree.all(jax.tree.map(
        lambda p: isinstance(p, jax.ShapeDtypeStruct), params))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_smoke(arch):
    rcfg = smoke_runconfig(arch)
    lm = LM(rcfg.model)
    step_fn, rt, opt = build_train_step(lm, rcfg)
    params = lm.init(jax.random.key(0))[0]
    state = opt.init(params)
    batch = synthetic_batches(rcfg)(0)
    state, metrics = jax.jit(step_fn)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(state.step) == 1


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_smoke(arch):
    cfg = dataclasses.replace(get_smoke_config(arch), n_patches=8)
    lm = LM(cfg)
    rt = lm.runtime(SMOKE_PARALLEL)
    params = lm.init(jax.random.key(0))[0]
    B, P, MAXLEN = 2, 16, 32
    tshape = (B, P) if cfg.n_codebooks <= 1 else (B, P, cfg.n_codebooks)
    batch = {"tokens": jnp.ones(tshape, jnp.int32)}
    if cfg.vision_stub:
        batch["patches"] = jnp.zeros((B, cfg.n_patches, cfg.d_model),
                                     jnp.dtype(cfg.dtype))
    logits, pre_caches, _ = lm.prefill(params, rt, batch)
    v = cfg.vocab_padded
    want = (B, v) if cfg.n_codebooks <= 1 else (B, cfg.n_codebooks, v)
    assert logits.shape == want
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # splice into a max-capacity cache and take one decode step
    full = lm.init_cache(B, MAXLEN)
    caches = jax.tree.map(
        lambda d, s: jax.lax.dynamic_update_slice(
            d, s.astype(d.dtype), (0,) * d.ndim), full, pre_caches)
    plen = P + (cfg.n_patches if cfg.vision_stub else 0)
    lengths = jnp.full((B,), plen, jnp.int32)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    tok = tok[:, None] if cfg.n_codebooks <= 1 else tok[:, None, :]
    logits2, new_caches = lm.decode(params, rt, tok, lengths, caches)
    assert logits2.shape == want
    assert np.isfinite(np.asarray(logits2, np.float32)).all()
    # caches keep their structure/shapes
    jax.tree.map(lambda a, b: (a.shape, a.dtype) == (b.shape, b.dtype)
                 or pytest.fail("cache shape changed"), caches, new_caches)


def test_decode_matches_prefill_continuation():
    """Decoding token t+1 must equal prefilling t+1 tokens (same arch).
    f32 params: in bf16 the two paths differ only by accumulation order,
    which is not what this test is about."""
    cfg = dataclasses.replace(get_smoke_config("granite-3-8b"),
                              dtype="float32")
    lm = LM(cfg)
    rt = lm.runtime(SMOKE_PARALLEL)
    params = lm.init(jax.random.key(1))[0]
    toks = np.arange(1, 10)[None].astype(np.int32)  # (1, 9)
    lg_a, caches, _ = lm.prefill(params, rt, {"tokens": jnp.asarray(toks)})
    full = lm.init_cache(1, 16)
    caches = jax.tree.map(
        lambda d, s: jax.lax.dynamic_update_slice(
            d, s.astype(d.dtype), (0,) * d.ndim), full, caches)
    nxt = jnp.asarray([[10]], jnp.int32)
    lg_dec, _ = lm.decode(params, rt, nxt,
                          jnp.asarray([9], jnp.int32), caches)
    toks10 = np.concatenate([toks, [[10]]], axis=1)
    lg_b, _, _ = lm.prefill(params, rt, {"tokens": jnp.asarray(toks10)})
    np.testing.assert_allclose(np.asarray(lg_dec, np.float32),
                               np.asarray(lg_b, np.float32),
                               rtol=1e-4, atol=1e-4)
