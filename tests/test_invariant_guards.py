"""Control-plane invariants survive ``python -O``.

Each test triggers one of the seven invariants that used to be bare
``assert`` statements (``core/tre.py`` x3, ``core/controller.py``,
``sim/engine.py``, ``sim/systems.py``, ``sim/traces.py``) and pins that
violating it raises a *guarded* error. Pre-conversion these tests fail
twice over: under normal python the violation raised ``AssertionError``
(wrong type, no message), and under ``python -O`` it raised nothing at
all and silently corrupted ledger/graph state. The suite runs in both
CI legs; the ``-O`` leg is the one these guards exist for.

Static companion: dclint rule DC101 rejects new bare asserts in
``src/repro/{core,serve,sim}`` at authoring time.
"""
from __future__ import annotations

import pytest

from repro.core.provision import ProvisionService
from repro.core.tre import HTCRuntimeEnv, TickClock
from repro.core.types import Job
from repro.sim.engine import Sim
from repro.sim.traces import _check_montage_graph, montage_like


def _env(nodes: int = 8) -> HTCRuntimeEnv:
    return HTCRuntimeEnv("t0", provision=ProvisionService(),
                         clock=TickClock(), launch=lambda task: None,
                         fixed_nodes=nodes)


# --------------------------------------------------------- core/tre.py
def test_extended_track_rejects_duplicate_jid():
    env = _env()
    env.track([Job(jid=1, arrival=0.0, runtime=1.0, nodes=1)])
    with pytest.raises(RuntimeError, match="duplicate jid 1"):
        env.track([Job(jid=1, arrival=0.0, runtime=1.0, nodes=1)],
                  extend=True)


def test_grow_beyond_free_raises():
    env = _env(nodes=4)
    task = Job(jid=1, arrival=0.0, runtime=10.0, nodes=2)
    env.track([task])
    env.submit(task)                      # fixed mode schedules immediately
    with pytest.raises(RuntimeError, match="grow exceeds free"):
        env.grow(task, env.free + 1)
    env.grow(task, env.free)              # exactly-free still allowed
    assert env.busy == 4


def test_shrink_beyond_allocation_raises():
    env = _env(nodes=4)
    task = Job(jid=1, arrival=0.0, runtime=10.0, nodes=2)
    env.track([task])
    env.submit(task)
    with pytest.raises(RuntimeError, match="shrink exceeds task allocation"):
        env.shrink(task, 3)
    env.shrink(task, 2)
    assert env.busy == 0


# --------------------------------------------------- core/controller.py
def test_mesh_wider_than_device_pool_raises():
    from repro.core.controller import ElasticController

    class _Stub:
        devices = [object(), object()]

    # unbound call on a stub: the guard must fire before any jax import
    with pytest.raises(RuntimeError, match="mesh wider than device pool"):
        ElasticController._mesh_for(_Stub(), 3)


# ------------------------------------------------------- sim/engine.py
def test_event_scheduled_in_past_raises():
    sim = Sim()
    sim.at(5.0, lambda: None)
    sim.run()
    with pytest.raises(RuntimeError, match="event scheduled in the past"):
        sim.at(1.0, lambda: None)
    sim.at(5.0, lambda: None)             # equal-time (epsilon) still fine


# ------------------------------------------------------ sim/systems.py
def test_unknown_tre_mode_raises():
    from repro.sim.systems import REServer

    with pytest.raises(ValueError, match="unknown TRE mode 'bogus'"):
        REServer(None, None, None, mode="bogus")


# ------------------------------------------------------- sim/traces.py
def test_montage_graph_miscount_raises():
    with pytest.raises(RuntimeError, match="montage graph inconsistent"):
        _check_montage_graph(9, 1)
    _check_montage_graph(10, 1)           # 6*1+4: consistent
    # and the real generator still satisfies its own guard
    assert len(montage_like(seed=0, n_project=5).jobs) == 34
