"""Sharding rule resolution + HLO collective analysis unit tests.

These run on the single CPU device (no mesh construction with >1 device
needed: Mesh objects over 1 device still exercise the rule logic via a
fake mesh namespace)."""
from __future__ import annotations

import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.hlo_analysis import (
    CollectiveOp, _parse_groups, _shape_bytes, collective_summary,
    parse_collectives, scale_by_loops,
)
from repro.parallel.sharding import resolve_spec


class FakeMesh:
    """Duck-typed mesh: .axis_names + .shape mapping (enough for rules)."""

    def __init__(self, **axes):
        self.axis_names = tuple(axes)
        self.shape = dict(axes)


POD_MESH = FakeMesh(data=16, model=16)
MULTI_MESH = FakeMesh(pod=2, data=16, model=16)


def test_tp_rules_shard_model_axes():
    spec = resolve_spec(("embed", "mlp"), (4096, 12800), POD_MESH, "tp")
    assert spec == P(None, "model")
    spec = resolve_spec(("vocab", "embed"), (49408, 4096), POD_MESH, "tp")
    assert spec == P("model")


def test_small_dims_replicate():
    # 8 kv heads over a 16-way axis would waste >2x: replicate
    spec = resolve_spec(("kv_heads",), (8,), POD_MESH, "tp")
    assert spec == P()
    # non-divisible dims replicate too
    spec = resolve_spec(("mlp",), (100,), POD_MESH, "tp")
    assert spec == P()


def test_fsdp_adds_data_axis_and_pod():
    spec = resolve_spec(("embed", "mlp"), (8192, 24576), POD_MESH, "fsdp_tp")
    assert spec == P("data", "model")
    spec = resolve_spec(("embed", "mlp"), (8192, 24576), MULTI_MESH,
                        "fsdp_tp")
    assert spec == P(("pod", "data"), "model")
    # a dim divisible by 16 but not 32 drops the pod axis, keeps data
    spec = resolve_spec(("embed",), (16 * 3,), MULTI_MESH, "fsdp_tp")
    assert spec == P("data")


def test_axis_used_once():
    spec = resolve_spec(("mlp", "vocab"), (12800, 49408), POD_MESH, "tp")
    assert spec == P("model")   # vocab loses: model already used


def test_unknown_strategy_raises():
    with pytest.raises(ValueError):
        resolve_spec(("embed",), (64,), POD_MESH, "zeRO9")


# ------------------------------------------------------------ hlo analysis
def test_shape_bytes():
    assert _shape_bytes("bf16[8,512]{1,0}") == 8 * 512 * 2
    assert _shape_bytes("(f32[4], s32[2])") == 16 + 8
    assert _shape_bytes("pred[10]") == 10


SAMPLE_HLO = """\
ENTRY %main.1 (p0: bf16[16,512]) -> bf16[16,512] {
  %w = bf16[16,512]{1,0} while(%t), condition=%cond.1, body=%body.1
  %ar0 = f32[1024]{0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
}
%body.1 (p: bf16[16,512]) -> bf16[16,512] {
  %ag = bf16[16,512]{1,0} all-gather(%y), replica_groups=[128,2]<=[16,8,2]T(1,0,2), dimensions={1}
  %cp = f32[64]{0} collective-permute(%z), source_target_pairs={{0,256},{256,0}}
}
"""


def test_parse_collectives_and_nesting():
    ops, whiles = parse_collectives(SAMPLE_HLO, n_devices=256, pod_size=256)
    kinds = sorted(o.kind for o in ops)
    assert kinds == ["all-gather", "all-reduce", "collective-permute"]
    assert ("body.1", "main.1") in whiles
    ar = next(o for o in ops if o.kind == "all-reduce")
    assert ar.group_size == 4 and ar.result_bytes == 4096
    assert ar.computation == "main.1"
    ag = next(o for o in ops if o.kind == "all-gather")
    assert ag.group_size == 2 and ag.computation == "body.1"
    cp = next(o for o in ops if o.kind == "collective-permute")
    assert cp.crosses_pod   # 0 <-> 256 crosses the 256-chip pod boundary
    # trip scaling: body.1 is one level deep
    scale_by_loops(ops, whiles, [40])
    assert ag.trips == 40 and ar.trips == 1


def test_wire_byte_model():
    ag = CollectiveOp("all-gather", 1000, 4, False, "c")
    assert ag.wire_bytes == pytest.approx(750)
    rs = CollectiveOp("reduce-scatter", 1000, 4, False, "c")
    assert rs.wire_bytes == pytest.approx(3000)
    ar = CollectiveOp("all-reduce", 1000, 4, False, "c")
    assert ar.wire_bytes == pytest.approx(1500)
    summary = collective_summary([ag, rs, ar])
    assert summary["wire_bytes_intra_pod"] == pytest.approx(5250)
    assert summary["n_ops"] == 3


def test_iota_groups_pod_crossing():
    # groups of 2 with stride 256 cross pods ([2,256] transposed)
    size, crosses = _parse_groups(
        "replica_groups=[256,2]<=[2,256]T(1,0)", 512, 256)
    assert size == 2 and crosses
    size, crosses = _parse_groups(
        "replica_groups=[32,16]<=[512]", 512, 256)
    assert size == 16 and not crosses
