"""int8 cross-pod gradient compression: quantizer properties + the wrapped
grad fn on a multi-'pod' host mesh (subprocess sets the device count)."""
from __future__ import annotations

import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests.conftest import given, settings, st

from repro.parallel.compression import _quantize


@given(st.lists(st.floats(-1e3, 1e3, allow_nan=False, width=32),
                min_size=1, max_size=64))
@settings(max_examples=50, deadline=None)
def test_quantize_error_bound(vals):
    x = jnp.asarray(np.asarray(vals, np.float32))
    q, scale = _quantize(x)
    assert q.dtype == jnp.int8
    err = np.abs(np.asarray(q, np.float32) * float(scale) - np.asarray(x))
    # symmetric RTN: error <= scale/2 (+ tiny eps slack)
    assert err.max() <= float(scale) / 2 + 1e-6


def test_quantize_zero_tensor():
    q, scale = _quantize(jnp.zeros((8,)))
    assert np.all(np.asarray(q) == 0)


_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.parallel.compression import build_pod_compressed_grad_fn

mesh = jax.make_mesh((2, 2), ("pod", "data"))

def loss_fn(params, batch):
    pred = batch["x"] @ params["w"]
    l = jnp.mean((pred - batch["y"]) ** 2)
    return l, {"l": l}

grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
comp_fn = build_pod_compressed_grad_fn(grad_fn, mesh)
rng = np.random.default_rng(0)
params = {"w": jnp.asarray(rng.standard_normal((8, 4)), jnp.float32)}
batch = {"x": jnp.asarray(rng.standard_normal((16, 8)), jnp.float32),
         "y": jnp.asarray(rng.standard_normal((16, 4)), jnp.float32)}
with mesh:
    ps = jax.device_put(params, NamedSharding(mesh, P()))
    bs = jax.device_put(batch, NamedSharding(mesh, P("pod")))
    (l_c, m_c), g_c = jax.jit(comp_fn)(ps, bs)
    (l_r, m_r), g_r = jax.jit(grad_fn)(params, batch)
# loss identical (pmean of per-pod losses == global mean here)
np.testing.assert_allclose(float(l_c), float(l_r), rtol=1e-5)
# grads agree up to int8 quantization error
gc = np.asarray(g_c["w"]); gr = np.asarray(g_r["w"])
scale = np.abs(gr).max() / 127
assert np.abs(gc - gr).max() < 4 * scale + 1e-6, np.abs(gc - gr).max()
print("OK")
"""


@pytest.mark.skipif(
    not hasattr(jax, "shard_map"),
    reason="partial-manual shard_map (axis_names subset of the mesh) "
           "needs the top-level jax.shard_map API; on older jax the "
           "experimental fallback's auto= path aborts inside XLA's SPMD "
           "partitioner (IsManualSubgroup check) for this program")
def test_pod_compressed_grads_match_reference():
    r = subprocess.run([sys.executable, "-c", _SUBPROC],
                       capture_output=True, text=True,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            # the stripped env must keep jax on CPU: the
                            # host-device-count trick is CPU-only, and
                            # without the pin jax probes for TPU metadata
                            # for minutes before falling back
                            "JAX_PLATFORMS": "cpu"},
                       cwd="/root/repo", timeout=900)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
