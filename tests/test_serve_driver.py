"""Trace-rate MTC serve driver: emulator-vs-live parity, trigger-monitor /
backpressure properties, request-stream emission, real-engine integration.

The parity contract (tests/README.md): the discrete-event emulator
(``repro.sim.systems.REServer``) and the live serve driver
(``repro.serve.driver.ServeDriver``) are two drivers of the SAME
``MTCRuntimeEnv``. Given the same Montage DAG and the same scripted grant
sequence (co-tenant contention on the shared ``ResourceProvider``), they
must make bit-identical scheduling and release decisions: the same
lease-adjustment events at the same instants, the same per-task
start/finish times, the same completion order.
"""
from __future__ import annotations

import numpy as np
import pytest

from tests.conftest import given, settings, st

from repro.core.policy import MgmtPolicy
from repro.core.provider import ResourceProvider
from repro.core.provision import ProvisionService
from repro.core.types import Job, Workload
from repro.serve.driver import (
    EmulatedEngine, JaxEngineAdapter, ServeDriver, decode_budget,
)
from repro.sim.engine import Sim
from repro.sim.systems import REServer
from repro.sim.traces import request_stream, workload_family


# --------------------------------------------------------------- fixture
def montage_mini(base: int = 0, arrival: float = 0.0, wid: int = 0,
                 ) -> list[Job]:
    """A Montage-shaped mini DAG (18 tasks, 1 node each). Integer runtimes
    chosen so no finish lands on a scan (3 s) or release-check (60 s) tick
    — equal-instant event ordering is the one place the discrete heap and
    a tick loop could legally diverge, so the parity fixture keeps every
    decision at a unique instant."""
    jobs: list[Job] = []
    jid = base

    def add(name, rt, deps):
        nonlocal jid
        jobs.append(Job(jid=jid, arrival=arrival, runtime=float(rt), nodes=1,
                        deps=tuple(deps), wid=wid, name=f"w{wid}/{name}"))
        jid += 1
        return jid - 1

    proj = [add(f"proj-{i}", 4, []) for i in range(3)]
    diff = [add(f"diff-{i}", 4, [proj[i % 3], proj[(i + 1) % 3]])
            for i in range(6)]
    concat = add("concat", 5, diff)
    bg = add("bgmodel", 4, [concat])
    back = [add(f"back-{i}", 4, [bg, proj[i]]) for i in range(3)]
    tbl = add("imgtbl", 5, back)
    madd = add("madd", 4, [tbl])
    shrink = add("shrink", 4, [madd])
    add("jpeg", 4, [shrink])
    return jobs


PARITY_POLICY = MgmtPolicy(initial=1, ratio=1.0, scan_interval=3.0,
                           release_interval=60.0)
# the scripted grant sequence: a co-tenant fills the platform before the
# first scan (the env's DR1 parks), then frees 2 nodes BETWEEN scans (the
# deferred grant lands through the admission queue, not a scan poll), then
# frees the rest late
PARITY_CONTENTION = [(1.0, "hog", 7), (4.0, "hog", -2), (80.0, "hog", -5)]
PARITY_CAPACITY = 8
PARITY_W1 = montage_mini(0, 0.0, 0)
PARITY_W2 = montage_mini(100, 31.0, 1)


def _run_parity_sim():
    jobs = [j.fresh() for j in PARITY_W1 + PARITY_W2]
    wl = Workload("parity-serve", "mtc", jobs, trace_nodes=3, period=600.0)
    sim = Sim()
    prov = ResourceProvider(PARITY_CAPACITY, coordination="first-come")
    srv = REServer(sim, wl, prov, mode="dsp", policy=PARITY_POLICY)
    for t, tre, d in PARITY_CONTENTION:
        if d > 0:
            sim.at(t, prov.request, tre, d, t)
        else:
            sim.at(t, prov.release, tre, -d, t)
    sim.run()
    deltas = [(e.t, e.delta) for e in prov.adjust_events
              if e.tre == "parity-serve"]
    order = [j.name for j in srv.env.completed]
    times = {j.name: (j.start, j.finish) for j in jobs}
    return deltas, order, times


def _run_parity_serve():
    w1 = [j.fresh() for j in PARITY_W1]
    w2 = [j.fresh() for j in PARITY_W2]
    prov = ResourceProvider(PARITY_CAPACITY, coordination="first-come")
    drv = ServeDriver([(0.0, w1), (31.0, w2)], provider=prov,
                      engine=EmulatedEngine(PARITY_CAPACITY),
                      policy=PARITY_POLICY, name="parity-serve",
                      contention=PARITY_CONTENTION)
    stats = drv.run()
    deltas = [(e.t, e.delta) for e in prov.adjust_events
              if e.tre == "parity-serve"]
    order = [j.name for j in drv.env.completed]
    times = {j.name: (j.start, j.finish) for j in w1 + w2}
    return deltas, order, times, stats


# ---------------------------------------------------------------- parity
def test_emulator_serve_parity_bit_identical():
    """The same MTCRuntimeEnv under the discrete-event clock and under the
    tick-driven serve driver must make identical decisions on the same DAG
    and grant sequence: lease adjustments (values AND instants), per-task
    start/finish times, and completion order."""
    sim_deltas, sim_order, sim_times = _run_parity_sim()
    drv_deltas, drv_order, drv_times, stats = _run_parity_serve()
    assert sim_deltas == drv_deltas
    assert sim_order == drv_order
    assert sim_times == drv_times
    # the sequence exercised the interesting paths, not just no-ops:
    # initial B, an inline DR1 grant, the deferred admission-queue grant
    # at the hog's release instant (t=4, between scans), and the destroy
    assert drv_deltas == [(0.0, 1), (4.0, 1), (12.0, 1), (79.0, -3)]
    assert stats.deferred_grants == 1 and stats.deferred_nodes == 1
    assert stats.over_admissions == 0
    assert stats.workflows_completed == 2


def test_serve_parity_env_state_agrees_mid_run():
    """Dynamic blocks and owned nodes agree between drivers at a mid-run
    instant (not just at the end)."""
    jobs = [j.fresh() for j in PARITY_W1 + PARITY_W2]
    wl = Workload("parity-serve", "mtc", jobs, trace_nodes=3, period=600.0)
    sim = Sim()
    prov_s = ResourceProvider(PARITY_CAPACITY, coordination="first-come")
    srv = REServer(sim, wl, prov_s, mode="dsp", policy=PARITY_POLICY)
    for t, tre, d in PARITY_CONTENTION:
        if d > 0:
            sim.at(t, prov_s.request, tre, d, t)
        else:
            sim.at(t, prov_s.release, tre, -d, t)
    sim.run(until=41.0)

    prov_l = ResourceProvider(PARITY_CAPACITY, coordination="first-come")
    drv = ServeDriver([(0.0, [j.fresh() for j in PARITY_W1]),
                       (31.0, [j.fresh() for j in PARITY_W2])],
                      provider=prov_l, engine=EmulatedEngine(PARITY_CAPACITY),
                      policy=PARITY_POLICY, name="parity-serve",
                      contention=PARITY_CONTENTION)
    drv._tick(0)
    for k in range(1, 42):
        drv.clock.advance(1.0)
        drv._tick(k)
    assert srv.env.engine.dynamic_blocks == drv.env.engine.dynamic_blocks
    assert srv.env.owned == drv.env.owned
    assert srv.env.busy == drv.env.busy


# ------------------------------------------------- request-DAG emission
def test_request_stream_rekeys_and_marks():
    fam = workload_family(0, 3, seed=0, jobs_scale=0.05)
    stream = request_stream(fam, period=600.0, seed=0)
    assert len(stream) == 3
    assert stream[0][0] == 0.0                      # never empty-headed
    assert [t for t, _ in stream] == sorted(t for t, _ in stream)
    all_jobs = [j for _, jobs in stream for j in jobs]
    jids = [j.jid for j in all_jobs]
    assert len(set(jids)) == len(jids)              # globally unique
    for _, jobs in stream:
        local = {j.jid for j in jobs}
        for j in jobs:
            assert set(j.deps) <= local             # deps stay in-workflow
            assert j.arrival == jobs[0].arrival
            assert j.decode_len >= 1                # token-length marks
            assert j.prompt_len in (4, 6, 8)
    # deterministic per seed
    again = request_stream(workload_family(0, 3, seed=0, jobs_scale=0.05),
                           period=600.0, seed=0)
    assert [(t, [(j.jid, j.decode_len, j.prompt_len) for j in jobs])
            for t, jobs in stream] == \
        [(t, [(j.jid, j.decode_len, j.prompt_len) for j in jobs])
         for t, jobs in again]


def test_request_stream_skips_htc():
    fam = workload_family(2, 1, seed=0, jobs_scale=0.02)
    stream = request_stream(fam, period=600.0, seed=0)
    assert len(stream) == 1                         # only the MTC workload


def test_request_stream_width_denominates_nodes():
    """A width-w tenant's tasks carry nodes == w (the heterogeneous-fleet
    unit denomination); width 1 stays the homogeneous marks bit-for-bit."""
    fam = workload_family(0, 2, seed=0, jobs_scale=0.05)
    wide = request_stream(fam, period=600.0, seed=0, width=3)
    assert all(j.nodes == 3 for _, jobs in wide for j in jobs)
    narrow = request_stream(workload_family(0, 2, seed=0, jobs_scale=0.05),
                            period=600.0, seed=0, width=1)
    plain = request_stream(workload_family(0, 2, seed=0, jobs_scale=0.05),
                           period=600.0, seed=0)
    key = lambda s: [(t, [(j.jid, j.nodes, j.decode_len, j.prompt_len)
                          for j in jobs]) for t, jobs in s]
    assert key(narrow) == key(plain)
    # widths only re-denominate nodes: jids/marks match the width-1 stream
    assert ([(j.jid, j.decode_len) for _, jobs in wide for j in jobs]
            == [(j.jid, j.decode_len) for _, jobs in plain for j in jobs])
    with pytest.raises(ValueError, match="width"):
        request_stream(fam, period=600.0, seed=0, width=0)


# ------------------------------------------------- decode-budget parity
def test_emulated_engine_caps_service_to_cache_budget():
    """Satellite regression (fails pre-fix): ``EmulatedEngine`` used to
    serve the raw ``decode_len`` mark while ``JaxEngineAdapter`` caps the
    budget to the cache (``min(decode_len + 1, max_len - plen)``) — a
    trace with ``decode_len > max_len - plen`` made the two backends
    disagree on finish ticks, silently voiding the bit-parity contract.
    A cache-aware emulator must serve exactly ``decode_budget(...) - 1``
    ticks; the uncapped default keeps the old marks."""
    capped = EmulatedEngine(4, max_len=48)
    long_job = Job(jid=0, arrival=0.0, runtime=1.0, nodes=1,
                   prompt_len=4, decode_len=100)
    assert capped.service_ticks(long_job) == 43          # 48 - 4 - 1
    assert capped.service_ticks(long_job) == \
        decode_budget(100, 4, 48) - 1
    short = Job(jid=1, arrival=0.0, runtime=1.0, nodes=1,
                prompt_len=4, decode_len=10)
    assert capped.service_ticks(short) == 10             # under cap: exact
    crowded = Job(jid=2, arrival=0.0, runtime=1.0, nodes=1,
                  prompt_len=47, decode_len=5)
    assert capped.service_ticks(crowded) == 1            # floor of 1 tick
    uncapped = EmulatedEngine(4)
    assert uncapped.service_ticks(long_job) == 100       # default unchanged
    # the capped emulator admits and finishes on the capped tick
    capped.admit_many([long_job])
    ticks = 0
    while capped.active_count:
        capped.step()
        ticks += 1
    assert ticks == 43


def test_serve_driver_wide_slot_tenant():
    """A width-2 tenant standalone: tasks carry nodes == slot_width, the
    provider/env account in units, the engine in slots — and the
    unit-weighted invariants hold end to end."""
    jobs = [Job(jid=i, arrival=0.0, runtime=3.0, nodes=2, decode_len=3,
                prompt_len=4, name=f"wide-{i}") for i in range(6)]
    prov = ResourceProvider(6, coordination="first-come")
    drv = ServeDriver(
        [(0.0, jobs)], provider=prov, engine=EmulatedEngine(3),
        policy=MgmtPolicy(initial=2, ratio=1.0, scan_interval=3.0,
                          release_interval=60.0),
        slot_width=2, strict=True)
    stats = drv.run()
    assert stats.tasks_completed == 6 and stats.workflows_completed == 1
    assert stats.over_admissions == 0
    assert stats.slot_width == 2
    assert stats.peak_owned <= 6 and stats.peak_owned % 2 == 0
    # busy integral is unit-denominated: 6 tasks x 3 ticks x 2 units
    assert stats.busy_node_ticks == 6 * 3 * 2
    assert prov.total_allocated == 0
    # a task at the wrong denomination is rejected, not silently admitted
    bad = Job(jid=99, arrival=0.0, runtime=1.0, nodes=1, decode_len=1)
    drv2 = ServeDriver([(0.0, [bad])], provider=ProvisionService(),
                       engine=EmulatedEngine(2), fixed_nodes=4,
                       slot_width=2)
    with pytest.raises(Exception, match="batching slot"):
        drv2.run()


# ----------------------------------------- backpressure / driver smoke
def test_serve_driver_trace_stream_under_contention():
    """A multi-workflow stream against a tight shared platform: deferred
    grants land, roots queue under backpressure, everything completes,
    zero over-admissions."""
    fam = workload_family(0, 12, seed=0, jobs_scale=0.05)
    stream = request_stream(fam, period=900.0, seed=0)
    prov = ResourceProvider(48, coordination="first-come")
    drv = ServeDriver(
        stream, provider=prov, engine=EmulatedEngine(48),
        policy=MgmtPolicy(initial=4, ratio=2.0, scan_interval=3.0,
                          release_interval=300.0),
        contention=[(1.0, "neighbors", 40), (400.0, "neighbors", -20),
                    (700.0, "neighbors", -20)])
    stats = drv.run()
    assert stats.workflows_completed == len(stream)
    assert stats.tasks_completed == sum(len(jobs) for _, jobs in stream)
    assert stats.deferred_grants > 0        # the admission queue worked
    assert stats.over_admissions == 0       # backpressure held
    assert stats.queue_peak > stats.peak_owned   # roots really queued
    assert prov.total_allocated == 0        # destroy closed every lease
    assert stats.node_hours > 0


def test_serve_driver_dedicated_baseline_mode():
    """fixed_nodes mode: a dedicated engine serves the same stream with no
    negotiation — the benchmark's baseline side."""
    fam = workload_family(0, 4, seed=1, jobs_scale=0.05)
    stream = request_stream(fam, period=300.0, seed=1)
    prov = ProvisionService()
    drv = ServeDriver(stream, provider=prov, engine=EmulatedEngine(32),
                      fixed_nodes=32)
    stats = drv.run()
    assert stats.workflows_completed == len(stream)
    assert stats.over_admissions == 0
    assert stats.peak_owned == 32           # never renegotiated
    assert stats.deferred_grants == 0


# ------------------------------------------------------ property tests
def _dag_from_spec(spec: list[tuple[int, int]], wid: int = 0,
                   base: int = 0) -> list[Job]:
    """(runtime, n_back_deps) tuples -> a DAG where task i depends on up
    to n of its immediate predecessors."""
    jobs = []
    for i, (rt, nd) in enumerate(spec):
        deps = tuple(base + j for j in range(max(i - nd, 0), i))
        jobs.append(Job(jid=base + i, arrival=0.0, runtime=float(rt),
                        nodes=1, deps=deps, wid=wid, name=f"t{base + i}"))
    return jobs


def _run_dag(spec, capacity, hold, policy=None):
    """Drive a random DAG under scripted contention; the driver's strict
    mode asserts slots <= granted and engine == env.busy at every tick."""
    jobs = _dag_from_spec(spec)
    hold = min(hold, capacity - 1)
    contention = ([(1.0, "hog", hold), (100.0, "hog", -hold)]
                  if hold > 0 else [])
    prov = ResourceProvider(capacity, coordination="first-come")
    drv = ServeDriver(
        [(0.0, jobs)], provider=prov, engine=EmulatedEngine(capacity),
        policy=policy or MgmtPolicy(initial=1, ratio=1.0, scan_interval=3.0,
                                    release_interval=60.0),
        contention=contention, strict=True)
    stats = drv.run()
    return jobs, stats, prov


def _assert_invariants(jobs, stats, prov):
    by_jid = {j.jid: j for j in jobs}
    # liveness: every admitted request finished (nothing lost in a queue)
    assert stats.tasks_completed == len(jobs)
    assert all(j.finish >= 0 for j in jobs)
    # trigger monitor: no task launched before its dependencies completed
    for j in jobs:
        for d in j.deps:
            assert by_jid[d].finish <= j.start, (j.name, d)
    # backpressure: the engine never held more requests than granted nodes
    assert stats.over_admissions == 0
    # teardown: the TRE's leases are all closed
    assert prov.allocated.get("mtc-serve", 0) == 0


@given(st.lists(st.tuples(st.integers(1, 9), st.integers(0, 3)),
                min_size=1, max_size=24),
       st.integers(2, 8), st.integers(0, 6))
@settings(max_examples=40, deadline=None)
def test_property_deps_liveness_slots(spec, capacity, hold):
    """Random DAGs x random platform sizes x random co-tenant holds: no
    task launches before its deps complete, every admitted request
    eventually finishes, engine slots never exceed granted nodes."""
    jobs, stats, prov = _run_dag(spec, capacity, hold)
    _assert_invariants(jobs, stats, prov)


@given(st.lists(st.integers(1, 6), min_size=1, max_size=16),
       st.integers(1, 5))
@settings(max_examples=30, deadline=None)
def test_property_chain_is_strictly_sequential(runtimes, capacity):
    """A pure dependency chain can never overlap, whatever the slot
    supply: finish(i) <= start(i+1) and exactly one slot is ever busy."""
    spec = [(rt, 1) for rt in runtimes]
    jobs, stats, prov = _run_dag(spec, capacity + 1, 0)
    _assert_invariants(jobs, stats, prov)
    for a, b in zip(jobs, jobs[1:]):
        assert a.finish <= b.start
    assert stats.peak_owned <= capacity + 1
    assert stats.busy_node_ticks == sum(int(rt) for rt in runtimes)


def test_driver_invariants_deterministic():
    """Shim-proof versions of the property checks (run even without
    hypothesis installed): a mix of wide, deep and diamond DAGs under
    tight and ample platforms."""
    cases = [
        ([(3, 0)] * 8, 3, 1),                    # wide, starved platform
        ([(2, 1)] * 10, 4, 2),                   # chain under contention
        ([(4, 0), (2, 1), (2, 2), (5, 3)], 2, 0),  # diamond-ish, tiny pool
        ([(1, 0)] * 20, 8, 6),                   # burst of singletons
    ]
    for spec, cap, hold in cases:
        jobs, stats, prov = _run_dag(spec, cap, hold)
        _assert_invariants(jobs, stats, prov)


# -------------------------------------------------- real-engine serving
@pytest.fixture(scope="module")
def musicgen_engine():
    import jax
    from repro.configs import get_smoke_config
    from repro.configs.base import ParallelConfig
    from repro.models.lm import LM
    from repro.serve.engine import Engine

    cfg = get_smoke_config("musicgen-large")
    lm = LM(cfg)
    rt = lm.runtime(ParallelConfig(attn_q_chunk=16, attn_kv_chunk=16))
    params = lm.init(jax.random.key(0))[0]
    return Engine(lm, params, rt, max_batch=4, max_len=48)


def test_real_engine_serves_workflow_dag(musicgen_engine):
    """The same driver against the actual jax continuous-batching engine:
    a Montage DAG becomes real prefill/decode traffic, slots are granted
    by the provider, and the trigger monitor's order is preserved."""
    jobs = montage_mini()
    wl = Workload("mini", "mtc", [j.fresh() for j in jobs],
                  trace_nodes=3, period=600.0)
    stream = request_stream([wl], period=600.0, seed=0,
                            seconds_per_token=2.0, prompt_lens=(4, 6))
    prov = ResourceProvider(4, coordination="first-come")
    drv = ServeDriver(
        stream, provider=prov,
        engine=JaxEngineAdapter(musicgen_engine, seed=0),
        policy=MgmtPolicy(initial=2, ratio=1.0, scan_interval=3.0,
                          release_interval=60.0))
    stats = drv.run()
    assert stats.tasks_completed == len(jobs)
    assert stats.over_admissions == 0
    # engine reusable: every slot freed
    assert len(musicgen_engine.free) == 4 and not musicgen_engine.active
    # dependency order respected in the completion sequence
    pos = {j.jid: i for i, j in enumerate(drv.env.completed)}
    for j in drv.env.completed:
        for d in j.deps:
            assert pos[d] < pos[j.jid]


def test_long_decode_parity_emulator_matches_jax(musicgen_engine):
    """Satellite regression (fails pre-fix): a trace whose ``decode_len``
    exceeds the cache room (``max_len - plen``) must produce IDENTICAL
    task start/finish ticks on the emulated and jax backends — the jax
    adapter caps the decode budget to the cache, so a cache-aware
    ``EmulatedEngine(max_len=...)`` must cap the same way. Pre-fix the
    emulator served the raw 60/50-tick marks while the engine finished
    at the cap, silently voiding the bit-parity contract."""
    def long_jobs():
        return [Job(jid=0, arrival=0.0, runtime=1.0, nodes=1, wid=0,
                    prompt_len=4, decode_len=60, name="long-root"),
                Job(jid=1, arrival=0.0, runtime=1.0, nodes=1, wid=0,
                    deps=(0,), prompt_len=6, decode_len=50, name="long-mid"),
                Job(jid=2, arrival=0.0, runtime=1.0, nodes=1, wid=0,
                    deps=(1,), prompt_len=4, decode_len=7, name="short")]

    assert 60 > musicgen_engine.max_len - 4     # the cap really binds

    def run(engine):
        jobs = long_jobs()
        drv = ServeDriver([(0.0, jobs)], provider=ProvisionService(),
                          engine=engine, fixed_nodes=4)
        stats = drv.run()
        assert stats.tasks_completed == 3 and stats.over_admissions == 0
        return {j.name: (j.start, j.finish) for j in jobs}

    jax_times = run(JaxEngineAdapter(musicgen_engine, seed=0))
    emu_times = run(EmulatedEngine(4, max_len=musicgen_engine.max_len))
    assert jax_times == emu_times
    # and the capped tick counts are the budget formula's, not the marks
    cap = musicgen_engine.max_len
    assert (emu_times["long-root"][1] - emu_times["long-root"][0]
            == decode_budget(60, 4, cap) - 1)


def test_batched_admit_matches_single_admit(musicgen_engine):
    """admit_many's grouped prefill must produce the same greedy tokens
    as one-at-a-time admission (continuous-batching invariance)."""
    from repro.serve.engine import Request

    eng = musicgen_engine
    ncb = eng.lm.cfg.n_codebooks

    def reqs(seed):
        r = np.random.default_rng(seed)
        return [Request(rid=i,
                        tokens=r.integers(1, eng.lm.cfg.vocab_size,
                                          (4, ncb)).astype(np.int32),
                        max_new_tokens=3) for i in range(3)]

    solo_out = []
    for req in reqs(11):
        assert eng.admit(req)
        while eng.active:
            eng.step()
        solo_out.append(np.asarray(req.out_tokens))
    batch = reqs(11)
    admitted = eng.admit_many(batch)
    assert len(admitted) == 3
    done = []
    while eng.active:
        done.extend(eng.step())
    assert len(done) == 3
    for req, ref in zip(batch, solo_out):
        np.testing.assert_array_equal(np.asarray(req.out_tokens), ref)


def test_admit_many_finishes_in_call_order_across_shape_groups(
        musicgen_engine):
    """Same-step finishes come back in ADMISSION order even when the
    batch spans prompt-shape groups (prefill grouping must not reorder
    the finish sequence the env observes)."""
    from repro.serve.engine import Request

    eng = musicgen_engine
    ncb = eng.lm.cfg.n_codebooks
    r = np.random.default_rng(3)
    plens = (4, 6, 4, 6)                   # interleaved shape groups
    batch = [Request(rid=i,
                     tokens=r.integers(1, eng.lm.cfg.vocab_size,
                                       (p, ncb)).astype(np.int32),
                     max_new_tokens=3) for i, p in enumerate(plens)]
    assert len(eng.admit_many(batch)) == 4
    done = []
    while eng.active:
        done.extend(eng.step())
    assert [req.rid for req in done] == [0, 1, 2, 3]


def test_chunked_prefill_matches_unchunked_and_bounds_jit(musicgen_engine):
    """``prefill_chunk`` must not change a single greedy token, and must
    bound JIT specialization to ONE compiled prefill per prompt shape no
    matter how many distinct admit-group sizes the stream produces (the
    multi-tenant fleet's prompt-shape-diversity caveat)."""
    from repro.serve.engine import Engine, Request

    ref = musicgen_engine
    eng = Engine(ref.lm, ref.params, ref.rt, max_batch=4, max_len=48,
                 prefill_chunk=2)
    ncb = eng.lm.cfg.n_codebooks

    def reqs(seed, n=3):
        r = np.random.default_rng(seed)
        return [Request(rid=i,
                        tokens=r.integers(1, eng.lm.cfg.vocab_size,
                                          (4, ncb)).astype(np.int32),
                        max_new_tokens=3) for i in range(n)]

    ref_batch = reqs(21)
    assert len(ref.admit_many(ref_batch)) == 3
    while ref.active:
        ref.step()
    # chunk=2 over 3 same-shape requests: one full chunk + one PADDED
    # partial chunk — group sizes 2 and 1 share a single compiled prefill
    batch = reqs(21)
    assert len(eng.admit_many(batch)) == 3
    while eng.active:
        eng.step()
    for got, want in zip(batch, ref_batch):
        np.testing.assert_array_equal(np.asarray(got.out_tokens),
                                      np.asarray(want.out_tokens))
    assert [r.rid for r in batch] == [0, 1, 2]     # admission order kept
    assert len(eng._prefill) == 1
    (prefill_fn,) = eng._prefill.values()
    assert prefill_fn._cache_size() == 1           # one shape, one trace
    assert len(eng.free) == 4 and not eng.active


def test_admit_many_oversize_rejected_individually(musicgen_engine):
    """An oversize request anywhere in the batch is rejected on its own
    (``rejected = done = True``, no slot consumed, excluded from the
    returned admitted list) and NEVER aborts the rest of the window —
    the returned-subset contract ``ServeDriver._flush_admissions``
    relies on. The old behavior raised mid-batch, and only validated
    ``reqs[:len(free)]``, so an oversize request parked beyond the free
    window aborted a later admit window instead."""
    from repro.serve.engine import Request

    eng = musicgen_engine
    ncb = eng.lm.cfg.n_codebooks
    r = np.random.default_rng(5)

    def req(rid, plen, new):
        return Request(rid=rid, tokens=r.integers(
            1, eng.lm.cfg.vocab_size, (plen, ncb)).astype(np.int32),
            max_new_tokens=new)

    ok, oversize, ok2 = req(0, 4, 3), req(1, 40, 40), req(2, 6, 2)
    free_before = len(eng.free)
    admitted = eng.admit_many([ok, oversize, ok2])
    assert [q.rid for q in admitted] == [0, 2]
    assert oversize.rejected and oversize.done and not oversize.out_tokens
    assert not ok.rejected and not ok2.rejected
    assert len(eng.free) == free_before - 2
    done = eng.run([])
    assert sorted(q.rid for q in done) == [0, 2]
    assert len(eng.free) == free_before and not eng.active
    # run() surfaces rejects in its result instead of spinning on them
    done = eng.run([req(3, 4, 3), req(4, 40, 40)])
    assert sorted(q.rid for q in done) == [3, 4]
    assert next(q for q in done if q.rid == 4).rejected
