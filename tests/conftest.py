"""Shared test fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches
must see the real single CPU device; only launch/dryrun.py forces 512.

Also provides a ``hypothesis`` degradation shim: property-based tests import
``given``/``settings``/``st`` from here instead of from ``hypothesis``
directly, so that on machines without hypothesis installed the property
tests *skip* (instead of hard-crashing collection) while every
example-based test in the same module still runs. Install the real thing
with ``pip install -e .[test]``.
"""
from __future__ import annotations

import dataclasses

import pytest

from repro.configs import get_smoke_config
from repro.configs.base import ParallelConfig, RunConfig, ShapeConfig

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:                                # degrade: skip, don't crash
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for ``hypothesis.strategies``: every strategy builder
        (``st.lists(...)``, ``st.integers(...)``, ...) returns None, which is
        fine because the decorated test body never runs."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def given(*_args, **_kwargs):
        def deco(fn):
            def _skipped():
                pytest.skip("hypothesis not installed (pip install -e .[test])")
            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

SMOKE_SHAPE = ShapeConfig("smoke", "train", 32, 2)
SMOKE_PARALLEL = ParallelConfig(attn_q_chunk=16, attn_kv_chunk=16)


def smoke_runconfig(arch: str, **over) -> RunConfig:
    cfg = dataclasses.replace(get_smoke_config(arch), n_patches=8)
    return RunConfig(model=cfg, shape=SMOKE_SHAPE, parallel=SMOKE_PARALLEL,
                     total_steps=over.pop("total_steps", 20),
                     warmup_steps=2, **over)


@pytest.fixture
def tmp_ckpt(tmp_path):
    return str(tmp_path / "ckpt")
