"""Shared test fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches
must see the real single CPU device; only launch/dryrun.py forces 512."""
from __future__ import annotations

import dataclasses

import pytest

from repro.configs import get_smoke_config
from repro.configs.base import ParallelConfig, RunConfig, ShapeConfig

SMOKE_SHAPE = ShapeConfig("smoke", "train", 32, 2)
SMOKE_PARALLEL = ParallelConfig(attn_q_chunk=16, attn_kv_chunk=16)


def smoke_runconfig(arch: str, **over) -> RunConfig:
    cfg = dataclasses.replace(get_smoke_config(arch), n_patches=8)
    return RunConfig(model=cfg, shape=SMOKE_SHAPE, parallel=SMOKE_PARALLEL,
                     total_steps=over.pop("total_steps", 20),
                     warmup_steps=2, **over)


@pytest.fixture
def tmp_ckpt(tmp_path):
    return str(tmp_path / "ckpt")
