"""Fixture self-tests for ``tools/dclint`` (the DSP contract linter).

Per rule: one must-flag snippet (the bug class the rule exists for) and
one must-not-flag snippet (the sanctioned fix pattern) — so a rule edit
that goes blind OR noisy fails here. Plus the infrastructure contracts:
pragma suppression, baseline burn-down (stale entries prune, new
violations fail), the JSON output schema, and the eval_shape kernel
contract harness.

tests/README.md maps each rule to the dynamic property test it
complements.
"""
from __future__ import annotations

import json
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
if str(REPO) not in sys.path:
    sys.path.insert(0, str(REPO))

from tools.dclint import Violation, lint_file  # noqa: E402
from tools.dclint import baseline as baseline_mod  # noqa: E402
from tools.dclint.__main__ import main as dclint_main  # noqa: E402


def run_on(tmp_path: Path, rel: str, code: str) -> list[Violation]:
    """Write a fixture at a scope-relevant relative path and lint it."""
    path = tmp_path / rel
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(textwrap.dedent(code), encoding="utf-8")
    return lint_file(path, root=tmp_path)


def codes(violations: list[Violation]) -> list[str]:
    return [v.code for v in violations]


# =====================================================================
# DC101 — invariant asserts
# =====================================================================
def test_dc101_flags_bare_assert(tmp_path):
    vs = run_on(tmp_path, "src/repro/core/x.py", """\
        def grow(self, extra):
            assert extra <= self.free, (extra, self.free)
            self.busy += extra
        """)
    assert codes(vs) == ["DC101"]
    assert "python -O" in vs[0].message


def test_dc101_passes_guarded_raise(tmp_path):
    vs = run_on(tmp_path, "src/repro/core/x.py", """\
        def grow(self, extra):
            if extra > self.free:
                raise RuntimeError(f"grow exceeds free: {extra}")
            self.busy += extra
        """)
    assert vs == []


def test_dc101_out_of_scope_not_flagged(tmp_path):
    # kernels/ arg validation is not control-plane invariant scope
    vs = run_on(tmp_path, "src/repro/kernels/x.py",
                "def f(n):\n    assert n > 0\n")
    assert "DC101" not in codes(vs)


# =====================================================================
# DC201 — determinism
# =====================================================================
def test_dc201_flags_wall_clock_and_global_rng(tmp_path):
    vs = run_on(tmp_path, "src/repro/sim/x.py", """\
        import time, random
        import numpy as np

        def jitter():
            t = time.time()
            np.random.seed(0)
            return t + random.random() + np.random.rand()
        """)
    assert codes(vs) == ["DC201"] * 4


def test_dc201_passes_seeded_rng_and_perf_counter(tmp_path):
    vs = run_on(tmp_path, "benchmarks/bench_x.py", """\
        import time
        import numpy as np

        def measure(seed):
            rng = np.random.default_rng(seed)
            r2 = __import__("random").Random(seed)
            t0 = time.perf_counter()
            return rng.normal(), r2.random(), time.perf_counter() - t0
        """)
    assert vs == []


def test_dc201_flags_unseeded_default_rng(tmp_path):
    vs = run_on(tmp_path, "src/repro/sim/x.py", """\
        import numpy as np

        def draw(seed):
            bad = np.random.default_rng()
            ok = np.random.default_rng(seed)
            return bad.random() + ok.random()
        """)
    assert codes(vs) == ["DC201"]
    assert "entropy" in vs[0].message


def test_dc201_launch_is_exempt(tmp_path):
    vs = run_on(tmp_path, "src/repro/launch/x.py",
                "import time\nSTAMP = time.time()\n")
    assert vs == []


# =====================================================================
# DC301 — drain re-entrancy
# =====================================================================
_DC301_BUG = """\
    class Env:
        def scan(self):
            self.provision.submit_request(
                "a", 4, 0.0, on_grant=self._apply_grant)

        def _apply_grant(self, offer, t):
            self._commit(offer)
            return offer

        def _commit(self, n):
            self.provision.release(self.name, n, 0.0)
            self.provider.allocated["x"] -= n
    """

_DC301_OK = """\
    class Env:
        def scan(self):
            self.provision.submit_request(
                "a", 4, 0.0, on_grant=self._apply_grant)
            self.provision.release(self.name, 1, 0.0)   # outside callback

        def _apply_grant(self, offer, t):
            take = min(offer, self.need)
            self.engine.granted(take)     # own bookkeeping only
            self.owned += take
            self.schedule()
            return take

        def schedule(self):
            pass
    """


def test_dc301_flags_ledger_reentry_transitively(tmp_path):
    vs = run_on(tmp_path, "src/repro/core/cb.py", _DC301_BUG)
    # the direct ledger write is now ALSO a DC302 finding (the flow
    # layer sees the same hazard project-wide)
    assert codes(vs) == ["DC301", "DC301", "DC302"]
    assert "mid-drain" in vs[0].message
    assert "_apply_grant -> _commit" in vs[0].message       # call path
    assert "allocated" in vs[1].message                     # ledger write


def test_dc301_passes_own_bookkeeping_callback(tmp_path):
    vs = run_on(tmp_path, "src/repro/core/cb.py", _DC301_OK)
    assert vs == []


def test_dc301_grant_listener_assignment_is_a_root(tmp_path):
    vs = run_on(tmp_path, "src/repro/serve/gl.py", """\
        class Driver:
            def __init__(self, env):
                env.grant_listener = self._on_grant

            def _on_grant(self, nodes, t, deferred):
                self.provision.amend(self.req, nodes, t)
        """)
    assert codes(vs) == ["DC301"]


# =====================================================================
# DC302 — re-entrancy soundness (flow layer)
# =====================================================================
def test_dc302_flags_drain_read_state_writes_via_helper(tmp_path):
    vs = run_on(tmp_path, "src/repro/core/cb.py", """\
        class Env:
            def scan(self):
                self.provision.submit_request(
                    "a", 4, 0.0, on_grant=self._apply)

            def _apply(self, offer, t):
                self._book(offer)
                return offer

            def _book(self, n):
                self.provider.allocated["me"] = n
                self.provider.admission_queue.remove(None)
                self.req.status = "granted"
        """)
    got = codes(vs)
    assert got.count("DC302") == 3
    msgs = [v.message for v in vs if v.code == "DC302"]
    # the interprocedural part: the offender is one hop from the root
    assert all("via _apply -> _book" in m for m in msgs)
    assert any("allocated" in m for m in msgs)        # ledger write
    assert any("admission_queue" in m for m in msgs)  # in-place mutation
    assert any("status" in m for m in msgs)           # parked-req write


def test_dc302_passes_own_bookkeeping_closure(tmp_path):
    vs = run_on(tmp_path, "src/repro/core/cb.py", """\
        class Env:
            def scan(self):
                self.provision.submit_request(
                    "a", 4, 0.0, on_grant=self._apply)

            def _apply(self, offer, t):
                take = min(offer, self.need)
                self._book(take)
                return take

            def _book(self, take):
                self.owned += take
                self.engine.free_slots.append(take)
                self.phase = "live"
        """)
    assert "DC302" not in codes(vs)


def test_dc302_out_of_scope_not_flagged(tmp_path):
    vs = run_on(tmp_path, "src/repro/kernels/cb.py", """\
        def on_grant(offer, t, provider):
            provider.allocated["me"] = offer
        """)
    assert "DC302" not in codes(vs)


# =====================================================================
# DC401 — slot/unit discipline
# =====================================================================
def test_dc401_flags_unweighted_slot_unit_compare(tmp_path):
    vs = run_on(tmp_path, "src/repro/serve/x.py", """\
        class D:
            def check(self):
                if self.engine.active_count > self.env.owned:
                    raise RuntimeError
                return self.active_slots + self.granted
        """)
    assert codes(vs) == ["DC401", "DC401"]
    assert "slot-count" in vs[0].message
    assert "node-unit" in vs[0].message


def test_dc401_flags_unconverted_page_mixes(tmp_path):
    vs = run_on(tmp_path, "src/repro/serve/x.py", """\
        class D:
            def check(self):
                if self.pager.used_pages > self.env.granted:
                    raise RuntimeError
                return self.free_pages - self.engine.active_count
        """)
    assert codes(vs) == ["DC401", "DC401"]
    assert "page-count" in vs[0].message


def test_dc401_passes_page_rate_weighted_comparison(tmp_path):
    vs = run_on(tmp_path, "src/repro/serve/x.py", """\
        class D:
            def check(self, tenant):
                quota = self.env.granted * self.pager.pages_per_unit
                if self.pager.used_pages > quota:
                    raise RuntimeError
                rate = self.width_of(tenant) * self.pager.pages_per_unit
                need = self.engine.active_count * rate
                return need + self.pager.used_pages
        """)
    assert vs == []


def test_dc401_passes_width_weighted_comparison(tmp_path):
    vs = run_on(tmp_path, "src/repro/serve/x.py", """\
        class D:
            def check(self):
                active = self.engine.active_count * self.slot_width
                active += len(self.buf) * self.slot_width
                if active > self.env.owned:
                    raise RuntimeError
                slots = self.env.owned // self.slot_width
                return slots + self.engine.active_count
        """)
    assert vs == []


def test_dc401_only_serve_scope(tmp_path):
    vs = run_on(tmp_path, "src/repro/core/x.py",
                "def f(active_count, owned):\n"
                "    return active_count > owned\n")
    assert "DC401" not in codes(vs)


# =====================================================================
# DC501 — tracer safety
# =====================================================================
def test_dc501_flags_tracer_hazards(tmp_path):
    vs = run_on(tmp_path, "src/repro/kernels/k.py", """\
        import functools
        from jax.experimental import pallas as pl

        def _kern(x_ref, o_ref, *, block: int):
            i = pl.program_id(0)
            if i == 0:
                o_ref[...] = x_ref[...]

        def run(x, lengths, buf=[]):
            return pl.pallas_call(
                functools.partial(_kern, block=4),
                in_specs=[pl.BlockSpec((lengths[0], 128),
                                       lambda i: (i, 0))],
                out_specs=pl.BlockSpec((8, 128), lambda i: (i, 0)),
            )(x)
        """)
    got = codes(vs)
    assert got.count("DC501") == 3 and set(got) == {"DC501"}
    msgs = " | ".join(v.message for v in vs)
    assert "pl.when" in msgs                 # python-if on traced value
    assert "statically resolvable" in msgs   # BlockSpec shape entry
    assert "mutable default" in msgs


def test_dc501_passes_tracer_safe_kernel(tmp_path):
    vs = run_on(tmp_path, "src/repro/kernels/k.py", """\
        import functools
        from jax.experimental import pallas as pl

        def _kern(x_ref, o_ref, *, block: int):
            i = pl.program_id(0)

            @pl.when(i == 0)
            def _init():
                o_ref[...] = x_ref[...]

            if block > 4:      # static kwarg bound via partial: fine
                pass

        def run(x, buf=None):
            bq = min(128, x.shape[0])
            return pl.pallas_call(
                functools.partial(_kern, block=4),
                in_specs=[pl.BlockSpec((bq, x.shape[1]),
                                       lambda i: (i, 0))],
                out_specs=pl.BlockSpec((bq, 128), lambda i: (i, 0)),
            )(x)
        """)
    assert vs == []


# =====================================================================
# DC601 — tenant phase discipline (flow layer)
# =====================================================================
def test_dc601_flags_out_of_phase_grant_traffic(tmp_path):
    vs = run_on(tmp_path, "src/repro/serve/bad.py", """\
        class BadTenant(Tenant):
            def begin_tick(self, t):
                if self.env.owned:
                    self.env.scan()

            def pre_step(self, t):
                self.env.release(2)

            def control(self, t):
                self.env.yield_nodes(1)

            def flush(self, t):
                self._helper(t)

            def _helper(self, t):
                self.env.provision.amend(None, 1, t)

            def next_event_tick(self, now):
                self.env.admission_queue.append(now)
                self.env.owned = 0
                return now
        """)
    hits = [v for v in vs if v.code == "DC601"]
    assert len(hits) == 6
    msgs = " | ".join(v.message for v in hits)
    assert "intake runs before" in msgs          # begin_tick read
    assert "scan" in msgs and "begin_tick" in msgs
    assert "yield_nodes" in msgs and "control" in msgs
    assert "via _helper" in msgs                 # interprocedural hop
    assert "event-skip parity" in msgs           # pure-hook mutation
    assert "never directly" in msgs              # pure-hook ledger write
    # pre_step release is the sanctioned phase: no pre_step findings
    assert "BadTenant.pre_step" not in msgs


def test_dc601_passes_phase_disciplined_tenant(tmp_path):
    vs = run_on(tmp_path, "src/repro/serve/good.py", """\
        class GoodTenant(Tenant):
            def begin_tick(self, t):
                self._arrivals.append(t)

            def pre_step(self, t):
                self.env.release_check(t)

            def post_step(self, t):
                self.env.finish(t)
                self.env.shrink(0)

            def control(self, t):
                self.env.scan()

            def flush(self, t):
                self.env.admit_many([])

            def next_event_tick(self, now):
                if self.env.owned:
                    return now
                return now + 1.0
        """)
    assert "DC601" not in codes(vs)


def test_dc601_non_tenant_classes_unrestricted(tmp_path):
    vs = run_on(tmp_path, "src/repro/serve/pool.py", """\
        class Pool:
            def begin_tick(self, t):
                self.owned = 3
                self.provider.scan()
        """)
    assert "DC601" not in codes(vs)


# =====================================================================
# pragma suppression
# =====================================================================
def test_line_pragma_suppresses_named_code_only(tmp_path):
    vs = run_on(tmp_path, "src/repro/sim/x.py", """\
        import time

        def a():
            return time.time()  # dclint: disable=DC201

        def b():
            return time.time()  # dclint: disable=DC101
        """)
    assert [(v.code, v.line) for v in vs] == [("DC201", 7)]


def test_file_pragma_suppresses_whole_file(tmp_path):
    vs = run_on(tmp_path, "src/repro/sim/x.py", """\
        # dclint: disable-file=DC201
        import time

        def a():
            return time.time()
        """)
    assert vs == []


def test_pragma_disable_all(tmp_path):
    vs = run_on(tmp_path, "src/repro/core/x.py",
                "def f(x):\n"
                "    assert x  # dclint: disable=all\n")
    assert vs == []


# =====================================================================
# baseline burn-down
# =====================================================================
_ASSERT_FIXTURE = "def f(x):\n    assert x > 0\n"


def _violations_of(tmp_path: Path) -> list[Violation]:
    return lint_file(tmp_path / "src/repro/core/x.py", root=tmp_path)


def test_baseline_suppresses_known_and_fails_new(tmp_path):
    p = tmp_path / "src/repro/core/x.py"
    p.parent.mkdir(parents=True)
    p.write_text(_ASSERT_FIXTURE)
    bl = tmp_path / "baseline.json"
    baseline_mod.write(bl, _violations_of(tmp_path))

    # the baselined violation is suppressed
    new, baselined, stale = baseline_mod.split(
        _violations_of(tmp_path), baseline_mod.load(bl))
    assert new == [] and len(baselined) == 1 and stale == []

    # a NEW violation alongside it fails even with the baseline
    p.write_text(_ASSERT_FIXTURE + "def g(y):\n    assert y < 9\n")
    new, baselined, stale = baseline_mod.split(
        _violations_of(tmp_path), baseline_mod.load(bl))
    assert len(new) == 1 and "y < 9" in new[0].source_line
    assert len(baselined) == 1


def test_baseline_stale_entry_is_pruned(tmp_path):
    p = tmp_path / "src/repro/core/x.py"
    p.parent.mkdir(parents=True)
    p.write_text(_ASSERT_FIXTURE)
    bl = tmp_path / "baseline.json"
    baseline_mod.write(bl, _violations_of(tmp_path))

    # pay the debt: the fixed file no longer matches the entry
    p.write_text("def f(x):\n"
                 "    if not x > 0:\n"
                 "        raise RuntimeError('x')\n")
    new, baselined, stale = baseline_mod.split(
        _violations_of(tmp_path), baseline_mod.load(bl))
    assert new == [] and baselined == [] and len(stale) == 1

    baseline_mod.prune(bl, _violations_of(tmp_path))
    assert baseline_mod.load(bl)["entries"] == []


def test_baseline_fingerprint_survives_line_moves(tmp_path):
    p = tmp_path / "src/repro/core/x.py"
    p.parent.mkdir(parents=True)
    p.write_text(_ASSERT_FIXTURE)
    bl = tmp_path / "baseline.json"
    baseline_mod.write(bl, _violations_of(tmp_path))

    p.write_text("# a comment shifting every line\n\n" + _ASSERT_FIXTURE)
    new, baselined, stale = baseline_mod.split(
        _violations_of(tmp_path), baseline_mod.load(bl))
    assert new == [] and len(baselined) == 1 and stale == []


# =====================================================================
# --fix: mechanical DC101 rewrite
# =====================================================================
_FIX_FIXTURE = """\
def grow(free, busy, extra):
    assert extra <= free, (extra, free)
    return busy + extra

def check(flag, items):
    assert not flag
    assert items, "no items queued"
    return len(items)
"""


def test_fix_rewrites_asserts_and_relints_clean(tmp_path):
    p = tmp_path / "src/repro/core/x.py"
    p.parent.mkdir(parents=True)
    p.write_text(_FIX_FIXTURE)
    bl = tmp_path / "baseline.json"
    argv = ["src", "--root", str(tmp_path), "--baseline", str(bl)]
    assert dclint_main(argv) == 1
    assert dclint_main(argv + ["--fix"]) == 0
    fixed = p.read_text()
    assert "assert" not in fixed
    assert lint_file(p, root=tmp_path) == []
    assert dclint_main(argv) == 0          # idempotent: stays clean

    # the rewrite preserves runtime behavior — and survives python -O
    # semantics, since the guards are plain if/raise
    ns: dict = {}
    exec(compile(fixed, str(p), "exec"), ns)
    assert ns["grow"](free=8, busy=2, extra=3) == 5
    with pytest.raises(RuntimeError, match=r"extra <= free.*9.*8"):
        ns["grow"](free=8, busy=2, extra=9)
    assert ns["check"](False, [1, 2]) == 2
    with pytest.raises(RuntimeError, match="invariant violated: not flag"):
        ns["check"](True, [1])
    with pytest.raises(RuntimeError, match="no items queued"):
        ns["check"](False, [])


def test_fix_burns_down_baseline(tmp_path):
    p = tmp_path / "src/repro/core/x.py"
    p.parent.mkdir(parents=True)
    p.write_text(_FIX_FIXTURE)
    bl = tmp_path / "baseline.json"
    baseline_mod.write(bl, _violations_of(tmp_path))
    assert len(baseline_mod.load(bl)["entries"]) == 3

    argv = ["src", "--root", str(tmp_path), "--baseline", str(bl), "--fix"]
    assert dclint_main(argv) == 0
    # every rewritten finding became stale and was pruned — the debt is paid
    assert baseline_mod.load(bl)["entries"] == []


def test_fix_skips_non_statement_initial_assert(tmp_path):
    p = tmp_path / "src/repro/core/x.py"
    p.parent.mkdir(parents=True)
    p.write_text("def f(x, y):\n"
                 "    if x: assert y\n"
                 "    return y\n")
    from tools.dclint.fix import fix_file
    assert fix_file(p, root=tmp_path) == (0, 1)
    assert "assert y" in p.read_text()     # left for a human
    assert codes(lint_file(p, root=tmp_path)) == ["DC101"]


def test_fix_honors_pragmas_and_scope(tmp_path):
    # pragma-suppressed and out-of-scope asserts are not touched
    sup = tmp_path / "src/repro/core/sup.py"
    sup.parent.mkdir(parents=True)
    sup.write_text("def f(x):\n    assert x  # dclint: disable=DC101\n")
    out = tmp_path / "src/repro/kernels/k.py"
    out.parent.mkdir(parents=True)
    out.write_text("def f(n):\n    assert n > 0\n")
    from tools.dclint.fix import fix_paths
    assert fix_paths([tmp_path / "src"], root=tmp_path) == (0, 0)
    assert "assert x" in sup.read_text()
    assert "assert n > 0" in out.read_text()


# =====================================================================
# --fix: mechanical DC201 numpy-RNG rewrite
# =====================================================================
_RNG_FIX_FIXTURE = """\
import numpy as np

def sample(values):
    rng = np.random.default_rng()
    a = np.random.rand(3, 4)
    b = np.random.randn(8)
    c = np.random.randint(0, 9, size=5)
    d = np.random.choice(values, 2, replace=False)
    return rng, a, b, c, d
"""


def test_fix_rewrites_numpy_rng_and_relints_clean(tmp_path):
    p = tmp_path / "src/repro/sim/x.py"
    p.parent.mkdir(parents=True)
    p.write_text(_RNG_FIX_FIXTURE)
    bl = tmp_path / "baseline.json"
    argv = ["src", "--root", str(tmp_path), "--baseline", str(bl)]
    assert dclint_main(argv) == 1
    assert dclint_main(argv + ["--fix"]) == 0
    fixed = p.read_text()
    assert "np.random.default_rng(0)" in fixed
    assert "np.random.default_rng(0).random((3, 4))" in fixed
    assert "np.random.default_rng(0).standard_normal(8)" in fixed
    assert "np.random.default_rng(0).integers(0, 9, size=5)" in fixed
    assert "np.random.default_rng(0).choice(values, 2, replace=False)" \
        in fixed
    assert lint_file(p, root=tmp_path) == []
    assert dclint_main(argv) == 0          # idempotent: stays clean

    # the rewrite is runnable and deterministic (fixed seed 0)
    import numpy as np
    ns: dict = {}
    exec(compile(fixed, str(p), "exec"), ns)
    rng, a, b, c, d = ns["sample"](np.arange(10))
    assert a.shape == (3, 4) and b.shape == (8,) and c.shape == (5,)
    assert np.array_equal(c, np.random.default_rng(0).integers(
        0, 9, size=5))


def test_fix_skips_rng_calls_with_no_mechanical_rewrite(tmp_path):
    p = tmp_path / "src/repro/sim/x.py"
    p.parent.mkdir(parents=True)
    p.write_text("import numpy as np\n"
                 "def f():\n"
                 "    np.random.seed(7)\n"          # unmapped method
                 "    x = np.random.uniform(\n"     # multi-line call
                 "        0.0, 1.0)\n"
                 "    return x + np.random.rand()\n")
    from tools.dclint.fix import fix_file
    assert fix_file(p, root=tmp_path) == (1, 2)
    txt = p.read_text()
    assert "np.random.seed(7)" in txt              # left for a human
    assert "np.random.uniform(\n" in txt           # multi-line untouched
    assert "np.random.default_rng(0).random()" in txt
    assert codes(lint_file(p, root=tmp_path)) == ["DC201"] * 2


def test_fix_rng_honors_pragma(tmp_path):
    p = tmp_path / "src/repro/sim/x.py"
    p.parent.mkdir(parents=True)
    p.write_text("import numpy as np\n"
                 "x = np.random.rand()  # dclint: disable=DC201\n")
    from tools.dclint.fix import fix_file
    assert fix_file(p, root=tmp_path) == (0, 0)
    assert "np.random.rand()" in p.read_text()


def test_fix_rng_nested_calls_converge_on_second_pass(tmp_path):
    # a flagged call nested inside another flagged call is skipped on
    # the first pass (its byte span goes stale after the outer splice)
    # and picked up by the next run — --fix converges, never corrupts
    p = tmp_path / "src/repro/sim/x.py"
    p.parent.mkdir(parents=True)
    p.write_text("import numpy as np\n"
                 "x = np.random.choice(np.random.rand(4))\n")
    from tools.dclint.fix import fix_file
    assert fix_file(p, root=tmp_path) == (1, 1)
    assert fix_file(p, root=tmp_path) == (1, 0)
    assert ("np.random.default_rng(0).choice("
            "np.random.default_rng(0).random(4))") in p.read_text()
    assert lint_file(p, root=tmp_path) == []


# =====================================================================
# --fix: DC301 post-drain deferral (CFG-validated hoist)
# =====================================================================
_DC301_DEFER_FIXTURE = """\
class AmendingCallback:
    def __init__(self, provision, victim_box, need):
        self.provision = provision
        self.victim_box = victim_box
        self.need = need
        self.accepted = 0

    def on_grant(self, offer, t):
        take = min(offer, self.need - self.accepted)
        self.accepted += take
        req = self.victim_box[0]
        if req is not None and req.status == "queued":
            self.provision.amend(req, 1, t, min_useful=1)
        return take
"""


class _Taker:
    """The victim's own callback: plain accept-up-to-need."""

    def __init__(self, need: int):
        self.need = need
        self.taken = 0

    def on_grant(self, offer, t):
        take = min(offer, self.need - self.taken)
        self.taken += take
        return take


class _ReferenceCallback:
    """Hand-written sanctioned pattern the fixer's rewrite must match
    bit-for-bit: record the amend at callback time, apply after the
    triggering provider call has unwound."""

    def __init__(self, provision, victim_box, need):
        self.provision = provision
        self.victim_box = victim_box
        self.need = need
        self.accepted = 0
        self.pending: list = []

    def on_grant(self, offer, t):
        take = min(offer, self.need - self.accepted)
        self.accepted += take
        req = self.victim_box[0]
        if req is not None and req.status == "queued":
            self.pending.append((req, t))
        return take


def _drive_reentrant_drain(make_cb, apply_deferred):
    """Free a hogged pool one node at a time so every drain interleaves
    with the callback's deferred side effect; check the ledger/queue
    invariants from the existing re-entrancy property suite each step."""
    from repro.core.provider import ResourceProvider
    from tests.test_provider import _reentrancy_invariants

    prov = ResourceProvider(30, coordination="first-come")
    prov.request("hog", 30, 0.0)
    box: list = [None]
    cb = make_cb(prov, box)
    taker = _Taker(need=20)
    r0 = prov.submit_request("t0", 10, 1.0, on_grant=cb.on_grant)
    victim = prov.submit_request("t1", 20, 2.0, on_grant=taker.on_grant)
    box[0] = victim
    for step in range(30):
        if prov.allocated.get("hog", 0) == 0:
            break
        prov.release("hog", 1, 100.0 + step)
        apply_deferred(cb)
        _reentrancy_invariants(
            prov, [r0, victim],
            {r0.seq: cb.accepted, victim.seq: taker.taken})
    return prov, r0, victim, cb, taker


def test_fix_dc301_hoists_to_post_drain_and_passes_reentrancy(tmp_path):
    p = tmp_path / "src/repro/core/cb.py"
    p.parent.mkdir(parents=True)
    p.write_text(_DC301_DEFER_FIXTURE)
    bl = tmp_path / "baseline.json"
    argv = ["src", "--root", str(tmp_path), "--baseline", str(bl)]
    assert dclint_main(argv) == 1          # the DC301 offender
    assert dclint_main(argv + ["--fix"]) == 0
    fixed = p.read_text()
    assert "self._post_drain = getattr(self, '_post_drain', [])" in fixed
    assert "lambda _f=self.provision.amend" in fixed
    assert "_k={'min_useful': 1}" in fixed
    assert lint_file(p, root=tmp_path) == []   # re-lints clean
    from tools.dclint.fix import fix_file
    assert fix_file(p, root=tmp_path) == (0, 0)   # idempotent

    # validation: the rewritten callback, driven through a REAL provider
    # drain with the deferral applied post-unwind, keeps the ledger
    # invariants AND lands bit-identically on the hand-deferred pattern
    ns: dict = {}
    exec(compile(fixed, str(p), "exec"), ns)

    def apply_post_drain(cb):
        for f in getattr(cb, "_post_drain", []):
            f()
        cb._post_drain = []

    def apply_pending(cb):
        for req, t in cb.pending:
            cb.provision.amend(req, 1, t, min_useful=1)
        cb.pending = []

    got = _drive_reentrant_drain(
        lambda prov, box: ns["AmendingCallback"](prov, box, need=10),
        apply_post_drain)
    ref = _drive_reentrant_drain(
        lambda prov, box: _ReferenceCallback(prov, box, need=10),
        apply_pending)
    prov_g, r0_g, v_g, cb_g, tk_g = got
    prov_r, r0_r, v_r, cb_r, tk_r = ref
    assert dict(prov_g.allocated) == dict(prov_r.allocated)
    assert (r0_g.status, r0_g.granted) == (r0_r.status, r0_r.granted)
    assert (v_g.status, v_g.nodes, v_g.granted) \
        == (v_r.status, v_r.nodes, v_r.granted)
    assert (cb_g.accepted, tk_g.taken) == (cb_r.accepted, tk_r.taken)
    # the deferral actually happened: the amend shrank the victim
    assert v_g.nodes == 1 and cb_g.accepted == 10


def test_fix_dc301_skips_when_downstream_reads_provider_state(tmp_path):
    p = tmp_path / "src/repro/core/cb.py"
    p.parent.mkdir(parents=True)
    p.write_text(textwrap.dedent("""\
        class CB:
            def on_grant(self, offer, t):
                self.provision.amend(self.req, offer, t)
                return min(offer, self.provision.headroom(t))
        """))
    from tools.dclint.fix import fix_file
    assert fix_file(p, root=tmp_path) == (0, 1)
    assert "_post_drain" not in p.read_text()   # left for a human
    assert "DC301" in codes(lint_file(p, root=tmp_path))


def test_fix_dc301_skips_non_method_and_mid_expression(tmp_path):
    p = tmp_path / "src/repro/core/cb.py"
    p.parent.mkdir(parents=True)
    p.write_text(textwrap.dedent("""\
        def on_grant(offer, t, provision):
            provision.cancel(offer)
            return offer

        class CB:
            def on_grant(self, offer, t):
                return self.provision.amend(self.req, offer, t)
        """))
    from tools.dclint.fix import fix_file
    # no `self` to hold the list / offender is not a whole statement
    assert fix_file(p, root=tmp_path) == (0, 2)
    assert "_post_drain" not in p.read_text()


# =====================================================================
# --fix: idempotence gate across every fixer
# =====================================================================
_ALL_FIXERS_FIXTURE = """\
import numpy as np

class CB:
    def on_grant(self, offer, t):
        self.provision.cancel(self.victim)
        return offer

def grow(free, extra):
    assert extra <= free
    return extra

def draw():
    return np.random.rand(4)
"""


def test_fix_applied_twice_is_noop_and_relints_clean(tmp_path):
    p = tmp_path / "src/repro/core/x.py"
    p.parent.mkdir(parents=True)
    p.write_text(_ALL_FIXERS_FIXTURE)
    from tools.dclint.fix import fix_paths
    assert fix_paths([tmp_path / "src"], root=tmp_path) == (3, 0)
    once = p.read_text()
    assert lint_file(p, root=tmp_path) == []
    # the gate: a second pass finds nothing and changes nothing
    assert fix_paths([tmp_path / "src"], root=tmp_path) == (0, 0)
    assert p.read_text() == once


# =====================================================================
# CLI + JSON schema
# =====================================================================
def _cli_fixture(tmp_path: Path) -> Path:
    p = tmp_path / "src/repro/core/x.py"
    p.parent.mkdir(parents=True)
    p.write_text(_ASSERT_FIXTURE)
    return p


def test_cli_exit_codes(tmp_path):
    _cli_fixture(tmp_path)
    bl = tmp_path / "baseline.json"
    argv = ["src", "--root", str(tmp_path), "--baseline", str(bl)]
    assert dclint_main(argv) == 1          # non-baselined finding
    baseline_mod.write(bl, _violations_of(tmp_path))
    assert dclint_main(argv) == 0          # baselined -> clean
    assert dclint_main(["no_such_dir", "--root", str(tmp_path)]) == 2


def test_cli_empty_scope_is_usage_error(tmp_path, capsys):
    # an existing path with zero .py files must not lint vacuously
    # clean (that's how a typo'd CI path silently passes) — exit 2
    (tmp_path / "src").mkdir()
    (tmp_path / "src" / "notes.txt").write_text("no python here")
    assert dclint_main(["src", "--root", str(tmp_path)]) == 2
    assert "no Python files" in capsys.readouterr().err


def test_json_output_schema(tmp_path, capsys):
    _cli_fixture(tmp_path)
    bl = tmp_path / "baseline.json"
    rc = dclint_main(["src", "--json", "--root", str(tmp_path),
                      "--baseline", str(bl)])
    report = json.loads(capsys.readouterr().out)
    assert rc == 1
    assert report["version"] == 1
    assert report["counts"] == {"new": 1, "baselined": 0,
                                "stale_baseline": 0}
    (row,) = report["violations"]
    assert set(row) == {"path", "line", "col", "code", "message",
                        "fingerprint", "baselined"}
    assert row["code"] == "DC101" and row["baselined"] is False
    assert row["path"] == "src/repro/core/x.py" and row["line"] == 2


def test_repo_lints_clean():
    """The acceptance gate, as a test: zero non-baselined violations in
    the live tree — including dclint linting itself (CI also runs the
    CLI as a blocking step over the same scope)."""
    rc = dclint_main(["src", "benchmarks", "tools/dclint"])
    assert rc == 0


# =====================================================================
# eval_shape kernel-contract harness
# =====================================================================
def test_shapecheck_contracts_hold_for_moe_and_ssm_archs():
    jax = pytest.importorskip("jax")  # noqa: F841
    from tools.dclint import shapecheck

    # one MoE arch and one SSM arch covers all four kernel contracts
    results = shapecheck.run(archs=["qwen2-7b", "mamba2-1.3b"])
    bad = [r for r in results if not r["ok"]]
    assert bad == [], bad
    kernels = {r["kernel"] for r in results}
    assert {"flash_attention", "decode_attention", "ssd_scan"} <= kernels
