"""Ring attention (sequence-parallel prefill) vs the attention oracle."""
from __future__ import annotations

import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.collectives import ring_attention
from repro.kernels.ref import flash_attention_ref


def test_single_device_fallback_matches_oracle():
    rng = np.random.default_rng(0)
    B, S, H, KVH, hd = 2, 64, 8, 2, 16
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KVH, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KVH, hd)), jnp.float32)
    out = ring_attention(q, k, v, mesh=None)
    rep = jnp.repeat(k, H // KVH, axis=2), jnp.repeat(v, H // KVH, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = rep[0].transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    vf = rep[1].transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    ref = flash_attention_ref(qf, kf, vf, causal=True)
    ref = ref.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.parallel.collectives import ring_attention
from repro.kernels.ref import flash_attention_ref

mesh = jax.make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(1)
B, S, H, KVH, hd = 4, 64, 8, 4, 32   # GQA: kv rotates unrepeated
q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
k = jnp.asarray(rng.standard_normal((B, S, KVH, hd)), jnp.float32)
v = jnp.asarray(rng.standard_normal((B, S, KVH, hd)), jnp.float32)
with mesh:
    out = jax.jit(lambda q, k, v: ring_attention(q, k, v, mesh))(q, k, v)
kr = jnp.repeat(k, H // KVH, axis=2)
vr = jnp.repeat(v, H // KVH, axis=2)
qf = q.transpose(0,2,1,3).reshape(B*H, S, hd)
kf = kr.transpose(0,2,1,3).reshape(B*H, S, hd)
vf = vr.transpose(0,2,1,3).reshape(B*H, S, hd)
ref = flash_attention_ref(qf, kf, vf, causal=True)
ref = ref.reshape(B,H,S,hd).transpose(0,2,1,3)
err = float(jnp.max(jnp.abs(out - ref)))
assert err < 1e-5, err
print("OK", err)
"""


def test_ring_matches_oracle_on_sharded_mesh():
    r = subprocess.run([sys.executable, "-c", _SUBPROC],
                       capture_output=True, text=True,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            # keep jax on CPU in the stripped env: the
                            # host-device-count trick is CPU-only, and
                            # without the pin jax probes for TPU metadata
                            # for minutes before falling back
                            "JAX_PLATFORMS": "cpu"},
                       cwd="/root/repo", timeout=900)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
