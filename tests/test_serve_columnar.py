"""Columnar serve tick + event-skipping: the trace-scale path's parity
contract (tests/README.md).

``ColumnarServeDriver`` over a ``ColumnarStream`` and the scalar
``ServeDriver`` over ``to_jobs()`` of the SAME stream are two drivers of
one workload; they must produce a bit-identical ``ServeStats``, identical
per-task start/finish times and identical lease-adjustment events — under
DSP contention, dedicated mode, widths > 1 and engine ``max_len`` caps.
Event-skipping (scalar, columnar and fleet) must be invisible: a skipped
run is bit-identical to the dense run, and no skip window may contain an
arrival, a contention/deferred-grant instant, or a release boundary
(the hypothesis property at the bottom checks the windows directly).
"""
from __future__ import annotations

from dataclasses import replace

import numpy as np
import pytest

from tests.conftest import given, settings, st
from tests.test_serve_driver import (
    PARITY_CAPACITY, PARITY_CONTENTION, PARITY_POLICY, PARITY_W1, PARITY_W2,
    _dag_from_spec, montage_mini,
)

from repro.core.policy import MgmtPolicy
from repro.core.provider import ResourceProvider
from repro.core.provision import ProvisionService
from repro.serve.columnar import (
    ColumnarEngine, ColumnarServeDriver, default_max_ticks_columnar,
)
from repro.serve.driver import (
    EmulatedEngine, ServeDriver, ServeInvariantError, default_max_ticks,
    due_tick_floor, next_boundary, service_ticks_batch,
)
from repro.serve.fleet import ServeFleet
from repro.sim.traces import ColumnarStream, montage_stream_columnar


# ---------------------------------------------------------------- helpers
def parity_stream(width: int = 1):
    """The PR 3 parity trace (two Montage-mini workflows, non-contiguous
    jids), re-denominated at ``width`` node units per task."""
    w1 = [replace(j.fresh(), nodes=width) for j in PARITY_W1]
    w2 = [replace(j.fresh(), nodes=width) for j in PARITY_W2]
    return [(0.0, w1), (31.0, w2)]


def run_scalar(stream, *, capacity, policy=None, fixed_nodes=None,
               contention=(), slot_width=1, max_len=None, event_skip=False):
    prov = (ResourceProvider(capacity * slot_width,
                             coordination="first-come")
            if policy is not None else ProvisionService())
    drv = ServeDriver(stream, provider=prov,
                      engine=EmulatedEngine(capacity, max_len=max_len),
                      policy=policy, fixed_nodes=fixed_nodes,
                      name="parity-serve", contention=contention,
                      slot_width=slot_width, event_skip=event_skip)
    stats = drv.run()
    events = [(e.t, e.tre, e.delta) for e in prov.adjust_events] \
        if policy is not None else []
    times = {j.name: (j.start, j.finish)
             for _, jobs in stream for j in jobs}
    return stats.as_dict(), events, times


def run_columnar(cs, *, capacity, policy=None, fixed_nodes=None,
                 contention=(), slot_width=1, max_len=None, event_skip=True):
    prov = (ResourceProvider(capacity * slot_width,
                             coordination="first-come")
            if policy is not None else ProvisionService())
    drv = ColumnarServeDriver(
        cs, provider=prov,
        engine=ColumnarEngine(capacity, max_len=max_len),
        policy=policy, fixed_nodes=fixed_nodes, name="parity-serve",
        contention=contention, slot_width=slot_width, event_skip=event_skip)
    stats = drv.run()
    events = [(e.t, e.tre, e.delta) for e in prov.adjust_events] \
        if policy is not None else []
    times = {cs.name_of(i): (float(drv.env.start_t[i]),
                             float(drv.env.finish_t[i]))
             for i in range(cs.n_tasks)}
    return stats.as_dict(), events, times


def assert_parity(scalar, columnar):
    s_stats, s_events, s_times = scalar
    c_stats, c_events, c_times = columnar
    assert s_stats == c_stats
    assert s_events == c_events
    assert s_times == c_times


# ------------------------------------------------------- bit-parity pins
def test_columnar_parity_dsp_contention():
    """The PR 3 parity trace under DSP negotiation + scripted co-tenant
    contention: deferred grants, parked requests, a late release — the
    columnar tick must match the scalar reference bit for bit, with
    event-skipping on AND off."""
    kw = dict(capacity=PARITY_CAPACITY, policy=PARITY_POLICY,
              contention=PARITY_CONTENTION)
    ref = run_scalar(parity_stream(), **kw)
    cs = ColumnarStream.from_jobs(parity_stream())
    assert_parity(ref, run_columnar(cs, event_skip=True, **kw))
    assert_parity(ref, run_columnar(cs, event_skip=False, **kw))
    # the scenario really exercised the negotiation paths
    assert ref[0]["deferred_grants"] == 1 and ref[0]["workflows_completed"] == 2


def test_columnar_parity_dedicated():
    """fixed_nodes (dedicated baseline) mode: the columnar env must
    dispatch on submission like the scalar ``submit`` does."""
    kw = dict(capacity=6, fixed_nodes=6)
    ref = run_scalar(parity_stream(), **kw)
    cs = ColumnarStream.from_jobs(parity_stream())
    assert_parity(ref, run_columnar(cs, **kw))
    assert ref[0]["workflows_completed"] == 2
    assert ref[0]["deferred_grants"] == 0


def test_columnar_parity_width2():
    """slot_width=2 in both modes: unit-denominated grants and busy
    integrals survive the columnar rewrite."""
    for kw in (dict(capacity=PARITY_CAPACITY, slot_width=2,
                    policy=MgmtPolicy(initial=2, ratio=1.0,
                                      scan_interval=3.0,
                                      release_interval=60.0)),
               dict(capacity=6, slot_width=2, fixed_nodes=12)):
        ref = run_scalar(parity_stream(width=2), **kw)
        cs = ColumnarStream.from_jobs(parity_stream(width=2))
        assert_parity(ref, run_columnar(cs, **kw))
        assert ref[0]["workflows_completed"] == 2


def test_columnar_parity_max_len():
    """An engine ``max_len`` that really caps some decode budgets: the
    batched service-tick precompute must cap identically."""
    stream = parity_stream()
    for _, jobs in stream:
        for j in jobs:
            j.decode_len = max(j.decode_len, 40)   # make the cap bind
            j.prompt_len = 8
    kw = dict(capacity=PARITY_CAPACITY, policy=PARITY_POLICY,
              contention=PARITY_CONTENTION, max_len=44)
    ref = run_scalar(stream, **kw)
    cs = ColumnarStream.from_jobs(stream)
    assert np.any(cs.decode_len + cs.prompt_len > 44)
    assert_parity(ref, run_columnar(cs, **kw))


def test_columnar_requires_fcfs_uniform_width_and_batch_engine():
    cs = ColumnarStream.from_jobs(parity_stream())
    prov = ProvisionService()
    with pytest.raises(TypeError, match="position-batch engine"):
        ColumnarServeDriver(cs, provider=prov, engine=EmulatedEngine(4),
                            fixed_nodes=4)
    with pytest.raises(ValueError, match="FCFS"):
        ColumnarServeDriver(cs, provider=prov, engine=ColumnarEngine(4),
                            fixed_nodes=4, scheduler="backfill")
    with pytest.raises(ServeInvariantError, match="batching slot"):
        ColumnarServeDriver(cs, provider=prov, engine=ColumnarEngine(4),
                            fixed_nodes=8, slot_width=2)


# ----------------------------------------------- stream columnarization
def test_columnar_stream_roundtrip():
    """from_jobs ∘ to_jobs is the identity on the parity trace (jids,
    deps, marks, names, arrival grouping)."""
    ref = parity_stream()
    back = ColumnarStream.from_jobs(ref).to_jobs()
    key = lambda s: [(t, [(j.jid, j.runtime, j.nodes, j.prompt_len,
                           j.decode_len, tuple(j.deps), j.wid, j.name)
                          for j in jobs]) for t, jobs in s]
    assert key(back) == key(ref)


def test_montage_stream_columnar_structure():
    cs = montage_stream_columnar(50, n_project=3, seed=7, period=500.0)
    m = 6 * 3 + 4                                  # tasks per workflow
    assert cs.n_entries == 50 and cs.n_tasks == 50 * m
    assert cs.entry_arrival[0] == 0.0
    assert np.all(np.diff(cs.entry_arrival) >= 0)
    assert cs.entry_arrival[-1] <= 500.0 - 1.0
    # deps stay inside their workflow's position block
    for e in range(cs.n_entries):
        lo, hi = cs.entry_ptr[e], cs.entry_ptr[e + 1]
        deps = cs.dep_idx[cs.dep_ptr[lo]:cs.dep_ptr[hi]]
        assert np.all((deps >= lo) & (deps < hi))
    # dependency-free roots per workflow = the n_project mProjectPP stage
    roots = (np.diff(cs.dep_ptr) == 0)
    assert roots.reshape(50, m).sum(axis=1).tolist() == [3] * 50
    # per-workflow mean runtime calibration (montage_like's contract)
    rt = cs.runtime.reshape(50, m)
    assert np.allclose(rt.mean(axis=1), 11.38)
    # deterministic per seed
    again = montage_stream_columnar(50, n_project=3, seed=7, period=500.0)
    assert np.array_equal(cs.runtime, again.runtime)
    assert np.array_equal(cs.entry_arrival, again.entry_arrival)


def test_montage_stream_columnar_chunked_bit_identical_at_1e5():
    """The chunked-generation contract: ANY chunk size produces the
    same stream bit-for-bit (per-purpose generators + element-sequential
    array fills), pinned at the 10^5-workflow scale the generator exists
    for — one monolithic pass vs a power-of-two chunk vs an odd chunk
    that straddles every boundary assumption."""
    n = 100_000
    kw = dict(n_project=2, seed=11, period=86_400.0)
    mono = montage_stream_columnar(n, chunk=n, **kw)
    assert mono.n_tasks == n * 16
    for chunk in (8192, 9999):
        cs = montage_stream_columnar(n, chunk=chunk, **kw)
        for f in ("entry_arrival", "entry_wid", "entry_ptr", "jid",
                  "runtime", "nodes", "prompt_len", "decode_len",
                  "dep_ptr", "dep_idx"):
            assert np.array_equal(getattr(cs, f), getattr(mono, f)), \
                (chunk, f)


def test_montage_stream_columnar_serves_end_to_end():
    """A generated columnar stream completes through the columnar driver
    under DSP negotiation, with zero over-admissions."""
    cs = montage_stream_columnar(40, n_project=2, seed=3, period=400.0)
    prov = ResourceProvider(64, coordination="first-come")
    drv = ColumnarServeDriver(
        cs, provider=prov, engine=ColumnarEngine(64),
        policy=MgmtPolicy(initial=4, ratio=2.0, scan_interval=3.0,
                          release_interval=300.0))
    stats = drv.run()
    assert stats.workflows_completed == 40
    assert stats.tasks_completed == cs.n_tasks
    assert stats.over_admissions == 0
    assert prov.total_allocated == 0


# ------------------------------------------------ batched service ticks
def test_service_ticks_batch_matches_engine_scalar():
    """Elementwise equality with ``EmulatedEngine.service_ticks`` across
    the decode/prompt grid, with and without a binding ``max_len``."""
    from repro.core.types import Job
    dlen, plen, rt = [], [], []
    for d in (0, 1, 2, 5, 40, 60):
        for p in (4, 6, 8):
            for r in (0.0, 0.4, 1.0, 7.3):
                dlen.append(d), plen.append(p), rt.append(r)
    dlen, plen, rt = (np.array(dlen, np.int64), np.array(plen, np.int64),
                      np.array(rt, float))
    for max_len in (None, 44):
        eng = EmulatedEngine(4, max_len=max_len)
        want = [eng.service_ticks(Job(jid=i, arrival=0.0, runtime=rt[i],
                                      nodes=1, prompt_len=int(plen[i]),
                                      decode_len=int(dlen[i])))
                for i in range(len(dlen))]
        got = service_ticks_batch(dlen, plen, rt, tick_s=1.0,
                                  max_len=max_len)
        assert got.tolist() == want


# ----------------------------------------------------- tick-bound pins
def test_default_max_ticks_single_pass_pinned():
    """The satellite regression pin: the single-pass fold returns the
    bound the original two-pass walk did (span and work folded in one
    loop must not change the float expression), and the columnar bound
    equals the scalar bound on the same workload."""
    stream = parity_stream()
    engine = EmulatedEngine(PARITY_CAPACITY)
    # the reference two-pass computation, inlined
    span = max(t for t, _ in stream)
    work = sum(engine.service_ticks(j) for _, jobs in stream for j in jobs)
    assert default_max_ticks(stream, engine, 1.0) \
        == int(span / 1.0 + 8 * work + 36_000)
    # unsorted streams still fold the true span (ServeFleet merges
    # tenants' events unsorted)
    assert default_max_ticks(list(reversed(stream)), engine, 1.0) \
        == default_max_ticks(stream, engine, 1.0)

    cs = ColumnarStream.from_jobs(stream)
    svc = service_ticks_batch(cs.decode_len, cs.prompt_len, cs.runtime,
                              tick_s=1.0, max_len=None)
    assert default_max_ticks_columnar(cs, svc, 1.0) \
        == default_max_ticks(stream, engine, 1.0)

    gen = montage_stream_columnar(20, n_project=2, seed=1, period=200.0)
    gsvc = service_ticks_batch(gen.decode_len, gen.prompt_len, gen.runtime,
                               tick_s=1.0, max_len=None)
    assert default_max_ticks_columnar(gen, gsvc, 1.0) \
        == default_max_ticks(gen.to_jobs(), engine, 1.0)


# ------------------------------------------- scalar/fleet event-skipping
def test_scalar_event_skip_bit_identical():
    """ServeDriver(event_skip=True) vs the dense loop on the parity trace
    (DSP + contention) and in dedicated mode: identical stats, events and
    per-task times — skipping must be invisible."""
    for kw in (dict(capacity=PARITY_CAPACITY, policy=PARITY_POLICY,
                    contention=PARITY_CONTENTION),
               dict(capacity=6, fixed_nodes=6)):
        dense = run_scalar(parity_stream(), event_skip=False, **kw)
        skip = run_scalar(parity_stream(), event_skip=True, **kw)
        assert_parity(dense, skip)


def _fleet_run(event_skip, widths):
    spec = [(3, 0)] * 5 + [(2, 1)] * 3
    streams, base = [], 0
    for w, width in enumerate(widths):
        jobs = [replace(j, nodes=width)
                for j in _dag_from_spec(spec, wid=w, base=base)]
        base += 100
        streams.append([(float(5 * w), jobs)])
    policies = [MgmtPolicy(initial=w, ratio=1.0, scan_interval=3.0,
                           release_interval=60.0) for w in widths]
    fleet = ServeFleet(streams, engine=EmulatedEngine(8),
                       coordination="first-come", policies=policies,
                       widths=list(widths), event_skip=event_skip)
    fs = fleet.run()
    events = [(e.t, e.tre, e.delta) for e in fleet.provider.adjust_events]
    times = {j.name: (j.start, j.finish)
             for s in streams for _, jobs in s for j in jobs}
    return fs.as_dict(), events, times


def test_fleet_of_one_event_skip_matches_dense_driver():
    """ServeFleet(N=1, event_skip=True) ≡ the dense ServeDriver on the
    PR 3 parity trace — the fleet's skip horizon must respect the shared
    pool exactly as the single driver's does."""
    ref = run_scalar(parity_stream(), capacity=PARITY_CAPACITY,
                     policy=PARITY_POLICY, contention=PARITY_CONTENTION,
                     event_skip=False)
    stream = parity_stream()
    fleet = ServeFleet([stream], engine=EmulatedEngine(PARITY_CAPACITY),
                       coordination="first-come", policies=PARITY_POLICY,
                       names=["parity-serve"], contention=PARITY_CONTENTION,
                       event_skip=True)
    fs = fleet.run()
    assert ref[0] == fleet.lanes[0].stats.as_dict()
    assert ref[1] == [(e.t, e.tre, e.delta)
                      for e in fleet.provider.adjust_events]
    assert ref[2] == {j.name: (j.start, j.finish)
                      for _, jobs in stream for j in jobs}
    assert fs.workflows_completed == 2


def test_fleet_event_skip_bit_identical():
    """ServeFleet(event_skip=True) vs dense, homogeneous and mixed-width:
    the fleet's pool-wide finish horizon and per-lane skip candidates must
    never jump a tenant past another tenant's event."""
    for widths in ((1, 1, 1), (1, 2, 4)):
        dense = _fleet_run(False, widths)
        skip = _fleet_run(True, widths)
        assert_parity(dense, skip)
        assert dense[0]["workflows_completed"] == 3


class _RecordingSkipDriver(ServeDriver):
    """Records every ``next_event_tick`` window the run loop acted on."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.windows: list[tuple[int, int]] = []

    def next_event_tick(self, k):
        kn = super().next_event_tick(k)
        self.windows.append((k, kn))
        return kn


# ------------------------------------------------- hypothesis properties
@settings(max_examples=25, deadline=None)
@given(
    spec=st.lists(st.tuples(st.integers(1, 9), st.integers(0, 3)),
                  min_size=1, max_size=10),
    arrival2=st.integers(0, 60),
    hold=st.integers(0, 5),
    release_t=st.integers(5, 90),
)
def test_property_event_skip_never_jumps_past_events(spec, arrival2, hold,
                                                     release_t):
    """Two random DAG workflows + scripted contention: (a) the skipped run
    is bit-identical to the dense run; (b) no recorded skip window
    contains an arrival's due tick, a contention instant (where deferred
    grants land), or a release boundary — the events the ISSUE contract
    says skipping must never jump."""
    def build(event_skip, cls=ServeDriver):
        w1 = _dag_from_spec(spec, wid=0, base=0)
        w2 = [replace(j, arrival=float(arrival2))
              for j in _dag_from_spec(spec, wid=1, base=100)]
        stream = [(0.0, w1), (float(arrival2), w2)]
        contention = ([(1.0, "hog", hold),
                       (float(release_t), "hog", -hold)] if hold else [])
        prov = ResourceProvider(6, coordination="first-come")
        drv = cls(stream, provider=prov, engine=EmulatedEngine(6),
                  policy=MgmtPolicy(initial=1, ratio=1.0, scan_interval=3.0,
                                    release_interval=60.0),
                  contention=contention, event_skip=event_skip)
        stats = drv.run()
        events = [(e.t, e.tre, e.delta) for e in prov.adjust_events]
        times = {j.name: (j.start, j.finish)
                 for _, jobs in stream for j in jobs}
        return drv, (stats.as_dict(), events, times)

    _, dense = build(False)
    drv, skipped = build(True, cls=_RecordingSkipDriver)
    assert dense == skipped

    event_ticks = {due_tick_floor(float(arrival2), 1.0),
                   due_tick_floor(0.0, 1.0)}
    if hold:
        event_ticks |= {due_tick_floor(1.0, 1.0),
                        due_tick_floor(float(release_t), 1.0)}
    for k, kn in drv.windows:
        for j in range(k + 1, kn):          # the ticks the loop skipped
            assert j not in event_ticks, (k, kn, j)
            assert j % drv._release_every != 0, (k, kn, j)


@settings(max_examples=20, deadline=None)
@given(
    spec=st.lists(st.tuples(st.integers(1, 7), st.integers(0, 3)),
                  min_size=1, max_size=9),
    arrival2=st.integers(0, 40),
    hold=st.integers(0, 4),
)
def test_property_columnar_parity_random_dags(spec, arrival2, hold):
    """Random DAG shapes through both paths: the columnar batch tick
    (finish sequencing, FCFS prefix dispatch, arrival spans) matches the
    scalar reference on workloads far from the Montage template."""
    def stream():
        w1 = _dag_from_spec(spec, wid=0, base=0)
        w2 = [replace(j, arrival=float(arrival2))
              for j in _dag_from_spec(spec, wid=1, base=100)]
        return [(0.0, w1), (float(arrival2), w2)]

    contention = ([(1.0, "hog", hold), (50.0, "hog", -hold)]
                  if hold else [])
    kw = dict(capacity=6,
              policy=MgmtPolicy(initial=1, ratio=1.0, scan_interval=3.0,
                                release_interval=60.0),
              contention=contention)
    ref = run_scalar(stream(), **kw)
    cs = ColumnarStream.from_jobs(stream())
    assert_parity(ref, run_columnar(cs, **kw))
