"""Flow-layer self-tests for ``tools/dclint/flow`` (CFG, dataflow,
project call graph).

Three tiers, mirroring the layer structure:

* **CFG goldens** — small functions with known block/edge shapes
  (branch join, loop back-edge + break/continue, try exceptional
  edges, early return), pinned via ``CFG.shape()`` so a builder edit
  that drops an edge (and silently weakens every flow rule) fails
  loudly here.
* **Dataflow units** — reaching definitions merge at joins, kill
  within a block, and seed from parameters.
* **Call graph** — the interprocedural spine DC302/DC601 stand on,
  pinned against the LIVE tree: the grant-callback edges
  ``ResourceProvider._drain -> RuntimeEnv._apply_grant ->
  {ServeDriver,TrainTenant}._on_grant`` must resolve across modules,
  and ``drain_read_attrs()`` must recover the ledger fields the drain
  loop actually reads. If a refactor renames the wiring, these fail
  before the rules go blind.
"""
from __future__ import annotations

import ast
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
if str(REPO) not in sys.path:
    sys.path.insert(0, str(REPO))

from tools.dclint import collect_files  # noqa: E402
from tools.dclint.flow import (  # noqa: E402
    Project, attr_writes, build_cfg, mutating_calls, reaching_definitions,
)
from tools.dclint.flow.cfg import CFG  # noqa: E402
from tools.dclint.flow.dataflow import chain_names  # noqa: E402


def fn_of(code: str) -> ast.FunctionDef:
    tree = ast.parse(textwrap.dedent(code))
    (node,) = tree.body
    assert isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
    return node


# =====================================================================
# CFG goldens
# =====================================================================
def test_cfg_if_else_joins(tmp_path):
    cfg = build_cfg(fn_of("""\
        def f(x):
            if x:
                a = 1
            else:
                a = 2
            return a
        """))
    assert cfg.shape() == [
        (0, "entry", (2, 3)),
        (1, "exit", ()),
        (2, "Assign", (4,)),          # then
        (3, "Assign", (4,)),          # else
        (4, "Return", (1,)),          # join
    ]


def test_cfg_early_return_false_edge_falls_through(tmp_path):
    cfg = build_cfg(fn_of("""\
        def g(x):
            if x:
                return 0
            x += 1
            return x
        """))
    assert cfg.shape() == [
        (0, "entry", (2, 3)),         # false edge goes straight to join
        (1, "exit", ()),
        (2, "Return", (1,)),          # early return terminates its block
        (3, "AugAssign,Return", (1,)),
    ]


def test_cfg_loop_back_edge_break_continue(tmp_path):
    cfg = build_cfg(fn_of("""\
        def h(items):
            total = 0
            for x in items:
                if x < 0:
                    continue
                if x > 9:
                    break
                total += x
            return total
        """))
    assert cfg.shape() == [
        (0, "entry", (2,)),
        (1, "exit", ()),
        (2, "For", (3, 4)),           # header: exit edge + body edge
        (3, "Return", (1,)),          # after-loop
        (4, "If", (5, 6)),
        (5, ".", (2,)),               # continue -> header
        (6, "If", (7, 8)),
        (7, ".", (3,)),               # break -> after
        (8, "AugAssign", (2,)),       # back-edge
    ]


def test_cfg_try_exceptional_edges_reach_handler_and_finally(tmp_path):
    cfg = build_cfg(fn_of("""\
        def k(q):
            try:
                q.validate()
                r = q.commit()
            except KeyError:
                r = None
            finally:
                q.close()
            return r
        """))
    assert cfg.shape() == [
        (0, "entry", (2,)),
        (1, "exit", ()),
        (2, "Expr,Assign", (3, 4)),   # body: may raise into the handler
        (3, "Name,Assign", (4,)),     # handler (type expr + its suite)
        (4, "Expr,Return", (1,)),     # finally, then fall through
    ]


def test_cfg_nodes_after_sees_loop_round_trip():
    fn = fn_of("""\
        def h(items):
            total = 0
            for x in items:
                total += x
            return total
        """)
    cfg = build_cfg(fn)
    aug = fn.body[1].body[0]
    after = cfg.nodes_after(aug)
    kinds = [type(n).__name__ for n in after]
    # the back-edge re-includes the header and the loop body itself
    assert "For" in kinds and "Return" in kinds and "AugAssign" in kinds
    # nothing runs after the final return
    assert cfg.nodes_after(fn.body[2]) == []


# =====================================================================
# dataflow units
# =====================================================================
def test_reaching_defs_merge_at_join_and_seed_params():
    fn = fn_of("""\
        def rd(flag):
            y = 0
            if flag:
                y = 1
            return y
        """)
    cfg = build_cfg(fn)
    rd = reaching_definitions(cfg, fn)
    ret_block = cfg.find(fn.body[2])[0]
    in_set, _ = rd[ret_block]
    assert {(n, ln) for n, ln, _ in in_set if n == "y"} == {
        ("y", 2), ("y", 4)}           # both branches' defs reach the join
    assert any(n == "flag" for n, _, _ in in_set)   # param seeded


def test_reaching_defs_kill_within_block():
    fn = fn_of("""\
        def rk(a):
            a = 1
            a = 2
            return a
        """)
    cfg = build_cfg(fn)
    rd = reaching_definitions(cfg, fn)
    _, out_set = rd[CFG.ENTRY]
    # the later def killed both the earlier one and the parameter
    assert {(n, ln) for n, ln, _ in out_set if n == "a"} == {("a", 3)}


def test_lexers_chain_orientation_and_subscript_writes():
    tree = ast.parse(
        "self.provider.admission_queue.remove(req)\n"
        "self._work[jid] = v\n")
    ((chain, meth, _),) = mutating_calls(tree)
    assert meth == "remove"
    assert chain == ("admission_queue", "provider", "self")
    ((wchain, wattr, _),) = attr_writes(tree)
    assert (wchain, wattr) == (("self",), "_work")
    assert chain_names(ast.parse("self.a.b[0].c", mode="eval").body) == \
        ("c", "b", "a", "self")


# =====================================================================
# project call graph — synthetic wiring
# =====================================================================
def test_callback_edges_resolve_across_modules(tmp_path):
    a = tmp_path / "env.py"
    a.write_text(textwrap.dedent("""\
        class Env:
            def scan(self):
                self.provision.submit_request(
                    "a", 4, 0.0, on_grant=self._apply)

            def _apply(self, offer, t):
                return offer
        """))
    b = tmp_path / "driver.py"
    b.write_text(textwrap.dedent("""\
        class Driver:
            def __init__(self, env):
                env.grant_listener = self._on_grant

            def _on_grant(self, take, t, live):
                return take

            def fire(self, req):
                req.on_grant(3, 0.0)

            def notify(self, take, t):
                self.grant_listener(take, t, True)
        """))
    project = Project.from_paths([a, b], root=tmp_path)
    cg = project.callgraph()
    # each callback-attr call fans out to the targets wired to ITS kind
    # — the on_grant edge crosses the module boundary
    assert "env.py::Env._apply" in cg["driver.py::Driver.fire"]
    assert "driver.py::Driver._on_grant" in cg["driver.py::Driver.notify"]
    # roots: the on_grant= kwarg and the .grant_listener assignment
    assert {fi.key for fi in project.callback_targets["on_grant"]} == {
        "env.py::Env._apply"}
    assert {fi.key for fi in project.callback_targets["grant_listener"]} \
        == {"driver.py::Driver._on_grant"}


# =====================================================================
# project call graph — the live tree (DC302/DC601's spine)
# =====================================================================
@pytest.fixture(scope="module")
def live_project() -> Project:
    files = collect_files([REPO / "src"])
    return Project.from_paths(files, root=REPO)


def test_live_drain_reaches_grant_callbacks(live_project):
    cg = live_project.callgraph()
    drain = "src/repro/core/provider.py::ResourceProvider._drain"
    apply_grant = "src/repro/core/tre.py::RuntimeEnv._apply_grant"
    assert apply_grant in cg[drain]
    # the env's grant_listener fan-out: serve driver AND train tenant
    assert "src/repro/serve/driver.py::ServeDriver._on_grant" \
        in cg[apply_grant]
    assert "src/repro/serve/tenant.py::TrainTenant._on_grant" \
        in cg[apply_grant]


def test_live_callback_roots_include_apply_grant(live_project):
    roots = {fi.key
             for targets in live_project.callback_targets.values()
             for fi in targets}
    assert "src/repro/core/tre.py::RuntimeEnv._apply_grant" in roots


def test_live_drain_read_attrs_cover_the_ledger(live_project):
    reads = live_project.drain_read_attrs()
    assert {"_draining", "admission_queue", "allocated", "capacity",
            "quotas", "reservations", "policy"} <= reads


def test_reachable_records_root_first_paths(live_project):
    roots = [fi
             for targets in live_project.callback_targets.values()
             for fi in targets
             if fi.qualname == "RuntimeEnv._apply_grant"]
    reach = live_project.reachable(roots)
    paths = {fi.qualname: p for fi, p in reach.items()}
    root_path = paths["RuntimeEnv._apply_grant"]
    assert len(root_path) == 1
    # every recorded path starts at its root (the "via a -> b" diagnostic)
    assert all(p[0] == root_path[0] for p in paths.values())
