"""Pallas kernel sweeps vs the ref.py oracles (interpret mode on CPU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.moe_gmm import moe_gmm
from repro.kernels.ref import (
    decode_attention_ref, flash_attention_ref, moe_gmm_ref, ssd_scan_ref,
)
from repro.kernels.ssd_scan import ssd_scan

RNG = np.random.default_rng(0)


def _rand(shape, dtype, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape) * scale, dtype)


TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=5e-2, atol=5e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "BH,S,Sk,hd,bq,bk,causal",
    [(2, 128, 128, 64, 32, 32, True),
     (3, 96, 96, 32, 32, 64, True),
     (2, 64, 192, 64, 64, 64, False),    # cross-attention shape
     (1, 200, 200, 16, 64, 64, True),    # ragged (padding path)
     (4, 32, 32, 128, 32, 32, True)])
def test_flash_attention_sweep(BH, S, Sk, hd, bq, bk, causal, dtype):
    q = _rand((BH, S, hd), dtype)
    k = _rand((BH, Sk, hd), dtype)
    v = _rand((BH, Sk, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,KVH,hd,S,bs",
    [(2, 8, 2, 64, 256, 64),
     (3, 4, 4, 32, 100, 32),     # MHA + ragged
     (1, 16, 2, 16, 512, 128),
     (2, 32, 8, 64, 64, 64)])
def test_decode_attention_sweep(B, H, KVH, hd, S, bs, dtype):
    q = _rand((B, H, hd), dtype)
    k = _rand((B, S, KVH, hd), dtype)
    v = _rand((B, S, KVH, hd), dtype)
    lengths = jnp.asarray(RNG.integers(1, S + 1, (B,)), jnp.int32)
    out = decode_attention(q, k, v, lengths, block_s=bs, interpret=True)
    ref = decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,nh,hp,ng,ds,chunk",
    [(2, 64, 4, 16, 1, 32, 16),
     (1, 128, 8, 32, 2, 64, 32),
     (2, 96, 2, 8, 2, 16, 48),
     (1, 64, 4, 64, 4, 128, 64)])
def test_ssd_scan_sweep(B, S, nh, hp, ng, ds, chunk, dtype):
    x = _rand((B, S, nh, hp), dtype, 0.5)
    dt = jnp.asarray(RNG.uniform(0.01, 0.3, (B, S, nh)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2.0, (nh,)), jnp.float32)
    Bg = _rand((B, S, ng, ds), dtype, 0.3)
    Cg = _rand((B, S, ng, ds), dtype, 0.3)
    y, st = ssd_scan(x, dt, A, Bg, Cg, chunk=chunk, interpret=True)
    yr, sr = ssd_scan_ref(x, dt, A, Bg, Cg, chunk=chunk)
    tol = dict(rtol=2e-4, atol=2e-4) if dtype == jnp.float32 else \
        dict(rtol=8e-2, atol=8e-2)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), **tol)
    np.testing.assert_allclose(np.asarray(st), np.asarray(sr), **tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "E,C,d,f,bc,bf,bd",
    [(4, 32, 64, 128, 16, 64, 32),
     (2, 16, 32, 32, 16, 32, 32),
     (8, 64, 128, 64, 32, 32, 64),
     (1, 128, 256, 128, 128, 128, 128)])
def test_moe_gmm_sweep(E, C, d, f, bc, bf, bd, dtype):
    x = _rand((E, C, d), dtype)
    w = _rand((E, d, f), dtype, 0.1)
    out = moe_gmm(x, w, block_c=bc, block_f=bf, block_d=bd, interpret=True)
    ref = moe_gmm_ref(x, w)
    tol = dict(rtol=1e-4, atol=1e-4) if dtype == jnp.float32 else \
        dict(rtol=8e-2, atol=4e-1)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol)


def test_model_attention_matches_kernel_oracle():
    """repro.models.attention.chunked_attention (the jit path) must agree
    with the flash kernel on the same inputs."""
    from repro.models.attention import chunked_attention, repeat_kv
    B, S, H, hd = 2, 128, 4, 32
    q = _rand((B, S, H, hd), jnp.float32)
    k = _rand((B, S, H, hd), jnp.float32)
    v = _rand((B, S, H, hd), jnp.float32)
    model_out = chunked_attention(q, k, v, causal=True, q_chunk=32,
                                  kv_chunk=32)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kern = flash_attention(qf, kf, vf, causal=True, block_q=32, block_k=32,
                           interpret=True)
    kern = kern.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(model_out), np.asarray(kern),
                               rtol=2e-5, atol=2e-5)


def test_model_ssd_matches_kernel():
    """repro.models.ssm.ssd_chunked must agree with the Pallas ssd_scan."""
    from repro.models.ssm import ssd_chunked
    B, S, nh, hp, ng, ds, chunk = 2, 64, 4, 16, 1, 32, 16
    x = _rand((B, S, nh, hp), jnp.float32, 0.5)
    dt = jnp.asarray(RNG.uniform(0.01, 0.3, (B, S, nh)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2.0, (nh,)), jnp.float32)
    Bg = _rand((B, S, ng, ds), jnp.float32, 0.3)
    Cg = _rand((B, S, ng, ds), jnp.float32, 0.3)
    y_m, st_m = ssd_chunked(x, dt, A, Bg, Cg, chunk)
    y_k, st_k = ssd_scan(x, dt, A, Bg, Cg, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(y_m, np.float32), np.asarray(y_k),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_m), np.asarray(st_k),
                               rtol=2e-4, atol=2e-4)
